"""End-to-end driver: clustered-sampling FL over a transformer LM.

The production tier's round step (``repro.launch.fl_train``) training a
reduced qwen3-family decoder across 16 synthetic clients — each
data-parallel group plays one sampled client, the weighted parameter
combine realizes eq. (4). On a pod the exact same jitted step shards over
("data","model"); here it runs on CPU with a reduced config.

Run:  PYTHONPATH=src python examples/federated_lm.py [--sampler algorithm1]
"""
import argparse
import contextlib
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core import ClientPopulation
from repro.fl.aggregation import flatten_params
from repro.launch.fl_train import FLLMConfig, make_lm_sampler, run_federated_lm
from repro.models import model as mdl


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--sampler", choices=("md", "algorithm1", "algorithm2"), default="algorithm1"
    )
    ap.add_argument(
        "--planner", choices=("sync", "async"), default="sync",
        help="algorithm2 only: rebuild the plan inline or overlapped with "
        "the next round's local work",
    )
    ap.add_argument(
        "--rebuild-every", type=int, default=1,
        help="algorithm2 only: re-cluster every k observed rounds "
        "(PlannerSpec cadence; 1 = the paper's every-round rebuild)",
    )
    ap.add_argument("--rounds", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config("qwen3-0.6b", reduced=True)
    cfg = dataclasses.replace(cfg, d_model=64, vocab_size=256, n_heads=2, n_kv_heads=2, head_dim=32)
    # sampler/planner are spec dicts: the same SamplerSpec/PlannerSpec path
    # the host-tier experiments resolve (repro.fl.experiment)
    planner = {"mode": args.planner, "rebuild_every": args.rebuild_every}
    fl = FLLMConfig(
        n_clients=16, m=4, n_rounds=args.rounds, n_local_steps=2,
        local_batch=2, seq_len=32, lr=0.1,
        sampler=args.sampler,
        planner=planner if args.sampler == "algorithm2" else "sync",
    )
    pop = ClientPopulation(np.full(fl.n_clients, 1000))
    # only algorithm2's gradient store needs the flattened model size
    d = (
        int(flatten_params(mdl.init_params(cfg, jax.random.PRNGKey(0))).shape[0])
        if args.sampler == "algorithm2"
        else 0
    )
    with contextlib.closing(make_lm_sampler(fl, pop, update_dim=d)) as sampler:
        print(f"federated LM ({cfg.name}, {args.sampler}"
              + (f", planner={planner}" if args.sampler == "algorithm2" else "")
              + f"); {fl.n_clients} clients, m={fl.m}, N={fl.n_local_steps} local steps")
        losses = run_federated_lm(cfg, fl, sampler)
    for t, l in enumerate(losses):
        print(f"  round {t:2d}  mean local loss {l:.4f}")
    print(f"improved: {losses[-1] < losses[0]}")


if __name__ == "__main__":
    main()
