"""Heterogeneity study (paper Fig. 2): Dirichlet(α) non-iid partitions on the
unbalanced 100-client profile (10×100 … 10×1000 samples). The smaller α,
the bigger clustered sampling's edge over MD sampling.

Each run is one declarative experiment spec; the per-round progress line
streams through the server's ``on_round`` telemetry hook.

Run:  PYTHONPATH=src python examples/dirichlet_heterogeneity.py [--alpha 0.01]
"""
import argparse

import numpy as np

from repro.fl import DataSpec, build_dataset, build_experiment


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--alpha", type=float, default=0.01)
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--verbose", action="store_true", help="stream per-round records")
    args = ap.parse_args()

    data = {"name": "dirichlet_labels", "options": {"alpha": args.alpha, "dim": 32, "noise": 2.0, "seed": 0}}
    ds = build_dataset(DataSpec.from_dict(data))
    pop = ds.population

    print(f"Dirichlet(α={args.alpha}) — {ds.n_clients} clients, "
          f"{pop.total_samples} samples, m=10 sampled/round")
    for name, sampler in (("MD", {"name": "md", "m": 10}),
                          ("Clustered-Alg2", {"name": "algorithm2", "m": 10})):
        spec = {
            "data": data,
            "sampler": sampler,
            "train": {"n_rounds": args.rounds, "n_local_steps": 10, "batch_size": 50, "lr": 0.05, "seed": 0},
        }
        on_round = (
            (lambda rec: print(f"    round {rec.round:3d}  loss {rec.train_loss:.4f}"))
            if args.verbose else None
        )
        with build_experiment(spec, dataset=ds) as srv:
            hist = srv.run(on_round=on_round)
        losses = hist.rolling("train_loss", 5)
        print(f"  {name:15s} loss: {losses[0]:.4f} -> {losses[-1]:.4f}   "
              f"acc: {np.nanmax(hist.series('test_acc')[-3:]):.3f}   "
              f"distinct clients/round: {hist.series('n_distinct_clients').mean():.2f}")


if __name__ == "__main__":
    main()
