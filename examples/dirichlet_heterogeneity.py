"""Heterogeneity study (paper Fig. 2): Dirichlet(α) non-iid partitions on the
unbalanced 100-client profile (10×100 … 10×1000 samples). The smaller α,
the bigger clustered sampling's edge over MD sampling.

Run:  PYTHONPATH=src python examples/dirichlet_heterogeneity.py [--alpha 0.01]
"""
import argparse

import numpy as np

from repro.core import Algorithm2Sampler, MDSampler
from repro.fl import FederatedServer, FLConfig, dirichlet_labels
from repro.fl.aggregation import flatten_params
from repro.models.simple import init_mlp
from repro.optim import sgd


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--alpha", type=float, default=0.01)
    ap.add_argument("--rounds", type=int, default=15)
    args = ap.parse_args()

    ds = dirichlet_labels(alpha=args.alpha, dim=32, noise=2.0, seed=0)
    pop = ds.population
    params = init_mlp((32, 50, 10), seed=1)
    d = int(flatten_params(params).shape[0])

    print(f"Dirichlet(α={args.alpha}) — {ds.n_clients} clients, "
          f"{pop.total_samples} samples, m=10 sampled/round")
    for name, sampler in (
        ("MD", MDSampler(pop, 10, seed=0)),
        ("Clustered-Alg2", Algorithm2Sampler(pop, 10, update_dim=d, seed=0)),
    ):
        srv = FederatedServer(
            ds, sampler, params, sgd(0.05),
            FLConfig(n_rounds=args.rounds, n_local_steps=10, batch_size=50, seed=0),
        )
        hist = srv.run()
        losses = hist.rolling("train_loss", 5)
        print(f"  {name:15s} loss: {losses[0]:.4f} -> {losses[-1]:.4f}   "
              f"acc: {np.nanmax(hist.series('test_acc')[-3:]):.3f}   "
              f"distinct clients/round: {hist.series('n_distinct_clients').mean():.2f}")


if __name__ == "__main__":
    main()
