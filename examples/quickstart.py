"""Quickstart: clustered sampling vs MD sampling in 60 seconds.

Reproduces the paper's controlled experiment (Fig. 1) at reduced scale:
100 clients, each owning ONE class of a 10-class problem, server samples
m=10 per round. Watch the per-round class representativity — MD sampling
aggregates 6-8 distinct classes per round, clustered sampling always 10.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import Algorithm1Sampler, Algorithm2Sampler, MDSampler
from repro.fl import FederatedServer, FLConfig, by_class_shards
from repro.fl.aggregation import flatten_params
from repro.models.simple import init_mlp
from repro.optim import sgd

ROUNDS = 15


def main() -> None:
    ds = by_class_shards(dim=32, noise=2.0, train_per_client=200, test_per_client=30, seed=0)
    pop = ds.population
    params = init_mlp((32, 50, 10), seed=1)  # the paper's 1-hidden-layer MLP
    d = int(flatten_params(params).shape[0])

    samplers = {
        "MD sampling (Li et al. 2018)": MDSampler(pop, 10, seed=0),
        "Clustered / Algorithm 1     ": Algorithm1Sampler(pop, 10, seed=0),
        "Clustered / Algorithm 2     ": Algorithm2Sampler(pop, 10, update_dim=d, seed=0),
    }
    print(f"{'sampler':30s} {'final loss':>10s} {'test acc':>9s} {'classes/round':>14s}")
    for name, sampler in samplers.items():
        srv = FederatedServer(
            ds, sampler, params, sgd(0.05),
            FLConfig(n_rounds=ROUNDS, n_local_steps=10, batch_size=50, seed=0),
        )
        hist = srv.run()
        print(
            f"{name:30s} {hist.rolling('train_loss', 5)[-1]:10.4f} "
            f"{np.nanmax(hist.series('test_acc')[-3:]):9.3f} "
            f"{hist.series('n_distinct_classes').mean():14.2f}"
        )
    print("\nClustered sampling: same communication budget, strictly better "
          "representativity (Proposition 1 + Section 3.2 of the paper).")


if __name__ == "__main__":
    main()
