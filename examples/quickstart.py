"""Quickstart: clustered sampling vs MD sampling in 60 seconds.

Reproduces the paper's controlled experiment (Fig. 1) at reduced scale:
100 clients, each owning ONE class of a 10-class problem, server samples
m=10 per round. Watch the per-round class representativity — MD sampling
aggregates 6-8 distinct classes per round, clustered sampling always 10.

The comparison is a scenario matrix of declarative experiment specs
(``repro.fl.experiment``): each scheme is one dict, ``build_experiment``
resolves it through the sampler registry, and the ``with`` block owns the
sampler's background resources. Add your own scheme with
``repro.core.register_sampler`` and one more dict.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.fl import DataSpec, build_dataset, build_experiment

ROUNDS = 15

DATA = {
    "name": "by_class_shards",
    "options": {"dim": 32, "noise": 2.0, "train_per_client": 200, "test_per_client": 30, "seed": 0},
}

SCENARIOS = {
    "MD sampling (Li et al. 2018)": {"name": "md", "m": 10},
    "Clustered / Algorithm 1     ": {"name": "algorithm1", "m": 10},
    "Clustered / Algorithm 2     ": {"name": "algorithm2", "m": 10},
}


def main() -> None:
    ds = build_dataset(DataSpec.from_dict(DATA))  # one partition, three schemes
    print(f"{'sampler':30s} {'final loss':>10s} {'test acc':>9s} {'classes/round':>14s}")
    for name, sampler in SCENARIOS.items():
        spec = {
            "data": DATA,
            "sampler": sampler,
            "train": {"n_rounds": ROUNDS, "n_local_steps": 10, "batch_size": 50, "lr": 0.05, "seed": 0},
        }
        with build_experiment(spec, dataset=ds) as srv:
            hist = srv.run()
        print(
            f"{name:30s} {hist.rolling('train_loss', 5)[-1]:10.4f} "
            f"{np.nanmax(hist.series('test_acc')[-3:]):9.3f} "
            f"{hist.series('n_distinct_classes').mean():14.2f}"
        )
    print("\nClustered sampling: same communication budget, strictly better "
          "representativity (Proposition 1 + Section 3.2 of the paper).")


if __name__ == "__main__":
    main()
