"""Explore clustered-sampling plans and their statistics on YOUR population.

Prints the r_{k,i} matrix for Algorithms 1/2 next to MD sampling, with the
paper's closed-form statistics per client (variance, inclusion probability,
max draws) — the fastest way to understand what the urn-filling does.

Run:  PYTHONPATH=src python examples/sampling_statistics.py \
          --sizes 100 100 300 300 700 1000 --m 4
"""
import argparse

import numpy as np

from repro.core import (
    ClientPopulation,
    build_plan_algorithm1,
    max_draws_bound,
    validate_plan,
)
from repro.fl.experiment import build_sampler
from repro.core.statistics import (
    clustered_inclusion_probability,
    clustered_weight_variance,
    md_inclusion_probability,
    md_weight_variance,
)


def show_plan(name, r):
    print(f"\n{name} — r[k, i] (rows = distributions W_k):")
    for k in range(r.shape[0]):
        print("   W_%d  " % k + " ".join(f"{v:5.2f}" for v in r[k]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+", default=[100, 100, 300, 300, 700, 1000])
    ap.add_argument("--m", type=int, default=4)
    args = ap.parse_args()

    pop = ClientPopulation(np.array(args.sizes))
    m = args.m
    p = pop.importances
    print(f"population: n={pop.n_clients} clients, M={pop.total_samples} samples, m={m}")
    print("p_i: " + " ".join(f"{v:5.2f}" for v in p))

    plan1 = build_plan_algorithm1(pop, m)
    validate_plan(plan1, pop)
    show_plan("Algorithm 1 (sample-size urns)", plan1.r)

    s2 = build_sampler({"name": "algorithm2", "m": m, "seed": 0}, pop, update_dim=8)
    rng = np.random.default_rng(0)
    s2.observe_updates(np.arange(pop.n_clients), rng.normal(size=(pop.n_clients, 8)))
    show_plan("Algorithm 2 (similarity urns, random gradients)", s2.plan.r)

    print("\nper-client statistics (MD -> Algorithm 1):")
    v_md, v_c = md_weight_variance(p, m), clustered_weight_variance(plan1)
    q_md, q_c = md_inclusion_probability(p, m), clustered_inclusion_probability(plan1)
    print(f"  {'i':>3} {'p_i':>6} {'Var_MD':>9} {'Var_C':>9} {'P_MD':>6} {'P_C':>6} {'max draws':>9}")
    for i in range(pop.n_clients):
        print(
            f"  {i:>3} {p[i]:6.3f} {v_md[i]:9.2e} {v_c[i]:9.2e} "
            f"{q_md[i]:6.3f} {q_c[i]:6.3f} {int(max_draws_bound(plan1)[i]):>9}"
        )
    print(
        f"\n  totals: Var ratio {v_c.sum() / v_md.sum():.3f} (paper: <= 1), "
        f"E[#distinct] {q_md.sum():.2f} -> {q_c.sum():.2f} (paper: improves)"
    )


if __name__ == "__main__":
    main()
