"""Sketch stage: kernel/reference parity, registry, distance preservation.

Three layers of contract:

* the Pallas SRP kernel is the *same function* as the numpy host
  reference (shared counter-based hash, shared blockwise accumulation
  order), so parity is to f32 tolerance on ragged shapes and any block
  size;
* the ``SKETCHERS`` registry behaves like every other repro registry
  (guarded override, precise unknown-name errors), ``"identity"`` is the
  exact legacy path (same object back), and ``resolve_sketcher`` pins the
  spec-facing validation;
* SRP actually *preserves the geometry the planner consumes*: pairwise
  inner products concentrate (JL), distance orderings survive with margin,
  and k-means cluster structure recovered from the sketch agrees with the
  exact clustering (adjusted Rand pin) — this is why a plan rebuilt from
  (n, d') is trustworthy.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels.sketch import SKETCHERS, Sketcher, resolve_sketcher
from repro.kernels.sketch.ops import (
    CountSketcher,
    IdentitySketcher,
    SRPSketcher,
)
from repro.kernels.sketch.ref import (
    sketch_countsketch_reference,
    sketch_srp_reference,
    srp_sign_block,
)

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.kernels.sketch.kernel import srp_sketch_kernel  # noqa: E402


def _rand(n, d, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


# --------------------------------------------------------------------------
# kernel vs host reference
# --------------------------------------------------------------------------
@pytest.mark.parametrize(
    "n,d,d_prime,block_n,block_d",
    [
        (13, 1037, 64, 8, 256),   # ragged n and d tails
        (32, 512, 16, 16, 512),   # d == one block exactly
        (8, 96, 8, 8, 32),        # several tiny d-blocks
        (128, 300, 32, 128, 128), # n == one block, ragged d
    ],
)
def test_srp_kernel_matches_reference(n, d, d_prime, block_n, block_d):
    X = _rand(n, d, seed=n + d)
    got = np.asarray(
        srp_sketch_kernel(
            jnp.asarray(X), d_prime=d_prime, seed=7,
            block_n=block_n, block_d=block_d, interpret=True,
        )
    )
    want = sketch_srp_reference(X, d_prime, 7, block_d=block_d)
    assert got.shape == (n, d_prime)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_srp_kernel_block_size_invariant():
    """Different tilings accumulate in different orders — same result to f32."""
    X = _rand(17, 700, seed=3)
    outs = [
        np.asarray(
            srp_sketch_kernel(
                jnp.asarray(X), d_prime=24, seed=1,
                block_n=bn, block_d=bd, interpret=True,
            )
        )
        for bn, bd in [(8, 64), (17, 512), (16, 128)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-4, atol=1e-4)


def test_srp_seed_changes_projection():
    X = _rand(6, 128)
    a = sketch_srp_reference(X, 16, 0)
    b = sketch_srp_reference(X, 16, 1)
    assert not np.allclose(a, b)
    # same seed → bitwise identical regeneration
    np.testing.assert_array_equal(a, sketch_srp_reference(X, 16, 0))


def test_srp_sign_block_is_scaled_rademacher():
    S = srp_sign_block(seed=0, k0=0, bd=64, d_prime=32, d_total=64)
    scale = np.float32(1.0 / np.sqrt(32.0))
    assert set(np.unique(S)) == {-scale, scale}
    # rows past d_total are zeroed (the ragged-tail mask)
    S_tail = srp_sign_block(seed=0, k0=0, bd=64, d_prime=32, d_total=40)
    assert np.all(S_tail[40:] == 0.0)
    np.testing.assert_array_equal(S_tail[:40], S[:40])


# --------------------------------------------------------------------------
# sketcher dispatch
# --------------------------------------------------------------------------
def test_identity_sketcher_returns_same_object():
    sk = SKETCHERS.get("identity")(32)
    X = _rand(4, 32)
    assert sk(X) is X
    assert sk.reference(X) is X
    Xd = jnp.asarray(X)
    assert sk(Xd) is Xd
    assert (sk.d_in, sk.d_out) == (32, 32)


def test_identity_rejects_compressing_dim():
    with pytest.raises(ValueError, match="identity"):
        SKETCHERS.get("identity")(32, 8)


def test_countsketch_device_matches_reference():
    X = _rand(9, 257, seed=5)
    sk = CountSketcher(257, 31, seed=2)
    got = np.asarray(sk(jnp.asarray(X)))
    want = sketch_countsketch_reference(X, 31, 2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(sk.reference(X), want, rtol=0, atol=0)


def test_srp_sketcher_device_matches_reference():
    X = _rand(5, 300, seed=9)
    sk = SRPSketcher(300, 12, seed=4)
    np.testing.assert_allclose(
        np.asarray(sk(jnp.asarray(X))), sk.reference(X), rtol=1e-5, atol=1e-5
    )


# --------------------------------------------------------------------------
# registry + resolution
# --------------------------------------------------------------------------
def test_registry_unknown_name_lists_options():
    with pytest.raises(ValueError, match="identity"):
        SKETCHERS.get("nope")


def test_registry_register_and_override():
    def factory(d_in, d_prime=None, *, seed=0):
        return IdentitySketcher(d_in, d_in, seed)

    SKETCHERS.register("_test_sk", factory)
    try:
        assert SKETCHERS.get("_test_sk") is factory
        with pytest.raises(ValueError, match="already registered"):
            SKETCHERS.register("_test_sk", factory)
        SKETCHERS.register("_test_sk", factory, override=True)
    finally:
        SKETCHERS.unregister("_test_sk")
    assert "_test_sk" not in SKETCHERS


def test_resolve_sketcher_contract():
    assert resolve_sketcher(None, 64) is None
    sk = resolve_sketcher("srp", 64, 8, seed=3)
    assert (sk.d_in, sk.d_out, sk.seed) == (64, 8, 3)
    # fitted instance passes through, after a d_in check
    assert resolve_sketcher(sk, 64) is sk
    with pytest.raises(ValueError, match="d_in"):
        resolve_sketcher(sk, 128)
    # compressing sketchers demand a dimension, and a sane one
    with pytest.raises(ValueError, match="sketch_dim"):
        resolve_sketcher("srp", 64)
    with pytest.raises(ValueError, match="1 <= d_prime"):
        resolve_sketcher("countsketch", 64, 0)
    with pytest.raises(ValueError, match="1 <= d_prime"):
        resolve_sketcher("srp", 64, 65)


def test_sketcher_base_is_abstract():
    sk = Sketcher(4, 4, 0)
    with pytest.raises(NotImplementedError):
        sk(np.zeros((1, 4), np.float32))


# --------------------------------------------------------------------------
# geometry preservation (the planner's actual requirement)
# --------------------------------------------------------------------------
def test_srp_preserves_inner_products_in_expectation():
    """JL concentration: Gram matrix of the sketch ≈ Gram of the input."""
    rng = np.random.default_rng(0)
    X = rng.normal(size=(12, 4096)).astype(np.float32)
    Y = sketch_srp_reference(X, 1024, 0)
    g_exact = X @ X.T
    g_sketch = Y @ Y.T
    # JL error concentrates at the ‖x_i‖‖x_j‖ scale (off-diagonal exact
    # inner products of Gaussian rows are themselves ≈ 0, so *relative*
    # error there is meaningless); expected deviation ~ 1/√d' ≈ 0.03
    norms = np.sqrt(np.diag(g_exact))
    scale = np.outer(norms, norms)
    err = np.abs(g_sketch - g_exact) / scale
    assert float(np.median(err)) < 0.1
    assert float(err.max()) < 0.25
    assert float(np.max(np.abs(np.diag(g_sketch) / np.diag(g_exact) - 1))) < 0.2


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_srp_preserves_distance_ordering(seed):
    """Pairs whose exact arccos/L1 distances (the planner's measures)
    differ by a clear margin keep their order in sketch space — the
    property plan quality rests on."""
    from repro.core.clustering import pairwise_distances

    rng = np.random.default_rng(seed)
    n, d, dp = 10, 2048, 512
    # clients drawn around a few shared directions: iid Gaussian rows all
    # sit ≈ √(2d) apart (no orderable margins at all), which is NOT the
    # planner's regime — heterogeneous client groups produce a genuine
    # spread of angular distances by construction
    anchors = rng.normal(size=(3, d)).astype(np.float32)
    X = (
        anchors[rng.integers(0, 3, size=n)]
        + 0.7 * rng.normal(size=(n, d)).astype(np.float32)
    )
    Y = sketch_srp_reference(X, dp, seed=seed % 7)
    iu = np.triu_indices(n, 1)
    for measure, rel_margin, min_agree in (("arccos", 0.25, 0.9), ("l1", 0.25, 0.85)):
        de = pairwise_distances(X, measure)[iu]
        ds = pairwise_distances(Y.astype(np.float64), measure)[iu]
        # only score pairs separated by a clear relative margin in exact
        # space; JL cannot (and the planner does not need to) rank
        # near-ties. L1 has no JL guarantee of its own — it rides the L2
        # concentration for Gaussian-like rows, hence the looser floor.
        order = np.argsort(de)
        de_s, ds_s = de[order], ds[order]
        a, b = np.triu_indices(de_s.size, 1)
        margin = de_s[b] > (1.0 + rel_margin) * de_s[a]
        agree = ds_s[b][margin] > ds_s[a][margin]
        assert margin.sum() > 0, measure
        assert float(agree.mean()) >= min_agree, measure


def _adjusted_rand(a: np.ndarray, b: np.ndarray) -> float:
    """Adjusted Rand index (local helper; no sklearn in the image)."""
    a = np.asarray(a)
    b = np.asarray(b)
    n = a.size
    ua, ia = np.unique(a, return_inverse=True)
    ub, ib = np.unique(b, return_inverse=True)
    C = np.zeros((ua.size, ub.size), np.int64)
    np.add.at(C, (ia, ib), 1)
    comb = lambda x: x * (x - 1) / 2.0
    sum_c = comb(C).sum()
    sum_a = comb(C.sum(1)).sum()
    sum_b = comb(C.sum(0)).sum()
    expected = sum_a * sum_b / comb(n)
    max_index = 0.5 * (sum_a + sum_b)
    if max_index == expected:
        return 1.0
    return float((sum_c - expected) / (max_index - expected))


def test_sketched_clustering_agrees_with_exact():
    """k-means labels from the (n, d') sketch match the exact (n, d) labels
    up to permutation (ARI pin) on separated clusters — the end-to-end
    reason a sketched plan rebuild is sound."""
    from repro.core.clustering.device import kmeans_labels

    rng = np.random.default_rng(1)
    n_per, k, d, dp = 30, 4, 2048, 64
    centers = rng.normal(size=(k, d)).astype(np.float32) * 3.0
    X = np.concatenate(
        [c + rng.normal(size=(n_per, d)).astype(np.float32) for c in centers]
    )
    truth = np.repeat(np.arange(k), n_per)
    Y = sketch_srp_reference(X, dp, 0)
    # seed 38's init permutation covers all 4 planted clusters (one row
    # each), so Lloyd converges to the planted optimum in *both* spaces —
    # this isolates the sketch's effect from k-means init local optima,
    # which split clusters identically with or without sketching
    lab_exact = np.asarray(kmeans_labels(jnp.asarray(X), k, seed=38))
    lab_sketch = np.asarray(kmeans_labels(jnp.asarray(Y), k, seed=38))
    assert _adjusted_rand(lab_exact, lab_sketch) >= 0.8
    assert _adjusted_rand(lab_sketch, truth) >= 0.8


def test_adjusted_rand_helper_sanity():
    a = np.array([0, 0, 1, 1])
    assert _adjusted_rand(a, a) == 1.0
    assert _adjusted_rand(a, np.array([1, 1, 0, 0])) == 1.0  # permutation
    rng = np.random.default_rng(0)
    big = rng.integers(0, 3, size=600)
    assert abs(_adjusted_rand(big, rng.permutation(big))) < 0.1  # ≈ chance
