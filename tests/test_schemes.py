"""The scheme zoo: stratified / importance / dp_stratified / hybrid.

Pins the contracts ISSUE-level claims rest on: every scheme is
constructible from a JSON ExperimentSpec and trains end-to-end; hybrid
degenerates to stratified token-for-token when no client is large;
importance at ``mix = 1.0`` is bit-identical to MD sampling; the DP
ledger spends exactly one ρ_step per observed round and converts to a
monotone (ε, δ).
"""
import json

import numpy as np
import pytest

from repro.core import (
    ClientPopulation,
    DPStratifiedSampler,
    HybridSampler,
    ImportanceSampler,
    MDSampler,
    StratifiedSampler,
    build_plan_hybrid,
    build_plan_stratified,
    validate_plan,
)
from repro.core.samplers.schemes.dp import gaussian_epsilon
from repro.core.samplers.schemes.importance import importance_probabilities
from repro.core.samplers.schemes.stratified import default_n_strata
from repro.fl.experiment import ExperimentSpec, build_experiment

SCHEMES = ["stratified", "importance", "dp_stratified", "hybrid"]


def _pop(sizes) -> ClientPopulation:
    return ClientPopulation(np.asarray(sizes, dtype=np.int64))


def _gradients(n: int, d: int = 16, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


# --------------------------------------------------------------------------
# JSON spec construction: the zoo is reachable from the declarative door
# --------------------------------------------------------------------------
@pytest.mark.parametrize("scheme", SCHEMES)
def test_scheme_constructible_from_json_spec(scheme):
    spec = ExperimentSpec.from_json(json.dumps({
        "data": {"name": "by_class_shards",
                 "options": {"n_classes": 4, "clients_per_class": 2, "dim": 8,
                              "train_per_client": 40, "test_per_client": 8,
                              "seed": 0}},
        "sampler": {"name": scheme, "m": 4, "seed": 1},
        "train": {"n_rounds": 2, "n_local_steps": 2, "batch_size": 10,
                   "hidden": [16], "seed": 1},
    }))
    with build_experiment(spec) as srv:
        hist = srv.run()
    assert len(hist.records) == 2
    assert all(np.isfinite(r.train_loss) for r in hist.records)
    for r in hist.records:
        assert r.n_distinct_clients >= 1
        w = np.asarray(r.agg_weights)
        assert np.all(np.isfinite(w)) and w.sum() > 0


# --------------------------------------------------------------------------
# stratified: exact eq.(7)/(8) plans with stratum structure
# --------------------------------------------------------------------------
def test_stratified_plan_exact_and_stratified():
    pop = _pop([30, 50, 20, 40, 10, 60, 25, 35, 45, 15])
    plan = build_plan_stratified(pop, 4, _gradients(10))
    validate_plan(plan, pop)  # exact eq.(7)/(8), integer tokens included
    k = default_n_strata(10)
    sids = np.unique(plan.cluster_of)
    assert sids.min() >= 0 and sids.size <= k
    assert plan.cluster_of.shape == (10,)


def test_stratified_n_strata_bounds():
    pop = _pop([10, 10, 10, 10])
    with pytest.raises(ValueError):
        build_plan_stratified(pop, 2, _gradients(4), n_strata=0)
    with pytest.raises(ValueError):
        build_plan_stratified(pop, 2, _gradients(4), n_strata=5)


# --------------------------------------------------------------------------
# hybrid: strict generalization of stratified
# --------------------------------------------------------------------------
def test_hybrid_equals_stratified_without_large_clients():
    """No client with p_i >= 1/m -> empty head -> token-for-token equality."""
    pop = _pop([30, 50, 20, 40, 10, 60, 25, 35, 45, 15])  # max p = 60/330 < 1/4
    G = _gradients(10)
    a = build_plan_stratified(pop, 4, G, seed=3)
    b = build_plan_hybrid(pop, 4, G, seed=3)
    np.testing.assert_array_equal(a.r_tokens, b.r_tokens)
    np.testing.assert_array_equal(a.cluster_of, b.cluster_of)


def test_hybrid_head_gets_probability_one_urns():
    sizes = [450, 50, 40, 30, 20, 10]  # p_0 = 3/4 exactly -> 3 dedicated urns
    pop = _pop(sizes)
    plan = build_plan_hybrid(pop, 4, _gradients(6))
    validate_plan(plan, pop)
    assert int(np.sum(plan.r[:, 0] == 1.0)) == 3
    assert plan.cluster_of[0] == -1  # no remainder: fully outside the strata

    # a head client WITH a remainder also rides the tail strata
    pop2 = _pop([500, 30, 20, 25, 15, 10])  # floor(4 * 500/600) = 3 urns + rest
    plan2 = build_plan_hybrid(pop2, 4, _gradients(6))
    validate_plan(plan2, pop2)
    assert int(np.sum(plan2.r[:, 0] == 1.0)) == 3
    assert plan2.cluster_of[0] >= 0  # its remainder joins a stratum


# --------------------------------------------------------------------------
# importance: proposal construction + exact MD degeneration
# --------------------------------------------------------------------------
def test_importance_probabilities_mix_floor():
    p = np.array([0.5, 0.3, 0.2])
    norms = np.array([0.0, 1.0, 4.0])
    q = importance_probabilities(p, norms, mix=0.25)
    assert q.sum() == pytest.approx(1.0)
    assert np.all(q >= 0.25 * p)  # the floor bounds p_i/q_i <= 1/mix
    assert q[2] > p[2]  # large-norm client is up-weighted
    # degenerate regimes return p EXACTLY (no float drift)
    assert np.array_equal(importance_probabilities(p, norms, mix=1.0), p)
    assert np.array_equal(importance_probabilities(p, np.zeros(3), mix=0.25), p)


def test_importance_mix_zero_rejected():
    pop = _pop([10, 20, 30, 40])
    with pytest.raises(ValueError, match="mix"):
        ImportanceSampler(pop, 2, 8, mix=0.0)


def test_importance_mix_one_bit_identical_to_md():
    pop = _pop([30, 50, 20, 40, 10, 60, 25, 35])
    md = MDSampler(pop, 4, seed=9)
    imp = ImportanceSampler(pop, 4, 16, mix=1.0, seed=9)
    try:
        rng = np.random.default_rng(2)
        for t in range(6):
            imp.observe_updates(np.arange(8), rng.normal(size=(8, 16)).astype(np.float32))
            mask = None if t % 2 == 0 else rng.random(8) < 0.7
            a = md.sample(t, mask)
            b = imp.sample(t, mask)
            np.testing.assert_array_equal(a.clients, b.clients)
            np.testing.assert_array_equal(a.agg_weights, b.agg_weights)
    finally:
        imp.close()


def test_importance_reweights_unbiasedly_toward_p():
    """Non-degenerate mix: E[ω_i] over many draws matches p_i exactly via the
    correction — the Monte-Carlo pin for the draw-time unbiasedness."""
    pop = _pop([10, 40, 30, 20])
    imp = ImportanceSampler(pop, 3, 8, mix=0.3, seed=0)
    try:
        G = np.diag([4.0, 1.0, 0.5, 2.0]) @ np.ones((4, 8))
        imp.observe_updates(np.arange(4), G.astype(np.float32))
        q = imp.plan.r[0]
        assert not np.allclose(q, pop.importances)  # genuinely tilted
        acc = np.zeros(4)
        n_draws = 4000
        for t in range(n_draws):
            res = imp.sample(t)
            acc += res.agg_weights
        np.testing.assert_allclose(acc / n_draws, pop.importances, atol=0.02)
    finally:
        imp.close()


# --------------------------------------------------------------------------
# dp_stratified: ledger accounting + plan exactness under noise
# --------------------------------------------------------------------------
def test_dp_ledger_spends_one_step_per_observation():
    pop = _pop([30, 50, 20, 40, 10, 60])
    dp = DPStratifiedSampler(pop, 3, 8, noise_multiplier=2.0, seed=1)
    try:
        assert dp.privacy_ledger == {
            "observations": 0, "rho": 0.0, "epsilon": 0.0, "delta": 1e-5,
        }  # cold-start plan spends nothing
        rng = np.random.default_rng(0)
        eps = [dp.privacy_ledger["epsilon"]]
        for t in range(3):
            dp.observe_updates(np.arange(6), rng.normal(size=(6, 8)).astype(np.float32))
            led = dp.privacy_ledger
            assert led["observations"] == t + 1
            assert led["rho"] == pytest.approx((t + 1) / (2.0 * 2.0**2))
            eps.append(led["epsilon"])
        assert all(b > a for a, b in zip(eps, eps[1:]))  # ε strictly grows
        # the plan under noise is STILL an exact eq.(7)/(8) plan
        validate_plan(dp.plan, pop)
    finally:
        dp.close()


def test_dp_more_noise_means_less_epsilon():
    rho_lo = 3 / (2.0 * 4.0**2)  # 3 releases at sigma=4
    rho_hi = 3 / (2.0 * 0.5**2)  # 3 releases at sigma=0.5
    assert gaussian_epsilon(rho_lo, 1e-5) < gaussian_epsilon(rho_hi, 1e-5)
    assert gaussian_epsilon(0.0, 1e-5) == 0.0


def test_dp_invalid_knobs_rejected():
    pop = _pop([10, 20, 30])
    with pytest.raises(ValueError, match="noise_multiplier"):
        DPStratifiedSampler(pop, 2, 8, noise_multiplier=0.0)
    with pytest.raises(ValueError, match="clip_norm"):
        DPStratifiedSampler(pop, 2, 8, clip_norm=-1.0)
    with pytest.raises(ValueError, match="delta"):
        DPStratifiedSampler(pop, 2, 8, delta=1.5)


# --------------------------------------------------------------------------
# the shared store-backed contract
# --------------------------------------------------------------------------
@pytest.mark.parametrize("cls, kwargs", [
    (StratifiedSampler, {}),
    (HybridSampler, {}),
    (DPStratifiedSampler, {"noise_multiplier": 3.0}),
])
def test_store_backed_schemes_restratify_on_observe(cls, kwargs):
    """Observed updates rebuild the plan (sync planner: next swap sees it)."""
    pop = _pop([30, 50, 20, 40, 10, 60, 25, 35])
    s = cls(pop, 4, 16, seed=0, **kwargs)
    try:
        v0 = s.plan_telemetry()[0]
        rng = np.random.default_rng(1)
        s.observe_updates(np.arange(8), rng.normal(size=(8, 16)).astype(np.float32))
        s.sample(0)  # swap-in point for the sync planner
        assert s.plan_telemetry()[0] > v0
        validate_plan(s.plan, pop)
    finally:
        s.close()
