"""Per-kernel shape/dtype sweeps against pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.aggregate.kernel import aggregate_kernel
from repro.kernels.aggregate.ops import aggregate_trees
from repro.kernels.aggregate.ref import aggregate_ref
from repro.kernels.flash_attention.ops import flash_attention_padded
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.similarity.kernel import pairwise_kernel
from repro.kernels.similarity.ops import (
    pairwise_distances_chunked,
    pairwise_distances_device,
    pairwise_distances_streamed,
)
from repro.kernels.similarity.ref import gram_ref, l1_ref
from repro.core.clustering.similarity import pairwise_distances as np_pairwise

RNG = np.random.default_rng(0)


# --------------------------------------------------------------------------
# similarity
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n,d", [(8, 16), (33, 70), (64, 128), (100, 257)])
@pytest.mark.parametrize("op", ["gram", "l1"])
def test_pairwise_kernel_shapes(n, d, op):
    G = jnp.asarray(RNG.normal(size=(n, d)), jnp.float32)
    got = pairwise_kernel(G, op=op, block_n=16, block_d=32, interpret=True)
    ref = gram_ref(G) if op == "gram" else l1_ref(G)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-3)


@pytest.mark.parametrize("measure", ["arccos", "l2", "l1"])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_pairwise_distances_vs_numpy_reference(measure, dtype):
    G = RNG.normal(size=(21, 45)).astype(dtype)
    dev = np.asarray(
        pairwise_distances_device(G, measure, block_n=8, block_d=16, interpret=True)
    )
    ref = np_pairwise(G, measure)
    np.testing.assert_allclose(dev, ref, atol=1e-4)


def test_pairwise_distance_zero_rows():
    G = np.zeros((5, 12), np.float32)
    G[2] = RNG.normal(size=12)
    dev = np.asarray(pairwise_distances_device(G, "arccos", interpret=True, block_n=8))
    assert dev[0, 1] == 0.0
    np.testing.assert_allclose(dev[0, 2], np.pi / 2, atol=1e-6)


@pytest.mark.parametrize("measure", ["arccos", "l2", "l1"])
def test_pairwise_zero_rows_parity_with_numpy_reference(measure):
    """Cold-start clients (never sampled) carry all-zero representative
    gradients: the device path must match the numpy reference exactly on
    mixed zero/non-zero G, including arccos's zero-vs-zero -> 0 and
    zero-vs-nonzero -> pi/2 conventions."""
    G = RNG.normal(size=(9, 24)).astype(np.float32)
    G[[0, 3, 7]] = 0.0  # never-sampled clients
    dev = np.asarray(
        pairwise_distances_device(G, measure, block_n=8, block_d=16, interpret=True)
    )
    ref = np_pairwise(G, measure)
    np.testing.assert_allclose(dev, ref, atol=1e-5)
    if measure == "arccos":
        assert dev[0, 3] == 0.0 and dev[3, 7] == 0.0
        np.testing.assert_allclose(dev[0, 1], np.pi / 2, atol=1e-6)
        np.testing.assert_allclose(dev[7, 2], np.pi / 2, atol=1e-6)


@pytest.mark.parametrize("measure", ["arccos", "l2", "l1"])
@pytest.mark.parametrize(
    "n,d,d_chunk",
    [
        (21, 45, 16),   # non-multiple n and d, ragged final chunk
        (33, 130, 32),  # 5 chunks, none aligned to the block size
        (16, 64, 64),   # single chunk == one-shot degenerate case
        (9, 200, 64),   # d >> n, the model-sized-d regime in miniature
    ],
)
def test_streamed_matches_one_shot_and_numpy(measure, n, d, d_chunk):
    """The fused streamed kernel, the legacy chunked loop, the one-shot
    kernel and the f64 numpy reference must all agree across all three
    measures (Gram and L1 are both exact sums over coordinate chunks)."""
    G = RNG.normal(size=(n, d)).astype(np.float32)
    st = np.asarray(
        pairwise_distances_streamed(
            G, measure, block_n=8, block_d=16, d_chunk=d_chunk, interpret=True
        )
    )
    ch = np.asarray(
        pairwise_distances_chunked(
            G, measure, block_n=8, block_d=16, d_chunk=d_chunk, interpret=True
        )
    )
    one = np.asarray(
        pairwise_distances_device(G, measure, block_n=8, block_d=16, interpret=True)
    )
    np.testing.assert_allclose(st, one, atol=1e-4)
    np.testing.assert_allclose(st, ch, atol=1e-4)
    np.testing.assert_allclose(st, np_pairwise(G, measure), atol=1e-4)
    assert (np.diag(st) == 0).all()
    np.testing.assert_allclose(st, st.T)


@pytest.mark.parametrize("measure", ["arccos", "l1"])
def test_chunked_never_sees_full_width_block(measure, monkeypatch):
    """The chunked parity path must hand the kernel (n, <= d_chunk) slabs
    only — the padded (n, d) block of the one-shot path is never
    materialized."""
    from repro.kernels.similarity import ops

    widths = []
    real = ops.pairwise_kernel

    def spy(G, **kw):
        widths.append(int(G.shape[1]))
        return real(G, **kw)

    monkeypatch.setattr(ops, "pairwise_kernel", spy)
    G = RNG.normal(size=(12, 100)).astype(np.float32)
    out = np.asarray(
        pairwise_distances_chunked(
            G, measure, block_n=8, block_d=16, d_chunk=32, interpret=True
        )
    )
    assert widths == [32, 32, 32, 4]  # chunked cover of d=100, ragged tail
    np.testing.assert_allclose(out, np_pairwise(G, measure), atol=1e-4)


@pytest.mark.parametrize("measure", ["arccos", "l1"])
def test_fused_streamed_no_pad_no_chunk_loop(measure, monkeypatch):
    """The fused path is ONE kernel launch on the unpadded G: no padded
    (n, d) block is built by the pipeline (the fused kernel receives G at
    its exact ragged shape — interpret mode's internal block emulation is
    the emulator's business, a compiled run feeds HBM directly) and no host
    d-chunk loop runs (the padded one-shot kernel is never called, the
    fused kernel exactly once), on a shape ragged in both n and d."""
    from repro.kernels.similarity import ops

    calls = []
    real_fused = ops.pairwise_kernel_fused

    def fused_spy(G, **kw):
        calls.append(tuple(G.shape))
        return real_fused(G, **kw)

    def one_shot_trap(G, **kw):
        raise AssertionError("fused path fell back to the padded one-shot kernel")

    monkeypatch.setattr(ops, "pairwise_kernel_fused", fused_spy)
    monkeypatch.setattr(ops, "pairwise_kernel", one_shot_trap)
    G = RNG.normal(size=(13, 101)).astype(np.float32)  # ragged n AND d
    out = np.asarray(
        pairwise_distances_streamed(
            G, measure, block_n=8, block_d=16, d_chunk=32, interpret=True
        )
    )
    assert calls == [(13, 101)]  # exactly one launch, G handed over unpadded
    np.testing.assert_allclose(out, np_pairwise(G, measure), atol=1e-4)


def test_streamed_zero_rows_conventions():
    """Cold-start (all-zero) rows keep the arccos conventions under
    chunked accumulation: zero-vs-zero -> 0, zero-vs-nonzero -> pi/2."""
    G = RNG.normal(size=(7, 40)).astype(np.float32)
    G[[1, 4]] = 0.0
    st = np.asarray(
        pairwise_distances_streamed(
            G, "arccos", block_n=8, block_d=16, d_chunk=16, interpret=True
        )
    )
    assert st[1, 4] == 0.0
    np.testing.assert_allclose(st[1, 0], np.pi / 2, atol=1e-6)


def test_streamed_backend_resolves():
    from repro.kernels.similarity.ops import resolve_distance_backend

    fn = resolve_distance_backend("streamed")
    G = RNG.normal(size=(10, 30)).astype(np.float32)
    np.testing.assert_allclose(fn(G, "l2"), np_pairwise(G, "l2"), atol=1e-4)


def test_pallas_backend_requires_tpu():
    """The compiled kernel uses pltpu.VMEM scratch — requesting it off-TPU
    must be a clear error, not a mosaic traceback at first distance call."""
    import jax

    from repro.kernels.similarity.ops import resolve_distance_backend

    if jax.default_backend() == "tpu":
        pytest.skip("compiled pallas is legitimate on TPU")
    with pytest.raises(RuntimeError, match="requires a TPU"):
        resolve_distance_backend("pallas")


# --------------------------------------------------------------------------
# aggregate
# --------------------------------------------------------------------------
@pytest.mark.parametrize("k,p", [(1, 64), (7, 1000), (32, 4096), (11, 12345)])
def test_aggregate_kernel_sweep(k, p):
    U = jnp.asarray(RNG.normal(size=(k, p)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(k,)), jnp.float32)
    got = aggregate_kernel(U, w, block_p=512, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(aggregate_ref(U, w)), rtol=2e-5, atol=2e-5
    )


def test_aggregate_trees_matches_tree_arithmetic():
    trees = [
        {"a": jnp.asarray(RNG.normal(size=(4, 5)), jnp.float32), "b": jnp.asarray(RNG.normal(size=(7,)), jnp.float32)}
        for _ in range(3)
    ]
    w = np.array([0.2, 0.3, 0.5])
    got = aggregate_trees(trees, w, interpret=True)
    from repro.fl.aggregation import weighted_tree_sum

    ref = weighted_tree_sum(trees, w)
    for kk in ("a", "b"):
        np.testing.assert_allclose(np.asarray(got[kk]), np.asarray(ref[kk]), atol=1e-5)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,s,h,kv,hd", [(1, 32, 4, 4, 16), (2, 64, 8, 2, 32), (1, 48, 6, 1, 64), (2, 40, 4, 2, 8)]
)
def test_flash_attention_gqa_sweep(b, s, h, kv, hd):
    q = jnp.asarray(RNG.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, kv, hd)), jnp.float32)
    got = flash_attention_padded(q, k, v, block_q=16, block_k=16, interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.normal(size=(1, 32, 4, 16)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, 32, 2, 16)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, 32, 2, 16)), jnp.bfloat16)
    got = flash_attention_padded(q, k, v, block_q=16, block_k=16, interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=3e-2
    )


def test_flash_attention_matches_model_layer():
    """Kernel output == the model's attend() (same masking semantics)."""
    from repro.configs import get_config
    from repro.models.layers.attention import attend, causal_mask

    cfg = get_config("qwen2-1.5b", reduced=True)
    b, s, h, kv, hd = 2, 32, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = jnp.asarray(RNG.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, kv, hd)), jnp.float32)
    model_out = attend(cfg, q, k, v, causal_mask(s, s, 0)).reshape(b, s, h, hd)
    kern_out = flash_attention_padded(q, k, v, block_q=16, block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(kern_out), np.asarray(model_out), atol=2e-5)
