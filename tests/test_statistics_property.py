"""Property-based tests (hypothesis) of the paper's statistical theorems.

For ANY population and ANY Proposition-1-satisfying plan produced by
Algorithm 1/2:
  * eq. (17): Var_C[ω_i] <= Var_MD[ω_i]  for every client,
  * eq. (23): P_C(i ∈ S) >= P_MD(i ∈ S)  for every client,
  * both with equality iff every W_k equals W_0.
"""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import (
    ClientPopulation,
    build_plan_algorithm1,
    build_plan_algorithm2,
    validate_plan,
)
from repro.core.statistics import (
    clustered_inclusion_probability,
    clustered_weight_variance,
    md_inclusion_probability,
    md_weight_variance,
    variance_reduction,
)

populations = st.lists(st.integers(min_value=1, max_value=2000), min_size=6, max_size=60)
ms = st.integers(min_value=2, max_value=12)


@given(populations, ms)
@settings(max_examples=40, deadline=None)
def test_algorithm1_variance_and_inclusion_theorems(ns, m):
    pop = ClientPopulation(np.array(ns))
    plan = build_plan_algorithm1(pop, m)
    validate_plan(plan, pop)
    p = pop.importances

    v_md = md_weight_variance(p, m)
    v_c = clustered_weight_variance(plan)
    assert (v_c <= v_md + 1e-12).all(), "eq.(17) violated"
    assert (variance_reduction(plan, pop) >= -1e-12).all()

    q_md = md_inclusion_probability(p, m)
    q_c = clustered_inclusion_probability(plan)
    assert (q_c >= q_md - 1e-12).all(), "eq.(23) violated"


@given(populations, ms, st.integers(min_value=0, max_value=10_000))
@settings(max_examples=25, deadline=None)
def test_algorithm2_theorems_random_gradients(ns, m, seed):
    pop = ClientPopulation(np.array(ns))
    rng = np.random.default_rng(seed)
    G = rng.normal(size=(pop.n_clients, 6))
    plan = build_plan_algorithm2(pop, m, G)
    validate_plan(plan, pop)

    p = pop.importances
    assert (clustered_weight_variance(plan) <= md_weight_variance(p, m) + 1e-12).all()
    assert (
        clustered_inclusion_probability(plan) >= md_inclusion_probability(p, m) - 1e-12
    ).all()


@given(populations, ms)
@settings(max_examples=25, deadline=None)
def test_equality_iff_md(ns, m):
    """MD sampling (r_k = p ∀k) achieves exact equality in both bounds."""
    pop = ClientPopulation(np.array(ns))
    from repro.core.types import SamplingPlan

    plan = SamplingPlan(r=np.tile(pop.importances, (m, 1)))
    p = pop.importances
    np.testing.assert_allclose(clustered_weight_variance(plan), md_weight_variance(p, m))
    np.testing.assert_allclose(
        clustered_inclusion_probability(plan), md_inclusion_probability(p, m)
    )


def test_closed_form_variance_matches_monte_carlo():
    """eq. (16) against realized sampling for Algorithm 1."""
    from repro.core import Algorithm1Sampler

    pop = ClientPopulation(np.array([100, 250, 500, 750, 1000] * 4))
    m, T = 6, 6000
    s = Algorithm1Sampler(pop, m, seed=0)
    ws = np.stack([s.sample(t).agg_weights for t in range(T)])
    theory = clustered_weight_variance(s.plan)
    mc = ws.var(axis=0)
    np.testing.assert_allclose(mc, theory, atol=5e-4)


def test_distinct_clients_probability_paper_number():
    """Section 6: with n=100 uniform, m=10, P(10 distinct) ≈ 63% for MD."""
    from repro.core.statistics import md_prob_all_distinct

    p = md_prob_all_distinct(np.full(100, 0.01), 10)
    assert abs(p - 0.6282) < 1e-3


# --------------------------------------------------------------------------
# availability-conditioned unbiasedness (the continuous-service extension)
# --------------------------------------------------------------------------
masks = st.integers(min_value=0, max_value=10_000)


def _conditional_expected_weights(plan, a):
    """E[ω_i | available] under the conditional draw, in closed form.

    Urn k draws client i w.p. r̃_ki = r_ki·a_i/s_k and contributes weight
    w_k = s_k/Σ_j s_j, so E[ω_i] = Σ_k w_k·r̃_ki = Σ_k r_ki·a_i / Σ_j s_j.
    """
    from repro.core.samplers.base import conditional_plan

    r_cond, w = conditional_plan(plan, a)
    return (w[:, None] * r_cond).sum(axis=0)


def _random_mask(n, seed, p_avail=0.6):
    rng = np.random.default_rng(seed)
    a = rng.random(n) < p_avail
    if not a.any():
        a[rng.integers(n)] = True
    return a


@given(populations, ms, masks)
@settings(max_examples=30, deadline=None)
def test_availability_conditioned_unbiasedness_algorithm1(ns, m, seed):
    """For ANY eq.(8)-satisfying plan and ANY availability mask, the
    importance-corrected conditional draw is unbiased over the available
    set: E[ω_i | available] = p_i·a_i / Σ_j p_j·a_j exactly."""
    pop = ClientPopulation(np.array(ns))
    plan = build_plan_algorithm1(pop, m)
    a = _random_mask(pop.n_clients, seed)
    expect = _conditional_expected_weights(plan, a)
    p = pop.importances
    target = p * a / (p * a).sum()
    np.testing.assert_allclose(expect, target, atol=1e-12)
    assert (expect[~a] == 0).all()
    np.testing.assert_allclose(expect.sum(), 1.0, atol=1e-12)


@given(populations, ms, masks)
@settings(max_examples=20, deadline=None)
def test_masked_rebuild_keeps_eq8_and_stays_unbiased(ns, m, seed):
    """Availability-restricted rebuilds (``cluster_mask``) for ANY mask:
    masked-out pool clients ride filler chunks instead of the similarity
    clustering, but their integer token mass is untouched — eq. (8) holds
    *exactly*, so the conditional draw stays exactly unbiased over any
    (independent) availability mask at draw time."""
    pop = ClientPopulation(np.array(ns))
    rng = np.random.default_rng(seed)
    G = rng.normal(size=(pop.n_clients, 6))
    cmask = _random_mask(pop.n_clients, seed + 7, p_avail=0.5)
    plan = build_plan_algorithm2(pop, m, G, cluster_mask=cmask)
    validate_plan(plan, pop)
    # exact integer eq.(8): column sums m·n_i, every urn holds M tokens
    np.testing.assert_array_equal(plan.r_tokens.sum(axis=0), m * pop.n_samples)
    np.testing.assert_array_equal(
        plan.r_tokens.sum(axis=1), np.full(m, pop.total_samples)
    )
    # only masked-in clients may carry similarity-cluster labels (except
    # the degenerate masks — all-in / no masked-in pool client — where the
    # build falls back to clustering the whole pool)
    mass = m * pop.n_samples
    pool = np.flatnonzero(mass % pop.total_samples > 0)
    if not cmask.all() and cmask[pool].any():
        assert (plan.cluster_of[~cmask] == -1).all()
    # the draw-time availability mask is independent of the rebuild mask
    a = _random_mask(pop.n_clients, seed + 13)
    p = pop.importances
    target = p * a / (p * a).sum()
    np.testing.assert_allclose(
        _conditional_expected_weights(plan, a), target, atol=1e-12
    )


@given(populations, ms, masks)
@settings(max_examples=20, deadline=None)
def test_availability_conditioned_unbiasedness_algorithm2_and_md(ns, m, seed):
    from repro.core.types import SamplingPlan

    pop = ClientPopulation(np.array(ns))
    rng = np.random.default_rng(seed)
    G = rng.normal(size=(pop.n_clients, 6))
    a = _random_mask(pop.n_clients, seed + 1)
    p = pop.importances
    target = p * a / (p * a).sum()
    for plan in (
        build_plan_algorithm2(pop, m, G),
        SamplingPlan(r=np.tile(p, (m, 1))),  # MD: all rows equal p
    ):
        np.testing.assert_allclose(
            _conditional_expected_weights(plan, a), target, atol=1e-12
        )


def test_conditional_draw_monte_carlo_matches_expectation():
    """The realized masked draw (importance-corrected urn weights) agrees
    with the closed-form conditional expectation."""
    from repro.core import Algorithm1Sampler

    pop = ClientPopulation(np.array([100, 250, 500, 750, 1000] * 3))
    m, T = 6, 8000
    s = Algorithm1Sampler(pop, m, seed=0)
    a = _random_mask(pop.n_clients, seed=5)
    ws = np.stack([s.sample(t, a).agg_weights for t in range(T)])
    np.testing.assert_allclose(ws.sum(axis=1), 1.0, atol=1e-12)  # mass conserved
    assert (ws[:, ~a] == 0).all()  # never draws the unavailable
    expect = _conditional_expected_weights(s.plan, a)
    np.testing.assert_allclose(ws.mean(axis=0), expect, atol=5e-3)


# --------------------------------------------------------------------------
# the scheme zoo: every stratified-family plan is an exact eq.(7)/(8) plan,
# so ALL the above theorems transfer; importance owns eq.(12) at draw time
# --------------------------------------------------------------------------
def _exact_expected_weights(plan):
    """E[ω_i] of the unconditional draw: Σ_k r_ki / m (eq. 12, closed form)."""
    return plan.r.sum(axis=0) / plan.m


@given(populations, ms, st.integers(min_value=0, max_value=10_000))
@settings(max_examples=25, deadline=None)
def test_stratified_and_hybrid_plans_satisfy_all_theorems(ns, m, seed):
    """For ANY population and gradients: stratified & hybrid plans pass the
    exact Proposition-1 check, are exactly unbiased (E[ω_i] = p_i, sum-to-one
    support included), and never exceed MD's weight variance (eq. 17)."""
    from repro.core import build_plan_hybrid, build_plan_stratified

    pop = ClientPopulation(np.array(ns))
    rng = np.random.default_rng(seed)
    G = rng.normal(size=(pop.n_clients, 6))
    p = pop.importances
    for build in (build_plan_stratified, build_plan_hybrid):
        plan = build(pop, m, G, seed=seed)
        validate_plan(plan, pop)  # exact: integer tokens, eq.(7) + eq.(8)
        np.testing.assert_allclose(_exact_expected_weights(plan), p, atol=1e-12)
        np.testing.assert_allclose(plan.r.sum(axis=1), 1.0, atol=1e-12)
        assert (clustered_weight_variance(plan) <= md_weight_variance(p, m) + 1e-12).all()


@given(populations, ms, masks)
@settings(max_examples=20, deadline=None)
def test_stratified_and_hybrid_availability_conditioned_unbiasedness(ns, m, seed):
    """Under ANY availability mask the conditional draw of a stratified /
    hybrid plan hits the eq.(8) conditional target exactly — no new code
    path: conditional_plan works off eq.(8) alone."""
    from repro.core import build_plan_hybrid, build_plan_stratified

    pop = ClientPopulation(np.array(ns))
    rng = np.random.default_rng(seed)
    G = rng.normal(size=(pop.n_clients, 6))
    a = _random_mask(pop.n_clients, seed + 1)
    p = pop.importances
    target = p * a / (p * a).sum()
    for build in (build_plan_stratified, build_plan_hybrid):
        expect = _conditional_expected_weights(build(pop, m, G, seed=seed), a)
        np.testing.assert_allclose(expect, target, atol=1e-12)
        assert (expect[~a] == 0).all()


@given(populations, ms, st.integers(min_value=0, max_value=10_000))
@settings(max_examples=15, deadline=None)
def test_dp_stratified_plans_stay_exactly_unbiased(ns, m, seed):
    """ANY noise level: the DP plan is still an exact eq.(7)/(8) plan —
    noise moves strata membership, never the allocation."""
    from repro.core import DPStratifiedSampler

    pop = ClientPopulation(np.array(ns))
    rng = np.random.default_rng(seed)
    s = DPStratifiedSampler(
        pop, m, 6, noise_multiplier=float(10.0 ** (seed % 5 - 2)), seed=seed
    )
    try:
        s.observe_updates(
            np.arange(pop.n_clients),
            rng.normal(size=(pop.n_clients, 6)).astype(np.float32),
        )
        s.sample(0)  # sync swap-in of the noised-strata plan
        plan = s.plan
    finally:
        s.close()
    validate_plan(plan, pop)
    np.testing.assert_allclose(_exact_expected_weights(plan), pop.importances, atol=1e-12)
    a = _random_mask(pop.n_clients, seed + 1)
    p = pop.importances
    np.testing.assert_allclose(
        _conditional_expected_weights(plan, a), p * a / (p * a).sum(), atol=1e-12
    )


@given(populations, ms, masks, st.floats(min_value=0.05, max_value=1.0))
@settings(max_examples=20, deadline=None)
def test_importance_expected_weights_exact(ns, m, seed, mix):
    """Importance sampling's draw-time bookkeeping is exactly unbiased for
    ANY proposal the mix floor can produce, unconditionally AND under any
    availability mask: E[ω_i] = p_i and E[ω_i | a] = p_i·a_i / Σ_j p_j·a_j.

    Closed form: per urn, client i is drawn w.p. q_i (masked: q_i·a_i/Σq·a)
    and carries weight (1/m)·c_i with c the sampler's correction, so
    E[ω_i] = q_i·c_i (masked: weight w_k·c_i with w_k = Σq·a/… folded
    into the correction's availability ratio).
    """
    from repro.core import ImportanceSampler

    pop = ClientPopulation(np.array(ns))
    rng = np.random.default_rng(seed)
    s = ImportanceSampler(pop, m, 6, mix=float(mix), seed=seed)
    try:
        s.observe_updates(
            np.arange(pop.n_clients),
            rng.normal(size=(pop.n_clients, 6)).astype(np.float32),
        )
        s.sample(0)  # swap in the norm-tilted proposal
        q = s.plan.r[0]
        p = pop.importances
        # unconditional: E[ω_i] = m·q_i·(1/m)·(p_i/q_i) = p_i exactly
        np.testing.assert_allclose(q * s.correction(), p, atol=1e-12)
        # masked: m urns × draw prob (q_i·a_i/Σq·a) × weight (1/m)·c_i
        a = _random_mask(pop.n_clients, seed + 1)
        qa = (q * a).sum()
        expect = (q * a / qa) * s.correction(a)
        np.testing.assert_allclose(expect, p * a / (p * a).sum(), atol=1e-12)
    finally:
        s.close()
