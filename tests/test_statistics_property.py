"""Property-based tests (hypothesis) of the paper's statistical theorems.

For ANY population and ANY Proposition-1-satisfying plan produced by
Algorithm 1/2:
  * eq. (17): Var_C[ω_i] <= Var_MD[ω_i]  for every client,
  * eq. (23): P_C(i ∈ S) >= P_MD(i ∈ S)  for every client,
  * both with equality iff every W_k equals W_0.
"""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import (
    ClientPopulation,
    build_plan_algorithm1,
    build_plan_algorithm2,
    validate_plan,
)
from repro.core.statistics import (
    clustered_inclusion_probability,
    clustered_weight_variance,
    md_inclusion_probability,
    md_weight_variance,
    variance_reduction,
)

populations = st.lists(st.integers(min_value=1, max_value=2000), min_size=6, max_size=60)
ms = st.integers(min_value=2, max_value=12)


@given(populations, ms)
@settings(max_examples=40, deadline=None)
def test_algorithm1_variance_and_inclusion_theorems(ns, m):
    pop = ClientPopulation(np.array(ns))
    plan = build_plan_algorithm1(pop, m)
    validate_plan(plan, pop)
    p = pop.importances

    v_md = md_weight_variance(p, m)
    v_c = clustered_weight_variance(plan)
    assert (v_c <= v_md + 1e-12).all(), "eq.(17) violated"
    assert (variance_reduction(plan, pop) >= -1e-12).all()

    q_md = md_inclusion_probability(p, m)
    q_c = clustered_inclusion_probability(plan)
    assert (q_c >= q_md - 1e-12).all(), "eq.(23) violated"


@given(populations, ms, st.integers(min_value=0, max_value=10_000))
@settings(max_examples=25, deadline=None)
def test_algorithm2_theorems_random_gradients(ns, m, seed):
    pop = ClientPopulation(np.array(ns))
    rng = np.random.default_rng(seed)
    G = rng.normal(size=(pop.n_clients, 6))
    plan = build_plan_algorithm2(pop, m, G)
    validate_plan(plan, pop)

    p = pop.importances
    assert (clustered_weight_variance(plan) <= md_weight_variance(p, m) + 1e-12).all()
    assert (
        clustered_inclusion_probability(plan) >= md_inclusion_probability(p, m) - 1e-12
    ).all()


@given(populations, ms)
@settings(max_examples=25, deadline=None)
def test_equality_iff_md(ns, m):
    """MD sampling (r_k = p ∀k) achieves exact equality in both bounds."""
    pop = ClientPopulation(np.array(ns))
    from repro.core.types import SamplingPlan

    plan = SamplingPlan(r=np.tile(pop.importances, (m, 1)))
    p = pop.importances
    np.testing.assert_allclose(clustered_weight_variance(plan), md_weight_variance(p, m))
    np.testing.assert_allclose(
        clustered_inclusion_probability(plan), md_inclusion_probability(p, m)
    )


def test_closed_form_variance_matches_monte_carlo():
    """eq. (16) against realized sampling for Algorithm 1."""
    from repro.core import Algorithm1Sampler

    pop = ClientPopulation(np.array([100, 250, 500, 750, 1000] * 4))
    m, T = 6, 6000
    s = Algorithm1Sampler(pop, m, seed=0)
    ws = np.stack([s.sample(t).agg_weights for t in range(T)])
    theory = clustered_weight_variance(s.plan)
    mc = ws.var(axis=0)
    np.testing.assert_allclose(mc, theory, atol=5e-4)


def test_distinct_clients_probability_paper_number():
    """Section 6: with n=100 uniform, m=10, P(10 distinct) ≈ 63% for MD."""
    from repro.core.statistics import md_prob_all_distinct

    p = md_prob_all_distinct(np.full(100, 0.01), 10)
    assert abs(p - 0.6282) < 1e-3
