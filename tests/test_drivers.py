"""CLI driver integration smoke: train / serve / report run end to end."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(args, timeout=520):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    return subprocess.run(
        [sys.executable, *args], capture_output=True, text=True, env=env, cwd=ROOT,
        timeout=timeout,
    )


def test_train_driver_reduces_loss(tmp_path):
    ckpt = os.path.join(tmp_path, "state.npz")
    out = _run(
        [
            "-m", "repro.launch.train", "--arch", "qwen3-0.6b", "--reduced",
            "--steps", "12", "--batch", "2", "--seq", "32", "--lr", "5e-3",
            "--checkpoint", ckpt,
        ]
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "improved: True" in out.stdout
    assert os.path.exists(ckpt)


def test_serve_driver_generates():
    out = _run(
        [
            "-m", "repro.launch.serve", "--arch", "qwen2-1.5b", "--reduced",
            "--batch", "2", "--prompt-len", "8", "--gen", "4",
        ]
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "decoded" in out.stdout


def test_report_renders_tables():
    if not os.path.isdir(os.path.join(ROOT, "experiments", "dryrun")):
        pytest.skip("dry-run artifacts absent")
    out = _run(["-m", "repro.launch.report"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "§Roofline" in out.stdout


def test_example_quickstart_runs():
    out = _run(["examples/sampling_statistics.py", "--sizes", "50", "50", "100", "--m", "2"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "Var ratio" in out.stdout
