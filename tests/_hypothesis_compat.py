"""Degrade-gracefully shim around ``hypothesis``.

The property tests (`test_allocation.py`, `test_statistics_property.py`)
are written against the real hypothesis API. On environments where
``hypothesis`` is not installed (the seed image, minimal CI runners) this
module provides a tiny deterministic stand-in so the suite still *collects
and runs*: ``given`` replays each test over a fixed, seeded grid of example
draws (always including a minimal example) instead of doing adaptive
search + shrinking.

Usage in tests::

    from _hypothesis_compat import given, settings, st

The fallback implements exactly the strategy surface the suite needs
(``st.integers``, ``st.lists``). Add cases here if a test grows a new
strategy.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised implicitly when hypothesis exists
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools
    import inspect
    import types

    import numpy as np

    HAVE_HYPOTHESIS = False

    # Cap on replayed examples: the fallback is a smoke grid, not a search.
    _MAX_FALLBACK_EXAMPLES = 15

    class _Strategy:
        """A draw function + a deterministic minimal example."""

        def __init__(self, draw, minimal):
            self.draw = draw
            self.minimal = minimal

    def _integers(min_value=0, max_value=2**31 - 1):
        return _Strategy(
            draw=lambda rng: int(rng.integers(min_value, max_value + 1)),
            minimal=lambda: int(min_value),
        )

    def _lists(elements: _Strategy, min_size=0, max_size=10):
        def draw(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(size)]

        return _Strategy(
            draw=draw,
            minimal=lambda: [elements.minimal() for _ in range(min_size)],
        )

    def _floats(min_value=0.0, max_value=1.0):
        return _Strategy(
            draw=lambda rng: float(rng.uniform(min_value, max_value)),
            minimal=lambda: float(min_value),
        )

    st = types.SimpleNamespace(integers=_integers, lists=_lists, floats=_floats)

    def settings(*, max_examples=10, **_ignored):
        """Record ``max_examples``; other knobs (deadline, …) are no-ops."""

        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            n = min(getattr(fn, "_compat_max_examples", 10), _MAX_FALLBACK_EXAMPLES)

            @functools.wraps(fn)
            def runner():
                # example 0: every strategy minimal — the classic edge case
                fn(*[s.minimal() for s in strategies])
                rng = np.random.default_rng(0)
                for _ in range(max(n - 1, 0)):
                    fn(*[s.draw(rng) for s in strategies])

            # hide the wrapped signature (and break the __wrapped__ chain),
            # else pytest mistakes the example parameters for fixtures
            del runner.__wrapped__
            runner.__signature__ = inspect.Signature()
            return runner

        return deco
