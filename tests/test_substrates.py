"""Optimizers, schedules, checkpointing, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.data import TokenPipeline, make_classification_data
from repro.optim import (
    adamw,
    apply_updates,
    clip_by_global_norm,
    constant,
    cosine_decay,
    linear_warmup_cosine,
    sgd,
)


def _quadratic_min(opt, steps=200):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    for t in range(steps):
        grads = {"w": 2 * (params["w"] - target)}
        upd, state = opt.update(grads, state, params, t)
        params = apply_updates(params, upd)
    return params["w"], target


def test_sgd_converges():
    w, t = _quadratic_min(sgd(0.1))
    np.testing.assert_allclose(w, t, atol=1e-3)


def test_sgd_momentum_converges():
    w, t = _quadratic_min(sgd(0.05, momentum=0.9))
    np.testing.assert_allclose(w, t, atol=1e-3)


def test_adamw_converges():
    w, t = _quadratic_min(adamw(0.1), steps=600)
    np.testing.assert_allclose(w, t, atol=1e-2)


def test_schedules():
    assert abs(float(constant(0.1)(0)) - 0.1) < 1e-6
    cd = cosine_decay(1.0, 100, final_scale=0.1)
    assert abs(float(cd(0)) - 1.0) < 1e-6
    assert abs(float(cd(100)) - 0.1) < 1e-6
    wu = linear_warmup_cosine(1.0, 10, 110)
    assert float(wu(0)) == 0.0
    assert abs(float(wu(10)) - 1.0) < 0.11


def test_clip_by_global_norm():
    tree = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(norm) - 20.0) < 1e-5
    from repro.optim import global_norm

    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "params": {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones((3,), jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, tree, step=7, extra={"note": "x"})
    restored, step, extra = restore_checkpoint(path, tree)
    assert step == 7
    assert extra == {"note": "x"}  # the side-channel survives the round trip
    np.testing.assert_allclose(restored["params"]["w"], tree["params"]["w"])
    assert restored["params"]["b"].dtype == jnp.bfloat16


def test_checkpoint_numpy_reference_stays_host_f64(tmp_path):
    """A host f64 reference leaf restores as host f64 (never via jax f32)."""
    tree = {"plan_r": np.linspace(0, 1, 7, dtype=np.float64)}
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, tree)
    restored, _, extra = restore_checkpoint(path, tree)
    assert extra == {}
    assert isinstance(restored["plan_r"], np.ndarray)
    assert restored["plan_r"].dtype == np.float64
    np.testing.assert_array_equal(restored["plan_r"], tree["plan_r"])


def test_checkpoint_restore_rejects_unknown_leaves(tmp_path):
    """State in the .npz that the reference cannot place is an error."""
    import pytest

    tree = {"a": np.ones(3), "b": np.zeros(2)}
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, tree)
    with pytest.raises(KeyError, match="refusing to silently drop"):
        restore_checkpoint(path, {"a": np.ones(3)})
    with pytest.raises(KeyError, match="missing leaf"):
        restore_checkpoint(path, {"a": np.ones(3), "c": np.zeros(2)})


def test_token_pipeline_learnable_structure():
    pipe = TokenPipeline(vocab_size=97, batch_size=4, seq_len=32, seed=0)
    b = pipe.next_batch()
    assert b.tokens.shape == (4, 32) and b.targets.shape == (4, 32)
    assert b.tokens.max() < 97 and b.tokens.min() >= 0
    np.testing.assert_array_equal(b.targets, (b.tokens + 31) % 97)


def test_classification_data_classes_separable():
    x, y = make_classification_data(500, n_classes=4, dim=16, noise=0.3, seed=0)
    # nearest-centroid accuracy should be high at low noise
    cents = np.stack([x[y == c].mean(0) for c in range(4)])
    pred = np.argmin(((x[:, None] - cents[None]) ** 2).sum(-1), axis=1)
    assert (pred == y).mean() > 0.95
