"""Ward clustering + tree cut + similarity measures."""
import numpy as np
import pytest
import scipy.cluster.hierarchy as sch
import scipy.spatial.distance as ssd

from repro.core.clustering import cut_tree, pairwise_distances, ward_linkage
from repro.core.clustering.ward import leaves_of, linkage_children


@pytest.mark.parametrize("n,d,seed", [(10, 4, 0), (25, 8, 1), (40, 3, 2)])
def test_ward_matches_scipy(n, d, seed):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d))
    dist = pairwise_distances(X, "l2")
    ours = ward_linkage(dist)
    ref = sch.linkage(ssd.squareform(dist, checks=False), method="ward")
    # merge heights must match (merge order can differ on exact ties)
    np.testing.assert_allclose(np.sort(ours[:, 2]), np.sort(ref[:, 2]), rtol=1e-8)
    np.testing.assert_allclose(ours[:, 3], ref[:, 3])


@pytest.mark.parametrize("measure", ["arccos", "l2", "l1"])
def test_similarity_measures_scipy(measure):
    rng = np.random.default_rng(0)
    X = rng.normal(size=(15, 6))
    ours = pairwise_distances(X, measure)
    metric = {"arccos": "cosine", "l2": "euclidean", "l1": "cityblock"}[measure]
    ref = ssd.squareform(ssd.pdist(X, metric=metric))
    if measure == "arccos":
        ref = np.arccos(np.clip(1 - ref, -1, 1))
    np.testing.assert_allclose(ours, ref, atol=1e-8)
    assert (np.diag(ours) == 0).all()
    np.testing.assert_allclose(ours, ours.T)


def test_arccos_zero_vector_convention():
    """Zero representative gradients (never-sampled clients) cluster together."""
    X = np.array([[0.0, 0.0], [0.0, 0.0], [1.0, 0.0]])
    d = pairwise_distances(X, "arccos")
    assert d[0, 1] == 0.0
    np.testing.assert_allclose(d[0, 2], np.pi / 2)


def test_cut_tree_respects_capacity_and_count():
    rng = np.random.default_rng(0)
    n, m = 30, 6
    X = rng.normal(size=(n, 4))
    mass = np.full(n, 10) * m
    capacity = int(10 * n)  # M = sum n_i
    link = ward_linkage(pairwise_distances(X, "l2"))
    groups = cut_tree(link, n, m, mass, capacity)
    assert len(groups) >= m
    covered = np.sort(np.concatenate(groups))
    np.testing.assert_array_equal(covered, np.arange(n))
    for g in groups:
        assert mass[g].sum() <= capacity


def test_cut_tree_rejects_oversize_client():
    link = ward_linkage(np.ones((4, 4)) - np.eye(4))
    with pytest.raises(ValueError):
        cut_tree(link, 4, 2, np.array([100, 1, 1, 1]), 50)


def test_leaves_of_partition():
    link = ward_linkage(np.random.default_rng(0).normal(size=(8, 8)) ** 2)
    children = linkage_children(link, 8)
    root = 8 + link.shape[0] - 1
    assert sorted(leaves_of(root, children)) == list(range(8))
