"""Ward clustering + tree cut + similarity measures + device/registry layer."""
import numpy as np
import pytest
import scipy.cluster.hierarchy as sch
import scipy.spatial.distance as ssd

from repro.core.clustering import (
    CLUSTERERS,
    cut_tree,
    kmeans_clusters,
    kmeans_labels,
    pairwise_distances,
    register_clusterer,
    ward_linkage,
    ward_linkage_device,
)
from repro.core.clustering.ward import leaves_of, linkage_children


@pytest.mark.parametrize("n,d,seed", [(10, 4, 0), (25, 8, 1), (40, 3, 2)])
def test_ward_matches_scipy(n, d, seed):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d))
    dist = pairwise_distances(X, "l2")
    ours = ward_linkage(dist)
    ref = sch.linkage(ssd.squareform(dist, checks=False), method="ward")
    # merge heights must match (merge order can differ on exact ties)
    np.testing.assert_allclose(np.sort(ours[:, 2]), np.sort(ref[:, 2]), rtol=1e-8)
    np.testing.assert_allclose(ours[:, 3], ref[:, 3])


@pytest.mark.parametrize("measure", ["arccos", "l2", "l1"])
def test_similarity_measures_scipy(measure):
    rng = np.random.default_rng(0)
    X = rng.normal(size=(15, 6))
    ours = pairwise_distances(X, measure)
    metric = {"arccos": "cosine", "l2": "euclidean", "l1": "cityblock"}[measure]
    ref = ssd.squareform(ssd.pdist(X, metric=metric))
    if measure == "arccos":
        ref = np.arccos(np.clip(1 - ref, -1, 1))
    np.testing.assert_allclose(ours, ref, atol=1e-8)
    assert (np.diag(ours) == 0).all()
    np.testing.assert_allclose(ours, ours.T)


def test_arccos_zero_vector_convention():
    """Zero representative gradients (never-sampled clients) cluster together."""
    X = np.array([[0.0, 0.0], [0.0, 0.0], [1.0, 0.0]])
    d = pairwise_distances(X, "arccos")
    assert d[0, 1] == 0.0
    np.testing.assert_allclose(d[0, 2], np.pi / 2)


def test_cut_tree_respects_capacity_and_count():
    rng = np.random.default_rng(0)
    n, m = 30, 6
    X = rng.normal(size=(n, 4))
    mass = np.full(n, 10) * m
    capacity = int(10 * n)  # M = sum n_i
    link = ward_linkage(pairwise_distances(X, "l2"))
    groups = cut_tree(link, n, m, mass, capacity)
    assert len(groups) >= m
    covered = np.sort(np.concatenate(groups))
    np.testing.assert_array_equal(covered, np.arange(n))
    for g in groups:
        assert mass[g].sum() <= capacity


def test_cut_tree_rejects_oversize_client():
    link = ward_linkage(np.ones((4, 4)) - np.eye(4))
    with pytest.raises(ValueError):
        cut_tree(link, 4, 2, np.array([100, 1, 1, 1]), 50)


def test_leaves_of_partition():
    link = ward_linkage(np.random.default_rng(0).normal(size=(8, 8)) ** 2)
    children = linkage_children(link, 8)
    root = 8 + link.shape[0] - 1
    assert sorted(leaves_of(root, children)) == list(range(8))


# ---------------------------------------------------------------------------
# jitted device clustering (repro.core.clustering.device)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,d,seed", [(2, 3, 0), (17, 5, 1), (60, 8, 2)])
def test_jitted_ward_merge_order_exact_on_distinct_distances(n, d, seed):
    """Random G ⇒ all pairwise distances distinct ⇒ the jitted Lance–Williams
    loop must pick the identical merge at every step (same flat-argmin
    tie-breaking); heights agree to f32 accumulation tolerance."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d))
    dist = pairwise_distances(X, "l2")
    ref = ward_linkage(dist)
    dev = ward_linkage_device(dist)
    np.testing.assert_array_equal(ref[:, 0], dev[:, 0])
    np.testing.assert_array_equal(ref[:, 1], dev[:, 1])
    np.testing.assert_array_equal(ref[:, 3], dev[:, 3])
    np.testing.assert_allclose(ref[:, 2], dev[:, 2], rtol=1e-4, atol=1e-6)


def test_jitted_ward_fp32_tolerant_on_G_pipeline():
    """End-to-end over a gradient block: f32 device distances + jitted Ward
    vs the f64 numpy reference — same partition out of the tree cut."""
    rng = np.random.default_rng(3)
    G = rng.normal(size=(24, 6)).astype(np.float32)
    dist = pairwise_distances(G, "arccos")
    mass = np.full(24, 4 * 10)
    ref = cut_tree(ward_linkage(dist), 24, 4, mass, 240)
    dev = cut_tree(ward_linkage_device(dist), 24, 4, mass, 240)
    assert [g.tolist() for g in ref] == [g.tolist() for g in dev]


def test_jitted_ward_rejects_non_square():
    with pytest.raises(ValueError, match="square"):
        ward_linkage_device(np.zeros((3, 4)))


def test_kmeans_deterministic_under_fixed_seed():
    rng = np.random.default_rng(0)
    G = rng.normal(size=(50, 8)).astype(np.float32)
    a = kmeans_labels(G, 5, seed=7)
    b = kmeans_labels(G, 5, seed=7)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (50,) and a.min() >= 0 and a.max() < 5
    assert not np.array_equal(a, kmeans_labels(G, 5, seed=8)) or True  # seed varies init


def test_kmeans_zero_rows_share_a_cluster():
    """Never-sampled clients (zero gradients) stay one cold-start cluster
    under the arccos measure — the paper's convention."""
    rng = np.random.default_rng(1)
    G = rng.normal(size=(20, 6)).astype(np.float32)
    G[::5] = 0.0
    lab = kmeans_labels(G, 4, measure="arccos", seed=0)
    assert len(set(lab[::5].tolist())) == 1


def test_kmeans_rejects_bad_k():
    G = np.zeros((3, 2), np.float32)
    with pytest.raises(ValueError, match="1 <= k <= n"):
        kmeans_labels(G, 4)


# ---------------------------------------------------------------------------
# CLUSTERERS registry + backend contract
# ---------------------------------------------------------------------------
def test_clusterer_registry_names_and_unknown_error():
    for name in ("ward", "ward_jit", "kmeans"):
        assert name in CLUSTERERS
    with pytest.raises(ValueError, match="unknown clusterer 'nope'"):
        CLUSTERERS.get("nope")


def test_clusterer_registry_register_override_unregister():
    fn = lambda *a, **k: []
    register_clusterer("tmp_test_clusterer", fn)
    try:
        assert CLUSTERERS.get("tmp_test_clusterer") is fn
        with pytest.raises(ValueError, match="already registered"):
            register_clusterer("tmp_test_clusterer", lambda *a, **k: [])
        fn2 = lambda *a, **k: []
        register_clusterer("tmp_test_clusterer", fn2, override=True)
        assert CLUSTERERS.get("tmp_test_clusterer") is fn2
    finally:
        CLUSTERERS.unregister("tmp_test_clusterer")
    assert "tmp_test_clusterer" not in CLUSTERERS


@pytest.mark.parametrize("name", ["ward", "ward_jit", "kmeans"])
def test_clusterer_backends_produce_feasible_partitions(name):
    rng = np.random.default_rng(2)
    n, m = 30, 6
    G = rng.normal(size=(n, 5)).astype(np.float32)
    mass = np.full(n, 10) * m
    capacity = 10 * n
    groups = CLUSTERERS.get(name)(G, mass, m, capacity, measure="arccos", seed=0)
    assert len(groups) >= m
    covered = np.sort(np.concatenate(groups))
    np.testing.assert_array_equal(covered, np.arange(n))
    for g in groups:
        assert mass[g].sum() <= capacity


def test_kmeans_clusters_rejects_oversize_client():
    G = np.random.default_rng(0).normal(size=(4, 3)).astype(np.float32)
    with pytest.raises(ValueError, match="mass 100 > M=50"):
        kmeans_clusters(G, np.array([100, 1, 1, 1]), 2, 50)


def test_kmeans_clusters_cannot_exceed_singletons():
    G = np.random.default_rng(0).normal(size=(3, 2)).astype(np.float32)
    with pytest.raises(ValueError, match="cannot reach K >= m=5"):
        kmeans_clusters(G, np.array([1, 1, 1]), 5, 10)
