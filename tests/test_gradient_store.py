"""GradientStore semantics: duplicate-id pin, sketch stage, mesh, load.

The backend-parity contract: the jax scatter path and the numpy fallback
implement *one* semantics — last-write-wins on duplicate ids, ids >= n
dropped (padded-slot sentinels), negative-free (callers pass real or
sentinel ids only). The sketch stage compresses before scatter so the
resident buffer is (n, d'); ``sketch="identity"`` must be bit-for-bit the
unsketched store. ``load`` adopts device arrays without a host round-trip
(checked by identity), and restores through a mesh re-place the sharding.

The multi-device sharded path runs in a subprocess (the XLA host-device
flag must be set before jax initializes), same pattern as
``test_engine_sharded``.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.fl.gradient_store import GradientStore

ROOT = os.path.join(os.path.dirname(__file__), "..")

BACKENDS = ["jax", "numpy"]


# --------------------------------------------------------------------------
# duplicate ids: one pinned semantics on both backends
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_duplicate_ids_last_write_wins(backend):
    store = GradientStore(5, 3, backend=backend)
    vals = np.stack([
        np.full(3, 1.0), np.full(3, 2.0), np.full(3, 3.0), np.full(3, 4.0),
    ]).astype(np.float32)
    store.update(np.array([2, 0, 2, 2]), vals)
    G = store.asnumpy()
    np.testing.assert_allclose(G[0], 2.0)
    np.testing.assert_allclose(G[2], 4.0)  # the LAST write to id 2
    np.testing.assert_allclose(G[[1, 3, 4]], 0.0)


@pytest.mark.parametrize("backend", BACKENDS)
def test_duplicate_sentinels_with_real_rows(backend):
    """Duplicates of a padded sentinel (id >= n) stay dropped; the real
    rows among them still land."""
    store = GradientStore(4, 2, backend=backend)
    vals = np.stack([
        np.full(2, 9.0), np.full(2, 1.0), np.full(2, 9.0), np.full(2, 5.0),
    ]).astype(np.float32)
    store.update(np.array([4, 1, 4, 1]), vals)
    G = store.asnumpy()
    np.testing.assert_allclose(G[1], 5.0)
    assert not np.isin(9.0, G)


def test_backends_agree_on_update_sequence():
    """Same scatter sequence (dups, sentinels, decay) → identical buffers."""
    rng = np.random.default_rng(0)
    stores = {
        b: GradientStore(8, 5, staleness_decay=0.75, backend=b) for b in BACKENDS
    }
    for _ in range(4):
        ids = rng.integers(0, 10, size=6)  # includes 8, 9 sentinels + dups
        vals = rng.normal(size=(6, 5)).astype(np.float32)
        for st in stores.values():
            st.update(ids, vals)
    np.testing.assert_array_equal(stores["jax"].asnumpy(), stores["numpy"].asnumpy())


# --------------------------------------------------------------------------
# sketch stage
# --------------------------------------------------------------------------
def test_sketched_store_resident_shape_and_bytes():
    store = GradientStore(10, 256, sketch="srp", sketch_dim=16)
    assert store.dim == 16
    assert store.update_dim == 256
    assert store.nbytes == 10 * 16 * 4
    store.update(np.array([3]), np.ones((1, 256), np.float32))
    snap = np.asarray(store.snapshot())
    assert snap.shape == (10, 16)
    assert np.any(snap[3] != 0) and np.all(snap[[0, 1, 2, 4]] == 0)
    # update() still takes full-width rows — the wrong width is rejected
    with pytest.raises(ValueError, match="updates shape"):
        store.update(np.array([0]), np.ones((1, 16), np.float32))


def test_identity_sketch_is_bitwise_legacy_path():
    rng = np.random.default_rng(1)
    plain = GradientStore(6, 12)
    ident = GradientStore(6, 12, sketch="identity")
    assert ident.dim == 12 and ident.nbytes == plain.nbytes
    for _ in range(3):
        ids = rng.integers(0, 7, size=4)
        vals = rng.normal(size=(4, 12)).astype(np.float32)
        plain.update(ids, vals)
        ident.update(ids, vals)
    np.testing.assert_array_equal(plain.asnumpy(), ident.asnumpy())


@pytest.mark.parametrize("sketch", ["srp", "countsketch"])
def test_sketched_backends_agree(sketch):
    """numpy fallback (sketch.reference) tracks the device path closely."""
    rng = np.random.default_rng(2)
    ids = np.array([0, 2, 3])
    vals = rng.normal(size=(3, 200)).astype(np.float32)
    out = {}
    for b in BACKENDS:
        st = GradientStore(5, 200, sketch=sketch, sketch_dim=8, backend=b)
        st.update(ids, vals)
        out[b] = st.asnumpy()
    np.testing.assert_allclose(out["jax"], out["numpy"], rtol=1e-5, atol=1e-5)


def test_sketch_seed_changes_resident_rows():
    vals = np.ones((1, 64), np.float32)
    a = GradientStore(3, 64, sketch="srp", sketch_dim=8, sketch_seed=0)
    b = GradientStore(3, 64, sketch="srp", sketch_dim=8, sketch_seed=1)
    a.update([0], vals)
    b.update([0], vals)
    assert not np.allclose(a.asnumpy()[0], b.asnumpy()[0])


# --------------------------------------------------------------------------
# gather_rows
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_gather_rows_returns_requested_rows(backend):
    store = GradientStore(6, 4, backend=backend)
    vals = np.arange(12, dtype=np.float32).reshape(3, 4)
    store.update(np.array([1, 4, 5]), vals)
    rows = np.asarray(store.gather_rows(np.array([4, 1])))
    np.testing.assert_allclose(rows, vals[[1, 0]])


# --------------------------------------------------------------------------
# load: device adoption, dtype/shape validation
# --------------------------------------------------------------------------
def test_load_adopts_device_array_without_host_roundtrip():
    jnp = pytest.importorskip("jax.numpy")
    store = GradientStore(4, 3)
    G = jnp.full((4, 3), 2.5, jnp.float32)
    store.load(G)
    assert store.snapshot() is G  # adopted, not copied through host
    np.testing.assert_allclose(store.asnumpy(), 2.5)


def test_load_rejects_wrong_dtype_device_array():
    jnp = pytest.importorskip("jax.numpy")
    store = GradientStore(4, 3)
    # (f64 can't be exercised without the x64 flag — jax silently builds f32)
    with pytest.raises(ValueError, match="float32"):
        store.load(jnp.zeros((4, 3), jnp.int32))
    with pytest.raises(ValueError, match="float32"):
        store.load(jnp.zeros((4, 3), jnp.bfloat16))


@pytest.mark.parametrize("backend", BACKENDS)
def test_load_rejects_wrong_shape(backend):
    store = GradientStore(4, 3, backend=backend)
    with pytest.raises(ValueError, match="checkpointed G shape"):
        store.load(np.zeros((4, 5), np.float32))
    # sketched store checkpoints the (n, d') buffer, not (n, d)
    sk = GradientStore(4, 64, sketch="srp", sketch_dim=3, backend=backend)
    with pytest.raises(ValueError, match="checkpointed G shape"):
        sk.load(np.zeros((4, 64), np.float32))
    sk.load(np.zeros((4, 3), np.float32))


@pytest.mark.parametrize("backend", BACKENDS)
def test_load_casts_host_f64(backend):
    store = GradientStore(2, 2, backend=backend)
    store.load(np.full((2, 2), 1.5, np.float64))
    out = store.asnumpy()
    assert out.dtype == np.float32
    np.testing.assert_allclose(out, 1.5)


# --------------------------------------------------------------------------
# mesh: single-device inline; 4-device parity in a subprocess
# --------------------------------------------------------------------------
def test_mesh_spec_single_device_matches_unsharded():
    pytest.importorskip("jax")
    rng = np.random.default_rng(3)
    plain = GradientStore(8, 6)
    meshed = GradientStore(8, 6, mesh_spec=(1, 1))
    for _ in range(2):
        ids = rng.integers(0, 9, size=5)
        vals = rng.normal(size=(5, 6)).astype(np.float32)
        plain.update(ids, vals)
        meshed.update(ids, vals)
    np.testing.assert_array_equal(plain.asnumpy(), meshed.asnumpy())
    np.testing.assert_array_equal(
        np.asarray(plain.gather_rows([2, 7])), np.asarray(meshed.gather_rows([2, 7]))
    )
    meshed.load(plain.asnumpy())
    np.testing.assert_array_equal(plain.asnumpy(), meshed.asnumpy())


def test_mesh_spec_rejected_on_numpy_backend():
    with pytest.raises(RuntimeError, match="mesh_spec"):
        GradientStore(4, 3, backend="numpy", mesh_spec=(1, 1))


SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax

from repro.fl.gradient_store import GradientStore

rng = np.random.default_rng(0)
n, d, dp = 8, 64, 16  # n divides the 4-way data axis -> client axis sharded
plain = GradientStore(n, d, sketch="srp", sketch_dim=dp)
shard = GradientStore(n, d, sketch="srp", sketch_dim=dp, mesh_spec="4x1")
for _ in range(3):
    ids = rng.integers(0, n + 2, size=5)
    vals = rng.normal(size=(5, d)).astype(np.float32)
    plain.update(ids, vals)
    shard.update(ids, vals)

G = shard.snapshot()
n_shards = len({str(s.index) for s in G.addressable_shards})
shard_rows = G.addressable_shards[0].data.shape[0]
rows = np.asarray(shard.gather_rows(np.array([1, 6])))
rows_plain = np.asarray(plain.gather_rows(np.array([1, 6])))

# restore through load(): device array adopted + re-placed on the mesh
shard2 = GradientStore(n, d, sketch="srp", sketch_dim=dp, mesh_spec="4x1")
shard2.load(G)

# replication fallback: n not divisible by the data degree still works
odd = GradientStore(n + 1, d, sketch="srp", sketch_dim=dp, mesh_spec="4x1")
odd.update(np.array([0]), np.ones((1, d), np.float32))

print(json.dumps({
    "devices": jax.device_count(),
    "sharded_matches": bool(np.array_equal(plain.asnumpy(), shard.asnumpy())),
    "n_shards": n_shards,
    "shard_rows": shard_rows,
    "gather_matches": bool(np.array_equal(rows, rows_plain)),
    "load_matches": bool(np.array_equal(shard2.asnumpy(), shard.asnumpy())),
    "odd_row_set": bool(np.any(odd.asnumpy()[0] != 0)),
}))
"""


@pytest.fixture(scope="module")
def sharded_store_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", SHARDED_SCRIPT], capture_output=True, text=True,
        env=env, cwd=ROOT, timeout=560,
    )
    assert out.returncode == 0, f"sharded-store subprocess failed:\n{out.stderr[-3000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_store_matches_unsharded(sharded_store_results):
    r = sharded_store_results
    assert r["devices"] == 4
    assert r["sharded_matches"]
    assert r["n_shards"] == 4  # client axis genuinely split across devices
    assert r["shard_rows"] == 2  # 8 clients / 4-way data axis
    assert r["gather_matches"]
    assert r["load_matches"]
    assert r["odd_row_set"]


# --------------------------------------------------------------------------
# checkpoint meta: restoring across sketch identities fails loudly
# --------------------------------------------------------------------------
def _algo2(n=8, **kw):
    from repro.core.samplers.algorithm2 import Algorithm2Sampler
    from repro.core.types import ClientPopulation

    pop = ClientPopulation(np.full(n, 100))
    return Algorithm2Sampler(pop, 4, update_dim=32, seed=0, **kw)


def test_sampler_state_roundtrips_sketched_store():
    s = _algo2(sketch="srp", sketch_dim=8)
    s.sample(0)
    s.observe_updates(
        np.arange(4), np.random.default_rng(0).normal(size=(4, 32)).astype(np.float32)
    )
    meta, arrays = s.state_meta(), s.state_arrays()
    assert meta["sketch"] == "srp"
    assert meta["sketch_dim"] == 8
    assert meta["sketch_seed"] == 0  # rides the sampler seed
    assert arrays["store_G"].shape == (8, 8)
    t = _algo2(sketch="srp", sketch_dim=8)
    t.load_state(meta, arrays)
    np.testing.assert_array_equal(
        t.gradient_store.asnumpy(), s.gradient_store.asnumpy()
    )


def test_sampler_rejects_checkpoint_from_other_sketch():
    s = _algo2(sketch="srp", sketch_dim=8)
    s.sample(0)
    meta, arrays = s.state_meta(), s.state_arrays()
    for other in (
        _algo2(),                               # unsketched
        _algo2(sketch="countsketch", sketch_dim=8),  # different construction
        _algo2(sketch="srp", sketch_dim=16),    # different width
    ):
        other.sample(0)
        with pytest.raises(ValueError, match="sketch"):
            other.load_state(meta, arrays)


def test_unsketched_checkpoint_without_sketch_keys_still_loads():
    """Pre-sketch checkpoints (no sketch meta keys) restore into an
    unsketched store — forward compatibility for existing bundles."""
    s = _algo2()
    s.sample(0)
    meta, arrays = s.state_meta(), s.state_arrays()
    for k in ("sketch", "sketch_dim", "sketch_seed"):
        meta.pop(k, None)
    t = _algo2()
    t.sample(0)
    t.load_state(meta, arrays)
    np.testing.assert_array_equal(
        t.gradient_store.asnumpy(), s.gradient_store.asnumpy()
    )
