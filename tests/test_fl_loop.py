"""End-to-end FL behaviour: convergence, unbiased aggregation, FedProx."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import SAMPLERS, Algorithm2Sampler, MDSampler
from repro.fl import FederatedServer, FLConfig, by_class_shards, dirichlet_labels
from repro.fl.aggregation import aggregate_round, flatten_params, weighted_tree_sum
from repro.models.simple import fedprox_loss, init_mlp
from repro.optim import sgd


@pytest.fixture(scope="module")
def dataset():
    return by_class_shards(dim=16, noise=0.8, train_per_client=60, test_per_client=10, seed=0)


def _run(dataset, sampler, rounds=8, mu=0.0, seed=0):
    params = init_mlp((16, 32, 10), seed=1)
    cfg = FLConfig(n_rounds=rounds, n_local_steps=8, batch_size=32, seed=seed, fedprox_mu=mu)
    loss_fn = fedprox_loss if mu else None
    kw = {"loss_fn": fedprox_loss} if mu else {}
    srv = FederatedServer(dataset, sampler, params, sgd(0.08), cfg, **kw)
    return srv.run()


@pytest.mark.parametrize("name", ["md", "algorithm1"])
def test_fl_converges(dataset, name):
    pop = dataset.population
    hist = _run(dataset, SAMPLERS[name](pop, 10, seed=0))
    losses = hist.series("train_loss")
    accs = hist.series("test_acc")
    assert losses[-1] < losses[0]
    assert accs[-1] > 0.3  # well above the 10% chance level


def test_fl_algorithm2_converges_and_reclusters(dataset):
    pop = dataset.population
    params = init_mlp((16, 32, 10), seed=1)
    d = int(flatten_params(params).shape[0])
    s = Algorithm2Sampler(pop, 10, update_dim=d, seed=0)
    hist = _run(dataset, s)
    assert hist.series("train_loss")[-1] < hist.series("train_loss")[0]
    # re-clustering happened: plan no longer groups all clients together
    assert len(np.unique(s.plan.cluster_of[s.plan.cluster_of >= 0])) > 1


def test_fl_uniform_runs_with_stale_mass(dataset):
    pop = dataset.population
    hist = _run(dataset, SAMPLERS["uniform"](pop, 10, seed=0), rounds=4)
    assert np.isfinite(hist.series("train_loss")).all()


def test_fedprox_regularization_runs(dataset):
    pop = dataset.population
    hist = _run(dataset, MDSampler(pop, 10, seed=0), rounds=3, mu=0.1)
    assert np.isfinite(hist.series("train_loss")).all()


def test_weighted_tree_sum_exact():
    t1 = {"a": jnp.ones((3,)), "b": jnp.full((2, 2), 2.0)}
    t2 = {"a": jnp.full((3,), 3.0), "b": jnp.ones((2, 2))}
    out = weighted_tree_sum([t1, t2], np.array([0.25, 0.75]))
    np.testing.assert_allclose(out["a"], 0.25 * 1 + 0.75 * 3)
    np.testing.assert_allclose(out["b"], 0.25 * 2 + 0.75 * 1)


def test_aggregate_round_stale_weight():
    g = {"w": jnp.ones((4,))}
    c = {"w": jnp.full((4,), 3.0)}
    out = aggregate_round(g, [c], np.array([0.5]), stale_weight=0.5)
    np.testing.assert_allclose(out["w"], 0.5 * 3 + 0.5 * 1)


def test_dirichlet_partition_profile():
    ds = dirichlet_labels(alpha=0.01, dim=8, seed=0)
    sizes = np.array([c.n_train for c in ds.clients])
    assert sizes.sum() == 10 * 100 + 30 * 250 + 30 * 500 + 20 * 750 + 10 * 1000
    assert ds.n_clients == 100
    # alpha=0.01 -> highly concentrated class mixtures
    dominant = [np.bincount(c.y_train, minlength=10).max() / c.n_train for c in ds.clients]
    assert np.mean(dominant) > 0.8


def test_dirichlet_alpha_controls_heterogeneity():
    hetero = dirichlet_labels(alpha=0.01, dim=8, seed=1)
    homog = dirichlet_labels(alpha=100.0, dim=8, seed=1)

    def mean_dom(ds):
        return np.mean(
            [np.bincount(c.y_train, minlength=10).max() / c.n_train for c in ds.clients]
        )

    assert mean_dom(hetero) > mean_dom(homog) + 0.3
