"""Crash-safe ServerState: kill/resume must be bit-identical to never dying.

The contract under test: ``FederatedServer.checkpoint`` captures everything
the rest of the campaign depends on — params, server rng, sampler rng, plan
matrices, the gradient store, plan version/observation cursor, and the
round history — so a server rebuilt from the spec plus the checkpoint
produces byte-for-byte the History of the uninterrupted run. Populations
are deliberately absent from the bundle (masks are pure in (seed, t)), so
the checks run under churn + dropout to prove the replay holds.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.fl import ExperimentSpec, build_experiment


def _canon_json(history) -> str:
    """History JSON with wall-clock telemetry normalized out.

    ``plan_build_ms`` measures real elapsed time, so it can never replay
    identically; everything else — params trajectory, draws, weights, plan
    versions, drift — must be byte-for-byte.
    """
    recs = json.loads(history.to_json())
    for r in recs:
        r["plan_build_ms"] = -1.0
    return json.dumps(recs)

SPEC = {
    "data": {
        "name": "by_class_shards",
        "options": {
            "clients_per_class": 2, "train_per_client": 40,
            "dim": 8, "n_classes": 4, "seed": 0,
        },
    },
    "sampler": {"name": "algorithm2", "m": 4, "seed": 3},
    "train": {"n_rounds": 8, "n_local_steps": 3, "batch_size": 10, "seed": 1},
    "population": {
        "name": "poisson",
        "options": {"join_rate": 0.4, "leave_rate": 0.4, "drop_rate": 0.15},
    },
}


def _spec(**over) -> ExperimentSpec:
    d = {**SPEC, **over}
    return ExperimentSpec.from_dict(d)


def _run_full(spec):
    with build_experiment(spec) as srv:
        return srv.run()


def _run_interrupted(spec, path, kill_at):
    with build_experiment(spec, checkpoint_path=path) as srv:
        for t in range(kill_at):
            srv.run_round(t)
        srv.checkpoint()
    # the process "dies" here; a fresh build restores from the bundle
    with build_experiment(spec, checkpoint_path=path) as srv:
        assert srv.resume() == kill_at
        return srv.run()


@pytest.mark.parametrize(
    "sampler",
    [
        {"name": "md", "m": 4, "seed": 3},
        {"name": "algorithm1", "m": 4, "seed": 3},
        {"name": "uniform", "m": 4, "seed": 3},
        {"name": "algorithm2", "m": 4, "seed": 3},
        {"name": "stratified", "m": 4, "seed": 3},
        {"name": "importance", "m": 4, "seed": 3, "options": {"mix": 0.3}},
        {"name": "dp_stratified", "m": 4, "seed": 3,
         "options": {"noise_multiplier": 2.0}},
        {"name": "hybrid", "m": 4, "seed": 3},
    ],
    ids=lambda s: s["name"],
)
def test_kill_resume_bit_identical(tmp_path, sampler):
    spec = _spec(sampler=sampler)
    full = _run_full(spec)
    resumed = _run_interrupted(spec, os.path.join(tmp_path, "ck.npz"), kill_at=4)
    assert _canon_json(full) == _canon_json(resumed)


@pytest.mark.parametrize(
    "planner",
    [
        {"rebuild_every": 2, "sketch": "identity"},
        {"rebuild_every": 2, "sketch": "srp", "sketch_dim": 16, "clusterer": "kmeans"},
    ],
    ids=["identity", "srp16"],
)
def test_kill_resume_bit_identical_sketched(tmp_path, planner):
    """The sketched store checkpoints its (n, d') buffer + sketch identity;
    a killed sketched campaign replays byte-for-byte. (``identity`` pins the
    machinery-on/bit-parity case; ``srp`` the genuinely compressed one.)"""
    spec = _spec(sampler={"name": "algorithm2", "m": 4, "seed": 3}, planner=planner)
    full = _run_full(spec)
    resumed = _run_interrupted(spec, os.path.join(tmp_path, "ck.npz"), kill_at=4)
    assert _canon_json(full) == _canon_json(resumed)


def test_identity_sketch_history_matches_unsketched():
    """sketch='identity' engages the sketch stage yet trains bit-identically
    to the store with no sketch stage at all — the tier-1 parity gate."""
    plain = _run_full(_spec(sampler={"name": "algorithm2", "m": 4, "seed": 3}))
    ident = _run_full(
        _spec(
            sampler={"name": "algorithm2", "m": 4, "seed": 3},
            planner={"sketch": "identity"},
        )
    )
    assert _canon_json(plain) == _canon_json(ident)


def test_sketched_checkpoint_rejects_differently_sketched_build(tmp_path):
    """A bundle written under srp/d'=16 must not restore into an unsketched
    or differently-sketched sampler. A width change trips the restore
    layer's shape guard ((n, 16) vs (n, d)); a same-width sketch swap gets
    past shapes and must be caught by the sketch identity in the meta."""
    path = os.path.join(tmp_path, "ck.npz")
    sam = {"name": "algorithm2", "m": 4, "seed": 3}
    spec = _spec(
        sampler=sam,
        planner={"sketch": "srp", "sketch_dim": 16, "clusterer": "kmeans"},
    )
    with build_experiment(spec, checkpoint_path=path) as srv:
        srv.run_round(0)
        srv.checkpoint()
    with build_experiment(_spec(sampler=sam)) as srv:
        with pytest.raises(ValueError):  # (n, 16) buffer vs unsketched (n, d)
            srv.resume(path)
    other = _spec(
        sampler=sam,
        planner={"sketch": "countsketch", "sketch_dim": 16, "clusterer": "kmeans"},
    )
    with build_experiment(other) as srv:
        with pytest.raises(ValueError, match="sketch"):
            srv.resume(path)


def test_async_planner_checkpoint_captures_sync_fixed_point(tmp_path):
    """Async campaigns checkpoint through prepare_state(): the in-flight
    rebuild is flushed, so the bundle holds the sync fixed point — the
    restored sampler is state-equal (plan matrix, observation cursor, rng)
    to the one that was killed, and the campaign runs to completion.

    (Bit-identical *continuations* are pinned only for deterministic
    planners above: async rebuild timing is a real race, so even two
    uninterrupted async runs may legitimately differ in plan_lag_rounds.)
    """
    spec = _spec(
        planner={"mode": "async", "rebuild_every": 1},
        population={"name": "poisson", "options": {"leave_rate": 0.2, "drop_rate": 0.05}},
    )
    path = os.path.join(tmp_path, "ck.npz")
    with build_experiment(spec, checkpoint_path=path) as srv:
        for t in range(4):
            srv.run_round(t)
        srv.checkpoint()
        plan_r = np.array(srv.sampler.plan.r, copy=True)
        meta = srv.sampler.state_meta()
        g = np.asarray(srv.sampler._store.snapshot())
    with build_experiment(spec, checkpoint_path=path) as srv:
        assert srv.resume() == 4
        np.testing.assert_array_equal(srv.sampler.plan.r, plan_r)
        restored = srv.sampler.state_meta()
        assert restored["obs_seen"] == meta["obs_seen"]
        assert restored["plan_version"] == meta["plan_version"]
        assert restored["rng"] == meta["rng"]
        np.testing.assert_array_equal(np.asarray(srv.sampler._store.snapshot()), g)
        hist = srv.run()
    assert [r.round for r in hist.records] == list(range(8))


def test_run_checkpoint_cadence_and_cursor(tmp_path):
    """run() writes on the checkpoint_every cadence; the bundle's cursor
    equals the number of completed rounds at the write."""
    path = os.path.join(tmp_path, "svc.npz")
    spec = _spec(
        train={**SPEC["train"], "n_rounds": 5, "checkpoint_every": 2},
    )
    with build_experiment(spec, checkpoint_path=path) as srv:
        srv.run()
        assert os.path.exists(path)
    # last cadence write is after round 4 (t+1 = 4); round 5 is off-cadence
    with build_experiment(spec, checkpoint_path=path) as srv:
        assert srv.resume() == 4
        hist = srv.run()
    assert [r.round for r in hist.records] == [0, 1, 2, 3, 4]


def test_should_stop_checkpoints_and_resume_extends_history(tmp_path):
    """The SIGTERM path: should_stop trips mid-campaign → final checkpoint;
    the resumed run's history strictly extends the checkpointed cursor."""
    path = os.path.join(tmp_path, "svc.npz")
    spec = _spec()
    calls = {"n": 0}

    def stop_after_3():
        calls["n"] += 1
        return calls["n"] >= 3

    with build_experiment(spec, checkpoint_path=path) as srv:
        srv.run(should_stop=stop_after_3)
        assert len(srv.history.records) == 3
    with build_experiment(spec, checkpoint_path=path) as srv:
        start = srv.resume()
        assert start == 3
        hist = srv.run()
    rounds = [r.round for r in hist.records]
    assert rounds == list(range(8)) and rounds[start:] == [3, 4, 5, 6, 7]


def test_resume_restores_history_and_rng_state(tmp_path):
    """The restored server carries the pre-kill records verbatim and the
    server/sampler rng mid-stream states (not re-seeded)."""
    path = os.path.join(tmp_path, "ck.npz")
    spec = _spec()
    with build_experiment(spec, checkpoint_path=path) as srv:
        for t in range(3):
            srv.run_round(t)
        pre = srv.history.to_json()
        srv.checkpoint()
        rng_state = srv._rng.bit_generator.state["state"]
    with build_experiment(spec, checkpoint_path=path) as srv:
        fresh_state = srv._rng.bit_generator.state["state"]
        assert fresh_state != rng_state  # fresh build is at stream origin
        srv.resume()
        assert srv.history.to_json() == pre
        assert srv._rng.bit_generator.state["state"] == rng_state


def test_checkpoint_without_path_is_an_error():
    spec = _spec(train={**SPEC["train"], "n_rounds": 1})
    with build_experiment(spec) as srv:
        with pytest.raises(ValueError, match="checkpoint path"):
            srv.checkpoint()
        with pytest.raises(ValueError, match="checkpoint path"):
            srv.resume()


def test_dp_ledger_survives_checkpoint_roundtrip(tmp_path):
    """The (ε, δ) ledger and the mechanism rng ride the bundle: the resumed
    campaign continues the SAME privacy accounting (count, ρ, ε) and noise
    stream instead of resetting either."""
    path = os.path.join(tmp_path, "ck.npz")
    spec = _spec(sampler={"name": "dp_stratified", "m": 4, "seed": 3,
                          "options": {"noise_multiplier": 2.0}})
    with build_experiment(spec, checkpoint_path=path) as srv:
        for t in range(4):
            srv.run_round(t)
        srv.checkpoint()
        ledger = srv.sampler.privacy_ledger
        dp_rng = srv.sampler._dp_rng.bit_generator.state
    assert ledger["observations"] == 4  # one release per observed round
    assert ledger["rho"] == pytest.approx(4 / (2.0 * 2.0**2))
    assert ledger["epsilon"] > 0
    with build_experiment(spec, checkpoint_path=path) as srv:
        assert srv.resume() == 4
        assert srv.sampler.privacy_ledger == ledger
        assert srv.sampler._dp_rng.bit_generator.state == dp_rng
        srv.run()
        post = srv.sampler.privacy_ledger
    assert post["observations"] == 8  # accounting continued, not reset
    assert post["epsilon"] > ledger["epsilon"]


def test_cross_scheme_restore_rejected(tmp_path):
    """Store-backed schemes stamp their scheme name into the bundle; a
    checkpoint written by one scheme must not restore into another even
    when every array shape happens to line up."""
    path = os.path.join(tmp_path, "ck.npz")
    spec = _spec(sampler={"name": "stratified", "m": 4, "seed": 3})
    with build_experiment(spec, checkpoint_path=path) as srv:
        srv.run_round(0)
        srv.checkpoint()
    other = _spec(sampler={"name": "dp_stratified", "m": 4, "seed": 3})
    with build_experiment(other) as srv:
        with pytest.raises(ValueError, match="scheme"):
            srv.resume(path)


def test_checkpoint_rejects_mismatched_sampler(tmp_path):
    """Restoring into a structurally different sampler fails loudly instead
    of silently mixing state (the restore layer's unknown/missing-leaf
    guards reach through the server bundle)."""
    path = os.path.join(tmp_path, "ck.npz")
    with build_experiment(_spec()) as srv:
        srv.run_round(0)
        srv.checkpoint(path)
    with build_experiment(_spec(sampler={"name": "md", "m": 4, "seed": 3})) as srv:
        with pytest.raises(KeyError):
            srv.resume(path)
