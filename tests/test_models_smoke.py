"""Per-architecture smoke tests on REDUCED configs (brief deliverable f).

Each assigned architecture instantiates a reduced variant of the same
family (<= a period of layers, d_model <= 512, <= 4 experts) and runs one
forward + one train step on CPU asserting output shapes and no NaNs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_params,
    logits_from_hidden,
    loss_fn,
)
from repro.optim import sgd
from repro.optim.base import apply_updates

B, S = 2, 16
KEY = jax.random.PRNGKey(0)


def _extras(cfg, batch=B):
    kw = {}
    if cfg.frontend == "vision":
        kw["vision_embeds"] = (
            jax.random.normal(jax.random.PRNGKey(5), (batch, cfg.n_vision_tokens, cfg.d_model))
            * 0.02
        )
    if cfg.frontend == "audio":
        kw["frames"] = (
            jax.random.normal(jax.random.PRNGKey(6), (batch, cfg.encoder.n_frames, cfg.d_model))
            * 0.02
        )
    return kw


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(name):
    cfg = get_config(name, reduced=True)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    hidden, _, aux = forward(cfg, params, toks, **_extras(cfg))
    logits = logits_from_hidden(cfg, params, hidden)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_one_train_step_reduces_loss(name):
    cfg = get_config(name, reduced=True)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    tgts = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)
    kw = _extras(cfg)

    def lf(p):
        return loss_fn(cfg, p, toks, tgts, **kw)

    (l0, _), grads = jax.value_and_grad(lf, has_aux=True)(params)
    opt = sgd(0.5)
    upd, _ = opt.update(grads, opt.init(params), params, 0)
    params2 = apply_updates(params, upd)
    l1, _ = lf(params2)
    assert bool(jnp.isfinite(l0)) and bool(jnp.isfinite(l1))
    assert float(l1) < float(l0)  # one big step on fixed batch must descend


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_step_shapes(name):
    cfg = get_config(name, reduced=True)
    params = init_params(cfg, KEY)
    cache = init_cache(cfg, B, 32)
    tok = jnp.full((B, 1), 3, jnp.int32)
    logits, cache = decode_step(cfg, params, tok, cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert int(cache["pos"]) == 1


@pytest.mark.parametrize(
    "name", ["qwen3-0.6b", "xlstm-125m", "recurrentgemma-9b", "deepseek-v2-lite-16b"]
)
def test_decode_matches_full_forward(name):
    cfg = get_config(name, reduced=True)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, cfg.vocab_size)
    hidden, _, _ = forward(cfg, params, toks)
    full = logits_from_hidden(cfg, params, hidden)
    cache = init_cache(cfg, B, S)
    errs = []
    for t in range(S):
        lt, cache = decode_step(cfg, params, toks[:, t : t + 1], cache)
        errs.append(float(jnp.abs(lt - full[:, t]).max()))
    assert max(errs) < 5e-4


def test_sliding_window_ring_decode_matches_windowed_forward():
    """Dense arch with decode_window: ring-buffer decode == full forward with
    the same sliding-window mask (the long_500k mechanism)."""
    cfg = get_config("qwen3-0.6b", reduced=True)
    window = 8
    cfg = dataclasses.replace(cfg, sliding_window=window)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(8), (B, S), 0, cfg.vocab_size)

    # reference: forward with 'local' mixers (same mask semantics)
    cfg_local = dataclasses.replace(cfg, pattern=(("local", "mlp"),))
    hidden, _, _ = forward(cfg_local, params, toks)
    ref = logits_from_hidden(cfg_local, params, hidden)

    cache = init_cache(cfg, B, window, decode_window=window)
    errs = []
    for t in range(S):
        lt, cache = decode_step(cfg, params, toks[:, t : t + 1], cache, decode_window=window)
        errs.append(float(jnp.abs(lt - ref[:, t]).max()))
    assert max(errs) < 5e-4


def test_prefill_seeds_decode_cache():
    """forward(caches=...) then decode continues exactly (pack_kv_cache)."""
    cfg = get_config("qwen2-1.5b", reduced=True)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(9), (B, S), 0, cfg.vocab_size)
    hidden, _, _ = forward(cfg, params, toks)
    full = logits_from_hidden(cfg, params, hidden)
    half = S // 2
    _, cache, _ = forward(cfg, params, toks[:, :half], caches=init_cache(cfg, B, S))
    errs = []
    for t in range(half, S):
        lt, cache = decode_step(cfg, params, toks[:, t : t + 1], cache)
        errs.append(float(jnp.abs(lt - full[:, t]).max()))
    assert max(errs) < 5e-4


def test_moe_routes_to_multiple_experts():
    cfg = get_config("qwen2-moe-a2.7b", reduced=True)
    from repro.models.layers import moe as moe_lib

    params = moe_lib.init_moe(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, cfg.d_model))
    out, aux = moe_lib.moe_ffn(cfg, params, x)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    # balanced-ish routing: aux loss near its lower bound of 1.0, not at the
    # one-expert-takes-all extreme (= n_routed)
    assert 0.5 < float(aux) < cfg.moe.n_routed


def test_mla_absorbed_equals_naive_decode():
    cfg = get_config("deepseek-v2-lite-16b", reduced=True)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(10), (B, S), 0, cfg.vocab_size)
    outs = {}
    for mode in ("naive", "absorbed"):
        c2 = dataclasses.replace(cfg, mla=dataclasses.replace(cfg.mla, decode_mode=mode))
        cache = init_cache(c2, B, S)
        ls = []
        for t in range(4):
            lt, cache = decode_step(c2, params, toks[:, t : t + 1], cache)
            ls.append(lt)
        outs[mode] = jnp.stack(ls)
    np.testing.assert_allclose(outs["naive"], outs["absorbed"], atol=2e-4)


def test_param_counts_full_configs():
    """Full (non-reduced) configs roughly match their nameplate sizes."""
    import math

    from repro.launch.roofline import active_params
    from repro.launch.steps import abstract_params

    expect = {
        "xlstm-125m": (0.1e9, 0.35e9),
        "qwen3-0.6b": (0.4e9, 0.9e9),
        "qwen2-1.5b": (1.0e9, 2.0e9),
        "llama3.2-3b": (2.5e9, 4.5e9),
        "qwen2.5-32b": (25e9, 40e9),
        "recurrentgemma-9b": (7e9, 12e9),
        "deepseek-v2-lite-16b": (8e9, 20e9),
        "qwen2-moe-a2.7b": (8e9, 18e9),
        "whisper-small": (0.15e9, 0.45e9),
        "qwen2-vl-2b": (1.0e9, 2.0e9),
    }
    for name, (lo, hi) in expect.items():
        cfg = get_config(name)
        shapes = abstract_params(cfg)
        total, active = active_params(shapes, cfg)
        assert lo <= total <= hi, f"{name}: {total / 1e9:.2f}B params out of range"
        assert active <= total
        assert math.isfinite(active)
