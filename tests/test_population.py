"""Population processes + the server's availability/drop-resolution phases."""
import numpy as np
import pytest

from repro.core import MDSampler
from repro.core.samplers.base import ClientSampler
from repro.core.types import SampleResult
from repro.fl import (
    POPULATIONS,
    BernoulliDropoutPopulation,
    EmptyRoundError,
    FederatedServer,
    FLConfig,
    PeriodicAvailabilityPopulation,
    PoissonChurnPopulation,
    PopulationProcess,
    StaticPopulation,
    build_population,
    by_class_shards,
    flatten_params,
)
from repro.models.simple import init_mlp
from repro.optim import sgd

N = 40


# --------------------------------------------------------------------------
# process semantics
# --------------------------------------------------------------------------
def test_registry_has_seed_scenarios():
    for name in ("static", "poisson", "periodic", "dropout"):
        assert name in POPULATIONS


def test_build_population_rejects_unknown_options():
    with pytest.raises(ValueError, match="does not accept option"):
        build_population({"name": "poisson", "options": {"jion_rate": 1.0}}, N)


def test_static_all_available_no_drops():
    pop = StaticPopulation(N)
    for t in (0, 3, 100):
        assert pop.available_mask(t).all()
        assert not pop.dropout_mask(t, np.arange(N)).any()


def test_masks_deterministic_in_seed_and_round():
    """The determinism contract: a mask is a pure function of (seed, t) —
    a second instance (a resumed server) replays the identical trajectory."""
    for cls, kw in (
        (PoissonChurnPopulation, dict(join_rate=0.3, leave_rate=0.4)),
        (PeriodicAvailabilityPopulation, dict(period=5, duty=0.4, stagger=False)),
        (BernoulliDropoutPopulation, dict(rate=0.3, straggle_rate=0.1)),
    ):
        a = cls(N, seed=7, **kw)
        b = cls(N, seed=7, **kw)
        ids = np.arange(N)
        # query b out of order / from the middle — replay must not care
        for t in (5, 0, 9, 2):
            np.testing.assert_array_equal(a.available_mask(t), b.available_mask(t))
            np.testing.assert_array_equal(a.dropout_mask(t, ids), b.dropout_mask(t, ids))
        c = cls(N, seed=8, **kw)
        assert any(
            not np.array_equal(a.available_mask(t), c.available_mask(t))
            or not np.array_equal(a.dropout_mask(t, ids), c.dropout_mask(t, ids))
            for t in range(10)
        )


def test_dropout_fate_independent_of_sampled_set():
    """A client's mid-round fate is keyed by its id, not by who else was
    drawn — the same client has the same fate under any co-sample."""
    pop = BernoulliDropoutPopulation(N, seed=3, rate=0.5)
    full = pop.dropout_mask(4, np.arange(N))
    subset = np.array([3, 17, 29])
    np.testing.assert_array_equal(pop.dropout_mask(4, subset), full[subset])


def test_poisson_churn_rates_move_the_mean():
    heavy = PoissonChurnPopulation(N, seed=0, join_rate=0.05, leave_rate=1.0)
    light = PoissonChurnPopulation(N, seed=0, join_rate=1.0, leave_rate=0.05)
    mh = np.mean([heavy.available_mask(t).mean() for t in range(30, 60)])
    ml = np.mean([light.available_mask(t).mean() for t in range(30, 60)])
    assert mh < 0.5 < ml


def test_poisson_min_available_floor():
    pop = PoissonChurnPopulation(N, seed=0, join_rate=0.0, leave_rate=5.0, min_available=3)
    for t in range(20):
        assert pop.available_mask(t).sum() >= 3


def test_periodic_windows_and_floor():
    pop = PeriodicAvailabilityPopulation(N, period=4, duty=0.5, stagger=True)
    masks = np.stack([pop.available_mask(t) for t in range(8)])
    # staggered phases: every round keeps roughly duty * n clients online
    assert (masks.sum(axis=1) >= 1).all()
    # period-4: the pattern repeats exactly
    np.testing.assert_array_equal(masks[:4], masks[4:])
    # degenerate duty with random phases still respects the floor
    tight = PeriodicAvailabilityPopulation(
        6, period=100, duty=0.01, stagger=False, min_available=2, seed=1
    )
    for t in range(10):
        assert tight.available_mask(t).sum() >= 2


def test_invalid_parameters_rejected():
    with pytest.raises(ValueError, match="drop_rate"):
        StaticPopulation(N, drop_rate=1.5)
    with pytest.raises(ValueError, match="min_available"):
        PoissonChurnPopulation(N, min_available=N + 1)
    with pytest.raises(ValueError, match="duty"):
        PeriodicAvailabilityPopulation(N, duty=0.0)
    with pytest.raises(ValueError, match="period"):
        PeriodicAvailabilityPopulation(N, period=0)


# --------------------------------------------------------------------------
# server integration: availability + degraded rounds
# --------------------------------------------------------------------------
class _ForcedDropPopulation(PopulationProcess):
    """Full availability; a fixed set of client ids always drops mid-round."""

    def __init__(self, n_clients, drop_ids=()):
        super().__init__(n_clients)
        self._drop = np.zeros(n_clients, dtype=bool)
        self._drop[list(drop_ids)] = True

    def _availability(self, t):
        return np.ones(self.n_clients, dtype=bool)

    def dropout_mask(self, t, client_ids):
        return self._drop[np.asarray(client_ids, dtype=np.int64)]


@pytest.fixture(scope="module")
def dataset():
    return by_class_shards(dim=16, noise=0.8, train_per_client=60, test_per_client=10, seed=0)


def _server(dataset, *, population, engine="batched", rounds=2, seed=0, m=10):
    params = init_mlp((16, 32, 10), seed=1)
    cfg = FLConfig(n_rounds=rounds, n_local_steps=4, batch_size=16, seed=seed, engine=engine)
    return FederatedServer(
        dataset, MDSampler(dataset.population, m, seed=seed), params, sgd(0.08), cfg,
        population=population,
    )


@pytest.mark.parametrize("engine", ["batched", "compat"])
def test_degraded_round_aggregates_survivors_only(dataset, engine):
    """Mid-round dropout with >= 1 live client: the dropped participants'
    weight is zeroed (their mass goes stale — the global model keeps it),
    telemetry reports the round as degraded, and the result equals a round
    where the same weights were zero from the start."""
    n = dataset.n_clients
    srv = _server(dataset, population=None, engine=engine, rounds=1)
    rec_full = srv.run_round(0)
    drawn = np.flatnonzero(rec_full.agg_weights)
    victim = int(drawn[0])

    a = _server(dataset, population=_ForcedDropPopulation(n, [victim]), engine=engine, rounds=1)
    rec = a.run_round(0)
    assert rec.round_status == "degraded"
    assert rec.n_dropped == 1
    assert rec.n_available == n
    assert rec.agg_weights[victim] == 0.0
    assert np.isfinite(rec.train_loss)

    # reference: a sampler that hands the server the already-zeroed weights
    # with the dropped mass pre-routed to the stale term
    w = np.array(rec_full.agg_weights, copy=True)
    stale = float(w[victim])
    w[victim] = 0.0

    class _Fixed(ClientSampler):
        def sample(self, round_idx, available=None):
            return SampleResult(
                clients=np.repeat(drawn, 1), agg_weights=w, stale_weight=stale
            )

    b = _server(dataset, population=None, engine=engine, rounds=1)
    b.sampler = _Fixed(dataset.population, 10, seed=0)
    rec_b = b.run_round(0)
    np.testing.assert_allclose(
        np.asarray(flatten_params(a.params)),
        np.asarray(flatten_params(b.params)),
        rtol=1e-5, atol=1e-6,
    )
    assert rec.train_loss == pytest.approx(rec_b.train_loss, rel=1e-5)


@pytest.mark.parametrize("engine", ["batched", "compat"])
def test_all_dropped_raises_empty_round_with_index(dataset, engine):
    """Every realized participant dropping is an EmptyRoundError naming the
    round — all realized aggregation mass is gone."""
    n = dataset.n_clients
    srv = _server(
        dataset, population=_ForcedDropPopulation(n, range(n)), engine=engine, rounds=1
    )
    with pytest.raises(EmptyRoundError, match=r"round 0.*dropped"):
        srv.run_round(0)
    assert len(srv.history.records) == 0


def test_nobody_available_raises_empty_round(dataset):
    class _Offline(PopulationProcess):
        def _availability(self, t):
            return np.zeros(self.n_clients, dtype=bool)

    srv = _server(dataset, population=_Offline(dataset.n_clients), rounds=1)
    with pytest.raises(EmptyRoundError, match="round 0.*zero"):
        srv.run_round(0)


def test_run_skip_empty_rides_out_dead_rounds(dataset):
    """skip_empty=True records empty placeholder rounds instead of raising;
    live rounds still train."""

    class _Blinking(PopulationProcess):
        def _availability(self, t):
            on = np.zeros(self.n_clients, dtype=bool)
            if t % 2 == 0:
                on[:] = True
            return on

    srv = _server(dataset, population=_Blinking(dataset.n_clients), rounds=4)
    hist = srv.run(skip_empty=True)
    status = [r.round_status for r in hist.records]
    assert status == ["ok", "empty", "ok", "empty"]
    empty = hist.records[1]
    assert empty.n_distinct_clients == 0 and np.isnan(empty.train_loss)
    assert empty.n_available == 0


def test_static_population_matches_no_population(dataset):
    """An attached all-available process must not perturb the numerics: the
    masked draw degenerates to the unconditional one bit-for-bit."""
    a = _server(dataset, population=None, rounds=3)
    b = _server(dataset, population=StaticPopulation(dataset.n_clients), rounds=3)
    ha, hb = a.run(), b.run()
    for ra, rb in zip(ha.records, hb.records):
        assert ra.train_loss == rb.train_loss
        np.testing.assert_array_equal(ra.agg_weights, rb.agg_weights)
        assert (ra.n_available, rb.n_available) == (-1, dataset.n_clients)


def test_availability_restricts_draws(dataset):
    """No draw ever lands on an unavailable client, and the realized weights
    re-normalize to 1 over the available set."""
    n = dataset.n_clients

    class _HalfOn(PopulationProcess):
        def _availability(self, t):
            mask = np.zeros(self.n_clients, dtype=bool)
            mask[: self.n_clients // 2] = True
            return mask

    srv = _server(dataset, population=_HalfOn(n), rounds=3)
    hist = srv.run()
    for rec in hist.records:
        assert rec.n_available == n // 2
        assert (rec.agg_weights[n // 2:] == 0).all()
        assert rec.agg_weights.sum() == pytest.approx(1.0)
