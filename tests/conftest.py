import os
import sys

# tests run against the source tree; smoke tests must see the REAL device
# count (1 CPU device) — the 512-device XLA flag is set ONLY inside
# repro.launch.dryrun / subprocess-based sharding tests.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# make tests/ importable as a flat namespace (for _hypothesis_compat)
sys.path.insert(0, os.path.dirname(__file__))
