"""Round schedulers: sync parity, straggler harvesting, kill/resume, bias.

The subsystem's contracts:

* an explicit :class:`SyncScheduler` is bit-identical to no scheduler at
  all (the hooks are free),
* the deadline scheduler grades stragglers instead of dropping them — late
  mass goes stale *this* round but the computed updates scatter into the
  *next* round's gradient store, and a round where only stragglers miss the
  deadline never raises ``EmptyRoundError``,
* overselection's urn-cyclic weighted draw stays exactly unbiased,
* harvest buffer + availability scores checkpoint inside ServerState and a
  killed campaign resumes bit-identically mid-decay.
"""
import json
import os

import numpy as np
import pytest

from repro.core import ClientPopulation, MDSampler
from repro.fl import ExperimentSpec, build_experiment
from repro.fl.availability import AvailabilityTracker
from repro.fl.scheduler import (
    DeadlineScheduler,
    LatencyModel,
    SyncScheduler,
    build_scheduler,
)
from repro.fl.server import EmptyRoundError


def _canon_json(history) -> str:
    """History JSON with wall-clock telemetry (plan_build_ms) normalized."""
    recs = json.loads(history.to_json())
    for r in recs:
        r["plan_build_ms"] = -1.0
    return json.dumps(recs)


SPEC = {
    "data": {
        "name": "by_class_shards",
        "options": {
            "clients_per_class": 2, "train_per_client": 40,
            "dim": 8, "n_classes": 4, "seed": 0,
        },
    },
    "sampler": {"name": "algorithm2", "m": 4, "seed": 3},
    "train": {"n_rounds": 8, "n_local_steps": 3, "batch_size": 10, "seed": 1},
    "population": {
        "name": "poisson",
        "options": {"join_rate": 0.3, "leave_rate": 0.3},
    },
}


def _spec(**over) -> ExperimentSpec:
    return ExperimentSpec.from_dict({**SPEC, **over})


def _run_full(spec):
    with build_experiment(spec) as srv:
        return srv.run()


# --------------------------------------------------------------------------
# parity + latency model
# --------------------------------------------------------------------------
def test_sync_scheduler_hooks_are_free():
    """A server with an explicit SyncScheduler attached trains bit-identically
    to the scheduler-free server — every hook is the exact legacy no-op."""
    spec = _spec()
    legacy = _run_full(spec)
    with build_experiment(spec) as srv:
        assert srv.scheduler is None  # the default spec attaches nothing
        n = srv.dataset.population.n_clients
        srv.scheduler = SyncScheduler(n, srv.sampler.m)
        explicit = srv.run()
    assert _canon_json(legacy) == _canon_json(explicit)


def test_latency_model_pure_and_straggler_split():
    model = LatencyModel(32, seed=7, straggle_frac=0.3, slow_factor=2.0)
    np.testing.assert_array_equal(model.latencies(5), model.latencies(5))
    assert not np.array_equal(model.latencies(5), model.latencies(6))
    # deadline=1.0 splits exactly: base U[0,1) never late, +2.0 always late
    fast = LatencyModel(32, seed=7, straggle_frac=0.0).latencies(0)
    slow = LatencyModel(32, seed=7, straggle_frac=1.0).latencies(0)
    assert (fast < 1.0).all()
    assert (slow > 1.0).all()


def test_build_scheduler_validates_options():
    with pytest.raises(ValueError, match="beta"):
        build_scheduler(
            {"name": "deadline", "options": {"beta": 0.5}}, n_clients=8, m=4
        )
    sched = build_scheduler(
        {"name": "deadline", "options": {"straggle_frac": 0.5}, "seed": 9},
        n_clients=8,
        m=4,
    )
    assert isinstance(sched, DeadlineScheduler)
    assert sched.model.straggle_frac == 0.5
    assert sched.seed == 9


# --------------------------------------------------------------------------
# deadline scheduler: grading, harvesting, empty-round behaviour
# --------------------------------------------------------------------------
def test_deadline_grades_and_harvests():
    spec = _spec(
        scheduler={
            "name": "deadline",
            "options": {"straggle_frac": 0.5, "harvest_discount": 0.5},
        }
    )
    hist = _run_full(spec)
    n_late = hist.series("n_late")
    n_harv = hist.series("n_harvested")
    assert n_late.sum() > 0, "50% stragglers over 8 rounds never missed a deadline"
    assert n_harv.sum() > 0, "late updates never reached the next round's store"
    # harvesting is strictly next-round: round 0 has nothing buffered yet
    assert n_harv[0] == 0
    # a round that lost someone to lateness is degraded, not ok
    status = hist.series("round_status")
    assert (status[n_late > 0] == "degraded").all()


def test_all_stragglers_is_degraded_not_empty():
    """straggle_frac=1.0: every participant misses every deadline. All the
    realized mass goes stale, yet the round must NOT raise EmptyRoundError —
    a straggler is not a crash, and its update is harvested next round."""
    spec = _spec(
        population={},  # fixed population: lateness is the only loss channel
        scheduler={"name": "deadline", "options": {"straggle_frac": 1.0}},
    )
    with build_experiment(spec) as srv:
        for t in range(3):
            rec = srv.run_round(t)  # must not raise
            assert rec.round_status == "degraded"
            assert rec.n_late > 0
            # no live mass: the model does not move and train_loss is nan
            assert np.isnan(rec.train_loss)
            assert rec.agg_weights.sum() == 0.0
        # the buffer keeps flowing into the store from round 1 on
        assert srv.history.series("n_harvested")[1:].sum() > 0


def test_deadline_with_plan_free_sampler_harvests_nothing():
    """MD holds no gradient store; begin_round flushes the buffer into the
    void and reports 0 harvested instead of failing."""
    pop = ClientPopulation(np.full(6, 10))
    md = MDSampler(pop, 3, seed=0)
    sched = DeadlineScheduler(6, 3, straggle_frac=1.0)
    sched.collect(0, np.array([1, 4]), np.ones((2, 5), np.float32))
    assert sched.begin_round(1, md) == 0
    assert sched._harvest_ids.size == 0  # buffer still consumed


# --------------------------------------------------------------------------
# overselection: exact draw-time unbiasedness
# --------------------------------------------------------------------------
def test_overselect_draw_weights_unbiased_monte_carlo():
    """Over all m·(1+β) weighted draws, E[Σ ω_i] = p_i unconditionally and
    p_i·a_i / Σ_j p_j·a_j under an availability mask; each round's draw
    weights sum to exactly 1."""
    from repro.core import Algorithm1Sampler

    rng = np.random.default_rng(0)
    pop = ClientPopulation(rng.integers(5, 60, size=9))
    sam = Algorithm1Sampler(pop, 3, seed=11)
    a = np.ones(9, bool)
    a[[2, 5, 7]] = False
    try:
        for mask, target in (
            (None, pop.importances),
            (a, pop.importances * a / (pop.importances * a).sum()),
        ):
            total = np.zeros(9)
            n_rounds = 3000
            for t in range(n_rounds):
                res = sam.sample_overselect(t, 5, mask)
                w = res.draw_weights
                np.testing.assert_allclose(
                    w.sum() + res.stale_weight, 1.0, atol=1e-12
                )
                np.add.at(total, res.clients, w)
                if mask is not None:
                    assert mask[res.clients].all()
            np.testing.assert_allclose(total / n_rounds, target, atol=0.02)
    finally:
        sam.close()


def test_overselect_importance_sampler_opts_out():
    """Importance re-weights its draws itself — the urn-cyclic re-weighting
    would double-correct, so it refuses overselection loudly."""
    from repro.core import ImportanceSampler

    pop = ClientPopulation(np.full(6, 10))
    sam = ImportanceSampler(pop, 3, update_dim=5, seed=0)
    try:
        with pytest.raises(NotImplementedError, match="re-weights its draws"):
            sam.sample_overselect(0, 5)
    finally:
        sam.close()


def test_overselect_end_to_end_keeps_m_slots():
    spec = _spec(scheduler={"name": "overselect", "options": {"beta": 0.5}})
    hist = _run_full(spec)
    # surplus draws are discarded and reported as n_late telemetry; under
    # churn a masked urn may draw nothing, so the surplus is at MOST
    # ceil(0.5 * 4) = 2 per round and must show up somewhere in the run
    n_late = hist.series("n_late")
    assert (n_late <= 2).all() and n_late.sum() > 0
    # planned surplus alone must not mark rounds degraded
    ok_rounds = hist.series("round_status") == "ok"
    assert ok_rounds.any(), "overselection's planned surplus degraded every round"


# --------------------------------------------------------------------------
# availability tracker
# --------------------------------------------------------------------------
def test_availability_tracker_fold_and_outcomes():
    tr = AvailabilityTracker(4, decay=0.5, threshold=0.4, late_credit=0.5,
                             backend="numpy")
    np.testing.assert_allclose(tr.scores(), 1.0)  # optimistic cold start
    mask = np.array([True, True, True, False])
    tr.update(mask, on_time=np.array([0]), late=np.array([1]),
              crashed=np.array([2]))
    # signal: on-time 1.0, late 0.5, crashed 0.0, absent 0.0
    np.testing.assert_allclose(tr.scores(), [1.0, 0.75, 0.5, 0.5])
    tr.update(np.array([False, False, False, False]))
    np.testing.assert_allclose(tr.scores(), [0.5, 0.375, 0.25, 0.25])
    np.testing.assert_array_equal(tr.active_mask(), [True, False, False, False])
    assert tr.min_score() == 0.25
    assert tr.rounds_seen == 2


def test_availability_tracker_backends_agree():
    pytest.importorskip("jax")
    kw = dict(decay=0.9, threshold=0.25, late_credit=0.5)
    a = AvailabilityTracker(16, backend="jax", **kw)
    b = AvailabilityTracker(16, backend="numpy", **kw)
    rng = np.random.default_rng(3)
    for _ in range(5):
        mask = rng.random(16) < 0.6
        drawn = rng.choice(16, size=4, replace=False)
        out = dict(on_time=drawn[:2], late=drawn[2:3], crashed=drawn[3:])
        a.update(mask, **out)
        b.update(mask, **out)
    np.testing.assert_allclose(a.scores(), b.scores(), atol=1e-7)


def test_availability_tracker_restore_guards():
    tr = AvailabilityTracker(4, decay=0.5, backend="numpy")
    tr.update(np.array([True, False, True, False]))
    meta, arrays = tr.state_meta(), tr.state_arrays()

    fresh = AvailabilityTracker(4, decay=0.5, backend="numpy")
    fresh.load_state(meta, arrays)
    np.testing.assert_array_equal(fresh.scores(), tr.scores())
    assert fresh.rounds_seen == 1

    with pytest.raises(ValueError, match="knobs"):
        AvailabilityTracker(4, decay=0.9, backend="numpy").load_state(meta, arrays)
    with pytest.raises(ValueError, match="shape"):
        AvailabilityTracker(5, decay=0.5, backend="numpy").load_state(meta, arrays)


# --------------------------------------------------------------------------
# checkpoint / resume
# --------------------------------------------------------------------------
def _sched_spec(**over):
    return _spec(
        scheduler={
            "name": "deadline",
            "options": {"straggle_frac": 0.5, "harvest_discount": 0.5},
            "track_availability": True,
            **over,
        }
    )


def test_kill_resume_bit_identical_with_harvest_and_tracker(tmp_path):
    """Kill at round 4 with a non-empty harvest buffer and a mid-decay score
    history; the resumed campaign must replay byte-for-byte."""
    spec = _sched_spec()
    full = _run_full(spec)
    path = os.path.join(tmp_path, "ck.npz")
    with build_experiment(spec, checkpoint_path=path) as srv:
        for t in range(4):
            srv.run_round(t)
        # the checkpoint must capture real pending state, or this test is
        # only pinning the empty-buffer case
        assert srv.scheduler._harvest_ids.size > 0
        assert srv.availability.rounds_seen == 4
        assert srv.availability.min_score() < 1.0
        srv.checkpoint()
    with build_experiment(spec, checkpoint_path=path) as srv:
        assert srv.resume() == 4
        assert srv.scheduler._harvest_ids.size > 0
        assert srv.availability.rounds_seen == 4
        resumed = srv.run()
    assert _canon_json(full) == _canon_json(resumed)


def test_resume_rejects_scheduler_free_checkpoint(tmp_path):
    path = os.path.join(tmp_path, "ck.npz")
    plain = _spec()
    with build_experiment(plain, checkpoint_path=path) as srv:
        srv.run_round(0)
        srv.checkpoint()
    with build_experiment(_sched_spec(), checkpoint_path=path) as srv:
        with pytest.raises(ValueError, match="scheduler"):
            srv.resume()


def test_cross_scheduler_restore_rejected():
    sched = DeadlineScheduler(8, 4)
    with pytest.raises(ValueError, match="cross-scheduler|sync"):
        sched.load_state({"scheduler": "sync"}, {})


# --------------------------------------------------------------------------
# spec plumbing
# --------------------------------------------------------------------------
def test_scheduler_spec_roundtrip():
    spec = _sched_spec(avail_decay=0.8)
    d = spec.to_dict()
    again = ExperimentSpec.from_dict(d)
    assert again == spec
    assert not again.scheduler.is_default
    assert again.scheduler.avail_decay == 0.8
    # the default section stays default through a roundtrip (legacy path)
    assert ExperimentSpec.from_dict(_spec().to_dict()).scheduler.is_default


def test_tracked_availability_restricts_rebuild_mask():
    """track_availability wires the tracker into the store-backed sampler:
    after rounds of absence push scores under the threshold, _cluster_mask
    reflects it (and stays None while everyone is healthy)."""
    spec = _sched_spec(avail_threshold=0.25)
    with build_experiment(spec) as srv:
        sam = srv.sampler
        assert sam._avail_tracker is srv.availability
        assert sam._cluster_mask() is None  # cold start: everyone at 1.0
        n = srv.dataset.population.n_clients
        dead = np.zeros(n, bool)
        dead[0] = True  # only client 0 ever shows up
        for _ in range(16):
            srv.availability.update(dead)
        mask = sam._cluster_mask()
        assert mask is not None and mask[0] and not mask[1:].any()
