"""Urn-filling allocator invariants (Appendix C)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.allocation import allocate_by_groups, allocate_by_size, fill_urns_sequential


@given(
    st.lists(st.integers(min_value=1, max_value=500), min_size=4, max_size=40),
    st.integers(min_value=2, max_value=8),
)
@settings(max_examples=50, deadline=None)
def test_allocate_by_size_invariants(ns, m):
    ns = np.array(ns)
    M = int(ns.sum())
    tokens = allocate_by_size(m * ns, n_urns=m, capacity=M)
    # every urn holds exactly M tokens (eq. 7 after normalization)
    assert (tokens.sum(axis=1) == M).all()
    # every client allocated exactly m*n_i tokens (eq. 8)
    assert (tokens.sum(axis=0) == m * ns).all()
    # contiguity: nonzero urns of a client form a contiguous range
    for i in range(len(ns)):
        nz = np.flatnonzero(tokens[:, i])
        assert (np.diff(nz) == 1).all() if len(nz) > 1 else True


def test_sequential_filling_overflow_raises():
    with pytest.raises(ValueError):
        fill_urns_sequential([(0, 11)], n_clients=1, n_urns=2, capacity=5)


def test_group_allocation_seeds_largest_groups():
    ns = np.full(12, 10)
    m = 3
    M = int(ns.sum())  # 120; per-client mass m*n_i = 30 -> <= 4 clients/group
    groups = [np.arange(0, 4), np.arange(4, 8), np.arange(8, 10), np.arange(10, 12)]
    tokens = allocate_by_groups(m * ns, m, M, groups)
    assert (tokens.sum(axis=1) == M).all()
    assert (tokens.sum(axis=0) == m * ns).all()
    # group 0 (a largest group) seeds one urn: its clients share an urn
    urn_of_g0 = np.flatnonzero(tokens[:, 0])
    for i in range(4):
        assert tokens[urn_of_g0, i].sum() > 0


def test_group_over_capacity_rejected():
    ns = np.array([10, 10, 1, 1])
    m = 2
    M = int(ns.sum())
    with pytest.raises(ValueError):
        allocate_by_groups(m * ns, m, M, [np.array([0, 1]), np.array([2]), np.array([3])])
