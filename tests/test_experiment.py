"""The declarative experiment layer: dict↔spec round-trips, registry
semantics, spec-built ≡ hand-built parity, lifecycle safety (no leaked
planner workers), rebuild cadence, and the vectorized plan draw."""
import json
import threading

import numpy as np
import pytest

from repro.core import (
    SAMPLERS,
    Algorithm2Sampler,
    ClientPopulation,
    MDSampler,
    register_sampler,
)
from repro.core.samplers.algorithm1 import Algorithm1Sampler
from repro.core.types import SamplingPlan
from repro.fl import ENGINES, FederatedServer, FLConfig, by_class_shards, register_engine
from repro.fl.aggregation import flatten_params
from repro.fl.experiment import (
    DATASETS,
    DataSpec,
    EngineSpec,
    ExperimentSpec,
    PlannerSpec,
    SamplerSpec,
    TrainSpec,
    build_dataset,
    build_experiment,
    build_sampler,
)
from repro.fl.planner import PlanService
from repro.models.simple import init_mlp
from repro.optim import sgd

DATA = {
    "name": "by_class_shards",
    "options": {
        "n_classes": 4, "clients_per_class": 3, "dim": 8, "noise": 0.8,
        "train_per_client": 40, "test_per_client": 8, "seed": 0,
    },
}
TRAIN = {"n_rounds": 3, "n_local_steps": 4, "batch_size": 16, "hidden": [16], "lr": 0.08, "seed": 0}


def _spec(sampler: dict, planner: "dict | None" = None, **train) -> dict:
    d = {"data": DATA, "sampler": sampler, "train": {**TRAIN, **train}}
    if planner is not None:
        d["planner"] = planner
    return d


@pytest.fixture(scope="module")
def dataset():
    return build_dataset(DataSpec.from_dict(DATA))


# --------------------------------------------------------------------------
# dict / json round-trips
# --------------------------------------------------------------------------
def test_spec_dict_round_trip_identity():
    spec = ExperimentSpec.from_dict(
        _spec({"name": "algorithm2", "m": 4, "options": {"measure": "l2"}},
              planner={"mode": "async", "rebuild_every": 2})
    )
    rt = ExperimentSpec.from_dict(spec.to_dict())
    assert rt == spec
    # and through actual JSON text
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    json.loads(spec.to_json())  # valid JSON


def test_sub_specs_round_trip():
    for cls, d in (
        (DataSpec, DATA),
        (SamplerSpec, {"name": "md", "m": 7, "seed": 3}),
        (PlannerSpec, {"mode": "async", "rebuild_every": 5}),
        (EngineSpec, {"name": "compat", "max_staged_bytes": 123}),
        (TrainSpec, {"n_rounds": 2, "hidden": [8, 8], "n_classes": 4}),
    ):
        spec = cls.from_dict(d)
        assert cls.from_dict(spec.to_dict()) == spec


def test_engine_spec_mesh_tuple_round_trip():
    spec = EngineSpec.from_dict({"mesh_spec": [2, 2]})
    assert spec.mesh_spec == (2, 2)  # JSON list normalizes to the tuple form
    assert spec.to_dict()["mesh_spec"] == [2, 2]
    assert EngineSpec.from_dict(spec.to_dict()) == spec


@pytest.mark.parametrize(
    "cls,d",
    [
        (DataSpec, {"name": "by_class_shards", "optons": {}}),
        (SamplerSpec, {"name": "md", "m": 4, "planner": "sync"}),
        (PlannerSpec, {"mode": "sync", "every": 2}),
        (EngineSpec, {"engine": "batched"}),
        (TrainSpec, {"rounds": 5}),
        (ExperimentSpec, {"data": DATA, "sampler": {"name": "md", "m": 4}, "sweep": []}),
    ],
)
def test_from_dict_unknown_key_is_precise(cls, d):
    with pytest.raises(ValueError, match=rf"{cls.__name__}\.from_dict: unknown key"):
        cls.from_dict(d)


def test_from_dict_missing_required_key_is_precise():
    with pytest.raises(ValueError, match=r"SamplerSpec\.from_dict: missing required key\(s\) \['m'\]"):
        SamplerSpec.from_dict({"name": "md"})
    with pytest.raises(ValueError, match=r"ExperimentSpec\.from_dict: missing required key"):
        ExperimentSpec.from_dict({})


def test_degenerate_plan_row_fails_fast():
    """A NaN-poisoned or zero-mass plan row must raise, not silently draw
    client 0 (the old per-urn rng.choice validated p every call)."""
    pop = ClientPopulation(np.full(3, 10))
    s = MDSampler(pop, 2, seed=0)
    s._plan = SamplingPlan(r=np.array([[0.5, 0.25, 0.25], [0.0, 0.0, 0.0]]))
    with pytest.raises(ValueError, match="plan row 1 is not a probability"):
        s.sample(0)
    s._plan = SamplingPlan(r=np.array([[np.nan, 0.5, 0.5], [1.0, 0.0, 0.0]]))
    with pytest.raises(ValueError, match="plan row 0 is not a probability"):
        s.sample(0)


def test_planner_spec_validates_eagerly():
    with pytest.raises(ValueError, match="unknown planner mode"):
        PlannerSpec(mode="turbo")
    with pytest.raises(ValueError, match="rebuild_every"):
        PlannerSpec(rebuild_every=0)
    with pytest.raises(ValueError, match="sketch_dim"):
        PlannerSpec(sketch_dim=16)  # a dimension with no sketch is a typo
    with pytest.raises(ValueError, match="sketch_dim"):
        PlannerSpec(sketch="srp", sketch_dim=0)


def test_planner_spec_sketch_round_trip():
    spec = PlannerSpec(sketch="srp", sketch_dim=64)
    d = spec.to_dict()
    assert d["sketch"] == "srp" and d["sketch_dim"] == 64
    assert PlannerSpec.from_dict(d) == spec
    assert not spec.is_default  # a sketched planner is never the no-op one
    assert PlannerSpec(sketch="identity").is_default is False


def test_sketch_threads_from_planner_spec_to_store():
    pop = ClientPopulation(np.full(6, 10))
    s = build_sampler(
        {"name": "algorithm2", "m": 2},
        pop,
        planner=PlannerSpec(sketch="srp", sketch_dim=8),
        update_dim=32,
    )
    try:
        st = s.gradient_store
        assert st.sketch.name == "srp"
        assert (st.update_dim, st.dim) == (32, 8)
        assert st.sketch.seed == 0  # rides SamplerSpec.seed (default 0)
    finally:
        s.close()
    seeded = build_sampler(
        {"name": "algorithm2", "m": 2, "options": {"seed": 5}},
        pop,
        planner=PlannerSpec(sketch="srp", sketch_dim=8),
        update_dim=32,
    )
    try:
        assert seeded.gradient_store.sketch.seed == 5
    finally:
        seeded.close()


# --------------------------------------------------------------------------
# registries
# --------------------------------------------------------------------------
def test_unknown_registry_names_list_known():
    pop = ClientPopulation(np.full(4, 10))
    with pytest.raises(ValueError, match=r"unknown sampler 'nope'.*algorithm2"):
        build_sampler({"name": "nope", "m": 2}, pop)
    with pytest.raises(ValueError, match=r"unknown dataset.*by_class_shards"):
        build_dataset({"name": "imaginary"})
    with pytest.raises(ValueError, match=r"unknown engine.*batched"):
        ENGINES.get("turbo")


def test_unknown_registry_names_suggest_close_match():
    """Near-miss names get a did-you-mean suffix across every registry."""
    from repro.core.clustering import CLUSTERERS
    from repro.fl.population import POPULATIONS
    from repro.kernels.sketch import SKETCHERS

    with pytest.raises(ValueError, match=r"did you mean 'algorithm2'\?"):
        SAMPLERS.get("algorithm2x")
    with pytest.raises(ValueError, match=r"did you mean 'ward'\?"):
        CLUSTERERS.get("wardd")
    with pytest.raises(ValueError, match=r"did you mean 'srp'\?"):
        SKETCHERS.get("srpp")
    with pytest.raises(ValueError, match=r"did you mean 'poisson'\?"):
        POPULATIONS.get("poissonn")
    # gibberish far from every entry: the listing stays, no suggestion
    with pytest.raises(ValueError, match=r"unknown sampler") as ei:
        SAMPLERS.get("zzqx")
    assert "did you mean" not in str(ei.value)


def test_sampler_options_checked_against_signature():
    pop = ClientPopulation(np.full(4, 10))
    with pytest.raises(ValueError, match=r"'algorithm2' does not accept option\(s\) \['measur'\]"):
        build_sampler({"name": "algorithm2", "m": 2, "options": {"measur": "l2"}}, pop)


def test_update_dim_required_for_similarity_sampler():
    pop = ClientPopulation(np.full(4, 10))
    with pytest.raises(ValueError, match="needs update_dim"):
        build_sampler({"name": "algorithm2", "m": 2}, pop)


def test_non_default_planner_rejected_for_planless_sampler():
    pop = ClientPopulation(np.full(4, 10))
    with pytest.raises(ValueError, match="has no plan service"):
        build_sampler({"name": "md", "m": 2}, pop, planner=PlannerSpec(mode="async"))
    # the default planner is a no-op and passes through
    s = build_sampler({"name": "md", "m": 2}, pop, planner=PlannerSpec())
    assert isinstance(s, MDSampler)


def test_register_sampler_override_and_unregister():
    class HalfSampler(MDSampler):
        pass

    register_sampler("half-md", HalfSampler)
    try:
        with pytest.raises(ValueError, match="already registered"):
            register_sampler("half-md", MDSampler)
        register_sampler("half-md", HalfSampler, override=True)
        pop = ClientPopulation(np.full(4, 10))
        s = build_sampler({"name": "half-md", "m": 2}, pop)
        assert isinstance(s, HalfSampler)
    finally:
        SAMPLERS.unregister("half-md")
    assert "half-md" not in SAMPLERS


def test_register_engine_reaches_server(dataset):
    calls = []

    def probe_engine(ds, m, config, mesh):
        calls.append((m, config.engine))
        return None  # fall through to the compat loop

    register_engine("probe", probe_engine)
    try:
        spec = ExperimentSpec.from_dict(_spec({"name": "md", "m": 4}, n_rounds=1))
        spec = ExperimentSpec.from_dict({**spec.to_dict(), "engine": {"name": "probe"}})
        with build_experiment(spec, dataset=dataset) as srv:
            hist = srv.run()
        assert calls == [(4, "probe")]
        assert np.isfinite(hist.series("train_loss")).all()
    finally:
        ENGINES.unregister("probe")


# --------------------------------------------------------------------------
# spec-built ≡ hand-built (bit-identical History for fixed seeds)
# --------------------------------------------------------------------------
def _hand_built(dataset, name: str, planner: str) -> FederatedServer:
    pop = dataset.population
    params = init_mlp((8, 16, 4), seed=1)
    d = int(flatten_params(params).shape[0])
    if name == "md":
        sampler = MDSampler(pop, 4, seed=0)
    elif name == "algorithm1":
        sampler = Algorithm1Sampler(pop, 4, seed=0)
    else:
        sampler = Algorithm2Sampler(pop, 4, update_dim=d, seed=0, planner=planner)
    cfg = FLConfig(n_rounds=3, n_local_steps=4, batch_size=16, seed=0)
    return FederatedServer(dataset, sampler, params, sgd(0.08), cfg)


def _run_forced(srv: FederatedServer):
    """Round loop that forces any async rebuild to land between rounds, so
    async runs are deterministic and comparable across servers."""
    for t in range(srv.cfg.n_rounds):
        srv.run_round(t)
        if hasattr(srv.sampler, "flush_plan"):
            srv.sampler.flush_plan()
    return srv.history


@pytest.mark.parametrize(
    "name,planner",
    [("md", "sync"), ("algorithm1", "sync"), ("algorithm2", "sync"), ("algorithm2", "async")],
)
def test_spec_built_matches_hand_built_bit_identical(dataset, name, planner):
    spec = _spec({"name": name, "m": 4}, planner={"mode": planner})
    with build_experiment(spec, dataset=dataset) as a, _hand_built(dataset, name, planner) as b:
        ha, hb = _run_forced(a), _run_forced(b)
        for field in ("train_loss", "test_acc", "n_distinct_clients",
                      "n_distinct_classes", "plan_version", "plan_lag_rounds"):
            np.testing.assert_array_equal(ha.series(field), hb.series(field), err_msg=field)
        np.testing.assert_array_equal(
            np.stack([r.agg_weights for r in ha.records]),
            np.stack([r.agg_weights for r in hb.records]),
        )
        np.testing.assert_array_equal(
            np.asarray(flatten_params(a.params)), np.asarray(flatten_params(b.params))
        )


def test_dict_round_trip_rebuilds_identical_history(dataset):
    """Acceptance: ExperimentSpec.from_dict(spec.to_dict()) rebuilds an
    experiment whose History is bit-identical for fixed seeds."""
    spec = ExperimentSpec.from_dict(_spec({"name": "algorithm2", "m": 4}))
    with build_experiment(spec, dataset=dataset) as a, build_experiment(
        ExperimentSpec.from_dict(spec.to_dict()), dataset=dataset
    ) as b:
        ha, hb = a.run(), b.run()
        np.testing.assert_array_equal(ha.series("train_loss"), hb.series("train_loss"))
        np.testing.assert_array_equal(ha.series("test_acc"), hb.series("test_acc"))


# --------------------------------------------------------------------------
# lifecycle: the context-managed server owns the planner worker
# --------------------------------------------------------------------------
def _planner_threads():
    return [t for t in threading.enumerate() if t.name == "plan-service" and t.is_alive()]


def test_context_manager_reaps_async_planner_worker(dataset):
    assert _planner_threads() == []
    with build_experiment(
        _spec({"name": "algorithm2", "m": 4}, planner={"mode": "async"}), dataset=dataset
    ) as srv:
        srv.run()
        srv.sampler.flush_plan()
        assert len(_planner_threads()) == 1  # worker exists inside the block
    assert _planner_threads() == []  # ...and never survives it
    srv.close()  # idempotent


def test_close_is_idempotent_and_explicit(dataset):
    srv = build_experiment(
        _spec({"name": "algorithm2", "m": 4}, planner={"mode": "async"}, n_rounds=1),
        dataset=dataset,
    )
    srv.run()
    srv.close()
    srv.close()
    assert _planner_threads() == []


# --------------------------------------------------------------------------
# planner rebuild cadence (PlannerSpec.rebuild_every)
# --------------------------------------------------------------------------
def test_plan_service_rebuild_cadence():
    pop = ClientPopulation(np.full(6, 10))
    built = []

    def build(G):
        built.append(G)
        return SamplingPlan(r=np.tile(pop.importances, (2, 1)))

    svc = PlanService(build, mode="sync", rebuild_every=2)
    assert svc.current().version == 0 and len(built) == 1
    svc.observe("a")
    assert svc.poll() is None and len(built) == 1  # skipped observation
    assert svc.telemetry() == (0, 1)  # ...but the lag records it
    svc.observe("b")
    vp = svc.poll()
    assert vp is not None and vp.version == 2 and len(built) == 2
    assert built[-1] == "b"  # the cadence-triggering snapshot is the cumulative one
    svc.observe("c")
    assert svc.poll() is None and svc.telemetry() == (2, 1)
    with pytest.raises(ValueError, match="rebuild_every"):
        PlanService(build, rebuild_every=0)


def test_rebuild_cadence_lands_in_round_telemetry(dataset):
    spec = _spec(
        {"name": "algorithm2", "m": 4},
        planner={"mode": "sync", "rebuild_every": 2},
        n_rounds=4,
    )
    with build_experiment(spec, dataset=dataset) as srv:
        hist = srv.run()
    np.testing.assert_array_equal(hist.series("plan_version"), [0, 0, 2, 2])
    np.testing.assert_array_equal(hist.series("plan_lag_rounds"), [0, 1, 0, 1])


# --------------------------------------------------------------------------
# streaming per-round callback
# --------------------------------------------------------------------------
def test_run_streams_records_through_on_round(dataset):
    seen = []
    with build_experiment(_spec({"name": "md", "m": 4}), dataset=dataset) as srv:
        hist = srv.run(on_round=seen.append)
    assert seen == hist.records


# --------------------------------------------------------------------------
# vectorized plan draw ≡ the per-urn rng.choice loop, bit for bit
# --------------------------------------------------------------------------
def test_vectorized_draw_matches_choice_loop_bitwise():
    pop = ClientPopulation(
        np.concatenate([np.full(10, 100), np.full(20, 500), np.full(10, 1000)])
    )
    s = MDSampler(pop, 12, seed=11)
    drawn = [s.sample(t).clients for t in range(30)]
    rng = np.random.default_rng(11)  # replay the exact uniform stream
    for clients in drawn:
        ref = np.array(
            [rng.choice(pop.n_clients, p=s.plan.r[k]) for k in range(s.plan.m)]
        )
        np.testing.assert_array_equal(clients, ref)


def test_inferred_n_classes_and_update_dim(dataset):
    with build_experiment(_spec({"name": "algorithm2", "m": 4}, n_rounds=1), dataset=dataset) as srv:
        d_model = int(flatten_params(srv.params).shape[0])
        # 8 -> 16 -> 4 MLP: inferred 4 classes, inferred update_dim
        assert srv.params["w1"].shape == (16, 4)
        assert srv.sampler.update_dim == d_model
        srv.run()


def test_load_spec_dict_inline_file_and_errors(tmp_path):
    from repro.fl.experiment import load_spec_dict

    assert load_spec_dict('{"a": 1}') == {"a": 1}
    p = tmp_path / "spec.json"
    p.write_text('{"b": 2}')
    assert load_spec_dict(str(p)) == {"b": 2}
    with pytest.raises(ValueError, match="neither an existing file nor valid JSON"):
        load_spec_dict("definitely-not-json")
    with pytest.raises(ValueError, match="must be an object"):
        load_spec_dict("[1, 2]")


def test_lm_config_sampler_spec_m_guard():
    from repro.launch.fl_train import FLLMConfig

    # a dict may omit m/seed — they inherit the config's
    fl = FLLMConfig(m=4, seed=7, sampler={"name": "md"})
    spec = fl.sampler_spec()
    assert (spec.m, spec.seed) == (4, 7)
    # a contradicting m fails fast with a precise error
    with pytest.raises(ValueError, match="contradicts FLLMConfig.m"):
        FLLMConfig(m=4, sampler={"name": "md", "m": 3}).sampler_spec()


def test_lm_config_resolves_through_spec_path():
    from repro.launch.fl_train import FLLMConfig, make_lm_sampler

    pop = ClientPopulation(np.full(8, 100))
    fl = FLLMConfig(
        m=4, sampler={"name": "algorithm2", "m": 4, "options": {"measure": "l2"}},
        planner={"mode": "async", "rebuild_every": 3},
    )
    s = make_lm_sampler(fl, pop, update_dim=16)
    try:
        assert isinstance(s, Algorithm2Sampler)
        assert s.measure == "l2"
        assert s.plan_service.mode == "async"
        assert s.plan_service.rebuild_every == 3
    finally:
        s.close()
    with pytest.raises(ValueError, match="has no plan service"):
        make_lm_sampler(FLLMConfig(m=4, sampler="md", planner="async"), pop, 0)
