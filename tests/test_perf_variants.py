"""§Perf optimization variants must be numerically equivalent to baselines."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import forward, init_params, loss_fn

B, S = 2, 32
KEY = jax.random.PRNGKey(0)


def _toks(cfg):
    return jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)


@pytest.mark.parametrize("name", ["qwen2-1.5b", "llama3.2-3b"])
def test_blockwise_attention_equivalent(name):
    cfg = get_config(name, reduced=True)
    params = init_params(cfg, KEY)
    toks = _toks(cfg)
    h1, _, _ = forward(cfg, params, toks)
    h2, _, _ = forward(dataclasses.replace(cfg, attn_block_q=8), params, toks)
    assert float(jnp.abs(h1 - h2).max()) < 5e-5


def test_blockwise_mlstm_equivalent():
    cfg = get_config("xlstm-125m", reduced=True)
    params = init_params(cfg, KEY)
    toks = _toks(cfg)
    h1, _, _ = forward(cfg, params, toks)
    h2, _, _ = forward(dataclasses.replace(cfg, attn_block_q=8), params, toks)
    assert float(jnp.abs(h1 - h2).max()) < 5e-5


def test_fused_ce_equivalent():
    cfg = get_config("qwen2-1.5b", reduced=True)
    params = init_params(cfg, KEY)
    toks = _toks(cfg)
    tgts = (toks + 1) % cfg.vocab_size
    l1, _ = loss_fn(cfg, params, toks, tgts)
    l2, _ = loss_fn(dataclasses.replace(cfg, fused_ce=True), params, toks, tgts)
    assert abs(float(l1) - float(l2)) < 1e-5


def test_fused_ce_gradient_equivalent():
    cfg = get_config("qwen2-1.5b", reduced=True)
    params = init_params(cfg, KEY)
    toks = _toks(cfg)
    tgts = (toks + 1) % cfg.vocab_size

    def g(c):
        return jax.grad(lambda p: loss_fn(c, p, toks, tgts)[0])(params)

    g1 = g(cfg)
    g2 = g(dataclasses.replace(cfg, fused_ce=True))
    for a, b in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)):
        assert float(jnp.abs(a - b).max()) < 5e-5


def test_chunkwise_mlstm_equivalent_and_seeds_decode():
    from repro.models import decode_step, init_cache, logits_from_hidden

    cfg = get_config("xlstm-125m", reduced=True)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 64), 0, cfg.vocab_size)
    h1, _, _ = forward(cfg, params, toks)
    cfgc = dataclasses.replace(cfg, mlstm_chunk=16)
    h2, _, _ = forward(cfgc, params, toks)
    assert float(jnp.abs(h1 - h2).max()) < 5e-5
    # chunkwise prefill state must continue exactly into decode
    full_logits = logits_from_hidden(cfg, params, h1)
    _, cache, _ = forward(cfgc, params, toks[:, :48], caches=init_cache(cfgc, B, 64))
    errs = []
    for t in range(48, 64):
        lt, cache = decode_step(cfg, params, toks[:, t : t + 1], cache)
        errs.append(float(jnp.abs(lt - full_logits[:, t]).max()))
    assert max(errs) < 5e-4
