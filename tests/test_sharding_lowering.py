"""Small-mesh lowering tests: the dry-run machinery on 8 fake CPU devices.

The 512-device flag must not leak into the other tests, so these run in a
subprocess with their own XLA_FLAGS.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, dataclasses
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh, batch_axes
from repro.launch.sharding import param_shardings, batch_shardings, cache_shardings
from repro.launch.steps import abstract_params, make_step, default_optimizer, input_specs
from repro.launch.dryrun import build_shardings
from repro.launch import roofline as rl
from repro.models.config import InputShape
from repro.models.sharding_hints import sharding_hints

results = {}
mesh = jax.make_mesh((2, 4), ("data", "model"))
for arch, kind in [("qwen3-0.6b", "train"), ("xlstm-125m", "decode"), ("deepseek-v2-lite-16b", "train")]:
    cfg = get_config(arch, reduced=True)
    cfg = dataclasses.replace(cfg, d_model=256, vocab_size=1024, scan_layers=False)
    shape = InputShape("t", 64, 8, kind)
    opt = default_optimizer()
    step_fn, k2 = make_step(cfg, shape, opt)
    in_sh, out_sh, (state_shape, specs) = build_shardings(cfg, shape, mesh, k2, opt)
    with mesh, sharding_hints(batch_axes(mesh)):
        jitted = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh)
        compiled = jitted.lower(state_shape, specs).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax returns one dict per device
        cost = cost[0] if cost else {}
    colls = rl.parse_collectives(compiled.as_text())
    results[arch] = {
        "flops": cost.get("flops", 0.0),
        "collective_bytes": sum(v["bytes"] for v in colls.values()),
        "mem_args": compiled.memory_analysis().argument_size_in_bytes,
    }

# FL engine: the batched round with the client axis sharded over "data",
# image-shaped clients, lowered from the same launch-layer hooks
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.steps import fl_engine_input_specs, fl_engine_shardings, make_fl_engine_step
from repro.models.simple import classification_loss, init_mlp
from repro.optim import sgd


def image_loss(params, x, y):
    return classification_loss(params, x.reshape(x.shape[0], -1), y)


specs = fl_engine_input_specs(
    n_clients=8, m_slots=4, n_pad=16, feat_shape=(4, 4), n_steps=2, batch_size=8
)
sh = fl_engine_shardings(mesh, specs)
fl_params = init_mlp((16, 32, 10), seed=0)
p_repl = jax.tree_util.tree_map(lambda l: NamedSharding(mesh, P()), fl_params)
p_abs = jax.tree_util.tree_map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), fl_params)
step = make_fl_engine_step(image_loss, sgd(0.1), mesh=mesh)
with mesh:
    compiled = jax.jit(step, in_shardings=(p_repl, sh)).lower(p_abs, specs).compile()
cost = compiled.cost_analysis()
if isinstance(cost, (list, tuple)):
    cost = cost[0] if cost else {}
colls = rl.parse_collectives(compiled.as_text())
results["fl_engine"] = {
    "flops": cost.get("flops", 0.0),
    "collective_bytes": sum(v["bytes"] for v in colls.values()),
    "mem_args": compiled.memory_analysis().argument_size_in_bytes,
}
print(json.dumps(results))
"""


@pytest.fixture(scope="module")
def lowering_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True, env=env, cwd=ROOT,
        timeout=560,
    )
    assert out.returncode == 0, f"lowering subprocess failed:\n{out.stderr[-3000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_reduced_configs_lower_on_2x4_mesh(lowering_results):
    assert set(lowering_results) == {
        "qwen3-0.6b", "xlstm-125m", "deepseek-v2-lite-16b", "fl_engine",
    }
    for arch, rec in lowering_results.items():
        assert rec["flops"] > 0, arch
        assert rec["mem_args"] > 0, arch


def test_fl_engine_lowers_sharded_with_one_collective_round(lowering_results):
    """The batched FL round lowers with the client axis sharded over "data";
    the weighted aggregation forces real cross-client communication."""
    assert lowering_results["fl_engine"]["collective_bytes"] > 0


def test_train_steps_emit_collectives(lowering_results):
    # sharded training must communicate (grad reduction at minimum)
    assert lowering_results["qwen3-0.6b"]["collective_bytes"] > 0
    assert lowering_results["deepseek-v2-lite-16b"]["collective_bytes"] > 0


def test_collective_parser_on_synthetic_hlo():
    from repro.launch.roofline import parse_collectives

    hlo = """
  %all-gather.1 = bf16[8,128]{1,0} all-gather(%x), replica_groups=[2,4]<=[8]
  %ar = (f32[16], f32[4,4]) all-reduce(%a, %b), to_apply=%sum
  %done = f32[8] all-reduce-done(%start)
  %unrelated = f32[4] add(%p, %q)
  %a2a = f32[2,64]{1,0} all-to-all(%y), dimensions={0}
"""
    d = parse_collectives(hlo)
    assert d["all-gather"]["count"] == 1
    assert d["all-gather"]["bytes"] == 8 * 128 * 2
    assert d["all-reduce"]["count"] == 1  # -done must NOT double count
    assert d["all-reduce"]["bytes"] == 16 * 4 + 16 * 4
    assert d["all-to-all"]["bytes"] == 2 * 64 * 4


def test_roofline_terms_and_dominance():
    from repro.launch.roofline import Roofline

    r = Roofline(
        arch="a", shape="s", mesh="16x16", chips=256,
        flops_per_chip=197e12, bytes_per_chip=819e9 / 2, coll_bytes_per_chip=50e9 * 2,
        coll_detail={}, model_flops_global=197e12 * 256 / 2,
    )
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 0.5) < 1e-9
    assert abs(r.t_collective - 2.0) < 1e-9
    assert r.dominant == "collective"
    assert abs(r.utility_ratio - 0.5) < 1e-9
