"""The campaign layer: SweepSpec round-trips and grid expansion, paired
SeedSequence-derived seeds, content-hash cell identity, RunStore resume
semantics (kill + re-invoke ⇒ bit-identical collated CSVs), collation
mean±std against hand-computed references, single-cell ≡ run_spec parity,
process-pool fan-out parity, and History/RoundRecord serialization."""
import json

import numpy as np
import pytest

from repro.fl.experiment import ExperimentSpec, build_experiment
from repro.fl.history import History, RoundRecord
from repro.fl.sweep import (
    SUMMARY_STATS,
    RunStore,
    SweepSpec,
    cell_group_label,
    cell_hash,
    collate,
    run_sweep,
    set_by_path,
    summarize_history,
    write_collated,
)

DATA = {
    "name": "by_class_shards",
    "options": {
        "n_classes": 4, "clients_per_class": 3, "dim": 8, "noise": 0.8,
        "train_per_client": 40, "test_per_client": 8,
    },
}
BASE = {
    "data": DATA,
    "sampler": {"name": "md", "m": 4},
    "train": {"n_rounds": 3, "n_local_steps": 4, "batch_size": 16, "hidden": [16], "lr": 0.08},
}


def _sweep(axes: "dict | None" = None, n_seeds: int = 1, root_seed: int = 7) -> SweepSpec:
    return SweepSpec.from_dict(
        {"base": BASE, "axes": axes or {}, "n_seeds": n_seeds, "root_seed": root_seed}
    )


# --------------------------------------------------------------------------
# spec round-trips + validation
# --------------------------------------------------------------------------
def test_sweep_spec_round_trip_identity():
    sweep = _sweep({"sampler.name": ["md", "algorithm1"]}, n_seeds=3, root_seed=11)
    assert SweepSpec.from_dict(sweep.to_dict()) == sweep
    assert SweepSpec.from_json(sweep.to_json()) == sweep
    json.loads(sweep.to_json())  # valid JSON


def test_sweep_spec_precise_errors():
    with pytest.raises(ValueError, match=r"SweepSpec\.from_dict: unknown key\(s\) \['grid'\]"):
        SweepSpec.from_dict({"base": BASE, "grid": {}})
    with pytest.raises(ValueError, match=r"missing required key\(s\) \['base'\]"):
        SweepSpec.from_dict({"axes": {}})
    with pytest.raises(ValueError, match="non-empty list"):
        _sweep({"sampler.name": []})
    with pytest.raises(ValueError, match="n_seeds"):
        _sweep(n_seeds=0)


def test_set_by_path_rejects_descent_into_scalar():
    d = {"sampler": {"m": 4}}
    with pytest.raises(ValueError, match="cannot descend"):
        set_by_path(d, "sampler.m.deep", 1)


# --------------------------------------------------------------------------
# grid expansion: determinism, ordering, hashes, seeds
# --------------------------------------------------------------------------
def test_grid_expansion_deterministic_and_ordered():
    sweep = _sweep(
        {"train.n_local_steps": [2, 4], "sampler.name": ["md", "algorithm1"]}, n_seeds=2
    )
    a, b = sweep.cells(), sweep.cells()
    assert [c.cell_id for c in a] == [c.cell_id for c in b]  # re-expansion identical
    assert len(a) == 2 * 2 * 2
    # declaration order: first axis outermost, seed axis innermost
    assert [(c.grid_index, c.seed_index) for c in a] == [
        (0, 0), (0, 1), (1, 0), (1, 1), (2, 0), (2, 1), (3, 0), (3, 1)
    ]
    assert a[0].overrides == {"train.n_local_steps": 2, "sampler.name": "md"}
    assert a[2].overrides == {"train.n_local_steps": 2, "sampler.name": "algorithm1"}
    assert a[4].overrides == {"train.n_local_steps": 4, "sampler.name": "md"}


def test_cell_hash_is_content_identity():
    sweep = _sweep({"sampler.name": ["md", "algorithm1"]})
    cells = sweep.cells()
    # the hash is a pure function of the resolved spec
    for c in cells:
        assert c.cell_id == cell_hash(c.spec) == cell_hash(c.spec.to_dict())
    assert len({c.cell_id for c in cells}) == len(cells)
    # a one-knob change changes it; key order does not
    d = cells[0].spec.to_dict()
    reordered = json.loads(json.dumps(d, sort_keys=True))
    assert cell_hash(reordered) == cells[0].cell_id
    d["train"]["lr"] = 0.09
    assert cell_hash(d) != cells[0].cell_id


def test_duplicate_resolved_cells_error():
    with pytest.raises(ValueError, match="identical spec"):
        _sweep({"sampler.name": ["md", "md"]}).cells()


def test_seeds_paired_across_grid_and_distinct_across_replicates():
    sweep = _sweep({"sampler": [{"name": "md", "m": 4}, {"name": "algorithm1", "m": 4}]},
                   n_seeds=2)
    cells = sweep.cells()
    by = {(c.grid_index, c.seed_index): c.spec for c in cells}
    triple = lambda s: (s.data.options["seed"], s.sampler.seed, s.train.seed)
    # same replicate ⇒ same (data, sampler, train) seeds across schemes
    assert triple(by[(0, 0)]) == triple(by[(1, 0)])
    assert triple(by[(0, 1)]) == triple(by[(1, 1)])
    # different replicates ⇒ independent streams (no seed monoculture) —
    # even though the "sampler" axis replaced the whole section dict
    assert triple(by[(0, 0)]) != triple(by[(0, 1)])
    # derivation is a pure function of (root_seed, n_seeds)
    assert sweep.replicate_seeds() == _sweep(n_seeds=2).replicate_seeds()
    assert _sweep(root_seed=8, n_seeds=2).replicate_seeds() != sweep.replicate_seeds()
    # every cell spec carries its replicate's derived value at all three paths
    seeds = sweep.replicate_seeds()
    for c in cells:
        expect = seeds[c.seed_index]
        assert c.spec.data.options["seed"] == expect["data.options.seed"]
        assert c.spec.sampler.seed == expect["sampler.seed"]
        assert c.spec.train.seed == expect["train.seed"]


def test_explicit_seed_axis_wins_over_derivation():
    sweep = _sweep({"data.options.seed": [123, 456]}, n_seeds=1)
    cells = sweep.cells()
    assert [c.spec.data.options["seed"] for c in cells] == [123, 456]
    # the other seed paths still derive
    assert cells[0].spec.train.seed == sweep.replicate_seeds()[0]["train.seed"]


def test_axis_value_dicts_are_not_mutated_by_expansion():
    sampler_axis = [{"name": "md", "m": 4}, {"name": "algorithm1", "m": 4}]
    _sweep({"sampler": sampler_axis}, n_seeds=2).cells()
    assert sampler_axis == [{"name": "md", "m": 4}, {"name": "algorithm1", "m": 4}]


def test_cell_group_label():
    assert cell_group_label({"data.options.alpha": 0.01, "sampler": {"name": "md", "m": 4}}) == (
        "alpha=0.01/sampler=md"
    )


# --------------------------------------------------------------------------
# single-cell sweep ≡ run_spec (bit-identical summary)
# --------------------------------------------------------------------------
def _run_spec(spec: ExperimentSpec) -> dict:
    """benchmarks.common.run_spec's exact code path, repro-side."""
    with build_experiment(spec) as srv:
        hist = srv.run()
    return summarize_history(hist, spec.train.n_rounds)


def test_single_cell_sweep_matches_run_spec(tmp_path):
    sweep = _sweep()
    (cell,) = sweep.cells()
    store = run_sweep(sweep, tmp_path / "store")
    stored = store.read_summary(cell.cell_id)
    direct = _run_spec(cell.spec)
    assert stored == direct  # bit-identical floats, same keys
    # and the persisted per-round records rebuild the identical summary
    hist = store.read_history(cell.cell_id)
    assert summarize_history(hist, cell.spec.train.n_rounds) == direct


# --------------------------------------------------------------------------
# resume: kill after k cells + re-invoke ⇒ bit-identical collated CSVs
# --------------------------------------------------------------------------
def _csv_bytes(store: RunStore) -> tuple[bytes, bytes]:
    cells_csv, summary_csv = write_collated(store)
    return cells_csv.read_bytes(), summary_csv.read_bytes()


def test_interrupted_sweep_resumes_bit_identical(tmp_path):
    sweep = _sweep({"sampler.name": ["md", "algorithm1"]}, n_seeds=2)
    ref = run_sweep(sweep, tmp_path / "uninterrupted")
    ref_bytes = _csv_bytes(ref)

    class Kill(Exception):
        pass

    ran = []

    def killer(cell, status, summary, dt):
        ran.append(cell.cell_id)
        if len(ran) == 2:
            raise Kill()

    with pytest.raises(Kill):
        run_sweep(sweep, tmp_path / "resumed", on_cell=killer)
    store = RunStore(tmp_path / "resumed")
    assert len(store.completed(sweep.cells())) == 2
    # simulate a kill mid-write of the 3rd cell: a partial, torn JSONL line
    # without a summary marker — the rerun must truncate it, not append
    third = sweep.cells()[2]
    assert not store.is_complete(third.cell_id)
    store.records_path(third.cell_id).write_text('{"round": 0, "train_l')
    with pytest.raises(ValueError, match="cells incomplete"):
        collate(store)  # collation refuses a partial campaign

    statuses = []
    run_sweep(sweep, tmp_path / "resumed",
              on_cell=lambda c, s, su, dt: statuses.append(s))
    assert sorted(statuses) == ["ran", "ran", "skipped", "skipped"]
    assert _csv_bytes(store) == ref_bytes


def test_store_rejects_foreign_sweep(tmp_path):
    run_sweep(_sweep(), tmp_path / "store")
    with pytest.raises(ValueError, match="different sweep"):
        run_sweep(_sweep(root_seed=8), tmp_path / "store")


def test_tuple_axis_values_survive_manifest_resume(tmp_path):
    """Python-API tuples JSON-normalize to lists; the resume comparison
    must not read that as a foreign sweep."""
    sweep = _sweep({"train.hidden": [(16,), (8, 8)]})
    store = RunStore(tmp_path / "store")
    store.write_manifest(sweep)
    store.write_manifest(sweep)  # re-invoke: must not raise
    assert [c.cell_id for c in store.read_manifest().cells()] == [
        c.cell_id for c in sweep.cells()
    ]


def test_pinned_base_seed_warns_when_derivation_overwrites():
    pinned = {**BASE, "train": {**BASE["train"], "seed": 5}}
    sweep = SweepSpec.from_dict({"base": pinned, "n_seeds": 1})
    with pytest.warns(UserWarning, match=r"pinned at \['train.seed'\] are overwritten"):
        cells = sweep.cells()
    assert cells[0].spec.train.seed == sweep.replicate_seeds()[0]["train.seed"]
    # pinning via a single-value seed axis is the sanctioned (silent) way
    import warnings as _warnings

    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        axis_cells = SweepSpec.from_dict(
            {"base": BASE, "axes": {"train.seed": [5]}, "n_seeds": 1}
        ).cells()
    assert axis_cells[0].spec.train.seed == 5


def test_store_manifest_preserves_axes_order(tmp_path):
    sweep = _sweep({"train.n_local_steps": [2, 4], "sampler.name": ["md", "algorithm1"]})
    store = RunStore(tmp_path / "store")
    store.write_manifest(sweep)
    rt = store.read_manifest()
    assert list(rt.axes) == ["train.n_local_steps", "sampler.name"]
    assert [c.cell_id for c in rt.cells()] == [c.cell_id for c in sweep.cells()]


# --------------------------------------------------------------------------
# collation: mean±std pinned against hand-computed references
# --------------------------------------------------------------------------
def test_collation_mean_std_hand_computed(tmp_path):
    """Fabricated summaries ⇒ exactly predictable aggregate rows."""
    sweep = _sweep({"sampler.name": ["md", "algorithm1"]}, n_seeds=2)
    store = RunStore(tmp_path / "store")
    store.write_manifest(sweep)
    planted = {(0, 0): 1.0, (0, 1): 2.0, (1, 0): 5.0, (1, 1): 5.0}
    for c in sweep.cells():
        v = planted[(c.grid_index, c.seed_index)]
        store.finalize_cell(c.cell_id, {stat: v for stat in SUMMARY_STATS})
    cell_rows, agg_rows = collate(store)
    assert len(cell_rows) == 4 and len(agg_rows) == 2
    md, a1 = agg_rows
    assert (md["sampler.name"], md["n_seeds"]) == ("md", 2)
    # mean(1, 2) = 1.5, population std = 0.5; mean(5, 5) = 5, std = 0
    assert md["final_loss_mean"] == 1.5 and md["final_loss_std"] == 0.5
    assert a1["final_loss_mean"] == 5.0 and a1["final_loss_std"] == 0.0
    # per-cell rows carry the axis column and the raw stat
    assert [r["final_loss"] for r in cell_rows] == [1.0, 2.0, 5.0, 5.0]
    assert all(r["sampler.name"] in ("md", "algorithm1") for r in cell_rows)


def test_collation_matches_numpy_over_real_runs(tmp_path):
    sweep = _sweep(n_seeds=2)
    store = run_sweep(sweep, tmp_path / "store")
    cell_rows, agg_rows = collate(store)
    losses = np.array([r["final_loss"] for r in cell_rows], dtype=np.float64)
    assert agg_rows[0]["final_loss_mean"] == float(losses.mean())
    assert agg_rows[0]["final_loss_std"] == float(losses.std())


# --------------------------------------------------------------------------
# process-pool fan-out ≡ serial, byte for byte
# --------------------------------------------------------------------------
def test_parallel_workers_match_serial(tmp_path):
    sweep = SweepSpec.from_dict(
        {
            "base": {**BASE, "train": {**BASE["train"], "n_rounds": 2, "n_local_steps": 2}},
            "axes": {"sampler.name": ["md", "algorithm1"]},
            "root_seed": 7,
        }
    )
    serial = run_sweep(sweep, tmp_path / "serial", workers=1)
    pooled = run_sweep(sweep, tmp_path / "pooled", workers=2)
    assert _csv_bytes(pooled) == _csv_bytes(serial)


# --------------------------------------------------------------------------
# History / RoundRecord serialization round-trips (the RunStore contract)
# --------------------------------------------------------------------------
def test_round_record_round_trip():
    rec = RoundRecord(
        round=3, train_loss=0.25, test_acc=0.75, n_distinct_clients=4,
        n_distinct_classes=3, agg_weights=np.array([0.1, 0.0, 0.9]),
        plan_version=2, plan_lag_rounds=1,
    )
    rt = RoundRecord.from_dict(json.loads(json.dumps(rec.to_dict())))
    np.testing.assert_array_equal(rt.agg_weights, rec.agg_weights)  # f64-exact
    assert rt.agg_weights.dtype == np.float64
    rec_no_w = RoundRecord(round=0, train_loss=1.0, test_acc=0.1,
                           n_distinct_clients=1, n_distinct_classes=1)
    assert RoundRecord.from_dict(rec_no_w.to_dict()) == rec_no_w
    with pytest.raises(ValueError, match=r"RoundRecord\.from_dict: unknown key"):
        RoundRecord.from_dict({"round": 0, "loss": 1.0})


def test_history_json_round_trip():
    hist = History()
    for t in range(3):
        hist.append(RoundRecord(round=t, train_loss=1.0 / (t + 1), test_acc=float(t),
                                n_distinct_clients=2, n_distinct_classes=2,
                                agg_weights=np.array([0.5, 0.5]) * (t + 1)))
    rt = History.from_json(hist.to_json())
    assert len(rt.records) == 3
    np.testing.assert_array_equal(rt.series("train_loss"), hist.series("train_loss"))
    for a, b in zip(rt.records, hist.records):
        np.testing.assert_array_equal(a.agg_weights, b.agg_weights)
    # the documented opt-out drops the weights but stays loadable
    slim = History.from_json(hist.to_json(include_agg_weights=False))
    assert all(r.agg_weights is None for r in slim.records)
    with pytest.raises(ValueError, match="expects a JSON list"):
        History.from_json('{"records": []}')
