"""Sampler behaviour: Proposition 1, unbiasedness, draw semantics."""
import numpy as np
import pytest

from repro.core import (
    Algorithm1Sampler,
    Algorithm2Sampler,
    ClientPopulation,
    MDSampler,
    TargetSampler,
    UniformSampler,
    build_plan_algorithm1,
    max_draws_bound,
    validate_plan,
)

BALANCED = ClientPopulation(np.full(100, 500))
UNBALANCED = ClientPopulation(
    np.concatenate(
        [np.full(10, 100), np.full(30, 250), np.full(30, 500), np.full(20, 750), np.full(10, 1000)]
    )
)  # the paper's CIFAR profile


@pytest.mark.parametrize("pop", [BALANCED, UNBALANCED], ids=["balanced", "unbalanced"])
@pytest.mark.parametrize("m", [5, 10, 20])
def test_md_plan_satisfies_proposition1(pop, m):
    validate_plan(MDSampler(pop, m).plan, pop)


@pytest.mark.parametrize("pop", [BALANCED, UNBALANCED], ids=["balanced", "unbalanced"])
@pytest.mark.parametrize("m", [5, 10, 20])
def test_algorithm1_plan_satisfies_proposition1(pop, m):
    validate_plan(Algorithm1Sampler(pop, m).plan, pop)


@pytest.mark.parametrize("m", [5, 10])
def test_algorithm2_plan_satisfies_proposition1(m):
    s = Algorithm2Sampler(UNBALANCED, m, update_dim=16, seed=0)
    validate_plan(s.plan, UNBALANCED)
    # after observing updates it re-clusters and must stay valid
    rng = np.random.default_rng(0)
    ids = np.arange(0, 40)
    s.observe_updates(ids, rng.normal(size=(len(ids), 16)))
    validate_plan(s.plan, UNBALANCED)


def test_algorithm1_max_draws_bound():
    """Section 4: client i appears in at most floor(m p_i) + 2 distributions."""
    for pop in (BALANCED, UNBALANCED):
        m = 10
        plan = build_plan_algorithm1(pop, m)
        bound = np.floor(m * pop.importances) + 2
        assert (max_draws_bound(plan) <= bound).all()


def test_algorithm1_balanced_divisor_is_partition():
    """n=100 balanced, m=10 divides n -> every client in exactly one urn."""
    plan = build_plan_algorithm1(BALANCED, 10)
    assert (max_draws_bound(plan) == 1).all()
    # each urn holds exactly 10 clients at probability 1/10 each
    assert ((plan.r > 0).sum(axis=1) == 10).all()


def test_sampling_weights_sum_to_one():
    for sampler in (
        MDSampler(BALANCED, 10),
        Algorithm1Sampler(BALANCED, 10),
        Algorithm2Sampler(BALANCED, 10, update_dim=4),
    ):
        res = sampler.sample(0)
        assert res.clients.shape == (10,)
        np.testing.assert_allclose(res.agg_weights.sum(), 1.0)
        assert res.stale_weight == 0.0


def test_uniform_sampler_is_biased_with_stale_mass():
    s = UniformSampler(UNBALANCED, 10)
    res = s.sample(0)
    assert len(res.clients) == 10
    assert res.stale_weight > 0  # eq. (3): non-sampled mass stays on θ^t
    np.testing.assert_allclose(res.agg_weights.sum() + res.stale_weight, 1.0)


def test_empirical_unbiasedness():
    """E[ω_i] = p_i (eq. 12) for the unbiased schemes."""
    m, T = 10, 4000
    for cls in (MDSampler, Algorithm1Sampler):
        s = cls(UNBALANCED, m, seed=3)
        ws = np.stack([s.sample(t).agg_weights for t in range(T)])
        np.testing.assert_allclose(
            ws.mean(axis=0), UNBALANCED.importances, atol=4 * np.sqrt(0.25 / m / T) + 2e-3
        )


def test_target_sampler_controlled_setting():
    """Oracle grouping: one client per class-cluster every round."""
    groups = [np.arange(i * 10, (i + 1) * 10) for i in range(10)]
    s = TargetSampler(BALANCED, 10, groups, seed=0)
    validate_plan(s.plan, BALANCED)
    for t in range(20):
        res = s.sample(t)
        # exactly one client from each oracle group
        got = sorted(c // 10 for c in res.clients)
        assert got == list(range(10))


def test_algorithm2_cold_start_zero_gradients():
    """Clients never sampled share a 0 representative gradient and cluster
    together (Section 5) — the plan must still be valid."""
    s = Algorithm2Sampler(UNBALANCED, 10, update_dim=8, seed=1)
    validate_plan(s.plan, UNBALANCED)
    res = s.sample(0)
    assert len(res.unique_clients) >= 1


def test_algorithm2_separates_known_clusters():
    """With clearly clustered updates, same-cluster clients land in the same
    distribution (mirrors Fig. 1's 'converges to target')."""
    pop = ClientPopulation(np.full(20, 100))
    s = Algorithm2Sampler(pop, 4, update_dim=8, seed=0)
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(4, 8)) * 10
    G = np.repeat(centers, 5, axis=0) + 0.01 * rng.normal(size=(20, 8))
    s.observe_updates(np.arange(20), G)
    validate_plan(s.plan, pop)
    cl = s.plan.cluster_of
    for g in range(4):
        members = cl[g * 5 : (g + 1) * 5]
        assert len(np.unique(members)) <= 2  # Ward K>=m cut may split one


def test_large_client_dedicated_distributions():
    """Section 5 final remark: p_i >= 1/m -> floor(m p_i) probability-1 urns."""
    pop = ClientPopulation(np.array([600, 100, 100, 100, 100]))  # p_0 = 0.6
    m = 5  # m p_0 = 3
    s = Algorithm2Sampler(pop, m, update_dim=4, seed=0)
    validate_plan(s.plan, pop)
    assert (s.plan.r[:, 0] == 1.0).sum() == 3


def test_large_client_remainder_joins_pool_exact_rows():
    """Two large clients: each gets floor(m p_i) dedicated urns, the
    remainder mass m p_i - floor(m p_i) competes in the pool, and every row
    of r holds exactly M tokens (sums to exactly 1)."""
    pop = ClientPopulation(np.array([500, 350, 50, 50, 50]))  # M = 1000
    m = 4  # m p = (2.0, 1.4, 0.2, 0.2, 0.2)
    s = Algorithm2Sampler(pop, m, update_dim=4, seed=0)
    plan = s.plan
    validate_plan(plan, pop)
    # client 0: m p_0 = 2 exactly -> 2 dedicated urns, NO pool mass
    assert (plan.r[:, 0] == 1.0).sum() == 2
    assert plan.r_tokens[:, 0].sum() == m * 500
    # client 1: floor(1.4) = 1 dedicated urn + 0.4 M tokens in the pool
    assert (plan.r[:, 1] == 1.0).sum() == 1
    pool_rows = plan.r[:, 1][(plan.r[:, 1] > 0) & (plan.r[:, 1] < 1.0)]
    np.testing.assert_allclose(pool_rows.sum(), 0.4)
    # token-exact eq. (7): every urn holds exactly M tokens
    M = pop.total_samples
    assert (plan.r_tokens.sum(axis=1) == M).all()
    np.testing.assert_allclose(plan.r.sum(axis=1), 1.0, atol=1e-12)
    # the realized draw semantics survive: dedicated urns always fire
    res = s.sample(0)
    assert (res.clients == 0).sum() >= 2
    assert (res.clients == 1).sum() >= 1


def test_cold_start_clients_promoted_jointly():
    """Never-sampled clients share the constant-0 representative gradient:
    after a partial observe, those whose joint mass fits a cluster's cap
    (q_k <= M) must land in ONE cluster together and the rebuilt plan must
    stay token-exact (rows sum to exactly 1)."""
    pop = ClientPopulation(np.full(30, 100))
    m = 5  # per-client mass m*n_i = 500, cap M = 3000 -> <= 6 clients/cluster
    s = Algorithm2Sampler(pop, m, update_dim=8, seed=0)
    rng = np.random.default_rng(0)
    seen = np.arange(0, 25)
    s.observe_updates(seen, rng.normal(size=(len(seen), 8)) * 5)
    plan = s.plan
    validate_plan(plan, pop)
    never = np.arange(25, 30)  # joint mass 2500 <= M: fits one cluster
    clusters = plan.cluster_of[never]
    assert (clusters >= 0).all()
    assert len(np.unique(clusters)) == 1, "cold-start clients split across clusters"
    # no cold-start client is clustered with an already-observed client
    assert not np.isin(plan.cluster_of[seen], clusters).any()
    # the joint cluster is seeded into urns together: the urns carrying
    # cold-start mass are shared across all never-sampled clients
    urns = {frozenset(np.flatnonzero(plan.r_tokens[:, i])) for i in never}
    assert len(urns) <= 2  # contiguity can split the group over a boundary
    M = pop.total_samples
    assert (plan.r_tokens.sum(axis=1) == M).all()
    np.testing.assert_allclose(plan.r.sum(axis=1), 1.0, atol=1e-12)
