"""System-level behaviour: the paper's technique driving LM training
end-to-end (FL round step), the synchronous trainer step, and serving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import Algorithm1Sampler, ClientPopulation, MDSampler
from repro.launch.fl_train import FLLMConfig, fl_input_specs, make_fl_round_step, run_federated_lm
from repro.launch.steps import make_train_step
from repro.models import model as mdl
from repro.optim import adamw


def _tiny_lm():
    cfg = get_config("qwen3-0.6b", reduced=True)
    return dataclasses.replace(cfg, d_model=64, vocab_size=128, n_heads=2, n_kv_heads=2, head_dim=32)


def test_fl_round_step_unbiased_combine():
    """Equal client data + weights 1/m == plain averaging of local models."""
    cfg = _tiny_lm()
    step = make_fl_round_step(cfg, lr=0.1, n_local_steps=2)
    params = mdl.init_params(cfg, jax.random.PRNGKey(0))
    m, n, b, s = 4, 2, 2, 16
    toks = jnp.tile(jnp.arange(s, dtype=jnp.int32)[None, None, None] % cfg.vocab_size, (m, n, b, 1))
    tgts = (toks + 1) % cfg.vocab_size
    w = jnp.full((m,), 1 / m)
    new_params, loss = step(params, toks, tgts, w)
    assert bool(jnp.isfinite(loss))
    # identical clients -> aggregate equals any single client's update
    single, _ = step(params, toks[:1], tgts[:1], jnp.ones((1,)))
    for a, b_ in zip(jax.tree_util.tree_leaves(new_params), jax.tree_util.tree_leaves(single)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b_, np.float32), atol=2e-5)


def test_federated_lm_loss_decreases():
    cfg = _tiny_lm()
    fl = FLLMConfig(n_clients=8, m=4, n_rounds=6, n_local_steps=2, local_batch=2, seq_len=16, lr=0.15)
    pop = ClientPopulation(np.full(fl.n_clients, 100))
    losses = run_federated_lm(cfg, fl, Algorithm1Sampler(pop, fl.m, seed=0))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


def test_federated_lm_md_and_clustered_agree_in_expectation():
    cfg = _tiny_lm()
    fl = FLLMConfig(n_clients=8, m=4, n_rounds=3, n_local_steps=2, local_batch=2, seq_len=16, lr=0.1)
    pop = ClientPopulation(np.full(fl.n_clients, 100))
    l_md = run_federated_lm(cfg, fl, MDSampler(pop, fl.m, seed=1))
    l_c = run_federated_lm(cfg, fl, Algorithm1Sampler(pop, fl.m, seed=1))
    # both unbiased schemes must train; exact values differ by sampling
    assert np.isfinite(l_md).all() and np.isfinite(l_c).all()


def test_fl_input_specs_shapes():
    cfg = _tiny_lm()
    specs = fl_input_specs(cfg, m=16, n_local=4, batch=2, seq=32)
    assert specs["client_tokens"].shape == (16, 4, 2, 32)
    assert specs["weights"].shape == (16,)


def test_train_step_improves_loss_and_increments():
    cfg = _tiny_lm()
    opt = adamw(5e-3)
    step = jax.jit(make_train_step(cfg, opt))
    params = mdl.init_params(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt_state": opt.init(params), "step": jnp.zeros((), jnp.int32)}
    toks = jnp.tile(jnp.arange(16, dtype=jnp.int32)[None] % cfg.vocab_size, (4, 1))
    batch = {"tokens": toks, "targets": (toks + 1) % cfg.vocab_size}
    losses = []
    for _ in range(12):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert int(state["step"]) == 12
    assert losses[-1] < losses[0]


def test_greedy_serving_consistent_with_forward():
    cfg = _tiny_lm()
    params = mdl.init_params(cfg, jax.random.PRNGKey(0))
    b, plen, gen = 2, 8, 4
    prompts = jax.random.randint(jax.random.PRNGKey(2), (b, plen), 0, cfg.vocab_size)
    caches = mdl.init_cache(cfg, b, plen + gen)
    hidden, caches, _ = mdl.forward(cfg, params, prompts, caches=caches)
    tok = jnp.argmax(
        mdl.logits_from_hidden(cfg, params, hidden[:, -1:, :])[:, 0], axis=-1
    )[:, None].astype(jnp.int32)
    toks = [tok]
    for _ in range(gen - 1):
        logits, caches = mdl.decode_step(cfg, params, tok, caches)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        toks.append(tok)
    seq = jnp.concatenate([prompts] + toks, axis=1)
    # teacher-forced re-scoring must reproduce the same greedy choices
    hidden2, _, _ = mdl.forward(cfg, params, seq)
    logits2 = mdl.logits_from_hidden(cfg, params, hidden2)
    for t in range(gen - 1):
        pos = plen - 1 + t
        np.testing.assert_array_equal(
            np.asarray(jnp.argmax(logits2[:, pos], -1)), np.asarray(seq[:, pos + 1])
        )
