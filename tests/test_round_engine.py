"""Batched round engine vs the compat looped path, plus its failure modes."""
import numpy as np
import pytest

from repro.core import Algorithm1Sampler, MDSampler
from repro.core.samplers.base import ClientSampler
from repro.core.types import SampleResult
from repro.fl import (
    BatchedRoundEngine,
    EmptyRoundError,
    FederatedServer,
    FLConfig,
    by_class_shards,
    flatten_params,
)
from repro.models.simple import fedprox_loss, init_mlp
from repro.optim import sgd


@pytest.fixture(scope="module")
def dataset():
    return by_class_shards(dim=16, noise=0.8, train_per_client=60, test_per_client=10, seed=0)


def _server(dataset, sampler, engine, *, rounds=4, mu=0.0, seed=0):
    params = init_mlp((16, 32, 10), seed=1)
    cfg = FLConfig(
        n_rounds=rounds, n_local_steps=8, batch_size=32,
        seed=seed, fedprox_mu=mu, engine=engine,
    )
    kw = {"loss_fn": fedprox_loss} if mu else {}
    return FederatedServer(dataset, sampler, params, sgd(0.08), cfg, **kw)


@pytest.mark.parametrize("mu", [0.0, 0.1], ids=["plain", "fedprox"])
@pytest.mark.parametrize("cls", [MDSampler, Algorithm1Sampler])
def test_batched_matches_compat(dataset, cls, mu):
    """Same sampler + server seed ⇒ identical realized rounds on both
    engines; final params must agree within fp32 tolerance."""
    pop = dataset.population
    runs = {}
    for engine in ("batched", "compat"):
        srv = _server(dataset, cls(pop, 10, seed=7), engine, mu=mu)
        srv.run()
        runs[engine] = srv
    fa = np.asarray(flatten_params(runs["batched"].params))
    fb = np.asarray(flatten_params(runs["compat"].params))
    np.testing.assert_allclose(fa, fb, rtol=1e-4, atol=1e-5)
    la = np.array(runs["batched"].history.series("train_loss"))
    lb = np.array(runs["compat"].history.series("train_loss"))
    np.testing.assert_allclose(la, lb, rtol=1e-4, atol=1e-6)


def test_batched_handles_stale_mass(dataset):
    """Biased (uniform) sampling routes eq. 3's stale mass through the
    engine's on-device aggregation."""
    from repro.core import SAMPLERS

    pop = dataset.population
    a = _server(dataset, SAMPLERS["uniform"](pop, 10, seed=3), "batched", rounds=3)
    b = _server(dataset, SAMPLERS["uniform"](pop, 10, seed=3), "compat", rounds=3)
    a.run(), b.run()
    np.testing.assert_allclose(
        np.asarray(flatten_params(a.params)),
        np.asarray(flatten_params(b.params)),
        rtol=1e-4, atol=1e-5,
    )


class _EmptySampler(ClientSampler):
    """Degenerate sampler: never selects anyone."""

    def sample(self, round_idx):
        del round_idx
        n = self.population.n_clients
        return SampleResult(
            clients=np.array([], dtype=np.int64), agg_weights=np.zeros(n)
        )


@pytest.mark.parametrize("engine", ["batched", "compat"])
def test_zero_distinct_clients_raises_clearly(dataset, engine):
    srv = _server(dataset, _EmptySampler(dataset.population, 10), engine, rounds=1)
    with pytest.raises(EmptyRoundError, match="zero\\s+distinct clients"):
        srv.run_round(0)


def test_engine_rejects_overfull_round(dataset):
    eng = BatchedRoundEngine(dataset, m_slots=2, n_steps=2, batch_size=8)
    params = init_mlp((16, 32, 10), seed=1)
    from repro.models.simple import classification_loss

    with pytest.raises(ValueError, match="3 distinct clients for 2 slots"):
        eng.run_round(
            params, np.arange(3), np.full(3, 1 / 3), 0.0,
            np.random.default_rng(0), classification_loss, sgd(0.1),
        )


def test_engine_pads_heterogeneous_client_sizes():
    """Clients of different sizes stack into one padded block; padded rows
    are never drawn, so results stay finite and aggregation exact."""
    from repro.fl import dirichlet_labels

    ds = dirichlet_labels(alpha=1.0, dim=8, seed=0)
    sizes = {c.n_train for c in ds.clients}
    assert len(sizes) > 1  # the paper's CIFAR profile is genuinely unbalanced
    params = init_mlp((8, 16, 10), seed=1)
    cfg = FLConfig(n_rounds=2, n_local_steps=4, batch_size=16, seed=0, engine="batched")
    server = FederatedServer(ds, MDSampler(ds.population, 8, seed=1), params, sgd(0.05), cfg)
    hist = server.run()
    assert np.isfinite(hist.series("train_loss")).all()


def test_fl_engine_step_lowers_from_specs():
    """The launch-layer specs lower the batched round with zero allocation."""
    import jax

    from repro.launch.steps import fl_engine_input_specs, make_fl_engine_step
    from repro.models.simple import classification_loss

    specs = fl_engine_input_specs(
        n_clients=8, m_slots=4, n_pad=20, feat_shape=16, n_steps=3, batch_size=8
    )
    step = make_fl_engine_step(classification_loss, sgd(0.1))
    params = init_mlp((16, 32, 10), seed=0)
    new_params, updates, losses = jax.eval_shape(step, params, specs)
    d = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
    assert updates.shape == (4, d)
    assert losses.shape == (4,)
    assert jax.tree_util.tree_structure(new_params) == jax.tree_util.tree_structure(params)


def _image_loss(params, x, y, *prox_args):
    """Flatten image-shaped features before the MLP (CIFAR-style clients)."""
    from repro.models.simple import classification_loss, fedprox_loss

    flat = x.reshape(x.shape[0], -1)
    if prox_args:
        return fedprox_loss(params, flat, y, *prox_args)
    return classification_loss(params, flat, y)


def test_fl_engine_step_lowers_image_shaped_clients():
    """Tuple feat_shape: (H, W, C) clients lower through the same hooks."""
    import jax

    from repro.launch.steps import fl_engine_input_specs, make_fl_engine_step

    specs = fl_engine_input_specs(
        n_clients=6, m_slots=4, n_pad=12, feat_shape=(4, 4, 3), n_steps=2, batch_size=6
    )
    assert specs["x_all"].shape == (6, 12, 4, 4, 3)
    step = make_fl_engine_step(_image_loss, sgd(0.1))
    params = init_mlp((48, 24, 10), seed=0)
    new_params, updates, losses = jax.eval_shape(step, params, specs)
    d = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
    assert updates.shape == (4, d)
    assert losses.shape == (4,)
    del new_params


def test_staged_bytes_counts_index_block_and_dtypes():
    """The footprint estimate must match what the engine actually stages:
    native (narrow) dtypes plus the per-round (m, N, B) i32 index block."""
    from repro.data.federated import ClientData, FederatedDataset
    from repro.fl.engine import staged_bytes

    rng = np.random.default_rng(0)
    clients = [
        ClientData(
            x_train=rng.normal(size=(30, 8)).astype(np.float32),
            y_train=rng.integers(0, 10, size=30).astype(np.int8),
            x_test=np.zeros((2, 8), np.float32),
            y_test=np.zeros(2, np.int8),
        )
        for _ in range(4)
    ]
    ds = FederatedDataset(clients)
    # 4 clients x 30 rows x (8 f32 features + 1 int8 label)
    base = 4 * 30 * (8 * 4 + 1)
    assert staged_bytes(ds) == base
    assert staged_bytes(ds, m_slots=3, n_steps=5, batch_size=7) == base + 3 * 5 * 7 * 4

    eng = BatchedRoundEngine(ds, m_slots=3, n_steps=5, batch_size=7)
    assert eng._x_all.nbytes + eng._y_all.nbytes == base
    assert eng._y_all.dtype == np.int8


class _ZeroWeightSampler(ClientSampler):
    """Degenerate sampler: selects clients but gives them zero weight."""

    def sample(self, round_idx):
        del round_idx
        n = self.population.n_clients
        return SampleResult(
            clients=np.arange(3, dtype=np.int64), agg_weights=np.zeros(n)
        )


@pytest.mark.parametrize("engine", ["batched", "compat"])
def test_zero_realized_weight_raises_instead_of_nan_loss(dataset, engine):
    """A round whose realized weights sum to 0 must fail loudly, not log a
    silent NaN train_loss (0/0 in the weighted average)."""
    srv = _server(dataset, _ZeroWeightSampler(dataset.population, 10), engine, rounds=1)
    with pytest.raises(EmptyRoundError, match="sum to zero"):
        srv.run_round(0)
    assert len(srv.history.records) == 0


def test_staging_budget_falls_back_to_compat(dataset):
    """A dataset too big to pin on device degrades to the compat loop with a
    warning instead of OOMing at construction."""
    params = init_mlp((16, 32, 10), seed=1)
    cfg = FLConfig(n_rounds=1, n_local_steps=2, batch_size=8, max_staged_bytes=1)
    with pytest.warns(UserWarning, match="falling back to the compat loop"):
        srv = FederatedServer(dataset, MDSampler(dataset.population, 10), params, sgd(0.1), cfg)
    assert srv._engine is None
    rec = srv.run_round(0)
    assert np.isfinite(rec.train_loss)


def test_staging_budget_fallback_drops_stale_mesh(dataset, monkeypatch):
    """The over-budget fallback must hand the compat factory mesh=None: the
    mesh resolved for the batched engine is dead weight once the fallback
    triggers (it would pin devices for an engine that never shards)."""
    from repro.fl.engine import ENGINES

    seen = {}

    def spy_compat(ds, m, config, mesh):
        seen["mesh"] = mesh
        return None

    monkeypatch.setitem(ENGINES._entries, "compat", spy_compat)
    params = init_mlp((16, 32, 10), seed=1)
    cfg = FLConfig(
        n_rounds=1, n_local_steps=2, batch_size=8,
        max_staged_bytes=1, mesh_spec="auto",
    )
    with pytest.warns(UserWarning, match="falling back to the compat loop"):
        srv = FederatedServer(dataset, MDSampler(dataset.population, 10), params, sgd(0.1), cfg)
    assert seen["mesh"] is None
    assert srv._engine is None


def test_unknown_engine_rejected(dataset):
    params = init_mlp((16, 32, 10), seed=1)
    cfg = FLConfig(n_rounds=1, engine="turbo")
    with pytest.raises(ValueError, match="unknown engine"):
        FederatedServer(dataset, MDSampler(dataset.population, 10), params, sgd(0.1), cfg)
