"""Planner subsystem: PlanService semantics, gradient store scatter,
async-forced-complete ≡ sync determinism, drift-triggered rebuilds, and
server plan telemetry."""
import threading

import numpy as np
import pytest

from repro.core import Algorithm1Sampler, Algorithm2Sampler, ClientPopulation, validate_plan
from repro.core.samplers.algorithm2 import build_plan_algorithm2
from repro.core.types import SamplingPlan
from repro.fl import FederatedServer, FLConfig, by_class_shards, flatten_params
from repro.fl.gradient_store import GradientStore
from repro.fl.planner import AssignmentDriftMonitor, PlanService
from repro.models.simple import init_mlp
from repro.optim import sgd

POP = ClientPopulation(np.full(30, 100))


def _build(G) -> SamplingPlan:
    return build_plan_algorithm2(POP, 5, np.asarray(G), distance_fn=None)


def _zeros():
    return np.zeros((30, 8))


# --------------------------------------------------------------------------
# PlanService
# --------------------------------------------------------------------------
def test_sync_service_rebuilds_inline():
    svc = PlanService(_build, mode="sync", initial_input=_zeros())
    assert svc.current().version == 0
    assert svc.telemetry() == (0, 0)
    rng = np.random.default_rng(0)
    for k in range(1, 4):
        G = _zeros()
        G[:10] = rng.normal(size=(10, 8))
        svc.observe(G)
        vp = svc.poll()
        assert vp is not None and vp.version == k
        assert svc.telemetry() == (k, 0)
    assert svc.poll() is None  # nothing new until the next observation


def test_async_service_latest_wins_and_flush():
    release = threading.Event()
    built = []

    def slow_build(G):
        if G is None:  # the inline initial build is not gated
            return _build(_zeros())
        release.wait(5.0)
        built.append(np.asarray(G).sum())
        return _build(G)

    svc = PlanService(slow_build, mode="async", initial_input=None)
    for k in range(1, 4):  # three rapid observations, worker gated shut
        G = _zeros()
        G[0] = k
        svc.observe(G)
    assert svc.poll() is None  # nothing completed yet — previous plan stays
    assert svc.telemetry()[1] >= 1  # lag visible while the rebuild is pending
    release.set()
    svc.flush()
    vp = svc.poll()
    assert vp is not None and vp.version == 3  # latest snapshot won
    # intermediate snapshots were dropped, not queued: at most the one the
    # worker had already picked up plus the final one were ever built
    assert len(built) <= 2
    assert svc.telemetry() == (3, 0)
    svc.close()


def test_async_worker_error_surfaces_and_recovers():
    calls = []

    def boom(G):
        if G is None:
            return _build(_zeros())
        calls.append(1)
        if len(calls) == 1:
            raise RuntimeError("ward exploded")
        return _build(G)

    svc = PlanService(boom, mode="async", initial_input=None)
    svc.observe(_zeros())
    with pytest.raises(RuntimeError, match="plan rebuild failed"):
        svc.flush()
        svc.poll()  # whichever of the two sees the error first must raise it
    # the failure is consumed; the previous plan stays active and the worker
    # survives to build later snapshots
    assert svc.current().version == 0
    svc.observe(_zeros())
    svc.flush()
    vp = svc.poll()
    assert vp is not None and vp.version == 2
    svc.close()


def test_pending_snapshot_survives_worker_error():
    """A snapshot enqueued while a failing build is in flight must still be
    built after the error — the worker keeps draining, flush cannot hang."""
    started, gate = threading.Event(), threading.Event()

    def build(G):
        if G is None:
            return _build(_zeros())
        if np.asarray(G)[0, 0] == 1.0:  # snapshot A: fail, but only after B queued
            started.set()
            gate.wait(5.0)
            raise RuntimeError("A failed")
        return _build(_zeros())

    svc = PlanService(build, mode="async", initial_input=None)
    A = _zeros()
    A[0, 0] = 1.0
    svc.observe(A)
    assert started.wait(5.0)  # worker is inside A's build
    svc.observe(_zeros())  # B becomes pending behind the doomed build
    gate.set()
    with pytest.raises(RuntimeError, match="plan rebuild failed"):
        svc.flush()
        svc.poll()
    svc.flush(timeout=5.0)  # B's rebuild still lands — no orphaned snapshot
    vp = svc.poll()
    assert vp is not None and vp.version == 2
    svc.close()


def test_unknown_planner_mode_rejected():
    with pytest.raises(ValueError, match="unknown planner mode"):
        PlanService(_build, mode="turbo", initial_input=_zeros())
    with pytest.raises(ValueError, match="unknown planner mode"):
        Algorithm2Sampler(POP, 5, update_dim=8, planner="turbo")


# --------------------------------------------------------------------------
# GradientStore
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["jax", "numpy"])
def test_gradient_store_scatter_and_decay(backend):
    store = GradientStore(6, 4, staleness_decay=0.5, backend=backend)
    u1 = np.arange(8, dtype=np.float32).reshape(2, 4)
    store.update(np.array([1, 3]), u1)
    G = store.asnumpy()
    np.testing.assert_allclose(G[[1, 3]], u1)
    np.testing.assert_allclose(G[[0, 2, 4, 5]], 0.0)
    # second round: survivors decay, observed rows are overwritten
    store.update(np.array([3]), np.full((1, 4), 7.0, np.float32))
    G = store.asnumpy()
    np.testing.assert_allclose(G[1], 0.5 * u1[0])
    np.testing.assert_allclose(G[3], 7.0)


@pytest.mark.parametrize("backend", ["jax", "numpy"])
def test_gradient_store_drops_out_of_range_slots(backend):
    """Padded slot blocks mark unused rows with id >= n — dropped, so client
    0's gradient is never clobbered by padding."""
    store = GradientStore(4, 3, backend=backend)
    store.update(np.array([0]), np.ones((1, 3), np.float32))
    store.update(
        np.array([2, 4, 4]),  # one real row + two padded sentinels
        np.stack([np.full(3, 5.0), np.full(3, 9.0), np.full(3, 9.0)]).astype(np.float32),
    )
    G = store.asnumpy()
    np.testing.assert_allclose(G[0], 1.0)
    np.testing.assert_allclose(G[2], 5.0)
    assert not np.isin(9.0, G)


def test_gradient_store_accepts_device_updates():
    jnp = pytest.importorskip("jax.numpy")
    store = GradientStore(5, 4)
    store.update(np.array([2]), jnp.full((1, 4), 3.0, jnp.float32))
    np.testing.assert_allclose(store.asnumpy()[2], 3.0)
    # snapshot is immutable under further updates (async worker safety)
    snap = store.snapshot()
    store.update(np.array([2]), jnp.zeros((1, 4), jnp.float32))
    np.testing.assert_allclose(np.asarray(snap)[2], 3.0)


def test_gradient_store_shape_mismatch():
    store = GradientStore(4, 3)
    with pytest.raises(ValueError, match="updates shape"):
        store.update(np.array([0]), np.ones((1, 5), np.float32))
    with pytest.raises(ValueError, match="ids for"):
        store.update(np.array([0, 1]), np.ones((1, 3), np.float32))


# --------------------------------------------------------------------------
# async-forced-complete ≡ sync
# --------------------------------------------------------------------------
@pytest.mark.parametrize("distance_fn", ["numpy", "pallas-interpret"])
def test_async_forced_complete_matches_sync_plans(distance_fn):
    """Flushing the async worker after every observation must reproduce the
    sync planner's plans (identical f32 store, identical backend)."""
    kw = dict(update_dim=8, seed=0, distance_fn=distance_fn)
    s_sync = Algorithm2Sampler(POP, 5, planner="sync", **kw)
    s_async = Algorithm2Sampler(POP, 5, planner="async", **kw)
    rng = np.random.default_rng(2)
    for _ in range(4):
        ids = rng.choice(POP.n_clients, size=6, replace=False)
        upd = rng.normal(size=(6, 8))
        s_sync.observe_updates(ids, upd)
        s_async.observe_updates(ids, upd)
        s_async.flush_plan()
        np.testing.assert_allclose(s_async.plan.r, s_sync.plan.r, atol=1e-6)
        assert s_async.plan_telemetry() == s_sync.plan_telemetry()
        validate_plan(s_async.plan, POP)
    s_async.close()


@pytest.fixture(scope="module")
def dataset():
    return by_class_shards(dim=16, noise=0.8, train_per_client=60, test_per_client=10, seed=0)


class _ForcedAsyncSampler(Algorithm2Sampler):
    """Async planner with every rebuild forced to land before the next draw."""

    def observe_updates(self, client_ids, updates):
        super().observe_updates(client_ids, updates)
        self.flush_plan()


def _run_server(dataset, sampler, rounds=5):
    params = init_mlp((16, 32, 10), seed=1)
    cfg = FLConfig(n_rounds=rounds, n_local_steps=8, batch_size=32, seed=0)
    srv = FederatedServer(dataset, sampler, params, sgd(0.08), cfg)
    srv.run()
    return srv


def test_async_forced_complete_matches_sync_training(dataset):
    """End-to-end: async-forced-complete ≡ sync to fp32 tolerance — same
    plans ⇒ same draws ⇒ same realized rounds ⇒ same final model."""
    pop = dataset.population
    params = init_mlp((16, 32, 10), seed=1)
    d = int(flatten_params(params).shape[0])
    a = _run_server(dataset, Algorithm2Sampler(pop, 10, update_dim=d, seed=0, planner="sync"))
    b = _run_server(dataset, _ForcedAsyncSampler(pop, 10, update_dim=d, seed=0, planner="async"))
    np.testing.assert_allclose(
        np.asarray(flatten_params(a.params)),
        np.asarray(flatten_params(b.params)),
        rtol=1e-4, atol=1e-5,
    )
    np.testing.assert_allclose(
        a.history.series("train_loss"), b.history.series("train_loss"),
        rtol=1e-4, atol=1e-6,
    )
    # forced-complete async is never stale
    assert (b.history.series("plan_lag_rounds") == 0).all()
    b.sampler.close()


# --------------------------------------------------------------------------
# server telemetry + free-running async
# --------------------------------------------------------------------------
def test_server_records_plan_telemetry_sync(dataset):
    pop = dataset.population
    params = init_mlp((16, 32, 10), seed=1)
    d = int(flatten_params(params).shape[0])
    srv = _run_server(dataset, Algorithm2Sampler(pop, 10, update_dim=d, seed=0), rounds=4)
    assert (srv.history.series("plan_lag_rounds") == 0).all()
    # round t draws from the plan rebuilt after round t-1's observation
    np.testing.assert_array_equal(srv.history.series("plan_version"), np.arange(4))


def test_server_records_plan_telemetry_static_sampler(dataset):
    s = Algorithm1Sampler(dataset.population, 10, seed=0)
    # Algorithm 1 runs through the same PlanService contract as Algorithm 2:
    # its static plan is the service's version-0 cold-start plan
    assert s.plan_service.current().plan is s.plan
    assert s.plan_service.mode == "sync"
    srv = _run_server(dataset, s, rounds=2)
    assert (srv.history.series("plan_version") == 0).all()
    assert (srv.history.series("plan_lag_rounds") == 0).all()
    s.close()


# --------------------------------------------------------------------------
# drift-triggered rebuilds
# --------------------------------------------------------------------------
_DRIFT_LABELS = np.array([0] * 10 + [1] * 10)


def _two_cluster_G(flip: int = 0) -> np.ndarray:
    """20 rows in two well-separated clusters; the first ``flip`` rows of
    cluster 0 are moved onto cluster 1's center (assignment churn = flip/20)."""
    G = np.zeros((20, 4), np.float32)
    G[:10, 0] = 5.0
    G[10:, 1] = 5.0
    if flip:
        G[:flip, 0] = 0.0
        G[:flip, 1] = 5.0
    return G


def _label_plan(G) -> SamplingPlan:
    del G
    return SamplingPlan(r=np.full((4, 20), 0.05), cluster_of=_DRIFT_LABELS)


def test_drift_monitor_zero_on_identical_assignments():
    mon = AssignmentDriftMonitor()
    mon.rebaseline(_two_cluster_G(), _label_plan(None))
    assert mon.drift(_two_cluster_G()) == 0.0


def test_drift_monitor_monotone_under_label_churn():
    mon = AssignmentDriftMonitor()
    mon.rebaseline(_two_cluster_G(), _label_plan(None))
    drifts = [mon.drift(_two_cluster_G(flip=k)) for k in (0, 2, 5, 10)]
    assert drifts == [0.0, 0.1, 0.25, 0.5]
    assert all(a < b for a, b in zip(drifts, drifts[1:]))


def test_drift_monitor_unmeasurable_plan_reports_inf():
    mon = AssignmentDriftMonitor()
    assert mon.drift(_two_cluster_G()) == float("inf")  # never baselined
    mon.rebaseline(_two_cluster_G(), SamplingPlan(r=np.full((4, 20), 0.05)))
    assert mon.drift(_two_cluster_G()) == float("inf")  # no cluster structure


def test_drift_trigger_fires_iff_threshold_crossed():
    svc = PlanService(
        _label_plan, drift_threshold=0.25, initial_input=_two_cluster_G()
    )
    svc.observe(_two_cluster_G(flip=2))  # drift 0.1 < 0.25: no rebuild
    assert svc.poll() is None
    assert svc.last_drift() == 0.1
    assert svc.rebuilds_done() == 0
    assert svc.telemetry() == (0, 1)  # observation counted, plan unchanged
    svc.observe(_two_cluster_G(flip=5))  # drift 0.25 >= 0.25: rebuild fires
    vp = svc.poll()
    assert vp is not None and vp.version == 2
    assert svc.last_drift() == 0.25
    assert svc.rebuilds_done() == 1
    # rebaselined at the rebuild: the same snapshot now measures zero churn
    svc.observe(_two_cluster_G(flip=5))
    assert svc.poll() is None and svc.last_drift() == 0.0
    assert svc.rebuilds_done() == 1


def test_drift_threshold_excludes_fixed_cadence():
    with pytest.raises(ValueError, match="alternative rebuild schedules"):
        PlanService(
            _label_plan,
            drift_threshold=0.1,
            rebuild_every=2,
            initial_input=_two_cluster_G(),
        )
    with pytest.raises(ValueError, match="drift_threshold must be >= 0"):
        PlanService(_label_plan, drift_threshold=-0.5, initial_input=_two_cluster_G())


def test_fixed_cadence_rebuild_every_remains_default(dataset):
    """rebuild_every cadence is untouched by the drift machinery: every k-th
    observation rebuilds, the rest only advance the counter (PR 4's pin)."""
    pop = dataset.population
    params = init_mlp((16, 32, 10), seed=1)
    d = int(flatten_params(params).shape[0])
    s = Algorithm2Sampler(pop, 10, update_dim=d, seed=0, rebuild_every=2)
    srv = _run_server(dataset, s, rounds=4)
    np.testing.assert_array_equal(
        srv.history.series("plan_version"), np.array([0, 0, 2, 2])
    )
    assert (srv.history.series("plan_drift") == -1.0).all()  # trigger off


def test_drift_zero_threshold_matches_fixed_cadence_training(dataset):
    """Acceptance: drift-triggered mode on a static population does <= the
    rebuilds of the equivalent fixed cadence while matching its accuracy.
    threshold=0.0 fires on every observation (drift >= 0 always), so the
    rebuild schedule — and therefore the whole training trajectory — is
    identical to rebuild_every=1."""
    pop = dataset.population
    params = init_mlp((16, 32, 10), seed=1)
    d = int(flatten_params(params).shape[0])
    a = Algorithm2Sampler(pop, 10, update_dim=d, seed=0)  # fixed cadence 1
    b = Algorithm2Sampler(pop, 10, update_dim=d, seed=0, drift_threshold=0.0)
    srv_a = _run_server(dataset, a)
    srv_b = _run_server(dataset, b)
    assert b.plan_service.rebuilds_done() <= a.plan_service.rebuilds_done()
    np.testing.assert_array_equal(
        srv_a.history.series("plan_version"), srv_b.history.series("plan_version")
    )
    np.testing.assert_allclose(
        srv_a.history.series("train_loss"),
        srv_b.history.series("train_loss"),
        rtol=1e-4, atol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(flatten_params(srv_a.params)),
        np.asarray(flatten_params(srv_b.params)),
        rtol=1e-4, atol=1e-5,
    )
    # the drift statistic rode along in telemetry
    assert (srv_b.history.series("plan_drift") >= 0.0).all()
    assert (srv_a.history.series("plan_drift") == -1.0).all()


def test_drift_high_threshold_skips_rebuilds(dataset):
    """A threshold no realizable churn reaches keeps the cold-start plan —
    strictly fewer rebuilds than any fixed cadence."""
    pop = dataset.population
    params = init_mlp((16, 32, 10), seed=1)
    d = int(flatten_params(params).shape[0])
    s = Algorithm2Sampler(pop, 10, update_dim=d, seed=0, drift_threshold=1.5)
    srv = _run_server(dataset, s, rounds=4)
    assert s.plan_service.rebuilds_done() == 0
    assert (srv.history.series("plan_version") == 0).all()
    assert np.isfinite(srv.history.series("train_loss")).all()


def test_server_records_plan_cost_telemetry(dataset):
    pop = dataset.population
    params = init_mlp((16, 32, 10), seed=1)
    d = int(flatten_params(params).shape[0])
    srv = _run_server(
        dataset, Algorithm2Sampler(pop, 10, update_dim=d, seed=0), rounds=3
    )
    assert (srv.history.series("plan_build_ms") > 0).all()
    assert (srv.history.series("plan_drift") == -1.0).all()


# --------------------------------------------------------------------------
# device-resident rebuild path
# --------------------------------------------------------------------------
def test_algorithm2_fused_device_rebuild_no_padded_block(monkeypatch):
    """The fused algorithm2 path never materializes a padded (n, d) block or
    runs the host d-chunk loop: G reaches the fused kernel as the store's
    exact device array, exactly once per rebuild, and the one-shot (padding)
    kernel is never invoked."""
    jax = pytest.importorskip("jax")
    from repro.kernels.similarity import ops

    calls = []
    real_fused = ops.pairwise_kernel_fused

    def spy(G, **kw):
        calls.append((isinstance(G, jax.Array), tuple(G.shape)))
        return real_fused(G, **kw)

    def trap(*a, **kw):
        raise AssertionError("padded one-shot kernel ran on the fused path")

    monkeypatch.setattr(ops, "pairwise_kernel_fused", spy)
    monkeypatch.setattr(ops, "pairwise_kernel", trap)
    monkeypatch.setattr(
        ops, "pairwise_distances_chunked", lambda *a, **kw: trap()
    )

    s = Algorithm2Sampler(
        POP, 5, update_dim=8, seed=0, distance_fn="streamed", clusterer="ward_jit"
    )
    rng = np.random.default_rng(0)
    ids = rng.choice(POP.n_clients, size=6, replace=False)
    s.observe_updates(ids, rng.normal(size=(6, 8)).astype(np.float32))
    # initial build + one observed rebuild, each exactly one fused launch
    assert len(calls) == 2
    for on_device, shape in calls:
        assert on_device  # G stayed device-resident end-to-end
        assert shape == (POP.n_clients, 8)  # exact ragged shape — no padding
    validate_plan(s.plan, POP)


def test_free_running_async_server_stays_valid(dataset):
    """Un-forced async: every adopted plan is Proposition-1 valid, versions
    are monotone, and lag stays within the observed horizon."""
    pop = dataset.population
    params = init_mlp((16, 32, 10), seed=1)
    d = int(flatten_params(params).shape[0])
    s = Algorithm2Sampler(pop, 10, update_dim=d, seed=0, planner="async")
    srv = _run_server(dataset, s, rounds=6)
    validate_plan(s.plan, pop)
    vers = srv.history.series("plan_version")
    lags = srv.history.series("plan_lag_rounds")
    assert (np.diff(vers) >= 0).all()
    assert (lags >= 0).all() and (vers + lags == np.arange(6)).all()
    assert np.isfinite(srv.history.series("train_loss")).all()
    s.close()
