"""Mesh-sharded batched round engine vs the single-device path.

The ``--xla_force_host_platform_device_count`` flag must be set before jax
initializes and must not leak into the other tests, so the actual runs
happen in a subprocess (same pattern as test_sharding_lowering).
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax

from repro.core import MDSampler
from repro.fl import FLConfig, FederatedServer, by_class_shards, flatten_params
from repro.models.simple import init_mlp
from repro.optim import sgd

ds = by_class_shards(dim=16, noise=0.8, train_per_client=60, test_per_client=10, seed=0)


def run(mesh_spec, seed=7):
    params = init_mlp((16, 32, 10), seed=1)
    cfg = FLConfig(
        n_rounds=3, n_local_steps=6, batch_size=32, seed=0,
        engine="batched", mesh_spec=mesh_spec,
    )
    srv = FederatedServer(
        ds, MDSampler(ds.population, 8, seed=seed), params, sgd(0.08), cfg
    )
    srv.run()
    return (
        np.asarray(flatten_params(srv.params)),
        srv.history.series("train_loss"),
        srv._engine.per_device_staged_bytes(),
    )


p1, l1, b1 = run(None)
p4, l4, b4 = run("4x1")
pa, la, ba = run("auto")

# the pod-scale LM round driver on the same host mesh: client axis sharded,
# params replicated over "data" (launch.fl_train's cross-silo layout)
import dataclasses
from repro.configs import get_config
from repro.core import Algorithm1Sampler, ClientPopulation
from repro.launch.fl_train import FLLMConfig, run_federated_lm
from repro.launch.mesh import make_host_mesh

lm = dataclasses.replace(
    get_config("qwen3-0.6b", reduced=True),
    d_model=64, vocab_size=128, n_heads=2, n_kv_heads=2, head_dim=32,
)
flc = FLLMConfig(
    n_clients=8, m=4, n_rounds=2, n_local_steps=2, local_batch=2, seq_len=16, lr=0.1
)
pop = ClientPopulation(np.full(flc.n_clients, 100))
lm_losses = run_federated_lm(
    lm, flc, Algorithm1Sampler(pop, flc.m, seed=0), mesh=make_host_mesh(4, 1)
)
try:  # m not a multiple of the data-parallel degree must fail fast
    run_federated_lm(
        lm, dataclasses.replace(flc, m=2),
        Algorithm1Sampler(pop, 2, seed=0), mesh=make_host_mesh(4, 1),
    )
    m_guard = False
except ValueError:
    m_guard = True

from repro.fl.engine import staged_bytes
from repro.launch.mesh import resolve_fl_mesh

est1 = staged_bytes(ds, 8, 6, 32)
est4 = staged_bytes(ds, 8, 6, 32, mesh=resolve_fl_mesh("4x1"))

print(json.dumps({
    "devices": jax.device_count(),
    "max_abs_params": float(np.max(np.abs(p1 - p4))),
    "scale": float(np.max(np.abs(p1))),
    "max_abs_loss": float(np.max(np.abs(l1 - l4))),
    "auto_matches": bool(np.allclose(p4, pa)),
    "bytes_unsharded": int(b1),
    "bytes_4x1": int(b4),
    "est_unsharded": int(est1),
    "est_4x1": int(est4),
    "lm_losses_finite": bool(np.isfinite(np.asarray(lm_losses)).all()),
    "lm_m_guard": m_guard,
}))
"""


@pytest.fixture(scope="module")
def sharded_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True, env=env,
        cwd=ROOT, timeout=560,
    )
    assert out.returncode == 0, f"sharded-engine subprocess failed:\n{out.stderr[-3000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_round_matches_single_device_to_fp32_tol(sharded_results):
    r = sharded_results
    assert r["devices"] == 4
    # same realized rounds, reduction order differs across devices -> fp32 tol
    assert r["max_abs_params"] <= 1e-5 + 1e-4 * r["scale"]
    assert r["max_abs_loss"] <= 1e-4


def test_auto_mesh_spec_uses_all_local_devices(sharded_results):
    assert sharded_results["auto_matches"]


def test_client_sharded_staging_shrinks_per_device_bytes(sharded_results):
    r = sharded_results
    # 100 clients over 4 data-parallel groups: each device pins 1/4 of the set
    assert r["bytes_4x1"] * 4 == r["bytes_unsharded"]
    # the planning estimate (staged_bytes) agrees with the mesh it plans for
    assert r["est_4x1"] * 4 == r["est_unsharded"]


def test_federated_lm_driver_runs_on_host_mesh(sharded_results):
    """launch.fl_train's driver trains with the client axis sharded, and
    rejects an m the data-parallel degree does not divide."""
    assert sharded_results["lm_losses_finite"]
    assert sharded_results["lm_m_guard"]
