"""Section 3.2 / Appendix B statistics table: aggregation-weight variance and
inclusion probability, theory vs Monte-Carlo, MD vs Algorithms 1/2.

Quantifies the paper's two theorems (eq. 17 variance reduction, eq. 23
inclusion-probability improvement) on the unbalanced CIFAR profile, plus
the max-draws bound (floor(m p_i) + 2) and the Section-6 distinct-clients
statistic (~63% for MD in the controlled setting, 100% for clustered)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core import ClientPopulation, max_draws_bound
from repro.core.statistics import (
    clustered_inclusion_probability,
    clustered_weight_variance,
    md_inclusion_probability,
    md_prob_all_distinct,
    md_weight_variance,
)

PROFILE = np.concatenate(
    [np.full(10, 100), np.full(30, 250), np.full(30, 500), np.full(20, 750), np.full(10, 1000)]
)


def main() -> None:
    from repro.fl.experiment import build_sampler

    pop = ClientPopulation(PROFILE)
    m, T = 10, 3000
    p = pop.importances

    samplers = {
        name: build_sampler({"name": name, "m": m, "seed": 0}, pop, update_dim=16)
        for name in ("md", "algorithm1", "algorithm2")
    }
    v_md_theory = md_weight_variance(p, m)
    q_md_theory = md_inclusion_probability(p, m)

    for name, s in samplers.items():
        us, _ = timed(lambda: s.sample(0), repeats=50)
        ws = np.stack([s.sample(t).agg_weights for t in range(T)])
        emp_var = ws.var(axis=0).mean()
        emp_inc = (ws > 0).mean(axis=0).mean()
        if name == "md":
            th_var, th_inc = v_md_theory.mean(), q_md_theory.mean()
        else:
            th_var = clustered_weight_variance(s.plan).mean()
            th_inc = clustered_inclusion_probability(s.plan).mean()
        emit(
            f"variance_table/{name}",
            us,
            f"var_mc={emp_var:.3e};var_theory={th_var:.3e};"
            f"incl_mc={emp_inc:.4f};incl_theory={th_inc:.4f};"
            f"var_vs_md={th_var / v_md_theory.mean():.3f}",
        )

    # max draws bound
    for name in ("algorithm1", "algorithm2"):
        s = samplers[name]
        bound = np.floor(m * p) + 2
        emit(
            f"variance_table/{name}_max_draws",
            0.0,
            f"max_support={int(max_draws_bound(s.plan).max())};bound={int(bound.max())}",
        )

    # distinct-clients statistic in the controlled balanced setting
    bal = ClientPopulation(np.full(100, 500))
    emit(
        "variance_table/md_prob_all_distinct",
        0.0,
        f"theory={md_prob_all_distinct(np.full(100, 0.01), m):.4f};paper=0.63",
    )
    s1 = build_sampler({"name": "algorithm1", "m": m, "seed": 0}, bal)
    distinct = np.mean([len(s1.sample(t).unique_clients) == m for t in range(500)])
    emit("variance_table/algorithm1_all_distinct_balanced", 0.0, f"mc={distinct:.3f};paper=1.0")
    for s in (*samplers.values(), s1):
        s.close()


if __name__ == "__main__":
    main()
