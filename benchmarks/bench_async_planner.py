"""Async re-clustering planner: round wall-time with Algorithm 2's rebuild
on vs off the critical path, and streamed-similarity peak memory vs ``d``.

Section 1 — planner overlap: the same FL run (batched engine, Algorithm 2
sampler) with ``planner="sync"`` pays the O(n²d) distances + O(n³) Ward +
urn filling *inside* every round; ``planner="async"`` hands the rebuild to
a background worker and the round only pays a device scatter + snapshot.
The acceptance target is a lower mean round wall-time for async at
n >= 200 clients on CPU; per-round plan staleness is reported as the mean
``plan_lag_rounds`` (0 for sync by construction).

Section 2 — streamed similarity: the one-shot kernel pads the full (n, d)
block to tile multiples before launching; ``pairwise_distances_streamed``
pads one (n, d_chunk) slab at a time, so the padded peak stops growing
with ``d``. Reported: the padded-slab peak bytes of each path (exact, from
the kernel's block arithmetic) and wall time.

Usage (module form — `benchmarks` is a package):
  PYTHONPATH=src python -m benchmarks.bench_async_planner [--smoke]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit


def _random_clients(n_clients: int, dim: int, per_client: int):
    from repro.data.federated import ClientData, FederatedDataset

    rng = np.random.default_rng(0)
    clients = []
    for _ in range(n_clients):
        x = rng.normal(size=(per_client, dim)).astype(np.float32)
        y = rng.integers(0, 10, size=per_client)
        clients.append(ClientData(x_train=x, y_train=y, x_test=x[:8], y_test=y[:8]))
    return FederatedDataset(clients)


def _register_dataset():
    from repro.fl.experiment import DATASETS

    if "random_clients" not in DATASETS:
        DATASETS.register("random_clients", _random_clients)


def _mean_round_time(dataset, planner: str, *, m: int, rounds: int, dim: int):
    """(mean seconds per round after compile warm-up, mean plan lag)."""
    from repro.fl.experiment import build_experiment

    spec = {
        "data": {
            "name": "random_clients",
            "options": {"n_clients": dataset.n_clients, "dim": dim, "per_client": 60},
        },
        "sampler": {"name": "algorithm2", "m": m},
        "planner": {"mode": planner},
        "train": {
            "n_rounds": rounds, "n_local_steps": 10, "batch_size": 32,
            "lr": 0.05, "seed": 0, "eval_every": 10**9, "hidden": [32],
        },
    }
    # the context manager owns sampler.close() — the async worker used to
    # leak here whenever a run raised between construction and close()
    with build_experiment(spec, dataset=dataset) as srv:
        srv.run_round(0)  # warm-up: engine compile + first rebuild
        t0 = time.perf_counter()
        for t in range(1, rounds + 1):
            srv.run_round(t)
        dt = (time.perf_counter() - t0) / rounds
        lag = float(np.mean(srv.history.series("plan_lag_rounds")[1:]))
    return dt, lag


def _padded_peak_bytes(n: int, d: int, block_n: int, block_d: int) -> int:
    """Bytes of the padded f32 block a single kernel launch materializes
    (mirrors pairwise_kernel's block arithmetic)."""
    bn = min(block_n, max(8, n))
    bd = min(block_d, max(8, d))
    return (n + (-n % bn)) * (d + (-d % bd)) * 4


def _streamed_sweep(d_values, *, n: int, d_chunk: int, block_n: int, block_d: int):
    from benchmarks.common import timed
    from repro.kernels.similarity.ops import (
        pairwise_distances_device,
        pairwise_distances_streamed,
    )

    rng = np.random.default_rng(1)
    for d in d_values:
        G = rng.normal(size=(n, d)).astype(np.float32)
        one_shot = _padded_peak_bytes(n, d, block_n, block_d)
        streamed = _padded_peak_bytes(n, min(d, d_chunk), block_n, block_d)
        us_one, out_one = timed(
            lambda: np.asarray(
                pairwise_distances_device(
                    G, "arccos", block_n=block_n, block_d=block_d, interpret=True
                )
            ),
            repeats=2,
        )
        us_st, out_st = timed(
            lambda: np.asarray(
                pairwise_distances_streamed(
                    G, "arccos", block_n=block_n, block_d=block_d,
                    d_chunk=d_chunk, interpret=True,
                )
            ),
            repeats=2,
        )
        np.testing.assert_allclose(out_one, out_st, atol=1e-4)
        emit(
            f"similarity_streamed/n={n}/d={d}/one_shot", us_one,
            f"padded_peak={one_shot / 2**20:.2f}MiB",
        )
        emit(
            f"similarity_streamed/n={n}/d={d}/streamed", us_st,
            f"padded_peak={streamed / 2**20:.2f}MiB (chunk={d_chunk}); "
            f"peak_ratio={one_shot / streamed:.1f}x",
        )


def main(argv: "list[str] | None" = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny sizes for CI")
    # programmatic callers (benchmarks.run) pass no argv and get defaults;
    # parse_args(None) would read the harness's own sys.argv and SystemExit
    args = ap.parse_args([] if argv is None else argv)

    _register_dataset()
    dim = 16
    ns = (40,) if args.smoke else (200, 400)
    rounds = 2 if args.smoke else 6
    for n in ns:
        dataset = _random_clients(n_clients=n, dim=dim, per_client=60)
        secs, lags = {}, {}
        for planner in ("sync", "async"):
            secs[planner], lags[planner] = _mean_round_time(
                dataset, planner, m=10, rounds=rounds, dim=dim
            )
        speedup = secs["sync"] / secs["async"]
        emit(f"async_planner/n={n}/sync", secs["sync"] * 1e6, "us per round; lag=0")
        emit(
            f"async_planner/n={n}/async", secs["async"] * 1e6,
            f"us per round; speedup={speedup:.2f}x "
            f"mean_lag={lags['async']:.2f} rounds",
        )

    if args.smoke:
        _streamed_sweep((96,), n=24, d_chunk=32, block_n=8, block_d=16)
    else:
        _streamed_sweep((512, 2048, 8192), n=128, d_chunk=512, block_n=128, block_d=128)


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
