"""Async re-clustering planner: round wall-time with Algorithm 2's rebuild
on vs off the critical path, and streamed-similarity peak memory vs ``d``.

Section 1 — planner overlap: the same FL run (batched engine, Algorithm 2
sampler) with ``planner="sync"`` pays the O(n²d) distances + O(n³) Ward +
urn filling *inside* every round; ``planner="async"`` hands the rebuild to
a background worker and the round only pays a device scatter + snapshot.
The acceptance target is a lower mean round wall-time for async at
n >= 200 clients on CPU; per-round plan staleness is reported as the mean
``plan_lag_rounds`` (0 for sync by construction).

Section 2 — streamed similarity: the one-shot kernel pads the full (n, d)
block to tile multiples before launching; ``pairwise_distances_streamed``
(now one fused ``pallas_call`` with an in-kernel d-grid) never pads the
full block, so the padded peak stops growing with ``d``. Reported: the
padded-slab peak bytes of each path (exact, from the kernel's block
arithmetic) and wall time.

Section 3 — rebuild at scale: one ``build_plan_algorithm2`` call per
(clusterer, n) cell. At moderate n the three registered clusterers are
compared end-to-end (host ward reference; ``ward_jit`` consuming the fused
streamed kernel's device distances; ``kmeans``). At n=10k clients (full
mode) the host O(n³) Ward is infeasible, so the section reports the
device paths that remain: the jitted k-means rebuild (cold + warm — no
(n, n) matrix at all on this path) and the distance stage alone (host
numpy f64 vs one fused streamed launch).

``--drift`` adds Section 4 — the measured rebuild trigger: the same run
with a fixed ``rebuild_every=1`` cadence vs ``drift_threshold``, reporting
round wall-time, rebuilds actually executed, and the mean assignment-churn
statistic (``RoundRecord.plan_drift``).

Usage (module form — `benchmarks` is a package):
  PYTHONPATH=src python -m benchmarks.bench_async_planner [--smoke] [--drift]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit


def _random_clients(n_clients: int, dim: int, per_client: int):
    from repro.data.federated import ClientData, FederatedDataset

    rng = np.random.default_rng(0)
    clients = []
    for _ in range(n_clients):
        x = rng.normal(size=(per_client, dim)).astype(np.float32)
        y = rng.integers(0, 10, size=per_client)
        clients.append(ClientData(x_train=x, y_train=y, x_test=x[:8], y_test=y[:8]))
    return FederatedDataset(clients)


def _register_dataset():
    from repro.fl.experiment import DATASETS

    if "random_clients" not in DATASETS:
        DATASETS.register("random_clients", _random_clients)


def _mean_round_time(dataset, planner: dict, *, m: int, rounds: int, dim: int):
    """(mean s/round after compile warm-up, mean lag, rebuilds, mean drift).

    ``planner`` is the spec's planner section verbatim (mode, cadence or
    drift threshold). Rebuilds counts only post-initial plan builds; drift
    averages the measured ``plan_drift`` telemetry (-1.0 when the run never
    measured drift, i.e. fixed-cadence mode).
    """
    from repro.fl.experiment import build_experiment

    spec = {
        "data": {
            "name": "random_clients",
            "options": {"n_clients": dataset.n_clients, "dim": dim, "per_client": 60},
        },
        "sampler": {"name": "algorithm2", "m": m},
        "planner": dict(planner),
        "train": {
            "n_rounds": rounds, "n_local_steps": 10, "batch_size": 32,
            "lr": 0.05, "seed": 0, "eval_every": 10**9, "hidden": [32],
        },
    }
    # the context manager owns sampler.close() — the async worker used to
    # leak here whenever a run raised between construction and close()
    with build_experiment(spec, dataset=dataset) as srv:
        srv.run_round(0)  # warm-up: engine compile + first rebuild
        t0 = time.perf_counter()
        for t in range(1, rounds + 1):
            srv.run_round(t)
        dt = (time.perf_counter() - t0) / rounds
        lag = float(np.mean(srv.history.series("plan_lag_rounds")[1:]))
        rebuilds = srv.sampler.plan_service.rebuilds_done()
        drifts = [v for v in srv.history.series("plan_drift") if v >= 0]
        drift = float(np.mean(drifts)) if drifts else -1.0
    return dt, lag, rebuilds, drift


def _padded_peak_bytes(n: int, d: int, block_n: int, block_d: int) -> int:
    """Bytes of the padded f32 block a single kernel launch materializes
    (mirrors pairwise_kernel's block arithmetic)."""
    bn = min(block_n, max(8, n))
    bd = min(block_d, max(8, d))
    return (n + (-n % bn)) * (d + (-d % bd)) * 4


def _streamed_sweep(d_values, *, n: int, d_chunk: int, block_n: int, block_d: int):
    from benchmarks.common import timed
    from repro.kernels.similarity.ops import (
        pairwise_distances_device,
        pairwise_distances_streamed,
    )

    rng = np.random.default_rng(1)
    for d in d_values:
        G = rng.normal(size=(n, d)).astype(np.float32)
        one_shot = _padded_peak_bytes(n, d, block_n, block_d)
        streamed = _padded_peak_bytes(n, min(d, d_chunk), block_n, block_d)
        us_one, out_one = timed(
            lambda: np.asarray(
                pairwise_distances_device(
                    G, "arccos", block_n=block_n, block_d=block_d, interpret=True
                )
            ),
            repeats=2,
        )
        us_st, out_st = timed(
            lambda: np.asarray(
                pairwise_distances_streamed(
                    G, "arccos", block_n=block_n, block_d=block_d,
                    d_chunk=d_chunk, interpret=True,
                )
            ),
            repeats=2,
        )
        np.testing.assert_allclose(out_one, out_st, atol=1e-4)
        emit(
            f"similarity_streamed/n={n}/d={d}/one_shot", us_one,
            f"padded_peak={one_shot / 2**20:.2f}MiB",
        )
        emit(
            f"similarity_streamed/n={n}/d={d}/streamed", us_st,
            f"padded_peak={streamed / 2**20:.2f}MiB (chunk={d_chunk}); "
            f"peak_ratio={one_shot / streamed:.1f}x",
        )


def _rebuild_scale(*, smoke: bool) -> None:
    """Section 3: plan-rebuild cost off the training profile.

    Every cell is one :func:`build_plan_algorithm2` call over a synthetic
    gradient block — exactly what the planner's worker executes. At n_big
    the host Ward reference is O(n³) ≈ 10¹² ops and is omitted as
    infeasible; the cells that remain are the device rebuild paths the
    tentpole added.
    """
    import jax

    from benchmarks.common import timed
    from repro.core.clustering import pairwise_distances
    from repro.core.samplers.algorithm2 import build_plan_algorithm2
    from repro.core.types import ClientPopulation
    from repro.kernels.similarity.ops import (
        make_distance_fn,
        pairwise_distances_streamed,
    )

    interpret = jax.default_backend() != "tpu"
    n_small, n_big, d = (48, 200, 16) if smoke else (512, 10_000, 64)
    m_small, m_big = (5, 5) if smoke else (24, 50)
    rng = np.random.default_rng(0)

    # moderate n: the three registered clusterers end-to-end. ward_jit gets
    # the fused streamed kernel's device distances — the (n, n) matrix and
    # the Lance–Williams loop both stay on device.
    G_small = rng.normal(size=(n_small, d)).astype(np.float32)
    pop_small = ClientPopulation(np.full(n_small, 100))
    device_dist = make_distance_fn(interpret=interpret, streamed=True, as_numpy=False)
    cells = [
        ("ward_host", dict(distance_fn=None, clusterer="ward")),
        ("ward_jit", dict(distance_fn=device_dist, clusterer="ward_jit")),
        ("kmeans", dict(distance_fn=None, clusterer="kmeans")),
    ]
    repeats = 1 if smoke else 2
    for name, kw in cells:
        us, _ = timed(
            lambda kw=kw: build_plan_algorithm2(pop_small, m_small, G_small, **kw),
            repeats=repeats,
        )
        emit(f"plan_rebuild/n={n_small}/{name}", us, "full plan build (warm)")

    # n_big: the off-profile rebuild. kmeans clusters G directly — no (n, n)
    # matrix exists anywhere on this path, so it is the one that scales.
    G_big = rng.normal(size=(n_big, d)).astype(np.float32)
    pop_big = ClientPopulation(np.full(n_big, 100))
    big_build = lambda: build_plan_algorithm2(pop_big, m_big, G_big, clusterer="kmeans")
    us_cold, _ = timed(big_build, repeats=1, warmup=0)
    us_warm, _ = timed(big_build, repeats=repeats)
    emit(f"plan_rebuild/n={n_big}/kmeans_cold", us_cold, "jit compile + build")
    emit(f"plan_rebuild/n={n_big}/kmeans_warm", us_warm, "no (n,n) matrix on this path")

    if not smoke:
        # distance stage alone at n_big: f64 host reference vs one fused
        # streamed launch. host ward on top of it would be O(n^3) — omitted.
        us_host, _ = timed(
            lambda: pairwise_distances(G_big, "arccos"), repeats=1, warmup=0
        )
        emit(
            f"plan_rebuild/n={n_big}/host_distances_only", us_host,
            "f64 numpy O(n^2 d) stage alone; host ward O(n^3) omitted (infeasible)",
        )
        us_fused, _ = timed(
            lambda: np.asarray(
                pairwise_distances_streamed(G_big, "arccos", interpret=interpret)
            ),
            repeats=1, warmup=0,
        )
        emit(
            f"plan_rebuild/n={n_big}/fused_distances", us_fused,
            "one fused streamed launch (interpret mode off-TPU), no padded (n,d) block",
        )


def _drift_section(*, smoke: bool) -> None:
    """Section 4: measured drift trigger vs the fixed rebuild cadence."""
    dim = 16
    n = 40 if smoke else 200
    rounds = 2 if smoke else 6
    dataset = _random_clients(n_clients=n, dim=dim, per_client=60)
    fx_dt, _, fx_rb, _ = _mean_round_time(
        dataset, {"mode": "sync", "rebuild_every": 1}, m=10, rounds=rounds, dim=dim
    )
    threshold = 0.2
    dr_dt, _, dr_rb, drift = _mean_round_time(
        dataset, {"mode": "sync", "drift_threshold": threshold},
        m=10, rounds=rounds, dim=dim,
    )
    emit(
        f"drift_planner/n={n}/fixed", fx_dt * 1e6,
        f"us per round; rebuilds={fx_rb}",
    )
    emit(
        f"drift_planner/n={n}/threshold={threshold}", dr_dt * 1e6,
        f"us per round; rebuilds={dr_rb} mean_drift={drift:.3f}",
    )


def main(argv: "list[str] | None" = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny sizes for CI")
    ap.add_argument(
        "--drift", action="store_true",
        help="also run the drift-triggered planner section",
    )
    # programmatic callers (benchmarks.run) pass no argv and get defaults;
    # parse_args(None) would read the harness's own sys.argv and SystemExit
    args = ap.parse_args([] if argv is None else argv)

    _register_dataset()
    dim = 16
    ns = (40,) if args.smoke else (200, 400)
    rounds = 2 if args.smoke else 6
    for n in ns:
        dataset = _random_clients(n_clients=n, dim=dim, per_client=60)
        secs, lags = {}, {}
        for planner in ("sync", "async"):
            secs[planner], lags[planner], _, _ = _mean_round_time(
                dataset, {"mode": planner}, m=10, rounds=rounds, dim=dim
            )
        speedup = secs["sync"] / secs["async"]
        emit(f"async_planner/n={n}/sync", secs["sync"] * 1e6, "us per round; lag=0")
        emit(
            f"async_planner/n={n}/async", secs["async"] * 1e6,
            f"us per round; speedup={speedup:.2f}x "
            f"mean_lag={lags['async']:.2f} rounds",
        )

    if args.smoke:
        _streamed_sweep((96,), n=24, d_chunk=32, block_n=8, block_d=16)
    else:
        _streamed_sweep((512, 2048, 8192), n=128, d_chunk=512, block_n=128, block_d=128)

    _rebuild_scale(smoke=args.smoke)
    if args.drift:
        _drift_section(smoke=args.smoke)


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
