"""Sketched GradientStore at scale: resident bytes, scatter, rebuild.

The tentpole claim under measurement: with ``sketch="srp"`` the store's
resident buffer — and the whole plan-rebuild pipeline behind it — scales
in ``d_prime`` instead of the model dimension ``d``, taking Algorithm 2's
plan rebuilds from the paper's n=400 toward n=10⁶ clients.

Section 1 — store footprint + scatter: for each (n, d) cell, build the
store exact and sketched (srp, d'=``D_PRIME``), report resident bytes and
the warm per-round scatter time of a (c, d) update block (sketch + dedupe
+ ``.at[ids].set``). Exact cells whose (n, d) f32 buffer would exceed
``EXACT_BYTE_CAP`` are reported as ``infeasible`` rather than risking a
real OOM on the CI host — that *is* the measurement: those are the cells
only the sketched store can hold.

Section 2 — plan rebuild: one ``build_plan_algorithm2`` call (``kmeans``
clusterer — no (n, n) matrix on this path) over the store's snapshot,
exact (n, d) vs sketched (n, d'). The acceptance cell is n=10⁵, d=10⁴:
exact is byte-capped off the host while the sketched rebuild completes.

Usage (module form — `benchmarks` is a package):
  PYTHONPATH=src python -m benchmarks.bench_store_scale [--smoke]
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, timed

#: sketch width of every sketched cell (the README scaling table's d')
D_PRIME = 64

#: largest exact (n, d) f32 buffer this benchmark will actually allocate;
#: ~1 GiB keeps the full grid safe on a CI-sized host. Cells past the cap
#: are emitted as infeasible instead of attempted.
EXACT_BYTE_CAP = 1 << 30


def _store(n: int, d: int, *, sketch=None, sketch_dim=None):
    from repro.fl.gradient_store import GradientStore

    return GradientStore(n, d, sketch=sketch, sketch_dim=sketch_dim)


def _scatter_us(store, ids: np.ndarray, updates: np.ndarray, repeats: int) -> float:
    import jax

    def step():
        store.update(ids, updates)
        return jax.block_until_ready(store.snapshot())

    us, _ = timed(step, repeats=repeats, warmup=1)
    return us


def _section_store(cells, *, c: int, repeats: int) -> None:
    rng = np.random.default_rng(0)
    for n, d in cells:
        ids = rng.choice(n, size=min(c, n), replace=False).astype(np.int32)
        updates = rng.normal(size=(ids.size, d)).astype(np.float32)
        exact_bytes = n * d * 4
        label = f"store/n={n}/d={d}"
        if exact_bytes > EXACT_BYTE_CAP:
            emit(
                f"{label}/exact", 0.0,
                f"infeasible: {exact_bytes / 2**30:.1f}GiB resident > "
                f"{EXACT_BYTE_CAP / 2**30:.0f}GiB cap",
            )
        else:
            st = _store(n, d)
            us = _scatter_us(st, ids, updates, repeats)
            emit(f"{label}/exact", us, f"bytes={st.nbytes};scatter of ({ids.size},{d})")
            del st
        dp = min(D_PRIME, d)
        st = _store(n, d, sketch="srp", sketch_dim=dp)
        us = _scatter_us(st, ids, updates, repeats)
        emit(
            f"{label}/srp{dp}", us,
            f"bytes={st.nbytes};ratio={exact_bytes / st.nbytes:.0f}x smaller",
        )
        del st


def _rebuild_us(G, n: int, m: int, repeats: int, *, warmup: int = 1) -> float:
    from repro.core.samplers.algorithm2 import build_plan_algorithm2
    from repro.core.types import ClientPopulation

    pop = ClientPopulation(np.full(n, 100))
    us, _ = timed(
        lambda: build_plan_algorithm2(pop, m, G, clusterer="kmeans"),
        repeats=repeats, warmup=warmup,
    )
    return us


def _section_rebuild(cells, *, c: int, m: int, repeats: int) -> None:
    rng = np.random.default_rng(1)
    for n, d in cells:
        ids = rng.choice(n, size=min(c, n), replace=False).astype(np.int32)
        updates = rng.normal(size=(ids.size, d)).astype(np.float32)
        label = f"rebuild/n={n}/d={d}"
        exact_bytes = n * d * 4
        if exact_bytes > EXACT_BYTE_CAP:
            emit(
                f"{label}/exact", 0.0,
                f"infeasible: (n,d) snapshot {exact_bytes / 2**30:.1f}GiB "
                "exceeds cap; sketched path below is the only one that runs",
            )
        else:
            st = _store(n, d)
            st.update(ids, updates)
            us = _rebuild_us(st.snapshot(), n, m, repeats)
            emit(f"{label}/exact", us, "kmeans plan build on (n,d) snapshot (warm)")
            del st
        dp = min(D_PRIME, d)
        st = _store(n, d, sketch="srp", sketch_dim=dp)
        st.update(ids, updates)
        us = _rebuild_us(st.snapshot(), n, m, repeats)
        emit(f"{label}/srp{dp}", us, f"kmeans plan build on (n,{dp}) snapshot (warm)")
        del st


def main(argv: "list[str] | None" = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny sizes for CI")
    # programmatic callers (benchmarks.run) pass no argv and get defaults;
    # parse_args(None) would read the harness's own sys.argv and SystemExit
    args = ap.parse_args([] if argv is None else argv)

    if args.smoke:
        cells = [(200, 2048), (400, 2048)]
        c, m, repeats = 32, 5, 1
        rebuild_cells = cells
    else:
        cells = [(1_000, 10_000), (1_000, 100_000), (10_000, 10_000),
                 (10_000, 100_000), (100_000, 10_000)]
        c, m, repeats = 64, 20, 2
        # the acceptance cell (n=1e5, d=1e4) plus one mid-scale exact point
        rebuild_cells = [(10_000, 10_000), (100_000, 10_000)]
    _section_store(cells, c=c, repeats=repeats)
    _section_rebuild(rebuild_cells, c=c, m=m, repeats=repeats)


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
