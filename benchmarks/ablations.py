"""Appendix D ablations: similarity measure (D.2), local work N and number of
sampled clients m (D.4), FedProx regularization (D.5).

Each ablation axis is a spec matrix (repro.fl.experiment): the varied knob
lands in the sampler options or the train section, nothing is hand-wired.
"""
from __future__ import annotations

import time

from benchmarks.common import PAPER_TRAIN, emit, run_spec
from repro.fl.experiment import DataSpec, build_dataset

DIM = 32
ROUNDS = 12

DATA = {"name": "dirichlet_labels", "options": {"alpha": 0.01, "dim": DIM, "noise": 2.5, "seed": 0}}


def _spec(sampler: dict, **train_overrides) -> dict:
    return {
        "data": DATA,
        "sampler": sampler,
        "train": {"n_rounds": ROUNDS, **PAPER_TRAIN, **train_overrides},
    }


def main() -> None:
    ds = build_dataset(DataSpec.from_dict(DATA))

    # D.2 — similarity measures are equivalent in practice
    for measure in ("arccos", "l2", "l1"):
        spec = _spec({"name": "algorithm2", "m": 10, "options": {"measure": measure}})
        t0 = time.perf_counter()
        r = run_spec(spec, dataset=ds)
        emit(
            f"ablation_D2/measure={measure}",
            (time.perf_counter() - t0) * 1e6 / ROUNDS,
            f"loss={r['final_loss']:.4f};acc={r['final_acc']:.3f}",
        )

    # D.4 — influence of N (local steps) and m (sampled clients)
    for n_local in (5, 20):
        for name, key in (("md", "md"), ("algorithm2", "alg2")):
            r = run_spec(_spec({"name": name, "m": 10}, n_local_steps=n_local), dataset=ds)
            emit(f"ablation_D4/N={n_local}/{key}", 0.0, f"loss={r['final_loss']:.4f}")
    for m in (5, 20):
        for name, key in (("md", "md"), ("algorithm2", "alg2")):
            r = run_spec(_spec({"name": name, "m": m}), dataset=ds)
            emit(f"ablation_D4/m={m}/{key}", 0.0, f"loss={r['final_loss']:.4f}")

    # D.5 — FedProx (mu = 0.1): clustered sampling still helps
    for name, key in (("md", "md"), ("algorithm2", "alg2")):
        r = run_spec(_spec({"name": name, "m": 10}, fedprox_mu=0.1), dataset=ds)
        emit(f"ablation_D5/fedprox/{key}", 0.0, f"loss={r['final_loss']:.4f}")


if __name__ == "__main__":
    main()
