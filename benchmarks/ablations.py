"""Appendix D ablations: similarity measure (D.2), local work N and number of
sampled clients m (D.4), FedProx regularization (D.5).

Each ablation axis is a ``SweepSpec`` through the shared campaign runner
(``repro.fl.sweep``): the varied knob is a dotted-path axis into the base
spec, nothing is hand-wired. Single replicate per cell (the ablations are
qualitative); the replicate's data/train seeds still derive from the sweep's
``root_seed`` so every ablation shares one partition, as in the appendix.
"""
from __future__ import annotations

from benchmarks.common import PAPER_TRAIN, run_sweep_emit

DIM = 32
ROUNDS = 12

DATA = {"name": "dirichlet_labels", "options": {"alpha": 0.01, "dim": DIM, "noise": 2.5}}


def _base(sampler: dict, **train_overrides) -> dict:
    return {
        "data": DATA,
        "sampler": sampler,
        "train": {"n_rounds": ROUNDS, **PAPER_TRAIN, **train_overrides},
    }


#: D.2 — similarity measures are equivalent in practice
SWEEP_D2 = {
    "base": _base({"name": "algorithm2", "m": 10}),
    "axes": {"sampler.options.measure": ["arccos", "l2", "l1"]},
    "root_seed": 3,
}

#: D.4 — influence of N (local steps) and m (sampled clients)
SWEEP_D4_N = {
    "base": _base({"name": "md", "m": 10}),
    "axes": {"train.n_local_steps": [5, 20], "sampler.name": ["md", "algorithm2"]},
    "root_seed": 3,
}
SWEEP_D4_M = {
    "base": _base({"name": "md", "m": 10}),
    "axes": {"sampler.m": [5, 20], "sampler.name": ["md", "algorithm2"]},
    "root_seed": 3,
}

#: D.5 — FedProx (mu = 0.1): clustered sampling still helps
SWEEP_D5 = {
    "base": _base({"name": "md", "m": 10}, fedprox_mu=0.1),
    "axes": {"sampler.name": ["md", "algorithm2"]},
    "root_seed": 3,
}


def main() -> None:
    # labels are also the per-sweep store keys under $BENCH_SWEEP_STORE,
    # so the two D.4 sub-sweeps must not share one
    run_sweep_emit(SWEEP_D2, "ablation_D2")
    run_sweep_emit(SWEEP_D4_N, "ablation_D4_N", stats={"loss": "final_loss"})
    run_sweep_emit(SWEEP_D4_M, "ablation_D4_m", stats={"loss": "final_loss"})
    run_sweep_emit(SWEEP_D5, "ablation_D5_fedprox", stats={"loss": "final_loss"})


if __name__ == "__main__":
    main()
