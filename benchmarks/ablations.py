"""Appendix D ablations: similarity measure (D.2), local work N and number of
sampled clients m (D.4), FedProx regularization (D.5)."""
from __future__ import annotations

import time

from benchmarks.common import emit, run_fl
from repro.core import Algorithm2Sampler, MDSampler
from repro.fl import dirichlet_labels
from repro.fl.aggregation import flatten_params
from repro.models.simple import init_mlp

DIM = 32
ROUNDS = 12


def main() -> None:
    ds = dirichlet_labels(alpha=0.01, dim=DIM, noise=2.5, seed=0)
    pop = ds.population
    d = int(flatten_params(init_mlp((DIM, 50, 10))).shape[0])

    # D.2 — similarity measures are equivalent in practice
    for measure in ("arccos", "l2", "l1"):
        s = Algorithm2Sampler(pop, 10, update_dim=d, measure=measure, seed=0)
        t0 = time.perf_counter()
        r = run_fl(ds, s, rounds=ROUNDS, n_local=10, batch=50, lr=0.05)
        emit(
            f"ablation_D2/measure={measure}",
            (time.perf_counter() - t0) * 1e6 / ROUNDS,
            f"loss={r['final_loss']:.4f};acc={r['final_acc']:.3f}",
        )

    # D.4 — influence of N (local steps) and m (sampled clients)
    for n_local in (5, 20):
        for name, mk in (("md", MDSampler), ("alg2", None)):
            s = mk(pop, 10, seed=0) if mk else Algorithm2Sampler(pop, 10, update_dim=d, seed=0)
            r = run_fl(ds, s, rounds=ROUNDS, n_local=n_local, batch=50, lr=0.05)
            emit(f"ablation_D4/N={n_local}/{name}", 0.0, f"loss={r['final_loss']:.4f}")
    for m in (5, 20):
        for name, mk in (("md", MDSampler), ("alg2", None)):
            s = mk(pop, m, seed=0) if mk else Algorithm2Sampler(pop, m, update_dim=d, seed=0)
            r = run_fl(ds, s, rounds=ROUNDS, n_local=10, batch=50, lr=0.05)
            emit(f"ablation_D4/m={m}/{name}", 0.0, f"loss={r['final_loss']:.4f}")

    # D.5 — FedProx (mu = 0.1): clustered sampling still helps
    for name, mk in (("md", MDSampler), ("alg2", None)):
        s = mk(pop, 10, seed=0) if mk else Algorithm2Sampler(pop, 10, update_dim=d, seed=0)
        r = run_fl(ds, s, rounds=ROUNDS, n_local=10, batch=50, lr=0.05, mu=0.1)
        emit(f"ablation_D5/fedprox/{name}", 0.0, f"loss={r['final_loss']:.4f}")


if __name__ == "__main__":
    main()
