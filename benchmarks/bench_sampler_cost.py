"""Algorithm cost scaling (Theorems 3 & 4): Algorithm 1 is O(n log n);
Algorithm 2 is O(n^2 d + X) dominated by the similarity matrix.

Also sweeps the *per-draw* cost of every registered sampling scheme in one
table (``sampler_cost/draw/<name>``): each scheme is constructed through
the same spec door experiments use, then its ``sample()`` is timed —
plan-build cost is amortized out, so the rows isolate what a round pays.

``--smoke`` runs one tiny size per algorithm — used by the tier-1 script to
catch import/collection regressions in the benchmark tree cheaply.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, timed
from repro.core import ClientPopulation, build_plan_algorithm1, build_plan_algorithm2


def draw_cost_sweep(*, smoke: bool) -> None:
    """Per-draw cost of every scheme in ``SAMPLERS``, one table."""
    from repro.core.samplers import SAMPLERS
    from repro.fl.experiment import build_sampler

    m = 4 if smoke else 10
    n = 5 * m  # uniform sizes + n % m == 0: target's oracle groups are balanced
    update_dim = 32 if smoke else 256
    pop = ClientPopulation(np.full(n, 100))
    oracle_groups = [g.tolist() for g in np.arange(n).reshape(m, -1)]
    for name in SAMPLERS.names():
        options = {"groups": oracle_groups} if name == "target" else {}
        sampler = build_sampler(
            {"name": name, "m": m, "seed": 0, "options": options},
            pop, update_dim=update_dim,
        )
        try:
            us, _ = timed(lambda: sampler.sample(0), repeats=3 if smoke else 20)
        finally:
            getattr(sampler, "close", lambda: None)()
        emit(f"sampler_cost/draw/{name}", us, f"n={n};m={m}")


def main(argv: "list[str] | None" = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny sizes for CI")
    # programmatic callers (benchmarks.run) pass no argv and get defaults;
    # parse_args(None) would read the harness's own sys.argv and SystemExit
    args = ap.parse_args([] if argv is None else argv)

    rng = np.random.default_rng(0)
    a1_sizes = (50,) if args.smoke else (50, 100, 200, 400)
    a2_sizes = (50,) if args.smoke else (50, 100, 200)
    for n in a1_sizes:
        pop = ClientPopulation(rng.integers(50, 1000, size=n))
        us, _ = timed(lambda: build_plan_algorithm1(pop, 10), repeats=5)
        emit(f"sampler_cost/algorithm1/n={n}", us, "theory=O(n log n)")
    for n in a2_sizes:
        pop = ClientPopulation(rng.integers(50, 1000, size=n))
        G = rng.normal(size=(n, 256))
        us, _ = timed(lambda: build_plan_algorithm2(pop, 10, G), repeats=2)
        emit(f"sampler_cost/algorithm2/n={n}", us, "theory=O(n^2 d + ward)")
    draw_cost_sweep(smoke=args.smoke)


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
