"""Algorithm cost scaling (Theorems 3 & 4): Algorithm 1 is O(n log n);
Algorithm 2 is O(n^2 d + X) dominated by the similarity matrix."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core import ClientPopulation, build_plan_algorithm1, build_plan_algorithm2


def main() -> None:
    rng = np.random.default_rng(0)
    for n in (50, 100, 200, 400):
        pop = ClientPopulation(rng.integers(50, 1000, size=n))
        us, _ = timed(lambda: build_plan_algorithm1(pop, 10), repeats=5)
        emit(f"sampler_cost/algorithm1/n={n}", us, "theory=O(n log n)")
    for n in (50, 100, 200):
        pop = ClientPopulation(rng.integers(50, 1000, size=n))
        G = rng.normal(size=(n, 256))
        us, _ = timed(lambda: build_plan_algorithm2(pop, 10, G), repeats=2)
        emit(f"sampler_cost/algorithm2/n={n}", us, "theory=O(n^2 d + ward)")


if __name__ == "__main__":
    main()
