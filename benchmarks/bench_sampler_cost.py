"""Algorithm cost scaling (Theorems 3 & 4): Algorithm 1 is O(n log n);
Algorithm 2 is O(n^2 d + X) dominated by the similarity matrix.

``--smoke`` runs one tiny size per algorithm — used by the tier-1 script to
catch import/collection regressions in the benchmark tree cheaply.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, timed
from repro.core import ClientPopulation, build_plan_algorithm1, build_plan_algorithm2


def main(argv: "list[str] | None" = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny sizes for CI")
    # programmatic callers (benchmarks.run) pass no argv and get defaults;
    # parse_args(None) would read the harness's own sys.argv and SystemExit
    args = ap.parse_args([] if argv is None else argv)

    rng = np.random.default_rng(0)
    a1_sizes = (50,) if args.smoke else (50, 100, 200, 400)
    a2_sizes = (50,) if args.smoke else (50, 100, 200)
    for n in a1_sizes:
        pop = ClientPopulation(rng.integers(50, 1000, size=n))
        us, _ = timed(lambda: build_plan_algorithm1(pop, 10), repeats=5)
        emit(f"sampler_cost/algorithm1/n={n}", us, "theory=O(n log n)")
    for n in a2_sizes:
        pop = ClientPopulation(rng.integers(50, 1000, size=n))
        G = rng.normal(size=(n, 256))
        us, _ = timed(lambda: build_plan_algorithm2(pop, 10, G), repeats=2)
        emit(f"sampler_cost/algorithm2/n={n}", us, "theory=O(n^2 d + ward)")


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
