"""Scheme race: every registered client-selection scheme on one campaign.

ONE ``SweepSpec`` with the sampler scheme as a grid axis — the paper's
algorithms (``md``, ``uniform``, ``algorithm2``) raced head-to-head against
the scheme zoo (``stratified``, ``importance``, ``dp_stratified``,
``hybrid``) over paired seed replicates. The collated ``summary.csv``
carries mean±std for every :data:`repro.fl.sweep.SUMMARY_STATS` column,
including the race's two quality axes:

  - ``rounds_to_acc``  — time-to-accuracy (first round reaching
    ``ACC_TARGET``; censored runs report the horizon)
  - ``agg_weight_var`` — Σ_i Var_t(ω_i), the variance the clustered /
    stratified schemes exist to shrink at fixed E[ω_i] = p_i

``--smoke`` shrinks the grid to 2 schemes × 2 seeds (the tier-1 entry);
``--store DIR`` makes the campaign resumable (re-invoking on the same
store skips completed cells — tier-1 pins that). ``--parity`` instead runs
the md-vs-importance gate: ``importance`` with ``mix = 1.0`` must produce a
bit-identical training history to ``md`` on the same seed (plan telemetry
normalized out — importance runs a PlanService, md does not).
"""
from __future__ import annotations

import argparse
import contextlib
import json
import tempfile

from benchmarks.common import PAPER_TRAIN, emit

SCHEMES = (
    "md",
    "uniform",
    "algorithm2",
    "stratified",
    "importance",
    "dp_stratified",
    "hybrid",
)
SMOKE_SCHEMES = ("md", "stratified")

#: mean±std columns emitted per grid point (short label -> summary stat)
RACE_STATS = {
    "loss": "final_loss",
    "acc": "final_acc",
    "tta": "rounds_to_acc",
    "wvar": "agg_weight_var",
}


def race_sweep(*, smoke: bool, n_seeds: "int | None" = None) -> dict:
    """The campaign spec: sampler scheme as a grid axis, paired seeds."""
    if smoke:
        return {
            "base": {
                "data": {
                    "name": "by_class_shards",
                    "options": {"n_classes": 4, "clients_per_class": 2, "dim": 8,
                                 "train_per_client": 40, "test_per_client": 8},
                },
                "sampler": {"name": "md", "m": 4},
                "train": {"n_rounds": 3, "n_local_steps": 2, "batch_size": 10,
                           "hidden": [16]},
            },
            "axes": {"sampler.name": list(SMOKE_SCHEMES)},
            "n_seeds": 2 if n_seeds is None else n_seeds,
            "root_seed": 11,
        }
    return {
        "base": {
            "data": {
                "name": "by_class_shards",
                "options": {"n_classes": 10, "clients_per_class": 10, "dim": 32,
                             "train_per_client": 100, "test_per_client": 20},
            },
            "sampler": {"name": "md", "m": 10},
            "train": {"n_rounds": 20, **PAPER_TRAIN},
        },
        "axes": {"sampler.name": list(SCHEMES)},
        "n_seeds": 3 if n_seeds is None else n_seeds,
        "root_seed": 11,
    }


def run_race(sweep: dict, store_dir: "str | None", workers: int = 1) -> list[dict]:
    """Run the race into a (resumable) RunStore; emit cells + mean±std rows.

    Unlike ``run_sweep_emit`` this also emits one ``status=`` row per cell,
    so a resumed invocation is observable (tier-1 greps ``status=skipped``).
    """
    from repro.fl.sweep import SweepSpec, cell_group_label, collate, run_sweep, write_collated

    spec = SweepSpec.from_dict(sweep)
    with contextlib.ExitStack() as stack:
        root = store_dir or stack.enter_context(
            tempfile.TemporaryDirectory(prefix="scheme-race-")
        )

        def on_cell(cell, status, summary, dt):
            rounds = max(cell.spec.train.n_rounds, 1)
            emit(
                f"scheme_race/{cell_group_label(cell.overrides)}/seed={cell.seed_index}",
                dt * 1e6 / rounds,
                f"status={status};loss={summary['final_loss']:.4f}",
            )

        store = run_sweep(spec, root, workers=workers, on_cell=on_cell)
        cell_rows, agg_rows = collate(store)
        cells_csv, summary_csv = write_collated(store, rows=(cell_rows, agg_rows))
        print(f"# collated: {cells_csv}")
        print(f"# collated: {summary_csv}")
    for row in agg_rows:
        derived = ";".join(
            f"{short}={row[f'{stat}_mean']:.4f}±{row[f'{stat}_std']:.4f}"
            for short, stat in RACE_STATS.items()
        )
        emit(
            f"scheme_race/scheme={row['sampler.name']}", 0.0,
            f"{derived};seeds={row['n_seeds']}",
        )
    return agg_rows


# -- md vs importance(mix=1.0) parity gate ---------------------------------
PARITY_SPEC = {
    "data": {"name": "by_class_shards",
             "options": {"n_classes": 4, "clients_per_class": 2, "dim": 8,
                          "train_per_client": 40, "test_per_client": 8, "seed": 0}},
    "train": {"n_rounds": 5, "n_local_steps": 2, "batch_size": 10,
               "hidden": [16], "seed": 1},
}
#: importance runs a PlanService (md does not) — its plan telemetry columns
#: are structural, not behavioral, and are normalized out of the comparison
PLAN_TELEMETRY = ("plan_version", "plan_lag_rounds", "plan_build_ms", "plan_drift")


def check_md_importance_parity(seed: int = 7) -> None:
    """``importance`` at ``mix=1.0`` proposes q = p exactly and its weight
    correction is elementwise 1.0, so the full training history must be
    bit-identical to ``md`` on the same seed. SystemExit on drift."""
    from repro.fl.experiment import build_experiment

    def history(sampler: dict) -> str:
        with build_experiment({**PARITY_SPEC, "sampler": sampler}) as srv:
            recs = json.loads(srv.run().to_json())
        for r in recs:
            for f in PLAN_TELEMETRY:
                r.pop(f, None)
        return json.dumps(recs, sort_keys=True)

    md = history({"name": "md", "m": 4, "seed": seed})
    imp = history({"name": "importance", "m": 4, "seed": seed,
                   "options": {"mix": 1.0}})
    if md != imp:
        raise SystemExit(
            "scheme_race parity gate FAILED: importance(mix=1.0) history "
            "diverged from md — the size-proportional degenerate case must "
            "be bit-identical"
        )
    emit("scheme_race/parity/md_vs_importance", 0.0, "bit_identical=1")


def main(argv: "list[str] | None" = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="2 schemes x 2 seeds tiny grid (tier-1 entry)")
    ap.add_argument("--seeds", type=int, default=None,
                    help="override the replicate count")
    ap.add_argument("--store", default=None,
                    help="RunStore directory (resumable; ephemeral if omitted)")
    ap.add_argument("--workers", type=int, default=1,
                    help="process-pool fan-out for independent cells")
    ap.add_argument("--parity", action="store_true",
                    help="run only the md-vs-importance(mix=1.0) bit-parity gate")
    # programmatic callers (benchmarks.run) pass no argv and get defaults
    args = ap.parse_args([] if argv is None else argv)

    if args.parity:
        check_md_importance_parity()
        return
    run_race(race_sweep(smoke=args.smoke, n_seeds=args.seeds),
             args.store, workers=args.workers)


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
