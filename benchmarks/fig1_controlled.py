"""Figure 1 reproduction: controlled 100-client / 10-class setting.

MD vs Algorithm 1 vs Algorithm 2 vs 'target' oracle on the paper's
controlled partition (each client one class, 10 clients per class,
balanced sizes, m = 10). Reports final rolling loss, accuracy and the
per-round class representativity — the paper's key qualitative claims:
clustered sampling always aggregates 10 distinct clients and Algorithm 2
approaches 'target'.

The whole figure is one scenario matrix of experiment specs — adding a
scheme to the comparison is one more dict (see repro.fl.experiment).
"""
from __future__ import annotations

import time

from benchmarks.common import PAPER_TRAIN, emit, run_spec
from repro.fl.experiment import DataSpec, build_dataset

ROUNDS = 25
DIM = 32

DATA = {
    "name": "by_class_shards",
    "options": {"dim": DIM, "noise": 2.5, "train_per_client": 200, "test_per_client": 30, "seed": 0},
}

SCENARIOS = {
    "md": {"name": "md", "m": 10},
    "algorithm1": {"name": "algorithm1", "m": 10},
    "algorithm2": {"name": "algorithm2", "m": 10},
    "target": {
        "name": "target",
        "m": 10,
        "options": {"groups": [list(range(i * 10, (i + 1) * 10)) for i in range(10)]},
    },
}


def main() -> None:
    ds = build_dataset(DataSpec.from_dict(DATA))  # shared across the matrix
    for name, sampler in SCENARIOS.items():
        spec = {
            "data": DATA,
            "sampler": sampler,
            "train": {"n_rounds": ROUNDS, **PAPER_TRAIN},
        }
        t0 = time.perf_counter()
        res = run_spec(spec, dataset=ds)
        us = (time.perf_counter() - t0) * 1e6 / ROUNDS
        emit(
            f"fig1/{name}",
            us,
            f"loss={res['final_loss']:.4f};acc={res['final_acc']:.3f};"
            f"classes={res['mean_distinct_classes']:.2f};clients={res['mean_distinct_clients']:.2f}",
        )


if __name__ == "__main__":
    main()
