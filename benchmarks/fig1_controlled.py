"""Figure 1 reproduction: controlled 100-client / 10-class setting.

MD vs Algorithm 1 vs Algorithm 2 vs 'target' oracle on the paper's
controlled partition (each client one class, 10 clients per class,
balanced sizes, m = 10). Reports mean±std final rolling loss, accuracy
and the per-round class representativity over N_SEEDS paired replicates —
the paper's key qualitative claims: clustered sampling always aggregates
10 distinct clients and Algorithm 2 approaches 'target'.

The whole figure is ONE campaign: a ``SweepSpec`` whose single axis is the
sampler section, run through the shared resumable runner
(``repro.fl.sweep``) — per-replicate data/sampler/train seeds derive from
``SeedSequence(root_seed)`` and are shared across the four schemes, so
the comparison is paired. Adding a scheme is one more dict.
"""
from __future__ import annotations

from benchmarks.common import PAPER_TRAIN, run_sweep_emit

ROUNDS = 25
DIM = 32
N_SEEDS = 2

DATA = {
    "name": "by_class_shards",
    "options": {"dim": DIM, "noise": 2.5, "train_per_client": 200, "test_per_client": 30},
}

SWEEP = {
    "base": {
        "data": DATA,
        "sampler": {"name": "md", "m": 10},
        "train": {"n_rounds": ROUNDS, **PAPER_TRAIN},
    },
    "axes": {
        "sampler": [
            {"name": "md", "m": 10},
            {"name": "algorithm1", "m": 10},
            {"name": "algorithm2", "m": 10},
            {
                "name": "target",
                "m": 10,
                "options": {"groups": [list(range(i * 10, (i + 1) * 10)) for i in range(10)]},
            },
        ],
    },
    "n_seeds": N_SEEDS,
    "root_seed": 1,
}

STATS = {
    "loss": "final_loss",
    "acc": "final_acc",
    "classes": "mean_distinct_classes",
    "clients": "mean_distinct_clients",
}


def main() -> None:
    run_sweep_emit(SWEEP, "fig1", stats=STATS)


if __name__ == "__main__":
    main()
