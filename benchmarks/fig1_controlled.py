"""Figure 1 reproduction: controlled 100-client / 10-class setting.

MD vs Algorithm 1 vs Algorithm 2 vs 'target' oracle on the paper's
controlled partition (each client one class, 10 clients per class,
balanced sizes, m = 10). Reports final rolling loss, accuracy and the
per-round class representativity — the paper's key qualitative claims:
clustered sampling always aggregates 10 distinct clients and Algorithm 2
approaches 'target'.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, run_fl
from repro.core import SAMPLERS, Algorithm2Sampler, TargetSampler
from repro.fl import by_class_shards
from repro.fl.aggregation import flatten_params
from repro.models.simple import init_mlp

ROUNDS = 25
DIM = 32


def main() -> None:
    ds = by_class_shards(dim=DIM, noise=2.5, train_per_client=200, test_per_client=30, seed=0)
    pop = ds.population
    m = 10
    d = int(flatten_params(init_mlp((DIM, 50, 10))).shape[0])

    samplers = {
        "md": SAMPLERS["md"](pop, m, seed=0),
        "algorithm1": SAMPLERS["algorithm1"](pop, m, seed=0),
        "algorithm2": Algorithm2Sampler(pop, m, update_dim=d, seed=0),
        "target": TargetSampler(pop, m, [np.arange(i * 10, (i + 1) * 10) for i in range(10)], seed=0),
    }
    for name, sampler in samplers.items():
        t0 = time.perf_counter()
        res = run_fl(ds, sampler, rounds=ROUNDS, n_local=10, batch=50, lr=0.05)
        us = (time.perf_counter() - t0) * 1e6 / ROUNDS
        emit(
            f"fig1/{name}",
            us,
            f"loss={res['final_loss']:.4f};acc={res['final_acc']:.3f};"
            f"classes={res['mean_distinct_classes']:.2f};clients={res['mean_distinct_clients']:.2f}",
        )


if __name__ == "__main__":
    main()
