"""Continuous-service churn sweep: time-to-accuracy and sustained rounds/s
under client churn and mid-round dropout.

Two claims are on trial:

1. **Zero-churn parity** — the availability/drop-resolution phases must be
   free when nothing churns: the ``static`` cell's History must be
   *bit-identical* to the plain batch loop's (same spec, no population
   process attached). This is the refactor's no-regression gate, asserted
   on every invocation.
2. **Graceful degradation** — under increasing churn/dropout the service
   keeps making progress (unbiased over the available set), paying in
   time-to-accuracy rather than in crashes. Reported per scenario:
   rounds-to-target-accuracy, final accuracy, degraded-round fraction and
   sustained rounds/s.

Usage (module form — `benchmarks` is a package):
  PYTHONPATH=src python -m benchmarks.bench_service_churn [--smoke]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import PAPER_TRAIN, emit
from repro.fl.experiment import ExperimentSpec, build_dataset, build_experiment

DIM = 16

#: the ≥3 churn/dropout scenarios swept, mildest to harshest; "static" is
#: the parity baseline (a real process with all-true masks, zero drops)
SCENARIOS = (
    ("static", {"name": "static"}),
    ("dropout10", {"name": "dropout", "options": {"rate": 0.1}}),
    ("dropout30", {"name": "dropout", "options": {"rate": 0.3}}),
    ("poisson", {"name": "poisson", "options": {"leave_rate": 0.3, "join_rate": 0.3}}),
    ("diurnal+drop", {"name": "periodic", "options": {"period": 8, "duty": 0.5, "drop_rate": 0.1}}),
)


def _base_spec(rounds: int, smoke: bool) -> dict:
    data_opts = (
        {"clients_per_class": 2, "train_per_client": 40, "dim": 8, "n_classes": 4, "seed": 0}
        if smoke
        else {"clients_per_class": 10, "dim": DIM, "noise": 1.0, "seed": 0}
    )
    train = dict(PAPER_TRAIN, n_rounds=rounds, seed=0)
    if smoke:
        train.update(n_local_steps=3, batch_size=10)
    return {
        "data": {"name": "by_class_shards", "options": data_opts},
        "sampler": {"name": "algorithm2", "m": 4 if smoke else 10},
        "train": train,
    }


def _run(spec_dict: dict, dataset) -> tuple:
    spec = ExperimentSpec.from_dict(spec_dict)
    with build_experiment(spec, dataset=dataset) as srv:
        t0 = time.perf_counter()
        hist = srv.run(skip_empty=True)
        wall = time.perf_counter() - t0
    return hist, wall


def main(argv: "list[str] | None" = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny sizes for CI")
    ap.add_argument("--target-acc", type=float, default=0.9)
    args = ap.parse_args([] if argv is None else argv)

    rounds = 8 if args.smoke else 40
    base = _base_spec(rounds, args.smoke)
    dataset = build_dataset(base["data"])

    # parity gate: the batch loop (no population process at all) vs the
    # service path with an explicit static process — bit-identical histories
    batch_hist, _ = _run(base, dataset)
    for label, pop in SCENARIOS:
        hist, wall = _run({**base, "population": pop}, dataset)
        acc = hist.series("test_acc")
        status = hist.series("round_status")
        hit = np.flatnonzero(np.nan_to_num(acc, nan=-1.0) >= args.target_acc)
        tta = int(hit[0]) + 1 if hit.size else -1
        degraded = float(np.mean(status == "degraded"))
        rps = len(hist.records) / wall if wall > 0 else float("inf")
        extra = ""
        if label == "static":
            a, b = batch_hist, hist
            identical = len(a.records) == len(b.records) and all(
                ra.train_loss == rb.train_loss
                and ra.test_acc == rb.test_acc
                and np.array_equal(ra.agg_weights, rb.agg_weights)
                for ra, rb in zip(a.records, b.records)
            )
            assert identical, (
                "zero-churn service history diverged from the batch loop — "
                "the availability phases are not free"
            )
            extra = ";parity=bit-identical"
        emit(
            f"service_churn/{label}",
            wall * 1e6 / max(len(hist.records), 1),
            f"rounds_to_acc{args.target_acc}={tta};final_acc={float(acc[np.isfinite(acc)][-1]):.4f};"
            f"degraded_frac={degraded:.2f};rounds_per_s={rps:.2f}{extra}",
        )


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
