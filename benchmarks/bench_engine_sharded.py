"""Sharded batched round engine: throughput + per-device staged bytes vs
mesh size.

The engine's client axis is embarrassingly parallel — with a mesh, each
data-parallel group plays one sampled client and the staged dataset is
sharded over its client axis, so per-device pinned bytes shrink with the
mesh while the round stays one jitted step (the weighted aggregation is the
single cross-client collective).

Usage (module form — `benchmarks` is a package):
  PYTHONPATH=src python -m benchmarks.bench_engine_sharded [--smoke]

Run standalone, the module forces a 4-device host platform before jax
initializes; under ``benchmarks.run`` (jax already up) it degrades to the
mesh sizes the visible devices allow. Host-platform "devices" are threads
carved out of the same CPU, so wall-clock on this sweep measures collective
overhead, not scaling — the per-device staged bytes column is the
hardware-independent signal; throughput gains need real multi-chip meshes.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if "jax" not in sys.modules:  # standalone run: give ourselves a host mesh
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")


def _rounds_per_sec(dataset, m: int, mesh_spec, *, rounds: int, dim: int, cfg_kw):
    from repro.fl import FLConfig, FederatedServer
    from repro.fl.experiment import build_sampler
    from repro.models.simple import init_mlp
    from repro.optim import sgd

    params = init_mlp((dim, 32, 10), seed=1)
    cfg = FLConfig(
        n_rounds=rounds, seed=0, eval_every=10**9, engine="batched",
        mesh_spec=mesh_spec, **cfg_kw,
    )
    sampler = build_sampler({"name": "md", "m": m, "seed": 0}, dataset.population)
    with FederatedServer(dataset, sampler, params, sgd(0.05), cfg) as srv:
        srv.run_round(0)  # warm-up: compile
        t0 = time.perf_counter()
        for t in range(1, rounds + 1):
            srv.run_round(t)
        return rounds / (time.perf_counter() - t0), srv._engine.per_device_staged_bytes()


def main(argv: "list[str] | None" = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny sizes for CI")
    # programmatic callers (benchmarks.run) pass no argv and get defaults
    args = ap.parse_args([] if argv is None else argv)

    import jax

    from benchmarks.bench_round_engine import _dataset
    from benchmarks.common import emit
    from repro.fl.engine import staged_bytes

    dim, m = 16, 8
    rounds = 3 if args.smoke else 10
    cfg_kw = dict(
        n_local_steps=4 if args.smoke else 10, batch_size=16 if args.smoke else 32
    )
    dataset = _dataset(n_clients=80, dim=dim, per_client=50 if args.smoke else 200)
    avail = jax.local_device_count()
    sizes = [d for d in (1, 2, 4) if d <= avail]
    total = staged_bytes(dataset, m, cfg_kw["n_local_steps"], cfg_kw["batch_size"])

    base_rps = None
    for d in sizes:
        spec = None if d == 1 else f"{d}x1"
        rps, per_dev = _rounds_per_sec(
            dataset, m, spec, rounds=rounds, dim=dim, cfg_kw=cfg_kw
        )
        base_rps = base_rps or rps
        emit(
            f"engine_sharded/mesh={d}x1",
            1e6 / rps,
            f"us per round; per_device_staged={per_dev / 2**20:.2f}MiB "
            f"(total_estimate={total / 2**20:.2f}MiB); speedup={rps / base_rps:.2f}x",
        )
    if len(sizes) == 1:
        emit(
            "engine_sharded/single_device_only",
            0.0,
            "run standalone (module sets --xla_force_host_platform_device_count=4) "
            "for the multi-device sweep",
        )


if __name__ == "__main__":
    main(sys.argv[1:])
