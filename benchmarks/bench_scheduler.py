"""Round-scheduler sweep: sync / deadline / overselect under poisson churn.

Two claims are on trial:

1. **Sync parity** — the scheduler hooks must be free when the policy is
   the legacy one: a server with an explicit :class:`SyncScheduler`
   attached must train **bit-identically** to one with no scheduler at all
   (same spec, scheduler=None). This is the subsystem's no-regression
   gate, asserted on every invocation.
2. **Straggler grading beats straggler dropping** — under a 30% straggler
   latency model the deadline scheduler keeps harvesting late updates into
   the next round's gradient store (``n_harvested > 0``) instead of
   forgetting slow clients, and overselection keeps rounds full by drawing
   ``m·(1+β)`` up front. Reported per scheduler: time-to-accuracy,
   final accuracy, degraded-round fraction, total late/harvested counts
   and sustained rounds/s.

Usage (module form — `benchmarks` is a package):
  PYTHONPATH=src python -m benchmarks.bench_scheduler [--smoke]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import PAPER_TRAIN, emit
from repro.fl.experiment import ExperimentSpec, build_dataset, build_experiment

DIM = 16

#: the scheduler policies swept; "sync" is the parity baseline
POLICIES = (
    ("sync", {"name": "sync"}),
    (
        "deadline",
        {
            "name": "deadline",
            "options": {"straggle_frac": 0.3, "harvest_discount": 0.5},
            "track_availability": True,
        },
    ),
    ("overselect", {"name": "overselect", "options": {"beta": 0.5}}),
)

#: mild churn so availability conditioning is exercised alongside lateness
CHURN = {"name": "poisson", "options": {"leave_rate": 0.2, "join_rate": 0.2}}


def _base_spec(rounds: int, smoke: bool) -> dict:
    data_opts = (
        {"clients_per_class": 2, "train_per_client": 40, "dim": 8, "n_classes": 4, "seed": 0}
        if smoke
        else {"clients_per_class": 10, "dim": DIM, "noise": 1.0, "seed": 0}
    )
    train = dict(PAPER_TRAIN, n_rounds=rounds, seed=0)
    if smoke:
        train.update(n_local_steps=3, batch_size=10)
    return {
        "data": {"name": "by_class_shards", "options": data_opts},
        "sampler": {"name": "algorithm2", "m": 4 if smoke else 10},
        "train": train,
        "population": CHURN,
    }


def _run(spec_dict: dict, dataset) -> tuple:
    spec = ExperimentSpec.from_dict(spec_dict)
    with build_experiment(spec, dataset=dataset) as srv:
        t0 = time.perf_counter()
        hist = srv.run(skip_empty=True)
        wall = time.perf_counter() - t0
    return hist, wall


def _assert_bit_identical(a, b, what: str) -> None:
    identical = len(a.records) == len(b.records) and all(
        ra.train_loss == rb.train_loss
        and ra.test_acc == rb.test_acc
        and np.array_equal(ra.agg_weights, rb.agg_weights)
        for ra, rb in zip(a.records, b.records)
    )
    assert identical, what


def main(argv: "list[str] | None" = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny sizes for CI")
    ap.add_argument("--target-acc", type=float, default=0.9)
    args = ap.parse_args([] if argv is None else argv)

    rounds = 8 if args.smoke else 40
    base = _base_spec(rounds, args.smoke)
    dataset = build_dataset(base["data"])

    # parity gate: no scheduler section at all (the exact legacy path)
    legacy_hist, _ = _run(base, dataset)
    for label, sched in POLICIES:
        hist, wall = _run({**base, "scheduler": sched}, dataset)
        if label == "sync":
            _assert_bit_identical(
                legacy_hist,
                hist,
                "explicit SyncScheduler history diverged from the "
                "scheduler-free server — the scheduler hooks are not free",
            )
        acc = hist.series("test_acc")
        status = hist.series("round_status")
        hit = np.flatnonzero(np.nan_to_num(acc, nan=-1.0) >= args.target_acc)
        tta = int(hit[0]) + 1 if hit.size else -1
        degraded = float(np.mean(status == "degraded"))
        n_late = int(hist.series("n_late").sum())
        n_harv = int(hist.series("n_harvested").sum())
        rps = len(hist.records) / wall if wall > 0 else float("inf")
        extra = ";parity=bit-identical" if label == "sync" else ""
        if label == "deadline":
            # 30% stragglers over 8+ rounds: the harvest path must fire, or
            # the buffer never reaches the store and slow clients go stale
            assert n_late > 0, "deadline scheduler saw no stragglers"
            assert n_harv > 0, (
                "deadline scheduler harvested nothing — late updates never "
                "reached the next round's gradient store"
            )
        finite = acc[np.isfinite(acc)]
        final_acc = float(finite[-1]) if finite.size else float("nan")
        emit(
            f"scheduler/{label}",
            wall * 1e6 / max(len(hist.records), 1),
            f"rounds_to_acc{args.target_acc}={tta};final_acc={final_acc:.4f};"
            f"degraded_frac={degraded:.2f};n_late={n_late};n_harvested={n_harv};"
            f"rounds_per_s={rps:.2f}{extra}",
        )


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
