"""Benchmark harness — one module per paper table/figure.

``--spec '<json>'`` (inline or a file path) instead runs ONE declarative
experiment through ``repro.fl.experiment`` and streams its per-round
records — the scenario door for comparison studies and the tier-1 smoke
for the spec layer.

Prints ``name,us_per_call,derived`` CSV rows:
  fig1_controlled      — Figure 1 (controlled MNIST-style setting)
  fig2_dirichlet       — Figure 2 (Dirichlet-α heterogeneity sweep)
  table_variance       — Section 3.2 / Appendix B statistics (theory vs MC)
  ablations            — Appendix D.2/D.4/D.5
  bench_sampler_cost   — Theorems 3/4 complexity scaling
  bench_kernels        — Pallas kernel paths + oracles
  bench_fl_collectives — communication accounting (paper's motivation)
  bench_round_engine   — batched on-device round engine vs compat loop
  bench_engine_sharded — mesh-sharded engine: per-device staged bytes sweep
  bench_async_planner  — async re-clustering planner + streamed similarity
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    ablations,
    bench_async_planner,
    bench_dryrun_roofline,
    bench_engine_sharded,
    bench_fl_collectives,
    bench_kernels,
    bench_round_engine,
    bench_sampler_cost,
    beyond_paper,
    fig1_controlled,
    fig2_dirichlet,
    table_variance,
)

MODULES = [
    ("table_variance", table_variance),
    ("bench_sampler_cost", bench_sampler_cost),
    ("bench_round_engine", bench_round_engine),
    ("bench_engine_sharded", bench_engine_sharded),
    ("bench_async_planner", bench_async_planner),
    ("bench_fl_collectives", bench_fl_collectives),
    ("bench_kernels", bench_kernels),
    ("bench_dryrun_roofline", bench_dryrun_roofline),
    ("fig1_controlled", fig1_controlled),
    ("fig2_dirichlet", fig2_dirichlet),
    ("ablations", ablations),
    ("beyond_paper", beyond_paper),
]


def run_one_spec(spec_arg: str) -> None:
    """Run a single experiment spec (inline JSON or a path to a JSON file)."""
    from benchmarks.common import emit, run_spec
    from repro.fl.experiment import ExperimentSpec

    spec = ExperimentSpec.from_arg(spec_arg)
    label = f"spec/{spec.data.name}/{spec.sampler.name}"
    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    res = run_spec(  # per-round records stream through the server's hook
        spec,
        on_round=lambda rec: emit(
            f"{label}/round={rec.round}", 0.0,
            f"loss={rec.train_loss:.4f};plan_v={rec.plan_version};"
            f"lag={rec.plan_lag_rounds}",
        ),
    )
    us = (time.perf_counter() - t0) * 1e6 / spec.train.n_rounds
    emit(label, us, f"loss={res['final_loss']:.4f};acc={res['final_acc']:.3f}")


def main(argv: "list[str] | None" = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--spec", default=None,
        help="experiment-spec JSON (inline or a file path): run that one "
        "declarative scenario instead of the full benchmark sweep",
    )
    args = ap.parse_args(sys.argv[1:] if argv is None else argv)
    if args.spec:
        run_one_spec(args.spec)
        return
    print("name,us_per_call,derived")
    failures = []
    for name, mod in MODULES:
        t0 = time.time()
        try:
            mod.main()
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        raise SystemExit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
