"""Benchmark harness — one module per paper table/figure.

``--spec '<json>'`` (inline or a file path) instead runs ONE declarative
experiment through ``repro.fl.experiment`` and streams its per-round
records — the scenario door for comparison studies and the tier-1 smoke
for the spec layer.

``--sweep '<json>'`` runs a whole campaign (``repro.fl.sweep.SweepSpec``:
grid × seeds) into a resumable RunStore (``--store DIR``, ephemeral when
omitted; ``--workers k`` fans independent cells over a process pool) and
collates it into figure-ready CSVs. ``--list`` prints every registered
sampler / engine / dataset / population / clusterer / benchmark module —
the discoverability door for the spec and sweep layers.

Prints ``name,us_per_call,derived`` CSV rows:
  fig1_controlled      — Figure 1 (controlled MNIST-style setting)
  fig2_dirichlet       — Figure 2 (Dirichlet-α heterogeneity sweep)
  table_variance       — Section 3.2 / Appendix B statistics (theory vs MC)
  ablations            — Appendix D.2/D.4/D.5
  bench_sampler_cost   — Theorems 3/4 complexity scaling
  bench_kernels        — Pallas kernel paths + oracles
  bench_fl_collectives — communication accounting (paper's motivation)
  bench_round_engine   — batched on-device round engine vs compat loop
  bench_engine_sharded — mesh-sharded engine: per-device staged bytes sweep
  bench_async_planner  — async re-clustering planner + streamed similarity
  bench_store_scale    — sketched GradientStore: bytes/scatter/rebuild at scale
  bench_scheduler      — round schedulers (sync/deadline/overselect) under churn
  scheme_race          — every registered selection scheme raced on one sweep
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    ablations,
    bench_async_planner,
    bench_dryrun_roofline,
    bench_engine_sharded,
    bench_fl_collectives,
    bench_kernels,
    bench_round_engine,
    bench_sampler_cost,
    bench_scheduler,
    bench_store_scale,
    beyond_paper,
    fig1_controlled,
    fig2_dirichlet,
    scheme_race,
    table_variance,
)

MODULES = [
    ("table_variance", table_variance),
    ("bench_sampler_cost", bench_sampler_cost),
    ("bench_round_engine", bench_round_engine),
    ("bench_engine_sharded", bench_engine_sharded),
    ("bench_async_planner", bench_async_planner),
    ("bench_store_scale", bench_store_scale),
    ("bench_scheduler", bench_scheduler),
    ("bench_fl_collectives", bench_fl_collectives),
    ("bench_kernels", bench_kernels),
    ("bench_dryrun_roofline", bench_dryrun_roofline),
    ("fig1_controlled", fig1_controlled),
    ("fig2_dirichlet", fig2_dirichlet),
    ("scheme_race", scheme_race),
    ("ablations", ablations),
    ("beyond_paper", beyond_paper),
]


def run_one_spec(spec_arg: str) -> None:
    """Run a single experiment spec (inline JSON or a path to a JSON file)."""
    from benchmarks.common import emit, run_spec
    from repro.fl.experiment import ExperimentSpec

    spec = ExperimentSpec.from_arg(spec_arg)
    label = f"spec/{spec.data.name}/{spec.sampler.name}"
    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    res = run_spec(  # per-round records stream through the server's hook
        spec,
        on_round=lambda rec: emit(
            f"{label}/round={rec.round}", 0.0,
            f"loss={rec.train_loss:.4f};plan_v={rec.plan_version};"
            f"lag={rec.plan_lag_rounds}",
        ),
    )
    us = (time.perf_counter() - t0) * 1e6 / spec.train.n_rounds
    emit(label, us, f"loss={res['final_loss']:.4f};acc={res['final_acc']:.3f}")


def run_one_sweep(sweep_arg: str, store_dir: "str | None", workers: int) -> None:
    """Run a whole campaign through the resumable sweep runner + collate."""
    import contextlib
    import tempfile

    from benchmarks.common import emit
    from repro.fl.sweep import SweepSpec, cell_group_label, run_sweep, write_collated

    sweep = SweepSpec.from_arg(sweep_arg)
    print("name,us_per_call,derived")

    def on_cell(cell, status, summary, dt):
        label = cell_group_label(cell.overrides) or "base"
        rounds = max(cell.spec.train.n_rounds, 1)
        emit(
            f"sweep/{label}/seed={cell.seed_index}",
            dt * 1e6 / rounds,
            f"status={status};loss={summary['final_loss']:.4f}",
        )

    with contextlib.ExitStack() as stack:
        root = store_dir or stack.enter_context(tempfile.TemporaryDirectory(prefix="sweep-"))
        store = run_sweep(sweep, root, workers=workers, on_cell=on_cell)
        cells_csv, summary_csv = write_collated(store)
        print(f"# collated: {cells_csv}")
        print(f"# collated: {summary_csv}")


def list_registered() -> None:
    """Print every registered name the spec/sweep doors can reach."""
    from repro.core.clustering import CLUSTERERS
    from repro.core.samplers import SAMPLERS
    from repro.fl.engine import ENGINES
    from repro.fl.experiment import DATASETS
    from repro.fl.population import POPULATIONS
    from repro.fl.scheduler import SCHEDULERS
    from repro.kernels.sketch import SKETCHERS

    print("samplers:    " + " ".join(SAMPLERS.names()))
    print("engines:     " + " ".join(ENGINES.names()))
    print("datasets:    " + " ".join(DATASETS.names()))
    print("populations: " + " ".join(POPULATIONS.names()))
    print("clusterers:  " + " ".join(CLUSTERERS.names()))
    print("sketchers:   " + " ".join(SKETCHERS.names()))
    print("schedulers:  " + " ".join(SCHEDULERS.names()))
    print("benchmarks:  " + " ".join(name for name, _ in MODULES))


def main(argv: "list[str] | None" = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--spec", default=None,
        help="experiment-spec JSON (inline or a file path): run that one "
        "declarative scenario instead of the full benchmark sweep",
    )
    ap.add_argument(
        "--sweep", default=None,
        help="sweep-spec JSON (inline or a file path): run a whole campaign "
        "(grid x seeds) through the resumable RunStore and collate it",
    )
    ap.add_argument(
        "--store", default=None,
        help="RunStore directory for --sweep (resumable; ephemeral if omitted)",
    )
    ap.add_argument(
        "--workers", type=int, default=1,
        help="process-pool fan-out for independent --sweep cells",
    )
    ap.add_argument(
        "--list", action="store_true",
        help="print registered samplers / engines / datasets / populations / "
        "clusterers / benchmark modules",
    )
    args = ap.parse_args(sys.argv[1:] if argv is None else argv)
    if args.list:
        list_registered()
        return
    if args.spec and args.sweep:
        ap.error("--spec and --sweep are mutually exclusive")
    if args.spec:
        run_one_spec(args.spec)
        return
    if args.sweep:
        run_one_sweep(args.sweep, args.store, args.workers)
        return
    print("name,us_per_call,derived")
    failures = []
    for name, mod in MODULES:
        t0 = time.time()
        try:
            mod.main()
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        raise SystemExit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
