"""Shared benchmark helpers: timed CSV rows + spec-driven FL runs."""
from __future__ import annotations

import time

import numpy as np

ROWS: list[tuple[str, float, str]] = []

#: train-hyperparameter block shared by the paper-figure scenario matrices
#: (the paper's N=10 local steps, B=50, lr=0.05 on the 1x50 MLP)
PAPER_TRAIN = {"n_local_steps": 10, "batch_size": 50, "lr": 0.05, "seed": 0}


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


def timed(fn, *args, repeats: int = 3, warmup: int = 1, **kw) -> tuple[float, object]:
    out = None
    for _ in range(warmup):
        out = fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return dt * 1e6, out


def summarize(hist, rounds: int) -> dict:
    """The figure-level summary statistics of one run's History."""
    losses = hist.series("train_loss")
    roll = hist.rolling("train_loss", window=min(10, rounds))
    return {
        "final_loss": float(roll[-1]),
        "first_loss": float(losses[0]),
        "final_acc": float(np.nanmax(hist.series("test_acc")[-3:])),
        "mean_distinct_classes": float(hist.series("n_distinct_classes").mean()),
        "mean_distinct_clients": float(hist.series("n_distinct_clients").mean()),
    }


def run_spec(spec, *, dataset=None, on_round=None) -> dict:
    """Run one declarative experiment and return its summary statistics.

    ``spec`` is an ``ExperimentSpec`` or its dict form; ``dataset``
    short-circuits the data section so a scenario matrix sharing one
    partition builds it once. The context manager guarantees async planner
    workers are released, and ``on_round`` streams each ``RoundRecord`` as
    it lands (the server's telemetry hook) — no hand-rolled collection.
    """
    from repro.fl.experiment import ExperimentSpec, build_experiment

    spec = ExperimentSpec.from_dict(spec) if isinstance(spec, dict) else spec
    with build_experiment(spec, dataset=dataset) as srv:
        hist = srv.run(on_round=on_round)
    return summarize(hist, spec.train.n_rounds)
