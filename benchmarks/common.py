"""Shared benchmark helpers: timed CSV rows + spec/sweep-driven FL runs."""
from __future__ import annotations

import contextlib
import os
import tempfile
import time

ROWS: list[tuple[str, float, str]] = []

#: train-hyperparameter block shared by the paper-figure scenario matrices
#: (the paper's N=10 local steps, B=50, lr=0.05 on the 1x50 MLP). Seeds are
#: NOT pinned here: the sweep layer derives per-replicate data/sampler/train
#: seeds from SeedSequence(root_seed), so "variance" comparisons never share
#: one stream across replicates (they *do* share streams across schemes of
#: the same replicate — paired comparisons, as in the paper's figures).
PAPER_TRAIN = {"n_local_steps": 10, "batch_size": 50, "lr": 0.05}

#: default summary stats emitted per grid point by run_sweep_emit
EMIT_STATS = {"loss": "final_loss", "acc": "final_acc"}


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


def timed(fn, *args, repeats: int = 3, warmup: int = 1, **kw) -> tuple[float, object]:
    out = None
    for _ in range(warmup):
        out = fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return dt * 1e6, out


def summarize(hist, rounds: int) -> dict:
    """The figure-level summary statistics of one run's History."""
    from repro.fl.sweep import summarize_history

    return summarize_history(hist, rounds)


def run_spec(spec, *, dataset=None, on_round=None) -> dict:
    """Run one declarative experiment and return its summary statistics.

    ``spec`` is an ``ExperimentSpec`` or its dict form; ``dataset``
    short-circuits the data section so a scenario matrix sharing one
    partition builds it once. The context manager guarantees async planner
    workers are released, and ``on_round`` streams each ``RoundRecord`` as
    it lands (the server's telemetry hook) — no hand-rolled collection.
    """
    from repro.fl.experiment import ExperimentSpec, build_experiment

    spec = ExperimentSpec.from_dict(spec) if isinstance(spec, dict) else spec
    with build_experiment(spec, dataset=dataset) as srv:
        hist = srv.run(on_round=on_round)
    return summarize(hist, spec.train.n_rounds)


def run_sweep_emit(
    sweep, label: str, *, stats: "dict[str, str] | None" = None, workers: int = 1
) -> list[dict]:
    """Run a SweepSpec through the shared campaign runner; emit mean±std rows.

    One ``emit`` row per grid point (``label/axis=value/...``) carrying
    ``short=mean±std`` for each stat in ``stats`` (default loss/acc) and
    the mean per-round wall time of the grid point's cells. The RunStore
    is ephemeral unless ``$BENCH_SWEEP_STORE`` is set, in which case the
    campaign is resumable and leaves its figure-ready ``cells.csv`` /
    ``summary.csv`` behind under ``$BENCH_SWEEP_STORE/<label>``.
    Returns the aggregated rows for derived emits (e.g. fig2's gain).
    """
    from repro.fl.sweep import SweepSpec, collate, run_sweep, write_collated

    sweep = SweepSpec.from_dict(sweep) if isinstance(sweep, dict) else sweep
    stats = EMIT_STATS if stats is None else stats
    durations: dict[str, float] = {}
    with contextlib.ExitStack() as stack:
        if os.environ.get("BENCH_SWEEP_STORE"):
            root = os.path.join(os.environ["BENCH_SWEEP_STORE"], label.replace("/", "_"))
        else:
            root = stack.enter_context(tempfile.TemporaryDirectory(prefix=f"sweep-{label.replace('/', '_')}-"))
        # only freshly-run cells carry a real wall time; resumed (skipped)
        # cells must not drag the emitted per-round timing toward zero
        store = run_sweep(
            sweep, root, workers=workers,
            on_cell=lambda cell, status, summary, dt: (
                durations.__setitem__(cell.cell_id, dt) if status == "ran" else None
            ),
        )
        cell_rows, agg_rows = collate(store)
        write_collated(store, rows=(cell_rows, agg_rows))
    axis_paths = list(sweep.axes)
    rounds = sweep.base.train.n_rounds
    for row in agg_rows:
        group = [r for r in cell_rows if r["grid"] == row["grid"]]
        dts = [durations[r["cell"]] for r in group if r["cell"] in durations]
        us = (sum(dts) / len(dts)) * 1e6 / max(rounds, 1) if dts else 0.0
        name = "/".join(
            [label] + [f"{p.split('.')[-1]}={row[p]}" for p in axis_paths]
        )
        derived = ";".join(
            f"{short}={row[f'{stat}_mean']:.4f}±{row[f'{stat}_std']:.4f}"
            for short, stat in stats.items()
        )
        emit(name, us, f"{derived};seeds={row['n_seeds']}")
    return agg_rows
