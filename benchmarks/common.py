"""Shared benchmark helpers: timed CSV rows + small FL runs."""
from __future__ import annotations

import time

import numpy as np

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


def timed(fn, *args, repeats: int = 3, warmup: int = 1, **kw) -> tuple[float, object]:
    out = None
    for _ in range(warmup):
        out = fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return dt * 1e6, out


def run_fl(dataset, sampler, *, rounds, n_local, batch, lr, mu=0.0, seed=0):
    """Small FL run returning (final rolling loss, final acc, mean distinct classes)."""
    import jax

    from repro.fl import FederatedServer, FLConfig
    from repro.models.simple import fedprox_loss, init_mlp

    dim = dataset.clients[0].x_train.shape[1]
    params = init_mlp((dim, 50, 10), seed=1)  # the paper's 1x50 hidden MLP
    from repro.optim import sgd

    cfg = FLConfig(n_rounds=rounds, n_local_steps=n_local, batch_size=batch, seed=seed, fedprox_mu=mu)
    kw = {"loss_fn": fedprox_loss} if mu else {}
    srv = FederatedServer(dataset, sampler, params, sgd(lr), cfg, **kw)
    hist = srv.run()
    del jax
    losses = hist.series("train_loss")
    roll = hist.rolling("train_loss", window=min(10, rounds))
    return {
        "final_loss": float(roll[-1]),
        "first_loss": float(losses[0]),
        "final_acc": float(np.nanmax(hist.series("test_acc")[-3:])),
        "mean_distinct_classes": float(hist.series("n_distinct_classes").mean()),
        "mean_distinct_clients": float(hist.series("n_distinct_clients").mean()),
    }
