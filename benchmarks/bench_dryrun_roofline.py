"""Roofline summary benchmark: reads experiments/dryrun/*.json (produced by
``python -m repro.launch.dryrun``) and emits one CSV row per (arch × shape ×
mesh × variant) with the three roofline terms. Skips silently when the
dry-run artifacts are absent (CPU-only test environments)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def main() -> None:
    files = sorted(glob.glob(os.path.join("experiments", "dryrun", "*.json")))
    if not files:
        emit("roofline/none", 0.0, "run `python -m repro.launch.dryrun --all` first")
        return
    for f in files:
        with open(f) as fh:
            d = json.load(fh)
        tag = "+".join(d.get("variants") or []) or "baseline"
        if d.get("kind") == "fl_round":
            emit(
                f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}/{tag}",
                d["t_collective_per_step"] * 1e6,
                f"coll_per_step={d['coll_bytes_per_chip_per_step'] / 2**20:.1f}MiB;"
                f"tx_per_step={d['t_collective_per_step'] * 1e3:.2f}ms",
            )
            continue
        emit(
            f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}/{tag}",
            d["bound_time"] * 1e6 if "bound_time" in d else max(
                d["t_compute"], d["t_memory"], d["t_collective"]
            ) * 1e6,
            f"tc={d['t_compute'] * 1e3:.2f}ms;tm={d['t_memory'] * 1e3:.2f}ms;"
            f"tx={d['t_collective'] * 1e3:.2f}ms;dom={d['dominant']};"
            f"util={d['utility_ratio']:.3f};hbm={d['hbm_per_chip_gb']}GB",
        )


if __name__ == "__main__":
    main()
