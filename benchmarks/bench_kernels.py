"""Kernel microbenchmarks.

On this CPU container the Pallas kernels run in interpret mode (a Python
emulator — timings are NOT TPU numbers and are reported only as
correctness-path cost); the jnp oracle timings are the XLA:CPU reference.
The derived column reports bytes/FLOPs so TPU projections can be made from
the roofline constants.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels.aggregate.ref import aggregate_ref
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.similarity.ref import gram_ref
from repro.kernels.similarity.ops import pairwise_distances_device


def main() -> None:
    rng = np.random.default_rng(0)

    # similarity: n=100 clients (paper scale), d = MLP parameter count
    n, d = 100, 2060
    G = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    us, _ = timed(lambda: np.asarray(gram_ref(G)))
    emit("kernels/similarity_gram_ref_cpu", us, f"n={n};d={d};flops={2 * n * n * d:.2e}")
    us, _ = timed(
        lambda: np.asarray(pairwise_distances_device(G, "arccos", interpret=True)), repeats=1
    )
    emit("kernels/similarity_pallas_interpret", us, "mode=interpret;NOT_tpu_time")

    # aggregation: m=10 clients × 1M-param model
    k, p = 10, 1_000_000
    U = jnp.asarray(rng.normal(size=(k, p)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k,)), jnp.float32)
    us, _ = timed(lambda: np.asarray(aggregate_ref(U, w)))
    emit("kernels/aggregate_ref_cpu", us, f"k={k};p={p};bytes={4 * k * p:.2e}")

    # flash attention: small block sweep
    q = jnp.asarray(rng.normal(size=(1, 256, 8, 64)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    us, _ = timed(lambda: np.asarray(attention_ref(q, kk, v)))
    emit("kernels/flash_attention_ref_cpu", us, "b=1;s=256;h=8;kv=2;hd=64")


if __name__ == "__main__":
    main()
