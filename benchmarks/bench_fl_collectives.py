"""Communication accounting: the paper's motivation is minimizing
server-client communication. This benchmark quantifies, per FL round and
per synchronous-DP step, the bytes a client/worker exchanges — showing the
N× collective reduction of FL local work vs synchronous data-parallelism,
and that clustered sampling costs ZERO extra bytes over MD sampling
(Section 5: only θ_i - θ differences the server already receives)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.fl.aggregation import flatten_params
from repro.models.simple import init_mlp


def main() -> None:
    params = init_mlp((32, 50, 10))
    p_bytes = int(flatten_params(params).size) * 4
    n_local = 100  # N in the paper
    m = 10

    # per-round bytes per sampled client: download θ + upload θ_i
    fl_round = 2 * p_bytes
    # synchronous DP equivalent: N steps × grad exchange each
    sync = n_local * 2 * p_bytes
    emit("fl_comm/per_client_round_bytes", 0.0, f"bytes={fl_round}")
    emit("fl_comm/sync_dp_equivalent_bytes", 0.0, f"bytes={sync};ratio={sync / fl_round:.0f}x")
    # clustered sampling server-side extra: similarity matrix only (no wire bytes)
    emit("fl_comm/clustered_extra_wire_bytes", 0.0, "bytes=0;server_flops=n^2*d")
    # aggregation traffic at the server: m models in, 1 out
    emit("fl_comm/server_round_bytes", 0.0, f"bytes={(m + m) * p_bytes}")


if __name__ == "__main__":
    main()
