"""Beyond-paper extensions (recorded separately from the faithful repro):

1. stale-aware Algorithm 2 — decay representative gradients by γ per round
   so long-unsampled clients return to the cold-start cluster (the paper
   clusters on arbitrarily stale similarity). Compared at γ ∈ {1.0 (paper),
   0.8, 0.5} under a small m (staleness is worst when few clients refresh
   per round) — a one-axis ``SweepSpec`` over ``staleness_decay`` through
   the shared campaign runner.
2. device-offloaded similarity — Algorithm 2 with the Pallas similarity
   kernel as its distance backend (interpret mode here; MXU path on TPU),
   asserting identical sampling plans to the numpy host path. The two
   backends differ by one spec option (``distance_fn``).
3. client churn — the paper assumes everyone answers every round; the
   continuous-service layer (``repro.fl.population``) relaxes that. One
   ``SweepSpec`` axis over whole ``population`` sections compares clustered
   sampling under a fixed fleet, Poisson arrival/departure churn, and 20%
   mid-round dropout — how much availability-conditioned re-normalization
   costs in final loss/accuracy at matched rounds.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import PAPER_TRAIN, emit, run_sweep_emit
from repro.core import validate_plan
from repro.fl.experiment import DataSpec, build_dataset, build_sampler

DIM = 32
ROUNDS = 12

DATA = {"name": "dirichlet_labels", "options": {"alpha": 0.01, "dim": DIM, "noise": 2.5}}

# NOTE: the decay must be paired with a magnitude-sensitive measure —
# arccos is scale-invariant, so uniformly shrinking stale vectors would
# not change any angle (verified: identical runs under arccos). L2 sees
# the decayed vectors drift toward the zero / cold-start cluster.
SWEEP_STALENESS = {
    "base": {
        "data": DATA,
        "sampler": {"name": "algorithm2", "m": 5, "options": {"measure": "l2"}},
        "train": {"n_rounds": ROUNDS, **PAPER_TRAIN},
    },
    "axes": {"sampler.options.staleness_decay": [1.0, 0.8, 0.5]},
    "root_seed": 4,
}

# churn axis: whole population sections as axis values (the sweep layer
# treats a section-level path as a swap of the entire dict)
SWEEP_CHURN = {
    "base": {
        "data": DATA,
        "sampler": {"name": "algorithm2", "m": 5},
        "train": {"n_rounds": ROUNDS, **PAPER_TRAIN},
    },
    "axes": {
        "population": [
            {"name": "static"},
            {"name": "poisson", "options": {"join_rate": 0.3, "leave_rate": 0.3}},
            {"name": "dropout", "options": {"rate": 0.2}},
        ]
    },
    "root_seed": 4,
}


def main() -> None:
    run_sweep_emit(SWEEP_STALENESS, "beyond/staleness")
    run_sweep_emit(SWEEP_CHURN, "beyond/churn")

    # kernel-backed similarity must produce the identical plan
    ds = build_dataset(DataSpec.from_dict(DATA))
    pop = ds.population
    rng = np.random.default_rng(0)
    d = 128
    G = rng.normal(size=(pop.n_clients, d))
    host, dev = (
        build_sampler(
            {"name": "algorithm2", "m": 10, "options": {"distance_fn": backend}},
            pop,
            update_dim=d,
        )
        for backend in ("numpy", "pallas-interpret")
    )
    ids = np.arange(pop.n_clients)
    host.observe_updates(ids, G)
    dev.observe_updates(ids, G)
    validate_plan(dev.plan, pop)
    same = np.allclose(host.plan.r, dev.plan.r)
    emit("beyond/pallas_similarity_plan_identical", 0.0, f"identical={same}")
    host.close()
    dev.close()


if __name__ == "__main__":
    main()
