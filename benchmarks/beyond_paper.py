"""Beyond-paper extensions (recorded separately from the faithful repro):

1. stale-aware Algorithm 2 — decay representative gradients by γ per round
   so long-unsampled clients return to the cold-start cluster (the paper
   clusters on arbitrarily stale similarity). Compared at γ ∈ {1.0 (paper),
   0.8, 0.5} under a small m (staleness is worst when few clients refresh
   per round).
2. device-offloaded similarity — Algorithm 2 with the Pallas similarity
   kernel as its distance backend (interpret mode here; MXU path on TPU),
   asserting identical sampling plans to the numpy host path.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, run_fl
from repro.core import Algorithm2Sampler, validate_plan
from repro.fl import dirichlet_labels
from repro.fl.aggregation import flatten_params
from repro.models.simple import init_mlp

DIM = 32
ROUNDS = 12


def main() -> None:
    ds = dirichlet_labels(alpha=0.01, dim=DIM, noise=2.5, seed=0)
    pop = ds.population
    d = int(flatten_params(init_mlp((DIM, 50, 10))).shape[0])

    # NOTE: the decay must be paired with a magnitude-sensitive measure —
    # arccos is scale-invariant, so uniformly shrinking stale vectors would
    # not change any angle (verified: identical runs under arccos). L2 sees
    # the decayed vectors drift toward the zero / cold-start cluster.
    for gamma in (1.0, 0.8, 0.5):
        s = Algorithm2Sampler(
            pop, 5, update_dim=d, seed=0, staleness_decay=gamma, measure="l2"
        )
        t0 = time.perf_counter()
        r = run_fl(ds, s, rounds=ROUNDS, n_local=10, batch=50, lr=0.05)
        emit(
            f"beyond/staleness_decay={gamma}",
            (time.perf_counter() - t0) * 1e6 / ROUNDS,
            f"measure=l2;loss={r['final_loss']:.4f};acc={r['final_acc']:.3f}",
        )

    # kernel-backed similarity must produce the identical plan
    from repro.kernels.similarity.ops import make_distance_fn

    rng = np.random.default_rng(0)
    G = rng.normal(size=(pop.n_clients, d))
    host = Algorithm2Sampler(pop, 10, update_dim=d, seed=0, distance_fn="numpy")
    dev = Algorithm2Sampler(pop, 10, update_dim=d, seed=0, distance_fn=make_distance_fn(interpret=True))
    ids = np.arange(pop.n_clients)
    host.observe_updates(ids, G)
    dev.observe_updates(ids, G)
    validate_plan(dev.plan, pop)
    same = np.allclose(host.plan.r, dev.plan.r)
    emit("beyond/pallas_similarity_plan_identical", 0.0, f"identical={same}")


if __name__ == "__main__":
    main()
