"""Figure 2 reproduction: Dirichlet(α) heterogeneity sweep on the paper's
unbalanced 100-client profile. The paper's claim: the smaller α (more
heterogeneous), the larger the improvement of clustered sampling over MD.

The figure is ONE campaign — a ``SweepSpec`` over α × sampler with
N_SEEDS paired replicates (``repro.fl.sweep``); the clustered gain per α
is derived from the collated mean final losses."""
from __future__ import annotations

from benchmarks.common import PAPER_TRAIN, emit, run_sweep_emit

ALPHAS = (0.001, 0.01, 0.1, 10.0)
ROUNDS = 20
DIM = 32
N_SEEDS = 2

SWEEP = {
    "base": {
        "data": {"name": "dirichlet_labels", "options": {"alpha": 0.001, "dim": DIM, "noise": 2.5}},
        "sampler": {"name": "md", "m": 10},
        "train": {"n_rounds": ROUNDS, **PAPER_TRAIN},
    },
    "axes": {
        "data.options.alpha": list(ALPHAS),
        "sampler.name": ["md", "algorithm2"],
    },
    "n_seeds": N_SEEDS,
    "root_seed": 2,
}


def main() -> None:
    agg = run_sweep_emit(SWEEP, "fig2")
    for alpha in ALPHAS:
        rows = {
            r["sampler.name"]: r for r in agg if r["data.options.alpha"] == str(alpha)
        }
        gain = rows["md"]["final_loss_mean"] - rows["algorithm2"]["final_loss_mean"]
        emit(f"fig2/alpha={alpha}/clustered_gain", 0.0, f"loss_delta={gain:.4f}")


if __name__ == "__main__":
    main()
