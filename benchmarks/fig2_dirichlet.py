"""Figure 2 reproduction: Dirichlet(α) heterogeneity sweep on the paper's
unbalanced 100-client profile. The paper's claim: the smaller α (more
heterogeneous), the larger the improvement of clustered sampling over MD."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, run_fl
from repro.core import Algorithm2Sampler, MDSampler
from repro.fl import dirichlet_labels
from repro.fl.aggregation import flatten_params
from repro.models.simple import init_mlp

ALPHAS = (0.001, 0.01, 0.1, 10.0)
ROUNDS = 20
DIM = 32


def main() -> None:
    d = int(flatten_params(init_mlp((DIM, 50, 10))).shape[0])
    for alpha in ALPHAS:
        ds = dirichlet_labels(alpha=alpha, dim=DIM, noise=2.5, seed=0)
        pop = ds.population
        results = {}
        for name, sampler in (
            ("md", MDSampler(pop, 10, seed=0)),
            ("algorithm2", Algorithm2Sampler(pop, 10, update_dim=d, seed=0)),
        ):
            t0 = time.perf_counter()
            results[name] = run_fl(ds, sampler, rounds=ROUNDS, n_local=10, batch=50, lr=0.05)
            us = (time.perf_counter() - t0) * 1e6 / ROUNDS
            r = results[name]
            emit(
                f"fig2/alpha={alpha}/{name}",
                us,
                f"loss={r['final_loss']:.4f};acc={r['final_acc']:.3f}",
            )
        gain = results["md"]["final_loss"] - results["algorithm2"]["final_loss"]
        emit(f"fig2/alpha={alpha}/clustered_gain", 0.0, f"loss_delta={gain:.4f}")


if __name__ == "__main__":
    main()
