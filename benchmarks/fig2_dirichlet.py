"""Figure 2 reproduction: Dirichlet(α) heterogeneity sweep on the paper's
unbalanced 100-client profile. The paper's claim: the smaller α (more
heterogeneous), the larger the improvement of clustered sampling over MD.

The sweep is a spec matrix over α × sampler (repro.fl.experiment)."""
from __future__ import annotations

import time

from benchmarks.common import PAPER_TRAIN, emit, run_spec
from repro.fl.experiment import DataSpec, build_dataset

ALPHAS = (0.001, 0.01, 0.1, 10.0)
ROUNDS = 20
DIM = 32

SAMPLER_SPECS = ({"name": "md", "m": 10}, {"name": "algorithm2", "m": 10})


def main() -> None:
    for alpha in ALPHAS:
        data = {"name": "dirichlet_labels", "options": {"alpha": alpha, "dim": DIM, "noise": 2.5, "seed": 0}}
        ds = build_dataset(DataSpec.from_dict(data))
        results = {}
        for sampler in SAMPLER_SPECS:
            spec = {"data": data, "sampler": sampler, "train": {"n_rounds": ROUNDS, **PAPER_TRAIN}}
            t0 = time.perf_counter()
            results[sampler["name"]] = r = run_spec(spec, dataset=ds)
            us = (time.perf_counter() - t0) * 1e6 / ROUNDS
            emit(
                f"fig2/alpha={alpha}/{sampler['name']}",
                us,
                f"loss={r['final_loss']:.4f};acc={r['final_acc']:.3f}",
            )
        gain = results["md"]["final_loss"] - results["algorithm2"]["final_loss"]
        emit(f"fig2/alpha={alpha}/clustered_gain", 0.0, f"loss_delta={gain:.4f}")


if __name__ == "__main__":
    main()
