"""Round-throughput: batched on-device engine vs the compat per-client loop.

The looped path pays m jitted dispatches + m host-side parameter flattens
per round; the batched engine runs the whole round (local training,
aggregation, representative gradients) as ONE jitted step over a padded
client axis, with the dataset resident on device. The gap widens with m —
the acceptance target is >= 3x at m = 40 on CPU.

Usage (module form — `benchmarks` is a package):
  PYTHONPATH=src python -m benchmarks.bench_round_engine [--smoke]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit
from repro.fl import FLConfig, FederatedServer
from repro.fl.experiment import build_sampler
from repro.models.simple import init_mlp
from repro.optim import sgd


def _dataset(n_clients: int, dim: int, per_client: int):
    from repro.data.federated import ClientData, FederatedDataset

    rng = np.random.default_rng(0)
    clients = []
    for c in range(n_clients):
        x = rng.normal(size=(per_client, dim)).astype(np.float32)
        y = rng.integers(0, 10, size=per_client)
        clients.append(
            ClientData(x_train=x, y_train=y, x_test=x[:8], y_test=y[:8])
        )
    return FederatedDataset(clients)


def _rounds_per_sec(dataset, m: int, engine: str, *, rounds: int, dim: int) -> float:
    params = init_mlp((dim, 32, 10), seed=1)
    cfg = FLConfig(
        n_rounds=rounds, n_local_steps=10, batch_size=32,
        seed=0, eval_every=10**9, engine=engine,
    )
    sampler = build_sampler({"name": "md", "m": m, "seed": 0}, dataset.population)
    with FederatedServer(dataset, sampler, params, sgd(0.05), cfg) as srv:
        srv.run_round(0)  # warm-up: compile
        t0 = time.perf_counter()
        for t in range(1, rounds + 1):
            srv.run_round(t)
        return rounds / (time.perf_counter() - t0)


def main(argv: "list[str] | None" = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny sizes for CI")
    # programmatic callers (benchmarks.run) pass no argv and get defaults;
    # parse_args(None) would read the harness's own sys.argv and SystemExit
    args = ap.parse_args([] if argv is None else argv)

    dim = 16
    ms = (5,) if args.smoke else (5, 10, 40)
    rounds = 3 if args.smoke else 12
    dataset = _dataset(n_clients=80, dim=dim, per_client=100)

    for m in ms:
        rps = {
            engine: _rounds_per_sec(dataset, m, engine, rounds=rounds, dim=dim)
            for engine in ("compat", "batched")
        }
        speedup = rps["batched"] / rps["compat"]
        emit(
            f"round_engine/m={m}/compat", 1e6 / rps["compat"], "us per round"
        )
        emit(
            f"round_engine/m={m}/batched",
            1e6 / rps["batched"],
            f"us per round; speedup={speedup:.2f}x",
        )


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
