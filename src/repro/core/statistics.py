"""Closed-form sampling statistics from Sections 3.2 / Appendix B.

These are the paper's theoretical quantities; the property tests and the
variance benchmark check realized sampling against them.
"""
from __future__ import annotations

import numpy as np

from repro.core.types import ClientPopulation, SamplingPlan


def md_weight_variance(p: np.ndarray, m: int) -> np.ndarray:
    """eq. (13): Var[ω_i] under MD sampling = p_i (1 - p_i) / m."""
    p = np.asarray(p, dtype=np.float64)
    return p * (1.0 - p) / m


def clustered_weight_variance(plan: SamplingPlan) -> np.ndarray:
    """eq. (16): Var[ω_i] under clustered sampling = (1/m²) Σ_k r_{k,i}(1-r_{k,i})."""
    r = plan.r
    return (r * (1.0 - r)).sum(axis=0) / plan.m**2


def md_inclusion_probability(p: np.ndarray, m: int) -> np.ndarray:
    """eq. (20): P(i ∈ S_MD) = 1 - (1 - p_i)^m."""
    p = np.asarray(p, dtype=np.float64)
    return 1.0 - (1.0 - p) ** m


def clustered_inclusion_probability(plan: SamplingPlan) -> np.ndarray:
    """eq. (22): P(i ∈ S_C) = 1 - Π_k (1 - r_{k,i})."""
    return 1.0 - np.prod(1.0 - plan.r, axis=0)


def variance_reduction(plan: SamplingPlan, population: ClientPopulation) -> np.ndarray:
    """Per-client Var_MD - Var_C ≥ 0 (eq. 17 / Appendix B.1).

    Closed form (eq. 49): (1/m²) [ Σ_k r_{k,i}² - m p_i² ].
    """
    p = population.importances
    m = plan.m
    return ((plan.r**2).sum(axis=0) - m * p**2) / m**2


def expected_distinct_clients(plan: SamplingPlan) -> float:
    """E[#distinct sampled clients] = Σ_i P(i ∈ S)."""
    return float(clustered_inclusion_probability(plan).sum())


def md_prob_all_distinct(p: np.ndarray, m: int) -> float:
    """P(all m MD draws are distinct) — permanent over distinct index tuples.

    For the paper's controlled setting (n=100 uniform clients, m=10) this is
    100!/(90! · 100^10) ≈ 63%. Computed exactly only for uniform ``p``;
    otherwise estimated by inclusion–exclusion is exponential, so we Monte
    Carlo (the tests only use the uniform case).
    """
    p = np.asarray(p, dtype=np.float64)
    n = p.shape[0]
    if np.allclose(p, 1.0 / n):
        # n!/(n-m)! / n^m
        val = 1.0
        for j in range(m):
            val *= (n - j) / n
        return float(val)
    rng = np.random.default_rng(0)
    draws = rng.choice(n, size=(20000, m), p=p)
    distinct = np.array([len(np.unique(row)) == m for row in draws])
    return float(distinct.mean())


def empirical_weight_moments(
    sample_fn, n_clients: int, n_rounds: int
) -> tuple[np.ndarray, np.ndarray]:
    """Monte-Carlo mean/variance of aggregation weights over ``n_rounds`` draws."""
    ws = np.empty((n_rounds, n_clients))
    for t in range(n_rounds):
        ws[t] = sample_fn(t).agg_weights
    return ws.mean(axis=0), ws.var(axis=0)
