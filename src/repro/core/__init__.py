"""Clustered client sampling for federated learning (Fraboni et al., ICML'21).

Public API:
  - ClientPopulation / SamplingPlan / SampleResult datatypes
  - samplers: UniformSampler (FedAvg), MDSampler, Algorithm1Sampler,
    Algorithm2Sampler, TargetSampler, generic ClusteredSampler
  - validate_plan: exact Proposition-1 checking
  - statistics: closed-form variance / inclusion-probability formulas
"""
from repro.core.types import ClientPopulation, SamplingPlan, SampleResult
from repro.core.registry import Registry
from repro.core.samplers import (
    SAMPLERS,
    register_sampler,
    Algorithm1Sampler,
    Algorithm2Sampler,
    ClientSampler,
    ClusteredSampler,
    MDSampler,
    TargetSampler,
    UniformSampler,
    build_plan_algorithm1,
    build_plan_algorithm2,
    build_plan_target,
    max_draws_bound,
    validate_plan,
)
from repro.core import statistics

__all__ = [
    "ClientPopulation",
    "SamplingPlan",
    "SampleResult",
    "ClientSampler",
    "UniformSampler",
    "MDSampler",
    "ClusteredSampler",
    "Algorithm1Sampler",
    "Algorithm2Sampler",
    "TargetSampler",
    "build_plan_algorithm1",
    "build_plan_algorithm2",
    "build_plan_target",
    "validate_plan",
    "max_draws_bound",
    "statistics",
    "Registry",
    "SAMPLERS",
    "register_sampler",
]
