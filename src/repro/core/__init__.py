"""Clustered client sampling for federated learning (Fraboni et al., ICML'21).

Public API:
  - ClientPopulation / SamplingPlan / SampleResult datatypes
  - samplers: UniformSampler (FedAvg), MDSampler, Algorithm1Sampler,
    Algorithm2Sampler, TargetSampler, generic ClusteredSampler, and the
    scheme zoo (StratifiedSampler, ImportanceSampler, DPStratifiedSampler,
    HybridSampler) on the shared StoreBackedSampler contract
  - validate_plan: exact Proposition-1 checking
  - statistics: closed-form variance / inclusion-probability formulas
"""
from repro.core.types import ClientPopulation, SamplingPlan, SampleResult
from repro.core.registry import Registry
from repro.core.samplers import (
    SAMPLERS,
    register_sampler,
    Algorithm1Sampler,
    Algorithm2Sampler,
    ClientSampler,
    ClusteredSampler,
    DPStratifiedSampler,
    HybridSampler,
    ImportanceSampler,
    MDSampler,
    StoreBackedSampler,
    StratifiedSampler,
    TargetSampler,
    UniformSampler,
    build_plan_algorithm1,
    build_plan_algorithm2,
    build_plan_hybrid,
    build_plan_stratified,
    build_plan_target,
    max_draws_bound,
    validate_plan,
)
from repro.core import statistics

__all__ = [
    "ClientPopulation",
    "SamplingPlan",
    "SampleResult",
    "ClientSampler",
    "UniformSampler",
    "MDSampler",
    "ClusteredSampler",
    "StoreBackedSampler",
    "Algorithm1Sampler",
    "Algorithm2Sampler",
    "TargetSampler",
    "StratifiedSampler",
    "ImportanceSampler",
    "DPStratifiedSampler",
    "HybridSampler",
    "build_plan_algorithm1",
    "build_plan_algorithm2",
    "build_plan_target",
    "build_plan_stratified",
    "build_plan_hybrid",
    "validate_plan",
    "max_draws_bound",
    "statistics",
    "Registry",
    "SAMPLERS",
    "register_sampler",
]
