"""Shared base for gradient-store-backed, plan-rebuilding samplers.

Every scheme that derives its sampling plan from the clients' representative
gradients shares the same producer/consumer skeleton: updates scatter into a
device-resident :class:`repro.fl.gradient_store.GradientStore`, a
:class:`repro.fl.planner.PlanService` rebuilds the plan (synchronously or on
a background worker, on a fixed cadence or a measured drift trigger), and
the freshest completed plan is swapped in at each round boundary. Algorithm
2 introduced that machinery; :class:`StoreBackedSampler` extracts it so the
scheme zoo (``stratified`` / ``importance`` / ``dp_stratified`` / ``hybrid``
in :mod:`repro.core.samplers.schemes`) is one ``_build_plan`` override away
— sketching, mesh sharding, async rebuilds, drift triggers and crash-safe
checkpointing all come for free.

Subclass contract:

* implement :meth:`_build_plan(G) <StoreBackedSampler._build_plan>` — map a
  (possibly device-resident, possibly sketched) gradient block to a
  :class:`~repro.core.types.SamplingPlan`;
* set :attr:`scheme_name` — rides every checkpoint so a restore into a
  *different* scheme fails loudly instead of silently mixing plan semantics;
* optionally override :meth:`_observe_snapshot` — the value handed to the
  plan service each observed round (``dp_stratified`` clips + noises here);
* optionally set ``validate_plans = False`` for schemes whose plans
  deliberately violate eq. (8) (``importance`` restores unbiasedness by
  re-weighting at draw time instead).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.samplers.clustered import ClusteredSampler
from repro.core.types import ClientPopulation, SamplingPlan, SampleResult


class StoreBackedSampler(ClusteredSampler):
    """Gradient-store + plan-service machinery shared by rebuild schemes."""

    consumes_updates = True

    #: checkpoint identity: restoring a bundle written by one scheme into a
    #: sampler of another raises (see :meth:`load_state`)
    scheme_name: str = "store_backed"

    #: whether plans are held to the exact Proposition-1 conditions on every
    #: swap; ``importance`` opts out (its rows are the proposal ``q``, not an
    #: eq.(8) allocation) and re-weights draws instead
    validate_plans: bool = True

    def __init__(
        self,
        population: ClientPopulation,
        m: int,
        update_dim: int,
        *,
        seed: int = 0,
        staleness_decay: float = 1.0,
        planner: str = "sync",
        rebuild_every: int = 1,
        drift_threshold: Optional[float] = None,
        sketch: Optional[str] = None,
        sketch_dim: Optional[int] = None,
        store_mesh_spec=None,
    ):
        """See :class:`~repro.core.samplers.algorithm2.Algorithm2Sampler`
        for the full knob semantics (``staleness_decay``, ``planner``,
        ``rebuild_every`` / ``drift_threshold``, ``sketch`` / ``sketch_dim``,
        ``store_mesh_spec``) — they are scheme-independent and documented
        once there."""
        from repro.fl.gradient_store import GradientStore
        from repro.fl.planner import PlanService

        self.update_dim = int(update_dim)
        self.staleness_decay = float(staleness_decay)
        # _build_plan runs for the cold-start plan inside PlanService's
        # constructor, before ClusteredSampler.__init__ sets these (and
        # before any tracker could be attached — set that first too)
        self.population = population
        self.m = int(m)
        self._avail_tracker = None
        self._store = GradientStore(
            population.n_clients,
            update_dim,
            staleness_decay=staleness_decay,
            sketch=sketch,
            sketch_dim=sketch_dim,
            sketch_seed=seed,
            mesh_spec=store_mesh_spec,
        )
        self._service = PlanService(
            self._build_plan,
            mode=planner,
            initial_input=self._store.snapshot(),
            rebuild_every=rebuild_every,
            drift_threshold=drift_threshold,
        )
        super().__init__(
            population,
            self._service.current().plan,
            seed=seed,
            validate=self.validate_plans,
        )

    # -- subclass hooks ------------------------------------------------------
    def _build_plan(self, G) -> SamplingPlan:
        """Map the gradient block (n, d') to this scheme's sampling plan."""
        raise NotImplementedError

    # -- availability-aware planning -----------------------------------------
    def attach_availability(self, tracker) -> None:
        """Restrict plan rebuilds to the tracker's recently-seen clients.

        ``tracker`` is a :class:`~repro.fl.availability.AvailabilityTracker`
        (owned and updated by the server). Schemes that honour it (via
        :meth:`_cluster_mask`) cluster only clients with presence score ≥
        the tracker threshold — FedSTaS-style restratification on the
        observed population — while the plan keeps every client's exact
        eq. (8) mass, so conditional draws stay exactly unbiased over
        whichever clients are available. The mask also rides every plan
        observation, giving the drift monitor its churn term.
        """
        self._avail_tracker = tracker

    def _cluster_mask(self):
        """The rebuild's active-client mask, or None for a full-fleet build.

        None when no tracker is attached or when the mask is degenerate
        (all active — the restriction is a no-op; none active — there would
        be nobody to cluster, so the rebuild falls back to the full fleet).
        Reads the tracker's device buffer by reference — safe against the
        async worker because score buffers are replaced, never mutated.
        """
        if self._avail_tracker is None:
            return None
        mask = self._avail_tracker.active_mask()
        if mask.all() or not mask.any():
            return None
        return mask

    def _observe_snapshot(self):
        """The value handed to the plan service per observed round.

        Default: the store's immutable snapshot. ``dp_stratified`` overrides
        this with a clipped + noised host copy (and spends privacy budget).
        """
        return self._store.snapshot()

    # -- introspection -------------------------------------------------------
    @property
    def representative_gradients(self) -> np.ndarray:
        """Host copy of the resident G — (n, d'), sketch space if sketched."""
        return self._store.asnumpy()

    @property
    def gradient_store(self):
        return self._store

    @property
    def plan_service(self):
        return self._service

    # -- plan lifecycle ------------------------------------------------------
    def _swap_freshest(self) -> None:
        vp = self._service.poll()
        if vp is not None:
            self.set_plan(vp.plan, validate=self.validate_plans)

    def observe_updates(self, client_ids, updates) -> None:
        """Scatter the round's updates into the store and trigger a rebuild.

        ``updates`` may be the engine's device array — it is neither copied
        to host nor cast; the store scatters it on device and the plan
        service receives :meth:`_observe_snapshot` (an immutable snapshot of
        G by default).
        """
        if tuple(updates.shape) != (len(client_ids), self.update_dim):
            raise ValueError(
                f"updates shape {tuple(updates.shape)} != ({len(client_ids)}, {self.update_dim})"
            )
        self._store.update(client_ids, updates)
        self._service.observe(self._observe_snapshot(), active=self._cluster_mask())
        if self._service.mode == "sync":
            self._swap_freshest()

    def plan_telemetry(self) -> tuple[int, int]:
        return self._service.telemetry()

    def plan_cost_telemetry(self) -> tuple[float, float]:
        return self._service.last_build_ms(), self._service.last_drift()

    def flush_plan(self) -> None:
        """Block until any in-flight rebuild lands, then swap it in.

        Forces the async planner to the sync fixed point — after this, the
        plan equals what ``planner="sync"`` would hold (fp32 tolerance)."""
        self._service.flush()
        self._swap_freshest()

    def close(self) -> None:
        self._service.close()

    # -- checkpointable state ------------------------------------------------
    def prepare_state(self) -> None:
        """Quiesce the planner so the checkpoint is the sync fixed point.

        With ``planner="async"`` an in-flight rebuild cannot ride in a
        checkpoint; flushing first makes the exported (G, plan, counters)
        bundle self-consistent — a restored server continues exactly as a
        sync-planned one would from this state.
        """
        self.flush_plan()

    def state_arrays(self) -> dict:
        arrays = super().state_arrays()
        arrays["store_G"] = self._store.asnumpy()
        return arrays

    def state_meta(self) -> dict:
        meta = super().state_meta()
        meta["scheme"] = self.scheme_name
        version, _ = self._service.telemetry()
        meta["plan_version"] = version
        meta["obs_seen"] = self._service.observations_seen()
        # the sketch identity rides along so a restore into a differently-
        # sketched store fails loudly instead of mixing sketch spaces
        sk = self._store.sketch
        meta["sketch"] = None if sk is None else sk.name
        meta["sketch_dim"] = None if sk is None else sk.d_out
        meta["sketch_seed"] = None if sk is None else sk.seed
        return meta

    def load_state(self, meta: dict, arrays: dict) -> None:
        scheme = meta.get("scheme", self.scheme_name)
        if scheme != self.scheme_name:
            raise ValueError(
                f"checkpoint was written by scheme {scheme!r}; this sampler "
                f"is {self.scheme_name!r} — a cross-scheme restore would mix "
                "incompatible plan/store semantics"
            )
        sk = self._store.sketch
        have = (
            (None if sk is None else sk.name),
            (None if sk is None else sk.d_out),
            (None if sk is None else sk.seed),
        )
        want = (
            meta.get("sketch"),
            meta.get("sketch_dim"),
            meta.get("sketch_seed"),
        )
        if want != have:
            raise ValueError(
                f"checkpointed sketch state {want} != this sampler's sketch "
                f"{have}: a (name, dim, seed) mismatch would scatter new "
                "updates into a different sketch space than the restored G"
            )
        super().load_state(meta, arrays)  # rng + the exact live plan
        self._store.load(arrays["store_G"])
        from repro.fl.planner import VersionedPlan

        self._service.restore(
            VersionedPlan(self._plan, int(meta["plan_version"])),
            obs_seen=int(meta["obs_seen"]),
        )

    def sample(
        self, round_idx: int, available: Optional[np.ndarray] = None
    ) -> SampleResult:
        del round_idx
        self._swap_freshest()  # round boundary: adopt the freshest plan
        return self._draw_from_plan(self._plan, available)

    def sample_overselect(
        self,
        round_idx: int,
        n_draws: int,
        available: Optional[np.ndarray] = None,
    ) -> SampleResult:
        del round_idx
        if not self.supports_overselect:
            raise NotImplementedError(
                f"{type(self).__name__} re-weights its draws itself; the "
                "urn-cyclic overselection re-weighting would not be unbiased "
                "for it — pick a plan-based scheme for scheduler='overselect'"
            )
        self._swap_freshest()  # the same round-boundary swap sample() does
        return self._draw_from_plan_overselect(self._plan, n_draws, available)
