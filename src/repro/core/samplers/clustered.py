"""Generic clustered sampler: m independent draws from an arbitrary plan.

Any ``r`` matrix satisfying Proposition 1 can be plugged in — Algorithms 1
and 2 are factories producing such plans; this class does the actual
per-round drawing (Section 3.1).
"""
from __future__ import annotations

from repro.core.samplers.base import ClientSampler, validate_plan
from repro.core.types import ClientPopulation, SamplingPlan, SampleResult


class ClusteredSampler(ClientSampler):
    unbiased = True

    def __init__(
        self,
        population: ClientPopulation,
        plan: SamplingPlan,
        *,
        seed: int = 0,
        validate: bool = True,
    ):
        super().__init__(population, plan.m, seed=seed)
        if validate:
            validate_plan(plan, population)
        self._plan = plan

    @property
    def plan(self) -> SamplingPlan:
        return self._plan

    def set_plan(self, plan: SamplingPlan, *, validate: bool = True) -> None:
        if validate:
            validate_plan(plan, self.population)
        if plan.m != self.m:
            raise ValueError(f"plan has m={plan.m}, sampler has m={self.m}")
        self._plan = plan

    def sample(self, round_idx: int) -> SampleResult:
        del round_idx
        return self._draw_from_plan(self._plan)
