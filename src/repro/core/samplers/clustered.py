"""Generic clustered sampler: m independent draws from an arbitrary plan.

Any ``r`` matrix satisfying Proposition 1 can be plugged in — Algorithms 1
and 2 are factories producing such plans; this class does the actual
per-round drawing (Section 3.1).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.samplers.base import ClientSampler, validate_plan
from repro.core.types import ClientPopulation, SamplingPlan, SampleResult


class ClusteredSampler(ClientSampler):
    unbiased = True

    def __init__(
        self,
        population: ClientPopulation,
        plan: SamplingPlan,
        *,
        seed: int = 0,
        validate: bool = True,
    ):
        super().__init__(population, plan.m, seed=seed)
        if validate:
            validate_plan(plan, population)
        self._plan = plan

    @property
    def plan(self) -> SamplingPlan:
        return self._plan

    def set_plan(self, plan: SamplingPlan, *, validate: bool = True) -> None:
        if validate:
            validate_plan(plan, self.population)
        if plan.m != self.m:
            raise ValueError(f"plan has m={plan.m}, sampler has m={self.m}")
        self._plan = plan

    def sample(
        self, round_idx: int, available: Optional[np.ndarray] = None
    ) -> SampleResult:
        del round_idx
        return self._draw_from_plan(self._plan, available)

    # -- checkpointable state ------------------------------------------------
    # The plan matrices ride in the checkpoint so a restored sampler draws
    # from the *exact* plan that was live at kill time (Algorithm 2's plan
    # is data-dependent; re-deriving it from a restored gradient store would
    # tie resume correctness to distance-backend determinism).
    def state_arrays(self) -> dict:
        arrays = {"plan_r": np.asarray(self._plan.r)}
        if self._plan.r_tokens is not None:
            arrays["plan_r_tokens"] = np.asarray(self._plan.r_tokens)
        if self._plan.cluster_of is not None:
            arrays["plan_cluster_of"] = np.asarray(self._plan.cluster_of)
        return arrays

    def load_state(self, meta: dict, arrays: dict) -> None:
        super().load_state(meta, {})
        plan = SamplingPlan(
            r=np.asarray(arrays["plan_r"], np.float64),
            r_tokens=(
                np.asarray(arrays["plan_r_tokens"], np.int64)
                if "plan_r_tokens" in arrays
                else None
            ),
            cluster_of=(
                np.asarray(arrays["plan_cluster_of"], np.int64)
                if "plan_cluster_of" in arrays
                else None
            ),
        )
        # restored state is trusted (it was validated when first set)
        self.set_plan(plan, validate=False)
