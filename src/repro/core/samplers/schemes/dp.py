"""Differentially private stratified selection (FedProx-stratified-DP lineage).

The selection statistics a stratified sampler consumes — the per-client
representative gradients that determine stratum membership — leak
information about client data. This scheme releases them through the
Gaussian mechanism each observed round: rows are L2-clipped to
``clip_norm`` (sensitivity C), Gaussian noise ``N(0, (σC)²)`` with
``σ = noise_multiplier`` is added, and only the *noised* statistics reach
the plan service. The resident gradient store itself keeps the exact
updates (it is server-side state, same trust domain as the model updates
the server already aggregates); what is protected is the selection
pipeline's view — strata, drift statistics, and anything derived from the
plan — which becomes a post-processing of the noised release.

Privacy accounting is zero-concentrated DP: each per-round release costs
``ρ_step = 1/(2σ²)``; after ``T`` releases ``ρ = T/(2σ²)`` converts to an
(ε, δ) guarantee via ``ε = ρ + 2·√(ρ·ln(1/δ))``. The ledger (release
count, ρ, ε, δ) rides ``state_meta`` so it survives kill/resume exactly —
a restored campaign continues the *same* privacy accounting rather than
resetting it. Accounting is deliberately conservative: every observed
round is counted as a release even when the rebuild cadence discards it.

Crucially the *plan* stays exactly unbiased: noise only moves clients
between strata; the token allocation is still driven by the true ``n_i``,
so eq. (7)/(8) hold exactly and ``E[ω_i] = p_i`` is untouched by any noise
level. DP costs convergence speed (worse strata), never bias.
"""
from __future__ import annotations

import math
from typing import Callable, Optional, Union

import numpy as np

from repro.core.samplers.algorithm2 import DistanceFn
from repro.core.samplers.schemes.stratified import StratifiedSampler
from repro.core.types import ClientPopulation


def gaussian_epsilon(rho: float, delta: float) -> float:
    """(ε, δ) from zCDP: ε = ρ + 2·√(ρ·ln(1/δ)) (Bun & Steinke, Prop. 1.3)."""
    if rho <= 0.0:
        return 0.0
    return float(rho + 2.0 * math.sqrt(rho * math.log(1.0 / delta)))


class DPStratifiedSampler(StratifiedSampler):
    """Stratified selection over Gaussian-noised statistics + (ε, δ) ledger."""

    scheme_name = "dp_stratified"

    def __init__(
        self,
        population: ClientPopulation,
        m: int,
        update_dim: int,
        *,
        noise_multiplier: float = 1.0,
        clip_norm: float = 1.0,
        delta: float = 1e-5,
        n_strata: Optional[int] = None,
        measure: str = "arccos",
        distance_fn: Union[DistanceFn, str, None] = "auto",
        clusterer: Union[Callable, str] = "ward",
        seed: int = 0,
        staleness_decay: float = 1.0,
        planner: str = "sync",
        rebuild_every: int = 1,
        drift_threshold: Optional[float] = None,
        sketch: Optional[str] = None,
        sketch_dim: Optional[int] = None,
        store_mesh_spec=None,
    ):
        """``noise_multiplier`` = σ (noise std is σ·clip_norm per coordinate),
        ``clip_norm`` = per-row L2 sensitivity bound C, ``delta`` the ledger's
        conversion target. The DP noise stream draws from its own generator
        (seeded from the sampler seed), so the selection rng and the
        mechanism rng are independent and both checkpoint bit-exactly."""
        if noise_multiplier <= 0.0:
            raise ValueError(f"noise_multiplier must be > 0, got {noise_multiplier}")
        if clip_norm <= 0.0:
            raise ValueError(f"clip_norm must be > 0, got {clip_norm}")
        if not 0.0 < delta < 1.0:
            raise ValueError(f"delta must be in (0, 1), got {delta}")
        self.noise_multiplier = float(noise_multiplier)
        self.clip_norm = float(clip_norm)
        self.delta = float(delta)
        self._dp_rng = np.random.default_rng([int(seed), 0xD9])
        self._ledger = {"observations": 0, "rho": 0.0}
        super().__init__(
            population,
            m,
            update_dim,
            n_strata=n_strata,
            measure=measure,
            distance_fn=distance_fn,
            clusterer=clusterer,
            seed=seed,
            staleness_decay=staleness_decay,
            planner=planner,
            rebuild_every=rebuild_every,
            drift_threshold=drift_threshold,
            sketch=sketch,
            sketch_dim=sketch_dim,
            store_mesh_spec=store_mesh_spec,
        )

    @property
    def privacy_ledger(self) -> dict:
        """The tracked budget: releases, zCDP ρ, and the converted (ε, δ)."""
        rho = float(self._ledger["rho"])
        return {
            "observations": int(self._ledger["observations"]),
            "rho": rho,
            "epsilon": gaussian_epsilon(rho, self.delta),
            "delta": self.delta,
        }

    def _observe_snapshot(self):
        """Clip + noise the statistics release; spend one ρ_step.

        One release per observed round (deterministic draw count — the noise
        generator state replays exactly across kill/resume). The cold-start
        plan built at construction sees the raw all-zeros buffer and spends
        nothing: no client data has entered the store yet.
        """
        G = np.asarray(self._store.snapshot(), dtype=np.float64)
        norms = np.linalg.norm(G, axis=1)
        scale = np.ones_like(norms)
        over = norms > self.clip_norm
        scale[over] = self.clip_norm / norms[over]
        sigma = self.noise_multiplier * self.clip_norm
        noised = G * scale[:, None] + self._dp_rng.normal(0.0, sigma, size=G.shape)
        self._ledger["observations"] += 1
        self._ledger["rho"] += 1.0 / (2.0 * self.noise_multiplier**2)
        return noised.astype(np.float32)

    # -- checkpointable state ------------------------------------------------
    def state_meta(self) -> dict:
        meta = super().state_meta()
        meta["dp_ledger"] = {
            "observations": int(self._ledger["observations"]),
            "rho": float(self._ledger["rho"]),
        }
        meta["dp_rng"] = self._dp_rng.bit_generator.state
        return meta

    def load_state(self, meta: dict, arrays: dict) -> None:
        super().load_state(meta, arrays)
        self._ledger = {
            "observations": int(meta["dp_ledger"]["observations"]),
            "rho": float(meta["dp_ledger"]["rho"]),
        }
        self._dp_rng.bit_generator.state = meta["dp_rng"]
