"""The client-selection scheme zoo (beyond the paper's Algorithms 1/2).

Four published competitors to clustered sampling, each implemented on the
shared :class:`~repro.core.samplers.store_backed.StoreBackedSampler`
contract (gradient store → plan service → eq.(7)/(8) ``SamplingPlan``), so
availability conditioning, vectorized draws, plan validation, kill/resume
checkpointing and population churn compose with zero new code paths:

* ``stratified``    — strata from a clustering objective over the sketched
  gradient store; per-stratum proportional allocation, within-stratum
  draws uniform over sample tokens (Shen et al., stratified client
  selection; FedSTaS-style restratification via the drift trigger).
* ``importance``    — aggregation-norm-proportional selection probabilities
  with exact unbiased re-weighting at draw time (importance sampling of
  clients; Rizk et al. / FedProx-IS lineage).
* ``dp_stratified`` — ``stratified`` with per-round Gaussian noise on the
  stratum statistics and a tracked zCDP → (ε, δ) privacy ledger riding
  ``state_meta`` through checkpoints.
* ``hybrid``        — deterministic head of high-mass clients (their
  ``floor(m·p_i)`` dedicated probability-1 urns) + stratified sampling of
  the tail (the Shen et al. split, sharing Algorithm 2's Section-5 head).

All four are ``SAMPLERS`` registry entries, hence constructible from a JSON
``ExperimentSpec`` and raced head-to-head by ``benchmarks/scheme_race.py``.
"""
from repro.core.samplers.schemes.dp import DPStratifiedSampler, gaussian_epsilon
from repro.core.samplers.schemes.hybrid import HybridSampler, build_plan_hybrid
from repro.core.samplers.schemes.importance import (
    ImportanceSampler,
    importance_probabilities,
)
from repro.core.samplers.schemes.stratified import (
    StratifiedSampler,
    build_plan_stratified,
    default_n_strata,
)

__all__ = [
    "StratifiedSampler",
    "ImportanceSampler",
    "DPStratifiedSampler",
    "HybridSampler",
    "build_plan_stratified",
    "build_plan_hybrid",
    "importance_probabilities",
    "default_n_strata",
    "gaussian_epsilon",
]
