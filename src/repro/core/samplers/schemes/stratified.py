"""Stratified client selection (Shen et al.; FedSTaS lineage).

Strata are formed by a clustering objective over the representative-gradient
store — any :data:`repro.core.clustering.backends.CLUSTERERS` entry — and
the plan allocates each stratum a *contiguous* run of urn capacity exactly
proportional to its data mass, with every client's within-stratum share
proportional to its sample count ``n_i`` (i.e. the within-stratum draw is
uniform over *sample tokens*, the integer-exact reading of "uniform within
the stratum" that keeps eq. (8) satisfiable for unequal client sizes).

Construction: give client ``i`` its ``m·n_i`` sample tokens, order strata by
descending token mass (stable), order clients within a stratum by descending
mass (stable), and pour the whole stream through the Appendix-C sequential
urn filler (``m`` urns of capacity ``M``). Total tokens are exactly ``m·M``,
so the resulting plan satisfies eq. (7)/(8) *exactly* — ``validate_plan``
passes with integer checks, E[ω_i] = p_i, availability conditioning through
``conditional_plan`` stays exactly unbiased over the available set, and the
variance/inclusion theorems (eq. 17/23) apply as to any Proposition-1 plan.

``cluster_of`` records the stratum id per client, so the plan service's
drift trigger (``drift_threshold``) measures assignment churn against the
live strata and restratifies only when the population has actually moved —
FedSTaS-style restratification on drift, for free.
"""
from __future__ import annotations

import math
from typing import Callable, Optional, Union

import numpy as np

from repro.core.allocation import fill_urns_sequential
from repro.core.clustering.backends import resolve_clusterer
from repro.core.samplers.algorithm2 import DistanceFn, _resolve_distance_fn
from repro.core.samplers.store_backed import StoreBackedSampler
from repro.core.types import ClientPopulation, SamplingPlan


def default_n_strata(n: int) -> int:
    """The √n heuristic (≥ 2 strata when the population allows it)."""
    return int(min(n, max(2, round(math.sqrt(n)))))


def build_plan_stratified(
    population: ClientPopulation,
    m: int,
    G,
    *,
    n_strata: Optional[int] = None,
    clusterer: Union[Callable, str] = "ward",
    measure: str = "arccos",
    distance_fn: Optional[DistanceFn] = None,
    seed: int = 0,
) -> SamplingPlan:
    """Stratify by the clustering objective, then stream strata into urns.

    The clusterer is called with ``m = n_strata`` and capacity ``m·M`` — no
    per-group mass cap (a stratum may exceed one urn and spill contiguously
    into the next), so *any* partition the backend produces is feasible.
    The clusterer may return more than ``n_strata`` groups (capacity-repair
    backends do); each returned group is simply its own stratum.
    """
    n = population.n_clients
    M = population.total_samples
    mass = m * population.n_samples  # m·n_i tokens per client
    k = default_n_strata(n) if n_strata is None else int(n_strata)
    if not 1 <= k <= n:
        raise ValueError(f"n_strata must be in [1, n={n}], got {k}")

    groups = resolve_clusterer(clusterer)(
        G, mass, k, m * M, measure=measure, distance_fn=distance_fn, seed=seed
    )
    groups = [np.asarray(g, dtype=np.int64) for g in groups]
    q = np.array([int(mass[g].sum()) for g in groups], dtype=np.int64)
    order = np.argsort(-q, kind="stable")  # descending stratum mass

    cluster_of = np.full(n, -1, dtype=np.int64)
    for sid, gi in enumerate(order):
        cluster_of[groups[gi]] = sid

    def stream():
        for gi in order:
            g = groups[gi]
            for i in g[np.argsort(-mass[g], kind="stable")]:
                yield int(i), int(mass[i])

    tokens = fill_urns_sequential(stream(), n, m, M)
    return SamplingPlan(r=tokens / M, r_tokens=tokens, cluster_of=cluster_of)


class StratifiedSampler(StoreBackedSampler):
    """Stratified selection with drift-triggered restratification.

    Strata live in the same device-resident (sketched, shardable) gradient
    store as Algorithm 2 and rebuild through the same plan service — sync or
    async, on a cadence or on measured assignment drift. Only the plan
    construction differs: proportional-allocation strata instead of
    capacity-capped similarity clusters.
    """

    scheme_name = "stratified"

    def __init__(
        self,
        population: ClientPopulation,
        m: int,
        update_dim: int,
        *,
        n_strata: Optional[int] = None,
        measure: str = "arccos",
        distance_fn: Union[DistanceFn, str, None] = "auto",
        clusterer: Union[Callable, str] = "ward",
        seed: int = 0,
        staleness_decay: float = 1.0,
        planner: str = "sync",
        rebuild_every: int = 1,
        drift_threshold: Optional[float] = None,
        sketch: Optional[str] = None,
        sketch_dim: Optional[int] = None,
        store_mesh_spec=None,
    ):
        """``n_strata`` defaults to the √n heuristic. All other knobs have
        Algorithm 2's semantics (see
        :class:`~repro.core.samplers.algorithm2.Algorithm2Sampler`)."""
        self.n_strata = None if n_strata is None else int(n_strata)
        self.measure = measure
        self._distance_fn = _resolve_distance_fn(distance_fn)
        self._clusterer = clusterer
        self._clusterer_seed = int(seed)
        super().__init__(
            population,
            m,
            update_dim,
            seed=seed,
            staleness_decay=staleness_decay,
            planner=planner,
            rebuild_every=rebuild_every,
            drift_threshold=drift_threshold,
            sketch=sketch,
            sketch_dim=sketch_dim,
            store_mesh_spec=store_mesh_spec,
        )

    def _build_plan(self, G) -> SamplingPlan:
        return build_plan_stratified(
            self.population,
            self.m,
            G,
            n_strata=self.n_strata,
            clusterer=self._clusterer,
            measure=self.measure,
            distance_fn=self._distance_fn,
            seed=self._clusterer_seed,
        )
