"""Importance sampling of clients: norm-proportional selection, unbiased
re-weighting.

Clients whose recent updates move the global model most (largest
representative-gradient norm) are selected more often; unbiasedness is
restored *exactly* by importance-weighting each draw. With selection
probabilities ``q`` and data ratios ``p``, a draw of client ``i`` carries
aggregation weight ``(1/m)·(p_i/q_i)`` instead of ``1/m``, so::

    E[ω_i] = m · q_i · (1/m)·(p_i/q_i) = p_i          (eq. 12, exact)

and under an availability mask ``a`` the conditional draw (client ``i``
w.p. ``q_i·a_i / Σ_j q_j·a_j`` per urn) is corrected by
``(p_i/q_i)·(Σ_j q_j a_j / Σ_j p_j a_j)``, giving exactly the same
conditional target as every eq.(8) scheme::

    E[ω_i | a] = p_i·a_i / Σ_j p_j·a_j

The plan's rows are the proposal ``q`` (all ``m`` urns identical), which
deliberately violates eq. (8) — columns sum to ``m·q_i``, not ``m·p_i`` —
so this scheme sets ``validate_plans = False`` and owns its unbiasedness at
draw time. Realized weights sum to ``(1/m)·Σ_k p_{l_k}/q_{l_k}`` (≈ 1, = 1
in expectation); the server consumes ``agg_weights`` directly, so the
estimator is the standard self-normalizing-free importance estimator.

``mix`` floors the proposal: ``q = (1−mix)·s/Σs + mix·p`` with
``s_i = p_i·‖G_i‖``, guaranteeing ``q_i > 0`` wherever ``p_i > 0`` (a
zero-probability client with data would make the estimator biased) and
bounding the weight ratio ``p_i/q_i ≤ 1/mix``. ``mix = 1.0`` is *exactly*
MD sampling — bit-identical draws and weights for the same seed — which is
the tier-1 parity gate for this scheme. Cold start (all-zero store) also
degenerates to MD.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.samplers.store_backed import StoreBackedSampler
from repro.core.types import ClientPopulation, SamplingPlan, SampleResult


def _row_norms(G) -> np.ndarray:
    """Per-client update norms, computed where G lives (device when it can)."""
    if isinstance(G, np.ndarray):
        return np.linalg.norm(np.asarray(G, dtype=np.float64), axis=1)
    import jax.numpy as jnp

    return np.asarray(jnp.linalg.norm(G, axis=1), dtype=np.float64)


def importance_probabilities(
    p: np.ndarray, norms: np.ndarray, mix: float
) -> np.ndarray:
    """The proposal ``q``: norm-proportional mass mixed with ``p``.

    ``s_i = p_i·‖G_i‖`` (norm-weighted data mass); ``q = (1−mix)·s/Σs +
    mix·p``. Degenerate norms (all zero — cold start — or non-finite) and
    ``mix >= 1`` return ``p`` *exactly* (same array values, no float drift),
    so the scheme is bit-identical to MD sampling in those regimes.
    """
    p = np.asarray(p, dtype=np.float64)
    s = p * np.asarray(norms, dtype=np.float64)
    tot = float(s.sum())
    if mix >= 1.0 or not np.isfinite(tot) or tot <= 0.0:
        return np.array(p, copy=True)
    return (1.0 - mix) * (s / tot) + mix * p


class ImportanceSampler(StoreBackedSampler):
    """Norm-proportional client selection with exact unbiased re-weighting."""

    scheme_name = "importance"
    validate_plans = False  # rows are the proposal q, not an eq.(8) plan
    # sample() multiplies its own p/q correction into the weights; layering
    # the scheduler's urn-cyclic overselection re-weighting on top would
    # double-correct, so this scheme opts out of scheduler="overselect"
    supports_overselect = False

    def __init__(
        self,
        population: ClientPopulation,
        m: int,
        update_dim: int,
        *,
        mix: float = 0.1,
        seed: int = 0,
        staleness_decay: float = 1.0,
        planner: str = "sync",
        rebuild_every: int = 1,
        sketch: Optional[str] = None,
        sketch_dim: Optional[int] = None,
        store_mesh_spec=None,
    ):
        """``mix`` ∈ (0, 1]: proposal floor (weight-ratio bound 1/mix);
        1.0 = exact MD sampling. No ``drift_threshold``/``clusterer`` — the
        plan has no cluster structure for the drift monitor to measure, so
        those PlannerSpec knobs are rejected at build time rather than
        silently degenerating."""
        if not 0.0 < mix <= 1.0:
            raise ValueError(
                f"mix must be in (0, 1], got {mix}; mix = 0 could assign a "
                "data-carrying client selection probability 0, making the "
                "importance estimator biased"
            )
        self.mix = float(mix)
        super().__init__(
            population,
            m,
            update_dim,
            seed=seed,
            staleness_decay=staleness_decay,
            planner=planner,
            rebuild_every=rebuild_every,
            sketch=sketch,
            sketch_dim=sketch_dim,
            store_mesh_spec=store_mesh_spec,
        )

    def _build_plan(self, G) -> SamplingPlan:
        q = importance_probabilities(
            self.population.importances, _row_norms(G), self.mix
        )
        return SamplingPlan(r=np.tile(q, (self.m, 1)))

    def correction(self, available: Optional[np.ndarray] = None) -> np.ndarray:
        """Per-client weight correction ``c_i`` the current plan's draws carry.

        A draw of client ``i`` is re-weighted by ``c_i = (p_i/q_i)·(Σ q_j a_j
        / Σ p_j a_j)`` (the availability ratio is 1 with a full mask), which
        is exactly what makes ``E[ω_i | a] = p_i·a_i / Σ_j p_j·a_j``. Exposed
        for the property tests' closed-form bookkeeping.
        """
        q = self._plan.r[0]
        p = self.population.importances
        c = np.divide(p, q, out=np.zeros_like(p), where=q > 0)
        if available is None:
            return c  # q and p both sum to 1: the ratio of sums is exactly 1
        a = np.asarray(available, dtype=bool)
        pa = float((p * a).sum())
        if pa <= 0.0:
            return np.zeros_like(p)
        return c * (float((q * a).sum()) / pa)

    def sample(
        self, round_idx: int, available: Optional[np.ndarray] = None
    ) -> SampleResult:
        del round_idx
        self._swap_freshest()
        res = self._draw_from_plan(self._plan, available)
        if res.clients.size == 0:  # fully-masked round: nothing to re-weight
            return res
        c = self.correction(available)
        # mix = 1.0 (or cold start): q == p exactly, c == 1.0 elementwise,
        # and the product below is bit-identical to the MD weights
        return SampleResult(
            clients=res.clients,
            agg_weights=res.agg_weights * c,
            stale_weight=res.stale_weight,
        )
