"""Hybrid selection: deterministic head + stratified tail (Shen et al.).

High-mass clients (``p_i ≥ 1/m``) are selected deterministically — client
``i`` owns ``floor(m·p_i)`` dedicated probability-1 urns, exactly the
Section-5 large-client head Algorithm 2 uses — and the remaining *tail*
mass (every client's remainder after its dedicated urns) is sampled via
the stratified scheme over the remaining urns: strata from the clustering
objective over the pool clients' gradients, poured mass-proportionally
through the sequential urn filler.

Total tokens are again exactly ``m·M`` (head urns hold ``M`` each, the pool
stream holds ``m_pool·M``), so the plan satisfies eq. (7)/(8) exactly with
all the downstream guarantees. When *no* client reaches ``p_i ≥ 1/m`` the
head is empty and the plan equals :func:`build_plan_stratified` on the same
gradients token-for-token (pinned by test), so ``hybrid`` is a strict
generalization of ``stratified`` to head-heavy populations.
"""
from __future__ import annotations

from typing import Callable, Optional, Union

import numpy as np

from repro.core.allocation import fill_urns_sequential
from repro.core.clustering.backends import resolve_clusterer
from repro.core.samplers.algorithm2 import DistanceFn, _resolve_distance_fn
from repro.core.samplers.schemes.stratified import default_n_strata
from repro.core.samplers.store_backed import StoreBackedSampler
from repro.core.types import ClientPopulation, SamplingPlan


def build_plan_hybrid(
    population: ClientPopulation,
    m: int,
    G,
    *,
    n_strata: Optional[int] = None,
    clusterer: Union[Callable, str] = "ward",
    measure: str = "arccos",
    distance_fn: Optional[DistanceFn] = None,
    seed: int = 0,
) -> SamplingPlan:
    """Dedicated urns for the ``floor(m·p_i)`` head, stratified tail."""
    n = population.n_clients
    M = population.total_samples
    mass = m * population.n_samples  # m·n_i tokens per client

    # --- deterministic head: probability-1 urns ------------------------------
    full_urns = (mass // M).astype(np.int64)  # floor(m·p_i) per client
    pool_mass = mass - full_urns * M  # remainder joins the stratified tail
    m_pool = m - int(full_urns.sum())
    if m_pool < 0:
        raise ValueError("impossible: sum floor(m p_i) > m")

    tokens = np.zeros((m, n), dtype=np.int64)
    owners = np.repeat(np.arange(n), full_urns)  # urn k -> its dedicated client
    tokens[np.arange(owners.size), owners] = M

    cluster_of = np.full(n, -1, dtype=np.int64)
    if m_pool > 0:
        pool = np.flatnonzero(pool_mass > 0)
        k = default_n_strata(int(pool.size)) if n_strata is None else int(n_strata)
        k = max(1, min(k, int(pool.size)))
        groups_local = resolve_clusterer(clusterer)(
            G[pool],
            pool_mass[pool],
            k,
            m_pool * M,  # no per-stratum cap: strata spill across urns
            measure=measure,
            distance_fn=distance_fn,
            seed=seed,
        )
        groups = [pool[np.asarray(g, dtype=np.int64)] for g in groups_local]
        q = np.array([int(pool_mass[g].sum()) for g in groups], dtype=np.int64)
        order = np.argsort(-q, kind="stable")
        for sid, gi in enumerate(order):
            cluster_of[groups[gi]] = sid

        def stream():
            for gi in order:
                g = groups[gi]
                for i in g[np.argsort(-pool_mass[g], kind="stable")]:
                    yield int(i), int(pool_mass[i])

        # head urns sit at capacity, so the pool stream fills urns m-m_pool..m
        tokens = fill_urns_sequential(stream(), n, m, M, initial=tokens)

    return SamplingPlan(r=tokens / M, r_tokens=tokens, cluster_of=cluster_of)


class HybridSampler(StoreBackedSampler):
    """Deterministic high-mass head + stratified tail over the shared store."""

    scheme_name = "hybrid"

    def __init__(
        self,
        population: ClientPopulation,
        m: int,
        update_dim: int,
        *,
        n_strata: Optional[int] = None,
        measure: str = "arccos",
        distance_fn: Union[DistanceFn, str, None] = "auto",
        clusterer: Union[Callable, str] = "ward",
        seed: int = 0,
        staleness_decay: float = 1.0,
        planner: str = "sync",
        rebuild_every: int = 1,
        drift_threshold: Optional[float] = None,
        sketch: Optional[str] = None,
        sketch_dim: Optional[int] = None,
        store_mesh_spec=None,
    ):
        """Knob semantics follow :class:`StratifiedSampler` (``n_strata``
        applies to the *pool* clients after the head is split off)."""
        self.n_strata = None if n_strata is None else int(n_strata)
        self.measure = measure
        self._distance_fn = _resolve_distance_fn(distance_fn)
        self._clusterer = clusterer
        self._clusterer_seed = int(seed)
        super().__init__(
            population,
            m,
            update_dim,
            seed=seed,
            staleness_decay=staleness_decay,
            planner=planner,
            rebuild_every=rebuild_every,
            drift_threshold=drift_threshold,
            sketch=sketch,
            sketch_dim=sketch_dim,
            store_mesh_spec=store_mesh_spec,
        )

    def _build_plan(self, G) -> SamplingPlan:
        return build_plan_hybrid(
            self.population,
            self.m,
            G,
            n_strata=self.n_strata,
            clusterer=self._clusterer,
            measure=self.measure,
            distance_fn=self._distance_fn,
            seed=self._clusterer_seed,
        )
