"""Client-selection schemes (the paper's core contribution lives here).

``SAMPLERS`` is the seed *registry* of schemes: spec-driven construction
(``repro.fl.experiment.SamplerSpec``) resolves names through it, and
``register_sampler("mine", MySampler)`` plugs a new scheme into every
driver, benchmark and CLI that speaks specs.
"""
from repro.core.registry import Registry
from repro.core.samplers.base import ClientSampler, max_draws_bound, validate_plan
from repro.core.samplers.uniform import UniformSampler
from repro.core.samplers.md import MDSampler
from repro.core.samplers.clustered import ClusteredSampler
from repro.core.samplers.algorithm1 import Algorithm1Sampler, build_plan_algorithm1
from repro.core.samplers.algorithm2 import Algorithm2Sampler, build_plan_algorithm2
from repro.core.samplers.target import TargetSampler, build_plan_target

SAMPLERS = Registry(
    "sampler",
    {
        "uniform": UniformSampler,
        "md": MDSampler,
        "algorithm1": Algorithm1Sampler,
        "algorithm2": Algorithm2Sampler,
        "target": TargetSampler,
    },
)

register_sampler = SAMPLERS.register

__all__ = [
    "ClientSampler",
    "UniformSampler",
    "MDSampler",
    "ClusteredSampler",
    "Algorithm1Sampler",
    "Algorithm2Sampler",
    "TargetSampler",
    "build_plan_algorithm1",
    "build_plan_algorithm2",
    "build_plan_target",
    "validate_plan",
    "max_draws_bound",
    "Registry",
    "SAMPLERS",
    "register_sampler",
]
