"""Client-selection schemes (the paper's core contribution lives here).

``SAMPLERS`` is the seed *registry* of schemes: spec-driven construction
(``repro.fl.experiment.SamplerSpec``) resolves names through it, and
``register_sampler("mine", MySampler)`` plugs a new scheme into every
driver, benchmark and CLI that speaks specs. Beyond the paper's own
algorithms, :mod:`repro.core.samplers.schemes` contributes the published
competitor zoo — ``stratified`` / ``importance`` / ``dp_stratified`` /
``hybrid`` — all built on the shared
:class:`~repro.core.samplers.store_backed.StoreBackedSampler` contract.
"""
from repro.core.registry import Registry
from repro.core.samplers.base import ClientSampler, max_draws_bound, validate_plan
from repro.core.samplers.uniform import UniformSampler
from repro.core.samplers.md import MDSampler
from repro.core.samplers.clustered import ClusteredSampler
from repro.core.samplers.store_backed import StoreBackedSampler
from repro.core.samplers.algorithm1 import Algorithm1Sampler, build_plan_algorithm1
from repro.core.samplers.algorithm2 import Algorithm2Sampler, build_plan_algorithm2
from repro.core.samplers.target import TargetSampler, build_plan_target
from repro.core.samplers.schemes import (
    DPStratifiedSampler,
    HybridSampler,
    ImportanceSampler,
    StratifiedSampler,
    build_plan_hybrid,
    build_plan_stratified,
)

SAMPLERS = Registry(
    "sampler",
    {
        "uniform": UniformSampler,
        "md": MDSampler,
        "algorithm1": Algorithm1Sampler,
        "algorithm2": Algorithm2Sampler,
        "target": TargetSampler,
        "stratified": StratifiedSampler,
        "importance": ImportanceSampler,
        "dp_stratified": DPStratifiedSampler,
        "hybrid": HybridSampler,
    },
)

register_sampler = SAMPLERS.register

__all__ = [
    "ClientSampler",
    "UniformSampler",
    "MDSampler",
    "ClusteredSampler",
    "StoreBackedSampler",
    "Algorithm1Sampler",
    "Algorithm2Sampler",
    "TargetSampler",
    "StratifiedSampler",
    "ImportanceSampler",
    "DPStratifiedSampler",
    "HybridSampler",
    "build_plan_algorithm1",
    "build_plan_algorithm2",
    "build_plan_target",
    "build_plan_stratified",
    "build_plan_hybrid",
    "validate_plan",
    "max_draws_bound",
    "Registry",
    "SAMPLERS",
    "register_sampler",
]
