"""Client-selection schemes (the paper's core contribution lives here)."""
from repro.core.samplers.base import ClientSampler, max_draws_bound, validate_plan
from repro.core.samplers.uniform import UniformSampler
from repro.core.samplers.md import MDSampler
from repro.core.samplers.clustered import ClusteredSampler
from repro.core.samplers.algorithm1 import Algorithm1Sampler, build_plan_algorithm1
from repro.core.samplers.algorithm2 import Algorithm2Sampler, build_plan_algorithm2
from repro.core.samplers.target import TargetSampler, build_plan_target

SAMPLERS = {
    "uniform": UniformSampler,
    "md": MDSampler,
    "algorithm1": Algorithm1Sampler,
    "algorithm2": Algorithm2Sampler,
    "target": TargetSampler,
}

__all__ = [
    "ClientSampler",
    "UniformSampler",
    "MDSampler",
    "ClusteredSampler",
    "Algorithm1Sampler",
    "Algorithm2Sampler",
    "TargetSampler",
    "build_plan_algorithm1",
    "build_plan_algorithm2",
    "build_plan_target",
    "validate_plan",
    "max_draws_bound",
    "SAMPLERS",
]
