"""Sampler interface + Proposition-1 validation.

A sampler consumes the client population (and, for Algorithm 2, the clients'
representative gradients) and produces a :class:`SampleResult` per round.
Plan-based samplers expose their ``SamplingPlan`` so its Proposition-1
conditions can be checked exactly.
"""
from __future__ import annotations

import abc
from typing import Optional

import numpy as np

from repro.core.types import ClientPopulation, SamplingPlan, SampleResult


class ClientSampler(abc.ABC):
    """Base class for all client-selection schemes."""

    #: whether the scheme satisfies Assumption 4 (unbiased aggregation)
    unbiased: bool = True
    #: whether ``observe_updates`` feeds a re-clustering pipeline (so the
    #: server / driver should bother producing representative gradients)
    consumes_updates: bool = False

    def __init__(self, population: ClientPopulation, m: int, *, seed: int = 0):
        if m <= 0:
            raise ValueError("m must be positive")
        self.population = population
        self.m = int(m)
        self._rng = np.random.default_rng(seed)

    @abc.abstractmethod
    def sample(self, round_idx: int) -> SampleResult:
        """Draw the clients participating in round ``round_idx``."""

    # Hooks -----------------------------------------------------------------
    def observe_updates(self, client_ids: np.ndarray, updates: np.ndarray) -> None:
        """Feed back the sampled clients' representative gradients.

        ``updates`` is (len(client_ids), d) — the flattened ``θ_i - θ`` per
        sampled client. Only similarity-based samplers use this.
        """
        del client_ids, updates

    @property
    def plan(self) -> Optional[SamplingPlan]:
        """Current ``r_{k,i}`` matrix for plan-based samplers, else None."""
        return None

    def plan_telemetry(self) -> tuple[int, int]:
        """(plan_version, plan_lag_rounds) of the plan the next draw uses.

        Static-plan and plan-free samplers report (0, 0); samplers backed by
        a :class:`repro.fl.planner.PlanService` report the service's active
        version and how many observed rounds it trails by (always 0 for the
        synchronous planner).
        """
        return (0, 0)

    def close(self) -> None:
        """Release background resources (async planner workers)."""

    # Shared machinery -------------------------------------------------------
    def _draw_from_plan(self, plan: SamplingPlan) -> SampleResult:
        """Sample l_k ~ W_k independently (the clustered-sampling draw).

        One vectorized inverse-CDF draw over the (m, n) row-cumsum instead of
        m ``rng.choice`` calls. The arithmetic mirrors ``Generator.choice``
        exactly (per-row cumsum, normalize by the last entry, insertion index
        with ties to the right) and ``rng.random(m)`` consumes the identical
        uniform stream, so the draws are bit-for-bit those of the old loop.
        """
        n = self.population.n_clients
        cdf = np.cumsum(plan.r, axis=1)
        total = cdf[:, -1]
        # rng.choice validated p per call — keep failing fast on degenerate
        # rows (NaN-poisoned gradients, zero-mass urns) instead of silently
        # collapsing every such draw onto client 0
        bad = ~(np.isfinite(total) & (total > 0))
        if bad.any():
            k = int(np.argmax(bad))
            raise ValueError(
                f"plan row {k} is not a probability distribution "
                f"(total mass {total[k]!r}); cannot draw from it"
            )
        cdf /= total[:, None]
        u = self._rng.random(plan.m)
        # searchsorted(side="right") per row: #{i: cdf[k,i] <= u_k}; u < 1 and
        # cdf[k,-1] == 1 exactly, so the index never reaches n. A zero-mass
        # client repeats its predecessor's cdf value and can never be hit.
        clients = (cdf <= u[:, None]).sum(axis=1).astype(np.int64)
        counts = np.bincount(clients, minlength=n)
        return SampleResult(clients=clients, agg_weights=counts / plan.m)


def validate_plan(
    plan: SamplingPlan, population: ClientPopulation, *, atol: float = 1e-9
) -> None:
    """Assert the two Proposition-1 conditions on an ``r`` matrix.

    * eq. (7): every row of ``r`` is a probability distribution,
    * eq. (8): every column sums to ``m * p_i`` (unbiasedness).

    Raises ``ValueError`` with a precise diagnostic on violation. When the
    plan carries its integer token allocation the check is exact.
    """
    r = plan.r
    m, n = r.shape
    if n != population.n_clients:
        raise ValueError(f"plan covers {n} clients, population has {population.n_clients}")
    if (r < -atol).any():
        bad = np.argwhere(r < -atol)[0]
        raise ValueError(f"negative probability r[{bad[0]},{bad[1]}] = {r[tuple(bad)]}")
    row_sums = r.sum(axis=1)
    if not np.allclose(row_sums, 1.0, atol=atol):
        k = int(np.argmax(np.abs(row_sums - 1.0)))
        raise ValueError(f"eq.(7) violated: sum_i r[{k},i] = {row_sums[k]!r} != 1")
    col_sums = r.sum(axis=0)
    target = plan.m * population.importances
    if not np.allclose(col_sums, target, atol=atol):
        i = int(np.argmax(np.abs(col_sums - target)))
        raise ValueError(
            f"eq.(8) violated: sum_k r[k,{i}] = {col_sums[i]!r} != m*p_i = {target[i]!r}"
        )
    if plan.r_tokens is not None:
        tok = np.asarray(plan.r_tokens, dtype=np.int64)
        M = population.total_samples
        if (tok.sum(axis=1) != M).any():
            raise ValueError("integer allocation: some urn does not hold exactly M tokens")
        expect = plan.m * population.n_samples
        if (tok.sum(axis=0) != expect).any():
            i = int(np.argmax(tok.sum(axis=0) != expect))
            raise ValueError(
                f"integer allocation: client {i} allocated {tok.sum(axis=0)[i]} "
                f"tokens, expected m*n_i = {expect[i]}"
            )


def max_draws_bound(plan: SamplingPlan) -> np.ndarray:
    """Upper bound on how many times each client can be drawn = #{k: r_{k,i} > 0}.

    For Algorithm 1 this is at most ``floor(m p_i) + 2`` (Section 4 of the
    paper), versus ``m`` for MD sampling.
    """
    return (plan.r > 0).sum(axis=0)
