"""Sampler interface + Proposition-1 validation + availability conditioning.

A sampler consumes the client population (and, for Algorithm 2, the clients'
representative gradients) and produces a :class:`SampleResult` per round.
Plan-based samplers expose their ``SamplingPlan`` so its Proposition-1
conditions can be checked exactly.

Availability conditioning (the continuous-service path): ``sample(t,
available=mask)`` restricts the draw to the currently-available client set.
For plan-based schemes the restriction is :func:`conditional_plan` — each
urn is masked to the available columns and re-normalized, and the urn's
per-draw aggregation weight becomes its share of the total available mass
instead of the unconditional ``1/m``. That importance correction is what
keeps the scheme unbiased *over the available set*: for any plan satisfying
eq. (8),

    E[ω_i | available] = p_i·a_i / Σ_j p_j·a_j

— exactly the re-normalized data ratios (property-tested in
``tests/test_statistics_property.py``). Urns whose entire mass is
unavailable draw nothing; realized weights still sum to 1 whenever any
available mass exists.

Samplers are also checkpointable: :meth:`ClientSampler.state_arrays` /
:meth:`~ClientSampler.state_meta` export the rng bit-generator state (plus
plan matrices and the gradient store for the schemes that carry them), and
:meth:`~ClientSampler.load_state` restores them bit-exactly — the sampler
half of ``FederatedServer``'s crash-safe ``ServerState`` bundle.
"""
from __future__ import annotations

import abc
from typing import Optional

import numpy as np

from repro.core.types import ClientPopulation, SamplingPlan, SampleResult


def conditional_plan(
    plan: SamplingPlan, available: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Condition a sampling plan on an availability mask.

    Returns ``(r_cond, urn_weights)`` where ``r_cond[k]`` is urn ``k``'s
    draw distribution restricted to the available columns (zero rows for
    urns with no available mass) and ``urn_weights[k]`` is the aggregation
    weight one draw from urn ``k`` carries: ``s_k / Σ_j s_j`` with ``s_k``
    the urn's available mass. For a plan satisfying eq. (8) this makes the
    conditional expectation of the realized weights exactly the
    re-normalized importances ``p_i·a_i / Σ_j p_j·a_j`` (with full
    availability it degenerates to ``1/m`` per draw, the unconditional
    scheme). Raises if no urn has any available mass.
    """
    a = np.asarray(available, dtype=bool)
    if a.shape != (plan.n_clients,):
        raise ValueError(
            f"availability mask shape {a.shape} != ({plan.n_clients},)"
        )
    masked = plan.r * a
    s = masked.sum(axis=1)  # available mass per urn
    total = s.sum()
    if not (np.isfinite(total) and total > 0):
        raise ValueError(
            "no sampling-plan mass on the available client set — every urn "
            "is fully masked out; nothing can be drawn"
        )
    r_cond = np.divide(masked, s[:, None], out=np.zeros_like(masked), where=s[:, None] > 0)
    return r_cond, s / total


class ClientSampler(abc.ABC):
    """Base class for all client-selection schemes."""

    #: whether the scheme satisfies Assumption 4 (unbiased aggregation)
    unbiased: bool = True
    #: whether ``observe_updates`` feeds a re-clustering pipeline (so the
    #: server / driver should bother producing representative gradients)
    consumes_updates: bool = False
    #: whether :meth:`sample_overselect`'s urn-cyclic re-weighting is exact
    #: for this scheme — requires the plan rows to *be* the draw
    #: distributions with eq. (8) column sums; schemes that re-weight draws
    #: themselves (``importance``) opt out
    supports_overselect: bool = True

    def __init__(self, population: ClientPopulation, m: int, *, seed: int = 0):
        if m <= 0:
            raise ValueError("m must be positive")
        self.population = population
        self.m = int(m)
        self._rng = np.random.default_rng(seed)

    @abc.abstractmethod
    def sample(
        self, round_idx: int, available: Optional[np.ndarray] = None
    ) -> SampleResult:
        """Draw the clients participating in round ``round_idx``.

        ``available`` is an optional boolean (n,) mask restricting the draw
        to the currently-available client set (``None`` = everyone, the
        paper's fixed-population behaviour, bit-identical to the
        pre-availability code path). Plan-based schemes condition through
        :func:`conditional_plan`; see the module docstring for the
        unbiasedness-over-the-available-set guarantee.
        """

    # Hooks -----------------------------------------------------------------
    def observe_updates(self, client_ids: np.ndarray, updates: np.ndarray) -> None:
        """Feed back the sampled clients' representative gradients.

        ``updates`` is (len(client_ids), d) — the flattened ``θ_i - θ`` per
        sampled client. Only similarity-based samplers use this.
        """
        del client_ids, updates

    @property
    def plan(self) -> Optional[SamplingPlan]:
        """Current ``r_{k,i}`` matrix for plan-based samplers, else None."""
        return None

    def plan_telemetry(self) -> tuple[int, int]:
        """(plan_version, plan_lag_rounds) of the plan the next draw uses.

        Static-plan and plan-free samplers report (0, 0); samplers backed by
        a :class:`repro.fl.planner.PlanService` report the service's active
        version and how many observed rounds it trails by (always 0 for the
        synchronous planner).
        """
        return (0, 0)

    def plan_cost_telemetry(self) -> tuple[float, float]:
        """(plan_build_ms, plan_drift) of the backing plan service.

        Plan-free and static-plan samplers report (-1.0, -1.0);
        PlanService-backed samplers report the wall-clock ms of the most
        recent completed rebuild and the drift statistic measured at the
        most recent observation (-1.0 when the drift trigger is off). Lands
        in ``RoundRecord.plan_build_ms`` / ``plan_drift``.
        """
        return (-1.0, -1.0)

    def close(self) -> None:
        """Release background resources (async planner workers)."""

    # Checkpointable state ---------------------------------------------------
    # The array/meta split mirrors repro.checkpoint's save_checkpoint(tree,
    # extra=...): arrays ride in the .npz pytree, meta in the JSON sidecar.
    def prepare_state(self) -> None:
        """Quiesce background work so the exported state is well-defined.

        Called by the server immediately before :meth:`state_arrays` /
        :meth:`state_meta`; async-planner samplers flush their in-flight
        rebuild here so the checkpoint captures the sync fixed point.
        """

    def state_arrays(self) -> dict:
        """Array-valued state (plan matrices, gradient stores); may be {}."""
        return {}

    def state_meta(self) -> dict:
        """JSON-serializable state: at minimum the rng bit-generator state."""
        return {"rng": self._rng.bit_generator.state}

    def load_state(self, meta: dict, arrays: dict) -> None:
        """Restore what :meth:`state_arrays`/:meth:`state_meta` exported.

        Bit-exact: after loading, the sampler's future draws equal those of
        the instance that was checkpointed.
        """
        del arrays
        self._rng.bit_generator.state = meta["rng"]

    # Shared machinery -------------------------------------------------------
    def _draw_from_plan(
        self, plan: SamplingPlan, available: Optional[np.ndarray] = None
    ) -> SampleResult:
        """Sample l_k ~ W_k independently (the clustered-sampling draw).

        One vectorized inverse-CDF draw over the (m, n) row-cumsum instead of
        m ``rng.choice`` calls. The arithmetic mirrors ``Generator.choice``
        exactly (per-row cumsum, normalize by the last entry, insertion index
        with ties to the right) and ``rng.random(m)`` consumes the identical
        uniform stream, so the draws are bit-for-bit those of the old loop.

        ``available`` conditions the draw on an availability mask (see
        :func:`conditional_plan`): masked urns re-normalize over their
        available columns and carry their share of the available mass as the
        per-draw aggregation weight; urns with no available mass draw
        nothing (still consuming their uniform, so the stream stays aligned
        across scenarios). An all-true mask takes the unconditional path —
        bit-identical to ``available=None``.
        """
        n = self.population.n_clients
        if available is not None:
            a = np.asarray(available, dtype=bool)
            if a.shape != (n,):
                raise ValueError(f"availability mask shape {a.shape} != ({n},)")
            if a.all():
                available = None
        if available is None:
            cdf = np.cumsum(plan.r, axis=1)
            total = cdf[:, -1]
            # rng.choice validated p per call — keep failing fast on
            # degenerate rows (NaN-poisoned gradients, zero-mass urns)
            # instead of silently collapsing every such draw onto client 0
            bad = ~(np.isfinite(total) & (total > 0))
            if bad.any():
                k = int(np.argmax(bad))
                raise ValueError(
                    f"plan row {k} is not a probability distribution "
                    f"(total mass {total[k]!r}); cannot draw from it"
                )
            cdf /= total[:, None]
            u = self._rng.random(plan.m)
            # searchsorted(side="right") per row: #{i: cdf[k,i] <= u_k};
            # u < 1 and cdf[k,-1] == 1 exactly, so the index never reaches
            # n. A zero-mass client repeats its predecessor's cdf value and
            # can never be hit.
            clients = (cdf <= u[:, None]).sum(axis=1).astype(np.int64)
            counts = np.bincount(clients, minlength=n)
            return SampleResult(clients=clients, agg_weights=counts / plan.m)

        # availability-conditioned draw
        masked = plan.r * a
        s = masked.sum(axis=1)  # available mass per urn
        total = float(s.sum())
        if not np.isfinite(total):
            raise ValueError("plan mass on the available set is not finite")
        u = self._rng.random(plan.m)
        agg = np.zeros(n)
        if total <= 0:
            # every urn fully masked out: nothing to draw — the caller
            # (FederatedServer) turns this into EmptyRoundError
            return SampleResult(clients=np.empty(0, np.int64), agg_weights=agg)
        active = s > 0
        cdf = np.cumsum(masked[active], axis=1)
        cdf /= cdf[:, -1][:, None]
        clients = (cdf <= u[active, None]).sum(axis=1).astype(np.int64)
        # importance-corrected urn weights: urn k's draw carries s_k / Σ s_j
        # so E[ω_i | available] is exactly the re-normalized importances
        np.add.at(agg, clients, s[active] / total)
        return SampleResult(clients=clients, agg_weights=agg)

    # -- overselection -------------------------------------------------------
    def sample_overselect(
        self,
        round_idx: int,
        n_draws: int,
        available: Optional[np.ndarray] = None,
    ) -> SampleResult:
        """Draw ``n_draws > m`` weighted draws from the current plan.

        The overselection scheduler's draw primitive: urns are re-used
        cyclically (draw ``j`` comes from urn ``j mod m``) and each draw
        from urn ``k`` carries ``w_k / c_k`` — ``w_k`` the urn's draw
        weight (``1/m``; its share of available mass when conditioned) and
        ``c_k`` how many of the ``n_draws`` use urn ``k`` — so summed over
        all draws ``E[ω_i] = p_i`` exactly for any eq. (8) plan (and the
        re-normalized ``p_i·a_i / Σ_j p_j·a_j`` under a mask). The result's
        ``draw_weights`` carries the per-draw weights the scheduler thins.

        Only meaningful for plan-based schemes whose rows are the actual
        draw distributions (``supports_overselect``); plan-free samplers
        raise.
        """
        del round_idx
        if not self.supports_overselect:
            raise NotImplementedError(
                f"{type(self).__name__} re-weights its draws itself; the "
                "urn-cyclic overselection re-weighting would not be unbiased "
                "for it — pick a plan-based scheme for scheduler='overselect'"
            )
        plan = self.plan
        if plan is None:
            raise NotImplementedError(
                f"{type(self).__name__} holds no sampling plan; "
                "scheduler='overselect' needs a plan-based scheme"
            )
        return self._draw_from_plan_overselect(plan, n_draws, available)

    def _draw_from_plan_overselect(
        self,
        plan: SamplingPlan,
        n_draws: int,
        available: Optional[np.ndarray] = None,
    ) -> SampleResult:
        """The cyclic-urn weighted draw behind :meth:`sample_overselect`.

        Mirrors :meth:`_draw_from_plan`'s vectorized inverse-CDF arithmetic
        (per-row cumsum, ties right, one uniform per draw) over the urn
        sequence ``0..m-1, 0..`` of length ``n_draws``. Conditioning
        follows :func:`conditional_plan`: masked urns re-normalize over
        their available columns, urns with no available mass consume their
        uniforms but draw nothing, and per-draw weights use the urn's share
        of the total available mass.
        """
        if n_draws < plan.m:
            raise ValueError(
                f"n_draws={n_draws} < m={plan.m}: overselection must cover "
                "every urn at least once"
            )
        n = self.population.n_clients
        urn_of_draw = np.arange(int(n_draws), dtype=np.int64) % plan.m
        c = np.bincount(urn_of_draw, minlength=plan.m).astype(np.float64)
        if available is not None:
            a = np.asarray(available, dtype=bool)
            if a.shape != (n,):
                raise ValueError(f"availability mask shape {a.shape} != ({n},)")
            if a.all():
                available = None
        if available is None:
            cdf = np.cumsum(plan.r, axis=1)
            total = cdf[:, -1]
            bad = ~(np.isfinite(total) & (total > 0))
            if bad.any():
                k = int(np.argmax(bad))
                raise ValueError(
                    f"plan row {k} is not a probability distribution "
                    f"(total mass {total[k]!r}); cannot draw from it"
                )
            cdf /= total[:, None]
            u = self._rng.random(int(n_draws))
            clients = (cdf[urn_of_draw] <= u[:, None]).sum(axis=1).astype(np.int64)
            w = (1.0 / plan.m) / c[urn_of_draw]
            agg = np.zeros(n)
            np.add.at(agg, clients, w)
            return SampleResult(
                clients=clients, agg_weights=agg, draw_weights=w
            )

        masked = plan.r * a
        s = masked.sum(axis=1)
        total = float(s.sum())
        if not np.isfinite(total):
            raise ValueError("plan mass on the available set is not finite")
        u = self._rng.random(int(n_draws))
        agg = np.zeros(n)
        if total <= 0:
            return SampleResult(
                clients=np.empty(0, np.int64),
                agg_weights=agg,
                draw_weights=np.empty(0, np.float64),
            )
        live = s[urn_of_draw] > 0  # draws whose urn has available mass
        cdf = np.cumsum(masked, axis=1)
        cdf = np.divide(
            cdf, s[:, None], out=np.zeros_like(cdf), where=s[:, None] > 0
        )
        rows = urn_of_draw[live]
        clients = (cdf[rows] <= u[live, None]).sum(axis=1).astype(np.int64)
        w = (s[rows] / total) / c[rows]
        np.add.at(agg, clients, w)
        return SampleResult(clients=clients, agg_weights=agg, draw_weights=w)


def validate_plan(
    plan: SamplingPlan, population: ClientPopulation, *, atol: float = 1e-9
) -> None:
    """Assert the two Proposition-1 conditions on an ``r`` matrix.

    * eq. (7): every row of ``r`` is a probability distribution,
    * eq. (8): every column sums to ``m * p_i`` (unbiasedness).

    Raises ``ValueError`` with a precise diagnostic on violation. When the
    plan carries its integer token allocation the check is exact.
    """
    r = plan.r
    m, n = r.shape
    if n != population.n_clients:
        raise ValueError(f"plan covers {n} clients, population has {population.n_clients}")
    if (r < -atol).any():
        bad = np.argwhere(r < -atol)[0]
        raise ValueError(f"negative probability r[{bad[0]},{bad[1]}] = {r[tuple(bad)]}")
    row_sums = r.sum(axis=1)
    if not np.allclose(row_sums, 1.0, atol=atol):
        k = int(np.argmax(np.abs(row_sums - 1.0)))
        raise ValueError(f"eq.(7) violated: sum_i r[{k},i] = {row_sums[k]!r} != 1")
    col_sums = r.sum(axis=0)
    target = plan.m * population.importances
    if not np.allclose(col_sums, target, atol=atol):
        i = int(np.argmax(np.abs(col_sums - target)))
        raise ValueError(
            f"eq.(8) violated: sum_k r[k,{i}] = {col_sums[i]!r} != m*p_i = {target[i]!r}"
        )
    if plan.r_tokens is not None:
        tok = np.asarray(plan.r_tokens, dtype=np.int64)
        M = population.total_samples
        if (tok.sum(axis=1) != M).any():
            raise ValueError("integer allocation: some urn does not hold exactly M tokens")
        expect = plan.m * population.n_samples
        if (tok.sum(axis=0) != expect).any():
            i = int(np.argmax(tok.sum(axis=0) != expect))
            raise ValueError(
                f"integer allocation: client {i} allocated {tok.sum(axis=0)[i]} "
                f"tokens, expected m*n_i = {expect[i]}"
            )


def max_draws_bound(plan: SamplingPlan) -> np.ndarray:
    """Upper bound on how many times each client can be drawn = #{k: r_{k,i} > 0}.

    For Algorithm 1 this is at most ``floor(m p_i) + 2`` (Section 4 of the
    paper), versus ``m`` for MD sampling.
    """
    return (plan.r > 0).sum(axis=0)
