"""'Target' oracle sampling (Section 6, Fig. 1).

The controlled MNIST experiment's ideal scheme: the true client grouping
(e.g. by owned class) is known, one client is drawn from each group per
round. Only usable in simulation — the server cannot know client data
distributions — but it upper-bounds what Algorithm 2 can converge to.

Implemented as a clustered-sampling plan whose groups are the oracle
clusters, so all Proposition-1 machinery applies when group masses are
balanced (each group must carry exactly M tokens for exact unbiasedness;
otherwise the plan is the best unbiased approximation via urn filling with
oracle groups).
"""
from __future__ import annotations

import numpy as np

from repro.core.allocation import allocate_by_groups
from repro.core.samplers.clustered import ClusteredSampler
from repro.core.types import ClientPopulation, SamplingPlan


def build_plan_target(
    population: ClientPopulation, m: int, groups: list[np.ndarray]
) -> SamplingPlan:
    M = population.total_samples
    mass = m * population.n_samples
    tokens = allocate_by_groups(mass, m, M, groups)
    cluster_of = np.full(population.n_clients, -1, dtype=np.int64)
    for gid, g in enumerate(groups):
        cluster_of[np.asarray(g, dtype=np.int64)] = gid
    return SamplingPlan(r=tokens / M, r_tokens=tokens, cluster_of=cluster_of)


class TargetSampler(ClusteredSampler):
    def __init__(
        self,
        population: ClientPopulation,
        m: int,
        groups: list[np.ndarray],
        *,
        seed: int = 0,
    ):
        super().__init__(population, build_plan_target(population, m, groups), seed=seed)
