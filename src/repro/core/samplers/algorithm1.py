"""Algorithm 1 — clustered sampling based on sample size (Section 4).

Deterministic urn-filling over descending-``n_i`` clients. O(n log n); since
it only depends on the ``n_i`` it is computed once and reused every round.
Each client appears in at most ``floor(m p_i) + 2`` distributions, versus
``m`` under MD sampling.
"""
from __future__ import annotations

from repro.core.allocation import allocate_by_size
from repro.core.samplers.clustered import ClusteredSampler
from repro.core.types import ClientPopulation, SamplingPlan


def build_plan_algorithm1(population: ClientPopulation, m: int) -> SamplingPlan:
    M = population.total_samples
    tokens = allocate_by_size(m * population.n_samples, n_urns=m, capacity=M)
    return SamplingPlan(r=tokens / M, r_tokens=tokens)


class Algorithm1Sampler(ClusteredSampler):
    """Sample-size clustered sampling; the plan is static across rounds."""

    def __init__(self, population: ClientPopulation, m: int, *, seed: int = 0):
        super().__init__(population, build_plan_algorithm1(population, m), seed=seed)
