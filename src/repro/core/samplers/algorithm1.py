"""Algorithm 1 — clustered sampling based on sample size (Section 4).

Deterministic urn-filling over descending-``n_i`` clients. O(n log n); since
it only depends on the ``n_i`` it is computed once and reused every round.
Each client appears in at most ``floor(m p_i) + 2`` distributions, versus
``m`` under MD sampling.
"""
from __future__ import annotations

from repro.core.allocation import allocate_by_size
from repro.core.samplers.clustered import ClusteredSampler
from repro.core.types import ClientPopulation, SamplingPlan


def build_plan_algorithm1(population: ClientPopulation, m: int) -> SamplingPlan:
    M = population.total_samples
    tokens = allocate_by_size(m * population.n_samples, n_urns=m, capacity=M)
    return SamplingPlan(r=tokens / M, r_tokens=tokens)


class Algorithm1Sampler(ClusteredSampler):
    """Sample-size clustered sampling; the plan is static across rounds.

    The plan still runs through the shared
    :class:`repro.fl.planner.PlanService` (always version 0, lag 0 — it
    never observes updates), so plan handoff, telemetry and re-planning
    machinery are uniform across the clustered samplers.
    """

    def __init__(self, population: ClientPopulation, m: int, *, seed: int = 0):
        from repro.fl.planner import PlanService

        self._service = PlanService(lambda _: build_plan_algorithm1(population, m))
        super().__init__(population, self._service.current().plan, seed=seed)

    @property
    def plan_service(self):
        return self._service

    def plan_telemetry(self) -> tuple[int, int]:
        return self._service.telemetry()

    def plan_cost_telemetry(self) -> tuple[float, float]:
        # build cost of the (static) version-0 plan; drift trigger never runs
        return self._service.last_build_ms(), self._service.last_drift()

    def close(self) -> None:
        self._service.close()
