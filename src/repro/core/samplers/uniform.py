"""FedAvg sampling (McMahan et al., 2017) — uniform without replacement.

Kept as the biased baseline the paper compares against: the non-sampled
clients' contribution is replaced by the current global model (eq. 3), so
``E[θ^{t+1}] != Σ p_i θ_i^{t+1}`` in general.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.samplers.base import ClientSampler
from repro.core.types import SampleResult


class UniformSampler(ClientSampler):
    unbiased = False

    def sample(
        self, round_idx: int, available: Optional[np.ndarray] = None
    ) -> SampleResult:
        del round_idx
        n = self.population.n_clients
        if available is None:
            pool = np.arange(n)
        else:
            pool = np.flatnonzero(np.asarray(available, dtype=bool))
            if pool.size == 0:
                # nothing to draw from; the server raises EmptyRoundError
                return SampleResult(
                    clients=np.empty(0, np.int64),
                    agg_weights=np.zeros(n),
                    stale_weight=1.0,
                )
        clients = pool[self._rng.choice(pool.size, size=min(self.m, pool.size), replace=False)]
        p = self.population.importances
        weights = np.zeros(n)
        weights[clients] = p[clients]  # n_i/M on sampled clients (eq. 3)
        stale = float(1.0 - weights.sum())  # mass left on the stale global model
        return SampleResult(
            clients=np.sort(clients), agg_weights=weights, stale_weight=stale
        )
