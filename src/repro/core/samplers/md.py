"""MD sampling (Li et al., 2018) — the paper's reference scheme.

``m`` iid draws from the multinomial W_0 with P(i) = p_i; aggregation
weight 1/m per draw (eq. 4). Special case of clustered sampling with
``W_k = W_0`` for every k.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.samplers.base import ClientSampler
from repro.core.types import ClientPopulation, SamplingPlan, SampleResult


class MDSampler(ClientSampler):
    unbiased = True

    def __init__(self, population: ClientPopulation, m: int, *, seed: int = 0):
        super().__init__(population, m, seed=seed)
        p = population.importances
        self._plan = SamplingPlan(r=np.tile(p, (m, 1)))

    @property
    def plan(self) -> SamplingPlan:
        return self._plan

    def sample(
        self, round_idx: int, available: Optional[np.ndarray] = None
    ) -> SampleResult:
        del round_idx
        # under an availability mask every row conditions to p·a / Σ p_j a_j
        # — MD sampling restricted to the available set, still unbiased there
        return self._draw_from_plan(self._plan, available)
