"""Algorithm 2 — clustered sampling based on model similarity (Section 5).

Pipeline per re-clustering round:
  1. similarity matrix over representative gradients ``G_i = θ_i - θ``
     (device-side, Pallas kernel on TPU; numpy here),
  2. Ward hierarchical clustering,
  3. cut into K >= m groups with mass q_k <= M,
  4. cluster-seeded urn filling -> ``r`` matrix.

Clients never sampled yet carry a constant 0 representative gradient, so
they cluster together and get promoted jointly (the paper's cold-start
rule). Clients with ``p_i >= 1/m`` receive ``floor(m p_i)`` dedicated
probability-1 distributions, their remainder mass joining the common pool
(final remark of Section 5).

The sampler is the *consumer* half of a producer/consumer split: gradients
live in a device-resident :class:`repro.fl.gradient_store.GradientStore`
(scatter-updated from the engine's round output, no host round-trip) and
plan rebuilds run through a :class:`repro.fl.planner.PlanService` —
synchronous by default, or overlapped with client local work via
``planner="async"`` (the paper's Section-5 overlap made explicit).
"""
from __future__ import annotations

from typing import Callable, Optional, Union

import numpy as np

from repro.core.allocation import allocate_by_groups
from repro.core.clustering.backends import resolve_clusterer
from repro.core.samplers.clustered import ClusteredSampler
from repro.core.types import ClientPopulation, SamplingPlan, SampleResult

# pairwise-distance backend signature: (G, measure) -> (n, n) distances
DistanceFn = Callable[[np.ndarray, str], np.ndarray]

# clusterer signature: see repro.core.clustering.backends
ClustererFn = Callable[..., list]


def _resolve_distance_fn(
    distance_fn: Union[DistanceFn, str, None], *, as_numpy: bool = False
) -> Optional[DistanceFn]:
    """Map the sampler's ``distance_fn`` argument to a callable.

    Strings name a backend (see
    :func:`repro.kernels.similarity.ops.resolve_distance_backend`); the
    import is deferred so ``repro.core`` stays importable without jax.
    ``None`` keeps the numpy host reference. ``as_numpy=False`` leaves
    device backends' (n, n) output on device — the clustering backend
    decides whether it ever visits host (the numpy Ward reference copies
    it; ``ward_jit``/``kmeans`` never do).
    """
    if distance_fn is None or callable(distance_fn):
        return distance_fn
    from repro.kernels.similarity.ops import resolve_distance_backend

    return resolve_distance_backend(distance_fn, as_numpy=as_numpy)


def build_plan_algorithm2(
    population: ClientPopulation,
    m: int,
    G,
    *,
    measure: str = "arccos",
    distance_fn: Optional[DistanceFn] = None,
    clusterer: Union[ClustererFn, str] = "ward",
    clusterer_seed: int = 0,
) -> SamplingPlan:
    """Build the similarity-clustered ``r`` matrix for one round.

    ``G`` is passed to the clustering backend untouched — a device array
    stays on device through the O(n²d) distance stage and (for the device
    clusterers) the clustering itself; only the group structure comes back
    to host for the final urn construction. ``clusterer`` names a
    :data:`repro.core.clustering.backends.CLUSTERERS` entry (``"ward"`` —
    the paper-faithful numpy reference and default; ``"ward_jit"``;
    ``"kmeans"``) or is a callable with the same signature.
    """
    n = population.n_clients
    M = population.total_samples
    mass = m * population.n_samples  # m * n_i tokens per client

    # --- large clients: dedicated probability-1 urns --------------------
    full_urns = (mass // M).astype(np.int64)  # floor(m p_i) per client
    pool_mass = mass - full_urns * M  # remainder joins the pool
    m_pool = m - int(full_urns.sum())
    if m_pool < 0:
        raise ValueError("impossible: sum floor(m p_i) > m")

    tokens = np.zeros((m, n), dtype=np.int64)
    owners = np.repeat(np.arange(n), full_urns)  # urn k -> its dedicated client
    tokens[np.arange(owners.size), owners] = M
    urn = int(owners.size)

    cluster_of = np.full(n, -1, dtype=np.int64)
    if m_pool > 0:
        pool = np.flatnonzero(pool_mass > 0)
        cluster = resolve_clusterer(clusterer)
        groups_local = cluster(
            G[pool],
            pool_mass[pool],
            m_pool,
            M,
            measure=measure,
            distance_fn=distance_fn,
            seed=clusterer_seed,
        )
        groups = [pool[g] for g in groups_local]
        for gid, g in enumerate(groups):
            cluster_of[g] = gid
        pool_tokens = allocate_by_groups(pool_mass, m_pool, M, groups)
        tokens[urn:, :] = pool_tokens

    return SamplingPlan(r=tokens / M, r_tokens=tokens, cluster_of=cluster_of)


class Algorithm2Sampler(ClusteredSampler):
    """Similarity-based clustered sampling with online re-clustering.

    The latest representative gradient of every client (zeros until first
    sampled) lives in a device-resident gradient store; observing a round's
    updates scatters them in and hands a snapshot to the plan service, which
    rebuilds the plan — inline (``planner="sync"``) or on a background
    worker overlapping the next round (``planner="async"``), matching the
    paper's server that overlaps re-clustering with client local work. The
    freshest completed plan is swapped in at each round boundary (in
    :meth:`sample`).
    """

    consumes_updates = True

    def __init__(
        self,
        population: ClientPopulation,
        m: int,
        update_dim: int,
        *,
        measure: str = "arccos",
        seed: int = 0,
        distance_fn: Union[DistanceFn, str, None] = "auto",
        clusterer: Union[ClustererFn, str] = "ward",
        staleness_decay: float = 1.0,
        planner: str = "sync",
        rebuild_every: int = 1,
        drift_threshold: Optional[float] = None,
        sketch: Optional[str] = None,
        sketch_dim: Optional[int] = None,
        store_mesh_spec=None,
    ):
        """``staleness_decay`` < 1 is a beyond-paper extension: every round,
        stored representative gradients shrink by this factor, so clients
        that have not been sampled for many rounds drift back toward the
        zero-vector (cold-start) cluster instead of being clustered on
        arbitrarily stale similarity. 1.0 = the paper's behaviour.

        ``distance_fn`` selects the O(n²d) pairwise-distance backend: a
        backend name (``"auto"`` — the default device path: compiled Pallas
        on TPU, interpret-mode Pallas everywhere else, GPU included — the
        kernel's VMEM scratch is TPU-only; ``"pallas"`` — TPU only, errors
        elsewhere; ``"pallas-interpret"``; ``"streamed"`` — d-chunked
        accumulation for model-sized gradients; ``"numpy"``), a custom
        callable, or ``None`` for the numpy host reference.

        ``clusterer`` selects the grouping backend for the pool clients
        (a ``CLUSTERERS`` name — ``"ward"`` default, ``"ward_jit"``,
        ``"kmeans"`` — or a callable; see
        :mod:`repro.core.clustering.backends`). The device clusterers
        consume the distance matrix / G where the store left them, so the
        rebuild never materializes a host copy of the gradient block.

        ``planner`` selects when Algorithm 2's O(n²d + n³) rebuild runs:
        ``"sync"`` inside ``observe_updates`` (the parity reference) or
        ``"async"`` on a background worker while the next round trains.
        ``rebuild_every=k`` re-clusters only every k observed rounds — the
        gradient store still absorbs every round's updates, so the k-th
        rebuild sees all of them (``RoundRecord.plan_version`` records which
        observation each round's plan incorporates). ``drift_threshold``
        replaces the fixed cadence with the planner's measured trigger: a
        rebuild runs only when the assignment churn of the fresh gradients
        against the live plan's clusters reaches the threshold (see
        :class:`repro.fl.planner.AssignmentDriftMonitor`).

        ``sketch`` / ``sketch_dim`` attach a device-side sketch stage to the
        gradient store (a :data:`repro.kernels.sketch.SKETCHERS` name —
        ``"srp"``, ``"countsketch"``, or ``"identity"`` for the exact
        bit-for-bit legacy path): the engine's (c, d) device updates are
        compressed to (c, d') *before* scatter, so the resident store, the
        O(n²·d) similarity stage and the drift monitor's centroids all live
        in sketch space. The sketch is seeded with the sampler ``seed``, so
        a checkpointed store restores against the identical projection.
        ``store_mesh_spec`` shards the store's client axis over a device
        mesh (the PR 2 engine mesh convention)."""
        from repro.fl.gradient_store import GradientStore
        from repro.fl.planner import PlanService

        self.measure = measure
        self.update_dim = int(update_dim)
        self._distance_fn = _resolve_distance_fn(distance_fn)
        self._clusterer = clusterer
        self.staleness_decay = float(staleness_decay)
        self._store = GradientStore(
            population.n_clients,
            update_dim,
            staleness_decay=staleness_decay,
            sketch=sketch,
            sketch_dim=sketch_dim,
            sketch_seed=seed,
            mesh_spec=store_mesh_spec,
        )

        def build(G) -> SamplingPlan:
            return build_plan_algorithm2(
                population,
                m,
                G,
                measure=measure,
                distance_fn=self._distance_fn,
                clusterer=self._clusterer,
                clusterer_seed=seed,
            )

        self._service = PlanService(
            build,
            mode=planner,
            initial_input=self._store.snapshot(),
            rebuild_every=rebuild_every,
            drift_threshold=drift_threshold,
        )
        super().__init__(population, self._service.current().plan, seed=seed)

    @property
    def representative_gradients(self) -> np.ndarray:
        """Host copy of the resident G — (n, d'), sketch space if sketched."""
        return self._store.asnumpy()

    @property
    def gradient_store(self):
        return self._store

    @property
    def plan_service(self):
        return self._service

    def _swap_freshest(self) -> None:
        vp = self._service.poll()
        if vp is not None:
            self.set_plan(vp.plan)

    def observe_updates(self, client_ids, updates) -> None:
        """Scatter the round's updates into the store and trigger a rebuild.

        ``updates`` may be the engine's device array — it is neither copied
        to host nor cast; the store scatters it on device and the plan
        service receives an immutable snapshot of G.
        """
        if tuple(updates.shape) != (len(client_ids), self.update_dim):
            raise ValueError(
                f"updates shape {tuple(updates.shape)} != ({len(client_ids)}, {self.update_dim})"
            )
        self._store.update(client_ids, updates)
        self._service.observe(self._store.snapshot())
        if self._service.mode == "sync":
            self._swap_freshest()

    def plan_telemetry(self) -> tuple[int, int]:
        return self._service.telemetry()

    def plan_cost_telemetry(self) -> tuple[float, float]:
        return self._service.last_build_ms(), self._service.last_drift()

    def flush_plan(self) -> None:
        """Block until any in-flight rebuild lands, then swap it in.

        Forces the async planner to the sync fixed point — after this, the
        plan equals what ``planner="sync"`` would hold (fp32 tolerance)."""
        self._service.flush()
        self._swap_freshest()

    def close(self) -> None:
        self._service.close()

    # -- checkpointable state ------------------------------------------------
    def prepare_state(self) -> None:
        """Quiesce the planner so the checkpoint is the sync fixed point.

        With ``planner="async"`` an in-flight rebuild cannot ride in a
        checkpoint; flushing first makes the exported (G, plan, counters)
        bundle self-consistent — a restored server continues exactly as a
        sync-planned one would from this state.
        """
        self.flush_plan()

    def state_arrays(self) -> dict:
        arrays = super().state_arrays()
        arrays["store_G"] = self._store.asnumpy()
        return arrays

    def state_meta(self) -> dict:
        meta = super().state_meta()
        version, _ = self._service.telemetry()
        meta["plan_version"] = version
        meta["obs_seen"] = self._service.observations_seen()
        # the sketch identity rides along so a restore into a differently-
        # sketched store fails loudly instead of mixing sketch spaces
        sk = self._store.sketch
        meta["sketch"] = None if sk is None else sk.name
        meta["sketch_dim"] = None if sk is None else sk.d_out
        meta["sketch_seed"] = None if sk is None else sk.seed
        return meta

    def load_state(self, meta: dict, arrays: dict) -> None:
        super().load_state(meta, arrays)  # rng + the exact live plan
        sk = self._store.sketch
        have = (
            (None if sk is None else sk.name),
            (None if sk is None else sk.d_out),
            (None if sk is None else sk.seed),
        )
        want = (
            meta.get("sketch"),
            meta.get("sketch_dim"),
            meta.get("sketch_seed"),
        )
        if want != have:
            raise ValueError(
                f"checkpointed sketch state {want} != this sampler's sketch "
                f"{have}: a (name, dim, seed) mismatch would scatter new "
                "updates into a different sketch space than the restored G"
            )
        self._store.load(arrays["store_G"])
        from repro.fl.planner import VersionedPlan

        self._service.restore(
            VersionedPlan(self._plan, int(meta["plan_version"])),
            obs_seen=int(meta["obs_seen"]),
        )

    def sample(
        self, round_idx: int, available: Optional[np.ndarray] = None
    ) -> SampleResult:
        del round_idx
        self._swap_freshest()  # round boundary: adopt the freshest plan
        return self._draw_from_plan(self._plan, available)
