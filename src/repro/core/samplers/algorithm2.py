"""Algorithm 2 — clustered sampling based on model similarity (Section 5).

Pipeline per re-clustering round:
  1. similarity matrix over representative gradients ``G_i = θ_i - θ``
     (device-side, Pallas kernel on TPU; numpy here),
  2. Ward hierarchical clustering,
  3. cut into K >= m groups with mass q_k <= M,
  4. cluster-seeded urn filling -> ``r`` matrix.

Clients never sampled yet carry a constant 0 representative gradient, so
they cluster together and get promoted jointly (the paper's cold-start
rule). Clients with ``p_i >= 1/m`` receive ``floor(m p_i)`` dedicated
probability-1 distributions, their remainder mass joining the common pool
(final remark of Section 5).
"""
from __future__ import annotations

from typing import Callable, Optional, Union

import numpy as np

from repro.core.allocation import allocate_by_groups
from repro.core.clustering.similarity import pairwise_distances
from repro.core.clustering.tree import cut_tree
from repro.core.clustering.ward import ward_linkage
from repro.core.samplers.clustered import ClusteredSampler
from repro.core.types import ClientPopulation, SamplingPlan, SampleResult

# pairwise-distance backend signature: (G, measure) -> (n, n) distances
DistanceFn = Callable[[np.ndarray, str], np.ndarray]


def _resolve_distance_fn(distance_fn: Union[DistanceFn, str, None]) -> Optional[DistanceFn]:
    """Map the sampler's ``distance_fn`` argument to a callable.

    Strings name a backend (see
    :func:`repro.kernels.similarity.ops.resolve_distance_backend`); the
    import is deferred so ``repro.core`` stays importable without jax.
    ``None`` keeps the numpy host reference.
    """
    if distance_fn is None or callable(distance_fn):
        return distance_fn
    from repro.kernels.similarity.ops import resolve_distance_backend

    return resolve_distance_backend(distance_fn)


def build_plan_algorithm2(
    population: ClientPopulation,
    m: int,
    G: np.ndarray,
    *,
    measure: str = "arccos",
    distance_fn: Optional[DistanceFn] = None,
) -> SamplingPlan:
    """Build the similarity-clustered ``r`` matrix for one round."""
    n = population.n_clients
    M = population.total_samples
    mass = m * population.n_samples  # m * n_i tokens per client

    # --- large clients: dedicated probability-1 urns --------------------
    full_urns = (mass // M).astype(np.int64)  # floor(m p_i) per client
    pool_mass = mass - full_urns * M  # remainder joins the pool
    m_pool = m - int(full_urns.sum())
    if m_pool < 0:
        raise ValueError("impossible: sum floor(m p_i) > m")

    tokens = np.zeros((m, n), dtype=np.int64)
    urn = 0
    for i in range(n):
        for _ in range(int(full_urns[i])):
            tokens[urn, i] = M
            urn += 1

    cluster_of = np.full(n, -1, dtype=np.int64)
    if m_pool > 0:
        pool = np.flatnonzero(pool_mass > 0)
        dfn = distance_fn or pairwise_distances
        dist = dfn(np.asarray(G, dtype=np.float64)[pool], measure)
        link = ward_linkage(dist)
        groups_local = cut_tree(link, len(pool), m_pool, pool_mass[pool], M)
        groups = [pool[g] for g in groups_local]
        for gid, g in enumerate(groups):
            cluster_of[g] = gid
        pool_tokens = allocate_by_groups(pool_mass, m_pool, M, groups)
        tokens[urn:, :] = pool_tokens

    return SamplingPlan(r=tokens / M, r_tokens=tokens, cluster_of=cluster_of)


class Algorithm2Sampler(ClusteredSampler):
    """Similarity-based clustered sampling with online re-clustering.

    The sampler stores the latest representative gradient of every client
    (zeros until first sampled) and rebuilds the plan whenever updates are
    observed — matching the paper's per-round re-clustering, which the
    server overlaps with client local work.
    """

    def __init__(
        self,
        population: ClientPopulation,
        m: int,
        update_dim: int,
        *,
        measure: str = "arccos",
        seed: int = 0,
        distance_fn: Union[DistanceFn, str, None] = "auto",
        staleness_decay: float = 1.0,
    ):
        """``staleness_decay`` < 1 is a beyond-paper extension: every round,
        stored representative gradients shrink by this factor, so clients
        that have not been sampled for many rounds drift back toward the
        zero-vector (cold-start) cluster instead of being clustered on
        arbitrarily stale similarity. 1.0 = the paper's behaviour.

        ``distance_fn`` selects the O(n²d) pairwise-distance backend: a
        backend name (``"auto"`` — the default device path: compiled Pallas
        on TPU, interpret-mode Pallas everywhere else, GPU included — the
        kernel's VMEM scratch is TPU-only; ``"pallas"`` — TPU only, errors
        elsewhere; ``"pallas-interpret"``; ``"numpy"``), a custom callable,
        or ``None`` for the numpy host reference."""
        self.measure = measure
        self.update_dim = int(update_dim)
        self._distance_fn = _resolve_distance_fn(distance_fn)
        self.staleness_decay = float(staleness_decay)
        self._G = np.zeros((population.n_clients, update_dim), dtype=np.float64)
        plan = build_plan_algorithm2(
            population, m, self._G, measure=measure, distance_fn=self._distance_fn
        )
        super().__init__(population, plan, seed=seed)

    @property
    def representative_gradients(self) -> np.ndarray:
        return self._G

    def observe_updates(self, client_ids: np.ndarray, updates: np.ndarray) -> None:
        updates = np.asarray(updates, dtype=np.float64)
        if updates.shape != (len(client_ids), self.update_dim):
            raise ValueError(
                f"updates shape {updates.shape} != ({len(client_ids)}, {self.update_dim})"
            )
        if self.staleness_decay < 1.0:
            self._G *= self.staleness_decay  # beyond-paper: age-out stale gradients
        self._G[np.asarray(client_ids, dtype=np.int64)] = updates
        self.set_plan(
            build_plan_algorithm2(
                self.population,
                self.m,
                self._G,
                measure=self.measure,
                distance_fn=self._distance_fn,
            )
        )

    def sample(self, round_idx: int) -> SampleResult:
        del round_idx
        return self._draw_from_plan(self._plan)
