"""Algorithm 2 — clustered sampling based on model similarity (Section 5).

Pipeline per re-clustering round:
  1. similarity matrix over representative gradients ``G_i = θ_i - θ``
     (device-side, Pallas kernel on TPU; numpy here),
  2. Ward hierarchical clustering,
  3. cut into K >= m groups with mass q_k <= M,
  4. cluster-seeded urn filling -> ``r`` matrix.

Clients never sampled yet carry a constant 0 representative gradient, so
they cluster together and get promoted jointly (the paper's cold-start
rule). Clients with ``p_i >= 1/m`` receive ``floor(m p_i)`` dedicated
probability-1 distributions, their remainder mass joining the common pool
(final remark of Section 5).

The sampler is the *consumer* half of a producer/consumer split: gradients
live in a device-resident :class:`repro.fl.gradient_store.GradientStore`
(scatter-updated from the engine's round output, no host round-trip) and
plan rebuilds run through a :class:`repro.fl.planner.PlanService` —
synchronous by default, or overlapped with client local work via
``planner="async"`` (the paper's Section-5 overlap made explicit).
"""
from __future__ import annotations

from typing import Callable, Optional, Union

import numpy as np

from repro.core.allocation import allocate_by_groups
from repro.core.clustering.backends import resolve_clusterer
from repro.core.samplers.store_backed import StoreBackedSampler
from repro.core.types import ClientPopulation, SamplingPlan

# pairwise-distance backend signature: (G, measure) -> (n, n) distances
DistanceFn = Callable[[np.ndarray, str], np.ndarray]

# clusterer signature: see repro.core.clustering.backends
ClustererFn = Callable[..., list]


def _resolve_distance_fn(
    distance_fn: Union[DistanceFn, str, None], *, as_numpy: bool = False
) -> Optional[DistanceFn]:
    """Map the sampler's ``distance_fn`` argument to a callable.

    Strings name a backend (see
    :func:`repro.kernels.similarity.ops.resolve_distance_backend`); the
    import is deferred so ``repro.core`` stays importable without jax.
    ``None`` keeps the numpy host reference. ``as_numpy=False`` leaves
    device backends' (n, n) output on device — the clustering backend
    decides whether it ever visits host (the numpy Ward reference copies
    it; ``ward_jit``/``kmeans`` never do).
    """
    if distance_fn is None or callable(distance_fn):
        return distance_fn
    from repro.kernels.similarity.ops import resolve_distance_backend

    return resolve_distance_backend(distance_fn, as_numpy=as_numpy)


def _fit_chunks(ids: np.ndarray, mass: np.ndarray, capacity: int) -> list[np.ndarray]:
    """Greedy first-fit packing of ``ids`` into chunks of mass <= capacity.

    Every pool client's remainder mass is < M by construction (it is
    ``m·n_i mod M``), so each singleton fits and the greedy pass always
    succeeds; the chunk count is at most twice the optimum, which only
    costs a few extra (still feasible) groups.
    """
    chunks: list[np.ndarray] = []
    cur: list[int] = []
    cur_mass = 0
    for i in ids:
        mi = int(mass[i])
        if cur and cur_mass + mi > capacity:
            chunks.append(np.asarray(cur, dtype=np.int64))
            cur, cur_mass = [], 0
        cur.append(int(i))
        cur_mass += mi
    if cur:
        chunks.append(np.asarray(cur, dtype=np.int64))
    return chunks


def build_plan_algorithm2(
    population: ClientPopulation,
    m: int,
    G,
    *,
    measure: str = "arccos",
    distance_fn: Optional[DistanceFn] = None,
    clusterer: Union[ClustererFn, str] = "ward",
    clusterer_seed: int = 0,
    cluster_mask: Optional[np.ndarray] = None,
) -> SamplingPlan:
    """Build the similarity-clustered ``r`` matrix for one round.

    ``G`` is passed to the clustering backend untouched — a device array
    stays on device through the O(n²d) distance stage and (for the device
    clusterers) the clustering itself; only the group structure comes back
    to host for the final urn construction. ``clusterer`` names a
    :data:`repro.core.clustering.backends.CLUSTERERS` entry (``"ward"`` —
    the paper-faithful numpy reference and default; ``"ward_jit"``;
    ``"kmeans"``) or is a callable with the same signature.

    ``cluster_mask`` ((n,) bool, optional) restricts the expensive
    *similarity clustering* to the masked-in clients — the FedSTaS-style
    restratification over the recently-available fleet. Masked-out pool
    clients keep their exact eq. (8) token mass but are packed into greedy
    capacity-feasible filler groups (``cluster_of = -1``) instead of riding
    the O(n²d + n³) pipeline. Because every group still carries <= M tokens
    over the same ``m_pool·M`` total, the allocation stays a valid eq. (8)
    plan — Proposition 1 exactness and ``conditional_plan`` unbiasedness
    over any availability mask hold regardless of the mask that built it.
    A degenerate mask (all-in, or excluding every pool client) falls back
    to the unrestricted build.
    """
    n = population.n_clients
    M = population.total_samples
    mass = m * population.n_samples  # m * n_i tokens per client

    # --- large clients: dedicated probability-1 urns --------------------
    full_urns = (mass // M).astype(np.int64)  # floor(m p_i) per client
    pool_mass = mass - full_urns * M  # remainder joins the pool
    m_pool = m - int(full_urns.sum())
    if m_pool < 0:
        raise ValueError("impossible: sum floor(m p_i) > m")

    tokens = np.zeros((m, n), dtype=np.int64)
    owners = np.repeat(np.arange(n), full_urns)  # urn k -> its dedicated client
    tokens[np.arange(owners.size), owners] = M
    urn = int(owners.size)

    cluster_of = np.full(n, -1, dtype=np.int64)
    if m_pool > 0:
        pool = np.flatnonzero(pool_mass > 0)
        mask = None
        if cluster_mask is not None:
            cm = np.asarray(cluster_mask, dtype=bool)
            if cm.shape != (n,):
                raise ValueError(f"cluster_mask shape {cm.shape} != ({n},)")
            if not cm.all() and cm[pool].any():
                mask = cm
        cluster = resolve_clusterer(clusterer)
        if mask is None:
            clustered, chunks = pool, []
            m_target = m_pool
        else:
            clustered = pool[mask[pool]]
            chunks = _fit_chunks(pool[~mask[pool]], pool_mass, M)
            # the clusterer needs >= 1 target group and cannot cut more
            # groups than it has clients; feasibility of the combined
            # grouping is automatic (every group <= M over m_pool·M total
            # mass forces K >= m_pool)
            m_target = max(1, min(clustered.size, m_pool - len(chunks)))
        groups_local = cluster(
            G[clustered],
            pool_mass[clustered],
            m_target,
            M,
            measure=measure,
            distance_fn=distance_fn,
            seed=clusterer_seed,
        )
        groups = [clustered[g] for g in groups_local]
        for gid, g in enumerate(groups):
            cluster_of[g] = gid  # filler chunks stay -1: not similarity groups
        pool_tokens = allocate_by_groups(pool_mass, m_pool, M, groups + chunks)
        tokens[urn:, :] = pool_tokens

    return SamplingPlan(r=tokens / M, r_tokens=tokens, cluster_of=cluster_of)


class Algorithm2Sampler(StoreBackedSampler):
    """Similarity-based clustered sampling with online re-clustering.

    The latest representative gradient of every client (zeros until first
    sampled) lives in a device-resident gradient store; observing a round's
    updates scatters them in and hands a snapshot to the plan service, which
    rebuilds the plan — inline (``planner="sync"``) or on a background
    worker overlapping the next round (``planner="async"``), matching the
    paper's server that overlaps re-clustering with client local work. The
    freshest completed plan is swapped in at each round boundary (in
    :meth:`sample`). The store/service machinery is the shared
    :class:`~repro.core.samplers.store_backed.StoreBackedSampler` skeleton;
    this class contributes only the Section-5 plan construction.
    """

    scheme_name = "algorithm2"

    def __init__(
        self,
        population: ClientPopulation,
        m: int,
        update_dim: int,
        *,
        measure: str = "arccos",
        seed: int = 0,
        distance_fn: Union[DistanceFn, str, None] = "auto",
        clusterer: Union[ClustererFn, str] = "ward",
        staleness_decay: float = 1.0,
        planner: str = "sync",
        rebuild_every: int = 1,
        drift_threshold: Optional[float] = None,
        sketch: Optional[str] = None,
        sketch_dim: Optional[int] = None,
        store_mesh_spec=None,
    ):
        """``staleness_decay`` < 1 is a beyond-paper extension: every round,
        stored representative gradients shrink by this factor, so clients
        that have not been sampled for many rounds drift back toward the
        zero-vector (cold-start) cluster instead of being clustered on
        arbitrarily stale similarity. 1.0 = the paper's behaviour.

        ``distance_fn`` selects the O(n²d) pairwise-distance backend: a
        backend name (``"auto"`` — the default device path: compiled Pallas
        on TPU, interpret-mode Pallas everywhere else, GPU included — the
        kernel's VMEM scratch is TPU-only; ``"pallas"`` — TPU only, errors
        elsewhere; ``"pallas-interpret"``; ``"streamed"`` — d-chunked
        accumulation for model-sized gradients; ``"numpy"``), a custom
        callable, or ``None`` for the numpy host reference.

        ``clusterer`` selects the grouping backend for the pool clients
        (a ``CLUSTERERS`` name — ``"ward"`` default, ``"ward_jit"``,
        ``"kmeans"`` — or a callable; see
        :mod:`repro.core.clustering.backends`). The device clusterers
        consume the distance matrix / G where the store left them, so the
        rebuild never materializes a host copy of the gradient block.

        ``planner`` selects when Algorithm 2's O(n²d + n³) rebuild runs:
        ``"sync"`` inside ``observe_updates`` (the parity reference) or
        ``"async"`` on a background worker while the next round trains.
        ``rebuild_every=k`` re-clusters only every k observed rounds — the
        gradient store still absorbs every round's updates, so the k-th
        rebuild sees all of them (``RoundRecord.plan_version`` records which
        observation each round's plan incorporates). ``drift_threshold``
        replaces the fixed cadence with the planner's measured trigger: a
        rebuild runs only when the assignment churn of the fresh gradients
        against the live plan's clusters reaches the threshold (see
        :class:`repro.fl.planner.AssignmentDriftMonitor`).

        ``sketch`` / ``sketch_dim`` attach a device-side sketch stage to the
        gradient store (a :data:`repro.kernels.sketch.SKETCHERS` name —
        ``"srp"``, ``"countsketch"``, or ``"identity"`` for the exact
        bit-for-bit legacy path): the engine's (c, d) device updates are
        compressed to (c, d') *before* scatter, so the resident store, the
        O(n²·d) similarity stage and the drift monitor's centroids all live
        in sketch space. The sketch is seeded with the sampler ``seed``, so
        a checkpointed store restores against the identical projection.
        ``store_mesh_spec`` shards the store's client axis over a device
        mesh (the PR 2 engine mesh convention)."""
        self.measure = measure
        self._distance_fn = _resolve_distance_fn(distance_fn)
        self._clusterer = clusterer
        self._clusterer_seed = int(seed)
        super().__init__(
            population,
            m,
            update_dim,
            seed=seed,
            staleness_decay=staleness_decay,
            planner=planner,
            rebuild_every=rebuild_every,
            drift_threshold=drift_threshold,
            sketch=sketch,
            sketch_dim=sketch_dim,
            store_mesh_spec=store_mesh_spec,
        )

    def _build_plan(self, G) -> SamplingPlan:
        return build_plan_algorithm2(
            self.population,
            self.m,
            G,
            measure=self.measure,
            distance_fn=self._distance_fn,
            clusterer=self._clusterer,
            clusterer_seed=self._clusterer_seed,
            # None unless an AvailabilityTracker is attached; read at build
            # time (tracker buffers are replaced, never mutated, so the
            # async worker sees a consistent mask)
            cluster_mask=self._cluster_mask(),
        )
