"""Tiny name → factory registries backing the declarative experiment API.

A :class:`Registry` is a dict with manners: registration can be guarded
against silent overwrites, lookups of unknown names raise a precise error
listing what *is* registered, and ``register`` doubles as a decorator.
The seed registries are ``repro.core.samplers.SAMPLERS`` (client-selection
schemes) and ``repro.fl.engine.ENGINES`` (round execution engines); the
spec layer (``repro.fl.experiment``) resolves every name through them, so
extending the system is ``register_sampler("mine", MySampler)`` plus a
spec dict — no call-site surgery.
"""
from __future__ import annotations

import difflib
from typing import Any, Callable, Iterator, Optional


class Registry:
    """Mapping from names to factories with precise unknown-name errors."""

    def __init__(self, kind: str, initial: Optional[dict] = None):
        self.kind = kind
        self._entries: dict[str, Any] = dict(initial or {})

    # -- registration -------------------------------------------------------
    def register(
        self, name: str, factory: Any = None, *, override: bool = False
    ) -> Callable:
        """Register ``factory`` under ``name``; decorator form when omitted.

        Re-registering an existing name is an error unless ``override=True``
        — sweeps that monkey-register variants must say so explicitly.
        """
        if factory is None:
            return lambda f: self.register(name, f, override=override)
        if name in self._entries and not override:
            raise ValueError(
                f"{self.kind} {name!r} is already registered "
                f"(pass override=True to replace it)"
            )
        self._entries[name] = factory
        return factory

    def unregister(self, name: str) -> None:
        if name not in self._entries:
            raise ValueError(self._unknown(name))
        del self._entries[name]

    # -- lookup -------------------------------------------------------------
    def get(self, name: str) -> Any:
        try:
            return self._entries[name]
        except KeyError:
            raise ValueError(self._unknown(name)) from None

    def _unknown(self, name: str) -> str:
        msg = (
            f"unknown {self.kind} {name!r}; registered {self.kind}s: "
            f"{sorted(self._entries)}"
        )
        close = difflib.get_close_matches(str(name), list(self._entries), n=1)
        if close:
            msg += f" — did you mean {close[0]!r}?"
        return msg

    def names(self) -> list[str]:
        return sorted(self._entries)

    # -- dict-ish surface (existing ``SAMPLERS["md"]`` call sites) ----------
    def __getitem__(self, name: str) -> Any:
        return self.get(name)

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __iter__(self) -> Iterator[str]:
        return iter(self._entries)

    def __len__(self) -> int:
        return len(self._entries)

    def items(self):
        return self._entries.items()

    def __repr__(self) -> str:
        return f"Registry({self.kind!r}, {sorted(self._entries)})"
