"""Urn-filling sample allocation shared by Algorithms 1 and 2.

Both algorithms reduce to the same integer partitioning problem
(Appendix C of the paper): give every client ``m * n_i`` *sample tokens*
(``m*M`` tokens total) and distribute them over ``m`` urns of capacity ``M``
each; urn ``k`` becomes distribution ``W_k`` with
``r_{k,i} = (tokens of client i in urn k) / M``.

* Algorithm 1 seeds nothing and streams clients in descending-mass order.
* Algorithm 2 seeds the ``m`` largest clusters into the urns, then streams
  the remaining clusters' clients into the free space.

Sequential filling guarantees each client occupies a *contiguous* run of
urns, hence appears in at most ``floor(m p_i) + 2`` distributions.

Functions here take an explicit per-client ``token_mass`` instead of
``n_samples`` so Algorithm 2's large-client extension (Section 5 final
remark: clients with ``p_i >= 1/m`` get dedicated probability-1 urns and
only their remainder mass joins the pool) can reuse the same machinery.
"""
from __future__ import annotations

from typing import Iterable, Sequence

import numpy as np


def fill_urns_sequential(
    token_stream: Iterable[tuple[int, int]],
    n_clients: int,
    n_urns: int,
    capacity: int,
    *,
    initial: np.ndarray | None = None,
) -> np.ndarray:
    """Pour ``(client, tokens)`` items into ``n_urns`` urns of ``capacity``.

    Urns are filled in index order; a client whose tokens do not fit in the
    current urn spills into the next one(s). Returns the integer allocation
    matrix ``r_tokens`` of shape (n_urns, n_clients).

    ``initial`` (optional) pre-seeds the urns (Algorithm 2's cluster
    seeding); filling then tops urns up to ``capacity`` in index order.
    """
    if initial is not None:
        r_tokens = np.array(initial, dtype=np.int64, copy=True)
        if r_tokens.shape != (n_urns, n_clients):
            raise ValueError(f"initial must be {(n_urns, n_clients)}, got {r_tokens.shape}")
    else:
        r_tokens = np.zeros((n_urns, n_clients), dtype=np.int64)

    fill = r_tokens.sum(axis=1)
    if (fill > capacity).any():
        k = int(np.argmax(fill > capacity))
        raise ValueError(f"urn {k} pre-seeded beyond capacity: {fill[k]} > {capacity}")

    k = 0
    for client, tokens in token_stream:
        if tokens < 0:
            raise ValueError(f"negative token count for client {client}")
        remaining = int(tokens)
        while remaining > 0:
            while k < n_urns and fill[k] >= capacity:
                k += 1
            if k >= n_urns:
                raise ValueError(
                    "ran out of urns — token stream exceeds n_urns * capacity "
                    "(Proposition 1 requires sum_i m*n_i == m*M)"
                )
            put = min(remaining, capacity - int(fill[k]))
            r_tokens[k, client] += put
            fill[k] += put
            remaining -= put
    return r_tokens


def allocate_by_size(token_mass: np.ndarray, n_urns: int, capacity: int) -> np.ndarray:
    """Algorithm 1's allocation: descending-mass sequential urn filling.

    Returns the (n_urns, n) integer token matrix; divide by ``capacity``
    (= M) for the probability matrix ``r``.
    """
    token_mass = np.asarray(token_mass, dtype=np.int64)
    if int(token_mass.sum()) != n_urns * capacity:
        raise ValueError(
            f"token mass {token_mass.sum()} != n_urns*capacity = {n_urns * capacity}"
        )
    order = np.argsort(-token_mass, kind="stable")  # descending importance
    stream = ((int(i), int(token_mass[i])) for i in order)
    return fill_urns_sequential(stream, token_mass.shape[0], n_urns, capacity)


def allocate_by_groups(
    token_mass: np.ndarray,
    n_urns: int,
    capacity: int,
    groups: Sequence[np.ndarray],
) -> np.ndarray:
    """Algorithm 2's allocation: cluster-seeded sequential urn filling.

    ``groups`` is the tree cut — K >= n_urns disjoint client-index arrays
    whose mass ``q_k = sum_{i in B_k} token_mass[i]`` must each be
    <= capacity. The n_urns largest groups seed the urns; remaining groups'
    clients stream into the free space in group order (Fig. 4 of the paper).
    """
    token_mass = np.asarray(token_mass, dtype=np.int64)
    n = token_mass.shape[0]
    if int(token_mass.sum()) != n_urns * capacity:
        raise ValueError(
            f"token mass {token_mass.sum()} != n_urns*capacity = {n_urns * capacity}"
        )
    K = len(groups)
    if K < n_urns:
        raise ValueError(f"need K >= m groups, got K={K} < m={n_urns}")

    q = np.array([int(token_mass[np.asarray(g, dtype=np.int64)].sum()) for g in groups])
    if (q > capacity).any():
        k = int(np.argmax(q > capacity))
        raise ValueError(f"group {k} carries {q[k]} tokens > M={capacity}; re-cut the tree")

    order = np.argsort(-q, kind="stable")  # decreasing q_k
    seeded, rest = order[:n_urns], order[n_urns:]

    initial = np.zeros((n_urns, n), dtype=np.int64)
    for k, g_idx in enumerate(seeded):
        for i in np.asarray(groups[g_idx], dtype=np.int64):
            initial[k, i] = int(token_mass[i])

    stream = (
        (int(i), int(token_mass[i]))
        for g_idx in rest
        for i in np.asarray(groups[g_idx], dtype=np.int64)
    )
    return fill_urns_sequential(stream, n, n_urns, capacity, initial=initial)
