"""Core datatypes for the clustered-sampling library.

Everything in ``repro.core`` is host-side (numpy) — client selection is an
O(n)–O(n^2) scalar problem the server solves between rounds; only the
similarity matrix over model-sized vectors runs on device (see
``repro.kernels.similarity``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# Integer "sample token" arithmetic (Appendix C of the paper): both Algorithm 1
# and 2 are proven in terms of integer sample counts n_i rather than ratios
# p_i, so the allocation is exact with no floating-point drift.


@dataclasses.dataclass(frozen=True)
class ClientPopulation:
    """The federated population the server samples from.

    Attributes:
      n_samples: integer sample counts ``n_i`` per client, shape (n,).
    """

    n_samples: np.ndarray

    def __post_init__(self):
        arr = np.asarray(self.n_samples, dtype=np.int64)
        if arr.ndim != 1:
            raise ValueError(f"n_samples must be 1-D, got shape {arr.shape}")
        if (arr <= 0).any():
            raise ValueError("every client must own at least one sample")
        object.__setattr__(self, "n_samples", arr)

    @property
    def n_clients(self) -> int:
        return int(self.n_samples.shape[0])

    @property
    def total_samples(self) -> int:
        """M = sum_i n_i."""
        return int(self.n_samples.sum())

    @property
    def importances(self) -> np.ndarray:
        """p_i = n_i / M (eq. 1 of the paper)."""
        return self.n_samples / self.total_samples


@dataclasses.dataclass(frozen=True)
class SamplingPlan:
    """The ``m`` per-distribution client probabilities ``r_{k,i}``.

    ``r[k, i]`` is the probability that distribution ``W_k`` draws client
    ``i`` (eq. 7/8 of the paper). MD sampling is the special case where every
    row equals ``p``.
    """

    r: np.ndarray  # (m, n) float64
    # Integer sample-token allocation r' with r = r'/M, kept when the plan was
    # built by the urn-filling allocator (exactness checks + debugging).
    r_tokens: Optional[np.ndarray] = None
    # Cluster assignment per client when the plan came from Algorithm 2.
    cluster_of: Optional[np.ndarray] = None

    def __post_init__(self):
        r = np.asarray(self.r, dtype=np.float64)
        if r.ndim != 2:
            raise ValueError(f"r must be (m, n), got {r.shape}")
        object.__setattr__(self, "r", r)

    @property
    def m(self) -> int:
        return int(self.r.shape[0])

    @property
    def n_clients(self) -> int:
        return int(self.r.shape[1])


@dataclasses.dataclass(frozen=True)
class SampleResult:
    """One realized round of client selection.

    Attributes:
      clients: the sampled client indices ``l_1..l_m`` (with multiplicity),
        shape (m,).
      agg_weights: aggregation weight ``ω_i`` for every client in the
        population, shape (n,): ``ω_i = (1/m) Σ_k 1{l_k == i}``. Unbiased
        schemes satisfy ``E[ω_i] = p_i`` (eq. 12).
      stale_weights: weight put on the *current global model* for clients that
        are not updated this round. Zero for unbiased schemes; FedAvg-style
        uniform sampling puts ``n_i/M`` of every non-sampled client here
        (eq. 3).
      draw_weights: the per-draw aggregation weight of each entry of
        ``clients``, aligned with it (``agg_weights`` is its client-indexed
        sum). Only populated by draws whose downstream consumer thins at the
        draw level (overselection schedulers); ``None`` for the ordinary
        per-round draw.
    """

    clients: np.ndarray
    agg_weights: np.ndarray
    stale_weight: float = 0.0
    draw_weights: Optional[np.ndarray] = None

    @property
    def unique_clients(self) -> np.ndarray:
        return np.unique(self.clients)
