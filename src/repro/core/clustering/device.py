"""Jitted (device-side) clustering primitives for the plan-rebuild pipeline.

The numpy Ward implementation (:mod:`repro.core.clustering.ward`) pulls the
(n, n) distance matrix to host and runs the Lance–Williams recurrence in
f64 — correct, but it puts an O(n³) host loop on every plan rebuild and
forces a device→host copy of the distance matrix. This module lowers the
same arithmetic onto the device:

* :func:`ward_linkage_device` — the exact Lance–Williams update as a jitted
  ``lax.fori_loop`` over the device distance matrix. Only the (n-1, 4)
  linkage rows come back to host (for the tree cut). Merge order is
  identical to the numpy reference whenever pairwise distances are distinct
  (both use first-minimum row-major argmin tie-breaking); heights agree to
  f32 accumulation tolerance.
* :func:`kmeans_labels` — jitted Lloyd iterations with deterministic
  host-seeded initialization; the O(n·k·d) alternative that never builds an
  (n, n) matrix at all, which is what makes n=10k rebuilds tractable.
* :func:`cluster_centroids` / :func:`nearest_centroid_labels` — the cheap
  assignment machinery the drift-triggered planner uses to decide *whether*
  a rebuild is worth scheduling (see ``repro.fl.planner``).

jax is imported lazily; every function falls back to numerically identical
numpy when jax is absent, keeping ``repro.core`` importable without it.
"""
from __future__ import annotations

import functools

import numpy as np


def _jax():
    try:
        import jax  # noqa: F401
    except ImportError:
        return None
    return jax


# --------------------------------------------------------------------------
# Ward: Lance–Williams as a jitted device loop
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=32)
def _ward_device_fn(n: int):
    jax = _jax()
    import jax.numpy as jnp

    def step(t, carry):
        d2, size, cid, active, out = carry
        masked = jnp.where(active[:, None] & active[None, :], d2, jnp.inf)
        # flat first-minimum in row-major order — numpy's argmin tie-breaking
        flat = jnp.argmin(masked)
        i0, j0 = flat // n, flat % n
        i, j = jnp.minimum(i0, j0), jnp.maximum(i0, j0)
        dij2 = masked[i, j]
        a = jnp.minimum(cid[i], cid[j]).astype(jnp.float32)
        b = jnp.maximum(cid[i], cid[j]).astype(jnp.float32)
        ni, nj = size[i], size[j]
        out = out.at[t].set(
            jnp.stack([a, b, jnp.sqrt(jnp.maximum(dij2, 0.0)), ni + nj])
        )
        # Lance–Williams Ward update: merge j into i (vector update over the
        # still-active others — the same masked arithmetic as the numpy
        # reference, so merge decisions coincide on distinct distances)
        upd = active.at[i].set(False).at[j].set(False)
        nk = size
        new = ((ni + nk) * d2[i] + (nj + nk) * d2[j] - nk * dij2) / (ni + nj + nk)
        rowi = jnp.where(upd, new, d2[i])
        d2 = d2.at[i, :].set(rowi)
        d2 = d2.at[:, i].set(rowi)
        size = size.at[i].set(ni + nj)
        active = active.at[j].set(False)
        cid = cid.at[i].set(n + t)
        return d2, size, cid, active, out

    def build(dist):
        d2 = dist.astype(jnp.float32) ** 2
        d2 = jnp.where(jnp.eye(n, dtype=bool), jnp.inf, d2)
        size = jnp.ones(n, jnp.float32)
        cid = jnp.arange(n, dtype=jnp.int32)
        active = jnp.ones(n, dtype=bool)
        out = jnp.zeros((n - 1, 4), jnp.float32)
        carry = (d2, size, cid, active, out)
        return jax.lax.fori_loop(0, n - 1, step, carry)[-1]

    return jax.jit(build)


def ward_linkage_device(dist) -> np.ndarray:
    """(n, n) distance matrix -> scipy-style (n-1, 4) linkage, on device.

    ``dist`` may be a jax device array (the fused similarity kernel's
    output) — it is consumed where it lives; only the linkage rows (a few
    KB) come back to host. Falls back to the numpy reference when jax is
    unavailable.
    """
    n = int(dist.shape[0])
    if tuple(dist.shape) != (n, n):
        raise ValueError(f"need square distance matrix, got {tuple(dist.shape)}")
    if n < 2:
        return np.zeros((0, 4))
    if _jax() is None:
        from repro.core.clustering.ward import ward_linkage

        return ward_linkage(np.asarray(dist))
    import jax.numpy as jnp

    out = _ward_device_fn(n)(jnp.asarray(dist, jnp.float32))
    return np.asarray(out, dtype=np.float64)


# --------------------------------------------------------------------------
# k-means: jitted Lloyd iterations
# --------------------------------------------------------------------------
def _normalize_rows(X, xp):
    norms = xp.sqrt((X * X).sum(axis=1))
    safe = xp.where(norms > 0, norms, 1.0)
    return X / safe[:, None]


@functools.lru_cache(maxsize=32)
def _lloyd_device_fn(n_iters: int):
    jax = _jax()
    import jax.numpy as jnp

    def assign(X, cent):
        d2 = (
            (X * X).sum(axis=1)[:, None]
            + (cent * cent).sum(axis=1)[None, :]
            - 2.0 * X @ cent.T
        )
        return jnp.argmin(d2, axis=1)

    def run(X, cent):
        k = cent.shape[0]

        def body(_, cent):
            lab = assign(X, cent)
            onehot = (lab[:, None] == jnp.arange(k)[None, :]).astype(jnp.float32)
            counts = onehot.sum(axis=0)
            sums = onehot.T @ X
            return jnp.where(
                counts[:, None] > 0, sums / jnp.maximum(counts, 1.0)[:, None], cent
            )

        cent = jax.lax.fori_loop(0, n_iters, body, cent)
        return assign(X, cent), cent

    return jax.jit(run)


def kmeans_labels(
    G,
    k: int,
    *,
    measure: str = "arccos",
    seed: int = 0,
    n_iters: int = 25,
) -> np.ndarray:
    """Deterministic Lloyd k-means over representative gradients.

    Initial centroids are ``k`` rows chosen by a host
    ``np.random.default_rng(seed)`` permutation (backend-independent), then
    ``n_iters`` jitted Lloyd iterations refine them on device (numpy
    fallback runs the identical arithmetic). For ``measure="arccos"`` rows
    are L2-normalized first (zero cold-start rows stay zero, so they share
    a cluster exactly like the paper's convention); ``l2``/``l1`` cluster
    the raw vectors. Fixed ``(G, k, measure, seed, n_iters)`` → identical
    labels on every call.
    """
    n = int(G.shape[0])
    if not 1 <= k <= n:
        raise ValueError(f"need 1 <= k <= n, got k={k} for n={n} rows")
    init_idx = np.random.default_rng(seed).permutation(n)[:k]
    jax = _jax()
    if jax is not None:
        import jax.numpy as jnp

        X = jnp.asarray(G, jnp.float32)
        if measure == "arccos":
            X = _normalize_rows(X, jnp)
        labels, _ = _lloyd_device_fn(int(n_iters))(X, X[jnp.asarray(init_idx)])
        return np.asarray(labels, dtype=np.int64)
    X = np.asarray(G, np.float32)
    if measure == "arccos":
        X = _normalize_rows(X, np)
    cent = X[init_idx]
    for _ in range(int(n_iters)):
        d2 = (X * X).sum(1)[:, None] + (cent * cent).sum(1)[None, :] - 2.0 * X @ cent.T
        lab = np.argmin(d2, axis=1)
        for c in range(k):
            members = lab == c
            if members.any():
                cent[c] = X[members].mean(axis=0)
    d2 = (X * X).sum(1)[:, None] + (cent * cent).sum(1)[None, :] - 2.0 * X @ cent.T
    return np.argmin(d2, axis=1).astype(np.int64)


# --------------------------------------------------------------------------
# assignment machinery for the drift trigger
# --------------------------------------------------------------------------
def cluster_centroids(G, labels: np.ndarray, n_clusters: int):
    """(k, d) per-cluster mean of G rows; rows with label < 0 are ignored.

    Runs on device when jax is present (one one-hot matmul — the G rows
    never round-trip to host); empty clusters get a zero centroid.
    """
    labels = np.asarray(labels)
    jax = _jax()
    if jax is not None:
        import jax.numpy as jnp

        X = jnp.asarray(G, jnp.float32)
        lab = jnp.asarray(labels)
        onehot = (
            (lab[:, None] == jnp.arange(n_clusters)[None, :]) & (lab >= 0)[:, None]
        ).astype(jnp.float32)
        counts = onehot.sum(axis=0)
        return (onehot.T @ X) / jnp.maximum(counts, 1.0)[:, None]
    X = np.asarray(G, np.float32)
    out = np.zeros((n_clusters, X.shape[1]), np.float32)
    for c in range(n_clusters):
        members = labels == c
        if members.any():
            out[c] = X[members].mean(axis=0)
    return out


def nearest_centroid_labels(G, centroids) -> np.ndarray:
    """Assign every G row to its nearest centroid (squared-L2, first-min).

    The O(n·k·d) statistic behind the drift trigger: with centroids frozen
    at the last rebuild, the fraction of rows whose nearest centroid
    changed is exactly the assignment churn of the fresh gradients against
    the live plan's clusters.
    """
    jax = _jax()
    if jax is not None:
        import jax.numpy as jnp

        X = jnp.asarray(G, jnp.float32)
        C = jnp.asarray(centroids, jnp.float32)
        d2 = (
            (X * X).sum(axis=1)[:, None]
            + (C * C).sum(axis=1)[None, :]
            - 2.0 * X @ C.T
        )
        return np.asarray(jnp.argmin(d2, axis=1), dtype=np.int64)
    X = np.asarray(G, np.float32)
    C = np.asarray(centroids, np.float32)
    d2 = (X * X).sum(1)[:, None] + (C * C).sum(1)[None, :] - 2.0 * X @ C.T
    return np.argmin(d2, axis=1).astype(np.int64)
