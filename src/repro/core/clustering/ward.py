"""Ward hierarchical agglomerative clustering (Ward, 1963).

Own implementation (Lance–Williams recurrence) producing a scipy-compatible
linkage matrix, so tests can cross-check against ``scipy.cluster.hierarchy``.
Complexity O(n³) worst case with the masked-matrix scan, but every inner
step is a vectorized numpy update (no per-k Python loop) — the planner runs
Ward on every rebuild, so at n ≈ 10³ this is the difference between
milliseconds and seconds; the O(n²d) part (the distance matrix itself) is
what the Pallas kernel accelerates.
"""
from __future__ import annotations

import numpy as np


def ward_linkage(dist: np.ndarray) -> np.ndarray:
    """(n, n) distance matrix -> (n-1, 4) linkage [id_a, id_b, dist, size].

    Follows scipy convention: original points are clusters 0..n-1; the merge
    at row t creates cluster n+t. Ward's minimum-variance criterion via the
    Lance–Williams update on squared distances.
    """
    dist = np.asarray(dist, dtype=np.float64)
    n = dist.shape[0]
    if dist.shape != (n, n):
        raise ValueError(f"need square distance matrix, got {dist.shape}")
    if n < 2:
        return np.zeros((0, 4))

    d2 = dist.astype(np.float64) ** 2  # work on squared distances
    size = np.ones(n, dtype=np.int64)
    cluster_id = np.arange(n)  # current scipy id of each active slot
    active = np.ones(n, dtype=bool)
    np.fill_diagonal(d2, np.inf)

    out = np.zeros((n - 1, 4))
    for t in range(n - 1):
        # find the closest active pair
        masked = np.where(active[:, None] & active[None, :], d2, np.inf)
        flat = int(np.argmin(masked))
        i, j = divmod(flat, n)
        if i > j:
            i, j = j, i
        dij2 = masked[i, j]
        a, b = cluster_id[i], cluster_id[j]
        if a > b:
            a, b = b, a
        out[t] = (a, b, np.sqrt(max(dij2, 0.0)), size[i] + size[j])

        # Lance–Williams Ward update: merge j into i (masked vector update —
        # same arithmetic as the per-k scalar recurrence, so bit-identical)
        ni, nj = size[i], size[j]
        upd = active.copy()
        upd[i] = upd[j] = False
        nk = size[upd]
        new = ((ni + nk) * d2[i, upd] + (nj + nk) * d2[j, upd] - nk * dij2) / (
            ni + nj + nk
        )
        d2[i, upd] = new
        d2[upd, i] = new
        size[i] = ni + nj
        active[j] = False
        cluster_id[i] = n + t
    return out


def linkage_children(linkage: np.ndarray, n: int) -> dict[int, tuple[int, int]]:
    """Map merged-cluster id -> (child_a, child_b)."""
    return {n + t: (int(linkage[t, 0]), int(linkage[t, 1])) for t in range(linkage.shape[0])}


def leaves_of(cluster: int, children: dict[int, tuple[int, int]]) -> list[int]:
    """Collect original leaf indices under a dendrogram node."""
    stack, leaves = [cluster], []
    while stack:
        c = stack.pop()
        if c in children:
            stack.extend(children[c])
        else:
            leaves.append(c)
    return leaves
