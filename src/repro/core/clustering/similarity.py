"""Client-similarity measures over representative gradients (Section 5).

The representative gradient of client i is ``G_i = θ_i^{t+1} - θ^t`` — the
difference between its locally-updated model and the global model it started
from (Sattler et al., 2019). The paper evaluates three measures: Arccos
(angle), L2 and L1, and finds them equivalent in practice (Appendix D.2).

The O(n²d) pairwise computation is the one device-side hot-spot of
Algorithm 2 — ``repro.kernels.similarity`` provides the Pallas TPU kernel;
this module provides the numpy fallback and the measure definitions shared
with the kernel's oracle.
"""
from __future__ import annotations

import numpy as np

MEASURES = ("arccos", "l2", "l1")


def pairwise_distances(G: np.ndarray, measure: str = "arccos") -> np.ndarray:
    """(n, d) stacked representative gradients -> (n, n) distance matrix.

    * ``arccos``: angle between vectors, in [0, π]. Zero vectors (clients
      never sampled yet — the paper assigns them a constant 0 representative
      gradient so they cluster together) are mutually at distance 0 and at
      π/2 from everything else.
    * ``l2`` / ``l1``: Minkowski distances.
    """
    G = np.asarray(G, dtype=np.float64)
    n = G.shape[0]
    if measure == "arccos":
        norms = np.linalg.norm(G, axis=1)
        safe = np.where(norms > 0, norms, 1.0)
        cos = (G @ G.T) / np.outer(safe, safe)
        zero = norms == 0
        # zero-vs-zero -> cos 1 (distance 0); zero-vs-nonzero -> cos 0 (π/2)
        cos[zero[:, None] & zero[None, :]] = 1.0
        cos[zero[:, None] ^ zero[None, :]] = 0.0
        cos = np.clip(cos, -1.0, 1.0)
        dist = np.arccos(cos)
    elif measure == "l2":
        sq = (G**2).sum(axis=1)
        dist = np.sqrt(np.maximum(sq[:, None] + sq[None, :] - 2.0 * (G @ G.T), 0.0))
    elif measure == "l1":
        dist = np.abs(G[:, None, :] - G[None, :, :]).sum(axis=-1)
    else:
        raise ValueError(f"unknown measure {measure!r}; choose from {MEASURES}")
    np.fill_diagonal(dist, 0.0)
    return np.maximum(dist, dist.T)  # enforce exact symmetry
