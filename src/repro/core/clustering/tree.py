"""Dendrogram cut producing Algorithm-2-feasible groups.

Algorithm 2 (line 3) needs ``K >= m`` groups whose token mass
``q_k = sum_{i in B_k} m * n_i`` is at most ``M`` each. We cut the Ward tree
top-down: starting from the root, repeatedly split the *worst* cluster —
any cluster over the mass cap, else (until K >= m) the one whose split is
cheapest in linkage distance. Splitting along dendrogram edges keeps
similar clients together, which is the whole point of the similarity-based
scheme.

Feasibility: with every ``m * n_i <= M`` (``p_i <= 1/m``, Theorem 4's
hypothesis) singleton clusters always satisfy the cap, so the loop
terminates.
"""
from __future__ import annotations

import numpy as np

from repro.core.clustering.ward import leaves_of, linkage_children


def cut_tree(
    linkage: np.ndarray,
    n: int,
    m: int,
    token_mass: np.ndarray,
    capacity: int,
) -> list[np.ndarray]:
    """Cut a linkage into K >= m groups with per-group mass <= capacity.

    Args:
      linkage: (n-1, 4) scipy-style linkage.
      n: number of leaves (clients).
      m: number of sampling distributions.
      token_mass: per-client mass ``m * n_i`` (shape (n,)).
      capacity: M, the per-urn capacity.

    Returns a list of disjoint client-index arrays covering 0..n-1.
    """
    token_mass = np.asarray(token_mass, dtype=np.int64)
    if (token_mass > capacity).any():
        i = int(np.argmax(token_mass > capacity))
        raise ValueError(
            f"client {i} has mass {token_mass[i]} > M={capacity}; allocate its "
            "dedicated distributions first (Section 5 final remark)"
        )
    children = linkage_children(linkage, n)
    # merge height of every internal node, for cheapest-split ordering
    height = {n + t: float(linkage[t, 2]) for t in range(linkage.shape[0])}

    root = n + linkage.shape[0] - 1 if linkage.shape[0] else 0
    clusters: list[int] = [root]

    def mass(c: int) -> int:
        return int(token_mass[leaves_of(c, children)].sum())

    while True:
        over = [c for c in clusters if c in children and mass(c) > capacity]
        if over:
            c = over[0]
        elif len(clusters) < m:
            splittable = [c for c in clusters if c in children]
            if not splittable:
                raise ValueError(f"cannot reach K >= m={m} groups with n={n} clients")
            # split the node merged last/highest -> least-similar grouping
            c = max(splittable, key=lambda c: height[c])
        else:
            break
        clusters.remove(c)
        clusters.extend(children[c])

    # any cluster left over the cap must be a leaf — impossible per guard above
    groups = [np.array(sorted(leaves_of(c, children)), dtype=np.int64) for c in clusters]
    assert sum(len(g) for g in groups) == n
    return groups
