from repro.core.clustering.similarity import MEASURES, pairwise_distances
from repro.core.clustering.ward import ward_linkage, linkage_children, leaves_of
from repro.core.clustering.tree import cut_tree
from repro.core.clustering.device import (
    cluster_centroids,
    kmeans_labels,
    nearest_centroid_labels,
    ward_linkage_device,
)
from repro.core.clustering.backends import (
    CLUSTERERS,
    kmeans_clusters,
    register_clusterer,
    resolve_clusterer,
    ward_clusters,
    ward_jit_clusters,
)

__all__ = [
    "MEASURES",
    "pairwise_distances",
    "ward_linkage",
    "linkage_children",
    "leaves_of",
    "cut_tree",
    "ward_linkage_device",
    "kmeans_labels",
    "cluster_centroids",
    "nearest_centroid_labels",
    "CLUSTERERS",
    "register_clusterer",
    "resolve_clusterer",
    "ward_clusters",
    "ward_jit_clusters",
    "kmeans_clusters",
]
