from repro.core.clustering.similarity import MEASURES, pairwise_distances
from repro.core.clustering.ward import ward_linkage, linkage_children, leaves_of
from repro.core.clustering.tree import cut_tree

__all__ = [
    "MEASURES",
    "pairwise_distances",
    "ward_linkage",
    "linkage_children",
    "leaves_of",
    "cut_tree",
]
