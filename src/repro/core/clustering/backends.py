"""Clustering backends behind the ``CLUSTERERS`` registry.

Algorithm 2's plan rebuild needs *some* partition of the pool clients into
K >= m groups of token mass <= M — Ward on pairwise similarity is the
paper's choice, not the only valid one (FedSTaS stratifies with k-means
over compressed gradients). This module gives every such partitioner one
uniform signature and a name:

    clusterer(G, token_mass, m, capacity, *,
              measure="arccos", distance_fn=None, seed=0) -> list[ndarray]

where ``G`` is the (n_pool, d) representative-gradient block (possibly a
device array — backends that can, keep it there), ``token_mass[i] = m·n_i``
and ``capacity = M`` are Algorithm 2's feasibility constraints, and the
return is a list of disjoint local-index arrays covering ``0..n_pool-1``.

Built-ins:

* ``"ward"``     — the numpy Lance–Williams reference + dendrogram cut;
  the default, bit-identical to the pre-registry pipeline.
* ``"ward_jit"`` — same recurrence lowered as a jitted device loop
  (:func:`repro.core.clustering.device.ward_linkage_device`); the distance
  matrix is consumed where the distance backend left it.
* ``"kmeans"``   — jitted Lloyd over G directly (no (n, n) matrix at all;
  O(n·k·d) — the backend that makes n≈10⁴ rebuilds tractable), followed by
  a host capacity repair that splits over-cap / too-few groups.

``register_clusterer("mine", fn)`` plugs a new partitioner into every
spec-driven experiment via ``PlannerSpec(clusterer="mine")``.
"""
from __future__ import annotations

import numpy as np

from repro.core.clustering.device import kmeans_labels, ward_linkage_device
from repro.core.clustering.similarity import pairwise_distances
from repro.core.clustering.tree import cut_tree
from repro.core.clustering.ward import ward_linkage
from repro.core.registry import Registry


def ward_clusters(
    G,
    token_mass: np.ndarray,
    m: int,
    capacity: int,
    *,
    measure: str = "arccos",
    distance_fn=None,
    seed: int = 0,
):
    """Numpy Ward + dendrogram cut — the paper-faithful reference path."""
    del seed  # deterministic
    dfn = distance_fn or pairwise_distances
    dist = np.asarray(dfn(G, measure))
    link = ward_linkage(dist)
    return cut_tree(link, int(G.shape[0]), m, token_mass, capacity)


def ward_jit_clusters(
    G,
    token_mass: np.ndarray,
    m: int,
    capacity: int,
    *,
    measure: str = "arccos",
    distance_fn=None,
    seed: int = 0,
):
    """Jitted Lance–Williams over the device distance matrix.

    The distance matrix never visits host — only the (n-1, 4) linkage rows
    do, for the (tiny) dendrogram cut. Merge order matches ``"ward"``
    exactly on distinct distances; heights agree to f32 tolerance.
    """
    del seed  # deterministic
    dfn = distance_fn or pairwise_distances
    link = ward_linkage_device(dfn(G, measure))
    return cut_tree(link, int(G.shape[0]), m, token_mass, capacity)


def _capacity_groups(
    labels: np.ndarray, token_mass: np.ndarray, m: int, capacity: int
) -> list[np.ndarray]:
    """Repair raw cluster labels into Algorithm-2-feasible groups.

    Over-cap clusters are split first-fit in client-index order (each piece
    <= capacity); then the largest groups split in half until K >= m. Same
    feasibility contract as :func:`repro.core.clustering.tree.cut_tree`:
    every singleton fits (mass <= capacity) or we raise.
    """
    token_mass = np.asarray(token_mass, dtype=np.int64)
    if (token_mass > capacity).any():
        i = int(np.argmax(token_mass > capacity))
        raise ValueError(
            f"client {i} has mass {token_mass[i]} > M={capacity}; allocate its "
            "dedicated distributions first (Section 5 final remark)"
        )
    groups: list[np.ndarray] = []
    for c in np.unique(labels):
        members = np.flatnonzero(labels == c)
        run: list[int] = []
        run_mass = 0
        for i in members:
            if run and run_mass + int(token_mass[i]) > capacity:
                groups.append(np.asarray(run, dtype=np.int64))
                run, run_mass = [], 0
            run.append(int(i))
            run_mass += int(token_mass[i])
        if run:
            groups.append(np.asarray(run, dtype=np.int64))
    n = int(labels.shape[0])
    while len(groups) < m:
        gi = max(range(len(groups)), key=lambda g: len(groups[g]))
        g = groups[gi]
        if len(g) < 2:
            raise ValueError(f"cannot reach K >= m={m} groups with n={n} clients")
        half = len(g) // 2
        groups[gi] = g[:half]
        groups.append(g[half:])
    return groups


def kmeans_clusters(
    G,
    token_mass: np.ndarray,
    m: int,
    capacity: int,
    *,
    measure: str = "arccos",
    distance_fn=None,
    seed: int = 0,
):
    """Jitted Lloyd k-means + capacity repair — the O(n·k·d) backend.

    Never forms an (n, n) matrix (``distance_fn`` is ignored), so it is the
    rebuild path that stays off the profile at n ≈ 10⁴ clients. ``seed``
    fixes the centroid initialization; the whole partition is deterministic
    in (G, m, measure, seed).
    """
    del distance_fn  # clusters G directly
    n = int(G.shape[0])
    labels = kmeans_labels(G, min(m, n), measure=measure, seed=seed)
    return _capacity_groups(labels, token_mass, m, capacity)


#: name -> clusterer; ``"ward"`` is the default everywhere a
#: ``PlannerSpec.clusterer`` is not given.
CLUSTERERS = Registry(
    "clusterer",
    {
        "ward": ward_clusters,
        "ward_jit": ward_jit_clusters,
        "kmeans": kmeans_clusters,
    },
)

register_clusterer = CLUSTERERS.register


def resolve_clusterer(clusterer):
    """Name or callable -> callable (names resolve through the registry)."""
    if callable(clusterer):
        return clusterer
    return CLUSTERERS.get(clusterer)
