"""Campaign orchestration: one declarative sweep → a figure-ready CSV.

Every figure in the paper is a *campaign*, not a run — Figures 1–2 and the
Appendix-D ablations are mean±std curves over many seeds and heterogeneity
settings. :class:`SweepSpec` makes that one JSON value on top of
:class:`~repro.fl.experiment.ExperimentSpec`::

    {
      "base":  {"data": {...}, "sampler": {"name": "md", "m": 10},
                "train": {"n_rounds": 20}},
      "axes":  {"sampler.name": ["md", "algorithm2"],
                "data.options.alpha": [0.001, 0.01, 10.0]},
      "n_seeds": 5,
      "root_seed": 0
    }

``axes`` maps dotted paths into the base spec's dict form to lists of
values (a path may also name a whole section, e.g. ``"sampler"`` with a
list of sampler dicts); the grid is their cartesian product in declaration
order, replicated ``n_seeds`` times (seed axis innermost). Per-replicate
seeds derive deterministically from
``np.random.SeedSequence(root_seed).spawn(n_seeds)``: replicate ``r``
spawns one (data, sampler, train) seed triple that is *shared by every
grid cell* of that replicate, so scheme comparisons are paired (common
random numbers — every sampler sees the same partition and batch stream
per replicate) while replicates get independent streams (no seed
monoculture). An axis that explicitly sweeps a seed path wins over the
derivation.

Cell identity is a stable content hash of the fully resolved
:class:`ExperimentSpec` dict — reordering axes, renaming the store, or
resuming cannot change what a cell *is*. Execution goes through a
:class:`RunStore` (one directory: ``manifest.json`` + one JSONL of
:class:`~repro.fl.history.RoundRecord` lines per cell + an atomically
written summary marker): completed cells are skipped on re-invoke, so a
killed sweep resumes where it left off and the collated output is
bit-identical to an uninterrupted run. Independent cells optionally fan
out over a process pool (``run_sweep(..., workers=k)``), and
:func:`collate` aggregates the per-cell summaries into tidy CSVs — one
row per cell plus mean±std over the seed axis, the exact table behind the
paper's figures.
"""
from __future__ import annotations

import copy
import dataclasses
import hashlib
import json
import os
import time
import warnings
from pathlib import Path
from typing import Callable, Optional, Union

import numpy as np

from repro.fl.experiment import ExperimentSpec, load_spec_dict
from repro.fl.history import History, RoundRecord

#: dotted paths that receive the SeedSequence-derived per-replicate seeds
#: (in this order); an axis sweeping one of these paths overrides it.
SEED_PATHS: tuple[str, ...] = ("data.options.seed", "sampler.seed", "train.seed")


# --------------------------------------------------------------------------
# dotted-path overrides
# --------------------------------------------------------------------------
def set_by_path(d: dict, path: str, value) -> None:
    """Set ``d[a][b][c] = value`` for ``path == "a.b.c"``, creating dicts."""
    keys = path.split(".")
    for k in keys[:-1]:
        nxt = d.setdefault(k, {})
        if not isinstance(nxt, dict):
            raise ValueError(
                f"override path {path!r}: {k!r} is a {type(nxt).__name__}, "
                "not a dict — cannot descend into it"
            )
        d = nxt
    d[keys[-1]] = value


def _get_by_path(d: dict, path: str):
    """``d[a][b][c]`` for ``path == "a.b.c"``; None when any level is absent."""
    for k in path.split("."):
        if not isinstance(d, dict) or k not in d:
            return None
        d = d[k]
    return d


def override_label(path: str, value) -> str:
    """Human-readable value label for CSV columns / emit rows.

    Scalars stringify; a dict override (a whole spec section) is labelled
    by its ``name`` when it has one, else by compact sorted JSON.
    """
    if isinstance(value, dict):
        return str(value["name"]) if "name" in value else json.dumps(value, sort_keys=True)
    return str(value)


def cell_group_label(overrides: dict) -> str:
    """``alpha=0.01/name=md`` style label for one grid point's overrides."""
    return "/".join(
        f"{path.split('.')[-1]}={override_label(path, v)}" for path, v in overrides.items()
    )


# --------------------------------------------------------------------------
# SweepSpec
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SweepCell:
    """One fully resolved point of the campaign grid."""

    cell_id: str  # stable content hash of the resolved spec
    grid_index: int  # which grid point (axes product, declaration order)
    seed_index: int  # which replicate
    overrides: dict  # dotted path -> value, this grid point's axis choices
    spec: ExperimentSpec  # the resolved experiment (seeds already injected)


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A whole campaign as one declarative, JSON-round-trippable value."""

    base: ExperimentSpec
    axes: dict = dataclasses.field(default_factory=dict)
    n_seeds: int = 1
    root_seed: int = 0

    def __post_init__(self):
        if self.n_seeds < 1:
            raise ValueError(f"n_seeds must be >= 1, got {self.n_seeds}")
        for path, values in self.axes.items():
            if not isinstance(path, str) or not path:
                raise ValueError(f"axis path {path!r} must be a non-empty string")
            if not isinstance(values, (list, tuple)) or len(values) == 0:
                raise ValueError(
                    f"axis {path!r} must map to a non-empty list of values, "
                    f"got {values!r}"
                )

    @classmethod
    def from_dict(cls, d: dict) -> "SweepSpec":
        if not isinstance(d, dict):
            raise ValueError(f"SweepSpec.from_dict expects a dict, got {type(d).__name__}")
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - fields
        if unknown:
            raise ValueError(
                f"SweepSpec.from_dict: unknown key(s) {sorted(unknown)}; "
                f"accepted keys: {sorted(fields)}"
            )
        if "base" not in d:
            raise ValueError("SweepSpec.from_dict: missing required key(s) ['base']")
        kw = dict(d)
        if not isinstance(kw["base"], ExperimentSpec):
            kw["base"] = ExperimentSpec.from_dict(kw["base"])
        return cls(**kw)

    def to_dict(self) -> dict:
        return {
            "base": self.base.to_dict(),
            "axes": {k: list(v) for k, v in self.axes.items()},
            "n_seeds": self.n_seeds,
            "root_seed": self.root_seed,
        }

    @classmethod
    def from_json(cls, s: str) -> "SweepSpec":
        return cls.from_dict(json.loads(s))

    @classmethod
    def from_arg(cls, arg: str) -> "SweepSpec":
        """Parse a CLI ``--sweep`` argument: inline JSON or a JSON file path."""
        return cls.from_dict(load_spec_dict(arg))

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    # -- expansion ----------------------------------------------------------
    def _grid(self) -> list[dict]:
        """Cartesian product of axes in declaration order (stable)."""
        points = [{}]
        for path, values in self.axes.items():
            points = [{**pt, path: v} for pt in points for v in values]
        return points

    def replicate_seeds(self) -> list[dict]:
        """The per-replicate ``{seed path: seed}`` triples, one per seed index.

        Deterministic in ``root_seed`` and ``n_seeds`` only — independent of
        the axes, so every grid cell of replicate ``r`` shares the same
        (data, sampler, train) seeds: paired comparisons across schemes.
        """
        children = np.random.SeedSequence(self.root_seed).spawn(self.n_seeds)
        return [
            dict(zip(SEED_PATHS, (int(s) for s in child.generate_state(len(SEED_PATHS)))))
            for child in children
        ]

    def cells(self) -> list[SweepCell]:
        """Expand the campaign: grid outer, seed axis innermost.

        The expansion is deterministic (axes declaration order × seed
        index) and each cell's identity is the content hash of its fully
        resolved spec dict — duplicate resolved specs are an error, not a
        silent collision in the store.
        """
        seeds = self.replicate_seeds()
        cells: list[SweepCell] = []
        seen: dict[str, tuple[int, int]] = {}
        clobbered: set[str] = set()
        for gi, overrides in enumerate(self._grid()):
            for si, seed_triple in enumerate(seeds):
                d = self.base.to_dict()
                # overrides land first (deep-copied: axis values are shared
                # across cells), then the derived seeds — so a "sampler"
                # axis of whole section dicts still gets per-replicate
                # seeds. Only an axis sweeping the exact seed path wins
                # over the derivation.
                for path, value in overrides.items():
                    set_by_path(d, path, copy.deepcopy(value))
                for path, seed in seed_triple.items():
                    if path not in self.axes:
                        pinned = _get_by_path(d, path)
                        if pinned not in (None, 0):
                            clobbered.add(path)
                        set_by_path(d, path, seed)
                spec = ExperimentSpec.from_dict(d)
                cid = cell_hash(spec)
                if cid in seen:
                    raise ValueError(
                        f"cells (grid {seen[cid]}) and (grid ({gi}, {si})) resolve "
                        f"to the identical spec (hash {cid}); axes "
                        f"{sorted(self.axes)} do not distinguish them"
                    )
                seen[cid] = (gi, si)
                cells.append(
                    SweepCell(
                        cell_id=cid,
                        grid_index=gi,
                        seed_index=si,
                        overrides=overrides,
                        spec=spec,
                    )
                )
        if clobbered:
            warnings.warn(
                f"seed(s) pinned at {sorted(clobbered)} are overwritten by the "
                "sweep's SeedSequence derivation; to pin a seed across "
                "replicates, sweep that exact path as a single-value axis",
                stacklevel=2,
            )
        return cells


def cell_hash(spec: Union[ExperimentSpec, dict]) -> str:
    """Stable content hash of a fully resolved spec (the cell's identity)."""
    d = spec.to_dict() if isinstance(spec, ExperimentSpec) else spec
    blob = json.dumps(d, sort_keys=True, separators=(",", ":")).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


# --------------------------------------------------------------------------
# summaries (the figure-level statistics of one run)
# --------------------------------------------------------------------------
#: summary fields aggregated (mean±std over the seed axis) by collate()
SUMMARY_STATS: tuple[str, ...] = (
    "final_loss",
    "first_loss",
    "final_acc",
    "mean_distinct_classes",
    "mean_distinct_clients",
    "rounds_to_acc",
    "agg_weight_var",
    "degraded_frac",
    "avail_time_to_acc",
)

#: test-accuracy threshold ``rounds_to_acc`` races schemes toward.
ACC_TARGET = 0.75


def rounds_to_accuracy(hist: History, rounds: int, target: float = ACC_TARGET) -> float:
    """First round count (1-based) at which test accuracy reaches ``target``.

    Censored runs (never reaching ``target``, or with no evaluated rounds)
    report ``rounds`` — a pessimistic, finite value, so mean±std over seeds
    stays well-defined for the time-to-accuracy race column.
    """
    acc = np.nan_to_num(hist.series("test_acc"), nan=-np.inf)
    hits = np.flatnonzero(acc >= target)
    return float(hits[0] + 1) if hits.size else float(rounds)


def agg_weight_variance(hist: History) -> float:
    """Σ_i Var_t(ω_i): total across-round variance of aggregation weights.

    The paper's quality axis for client selection — clustered/stratified
    schemes exist to shrink it at fixed E[ω_i] = p_i. NaN when the history
    carries no ``agg_weights`` telemetry or fewer than two rounds of it.
    """
    ws = [r.agg_weights for r in hist.records if r.agg_weights is not None]
    if len(ws) < 2:
        return float("nan")
    W = np.asarray(ws, dtype=np.float64)
    return float(W.var(axis=0, ddof=0).sum())


def degraded_fraction(hist: History) -> float:
    """Fraction of rounds that did not close cleanly (status != "ok").

    Counts both "degraded" rounds (mid-round drops and/or deadline
    stragglers among the realized participants — see
    ``RoundRecord.round_status``) and "empty" skipped rounds. The service-
    quality axis the round-scheduler sweep trades against time-to-accuracy.
    """
    status = [r.round_status for r in hist.records]
    if not status:
        return float("nan")
    return float(np.mean([s != "ok" for s in status]))


def availability_weighted_time_to_acc(
    hist: History, rounds: int, target: float = ACC_TARGET
) -> float:
    """Availability-weighted rounds-to-accuracy: Σ_{t<T_hit} a_t / n.

    Each round before the accuracy hit costs its *available fraction* of
    the fleet (``n_available / n_clients``; 1.0 for fixed-population rounds
    with ``n_available == -1``), so a scheme that reaches the target while
    most of the fleet is offline scores better than the plain round count
    suggests — it extracted its progress from fewer client-opportunities.
    Equals :func:`rounds_to_accuracy` exactly on a fixed population;
    censored runs integrate over all ``rounds`` like the unweighted race.
    """
    n_ref = max((r.n_distinct_clients for r in hist.records), default=0)
    n_avail = hist.series("n_available").astype(np.float64)
    # the fleet size: any round's n_available upper-bounds realized distinct
    # clients; with no population process every entry is -1 → weight 1.0
    n_fleet = float(max(n_avail.max(), n_ref, 1))
    w = np.where(n_avail < 0, 1.0, n_avail / n_fleet)
    t_hit = rounds_to_accuracy(hist, rounds, target)
    return float(w[: int(min(t_hit, len(w)))].sum())


def summarize_history(hist: History, rounds: int) -> dict:
    """The figure-level summary statistics of one run's History."""
    losses = hist.series("train_loss")
    roll = hist.rolling("train_loss", window=min(10, rounds))
    return {
        "final_loss": float(roll[-1]),
        "first_loss": float(losses[0]),
        "final_acc": float(np.nanmax(hist.series("test_acc")[-3:])),
        "mean_distinct_classes": float(hist.series("n_distinct_classes").mean()),
        "mean_distinct_clients": float(hist.series("n_distinct_clients").mean()),
        "rounds_to_acc": rounds_to_accuracy(hist, rounds),
        "agg_weight_var": agg_weight_variance(hist),
        "degraded_frac": degraded_fraction(hist),
        "avail_time_to_acc": availability_weighted_time_to_acc(hist, rounds),
    }


# --------------------------------------------------------------------------
# RunStore
# --------------------------------------------------------------------------
class RunStore:
    """One sweep's on-disk state: manifest + per-cell records + summaries.

    Layout::

        <root>/manifest.json            the SweepSpec (verified on reuse)
        <root>/cells/<id>.jsonl         one RoundRecord per line, streamed
        <root>/cells/<id>.summary.json  atomic completion marker + summary
        <root>/cells.csv                collated per-cell rows
        <root>/summary.csv              mean±std over the seed axis

    A cell is *complete* iff its summary marker exists (written via
    tmp + ``os.replace``, so a kill mid-cell leaves only a partial JSONL
    that the rerun truncates). Reusing a store for a different sweep is an
    error, not silent cross-contamination.
    """

    MANIFEST = "manifest.json"

    def __init__(self, root: Union[str, Path]):
        self.root = Path(root)
        (self.root / "cells").mkdir(parents=True, exist_ok=True)

    # -- manifest -----------------------------------------------------------
    def manifest_path(self) -> Path:
        return self.root / self.MANIFEST

    def write_manifest(self, sweep: SweepSpec) -> None:
        # JSON-normalize (tuples → lists) so the resume comparison sees
        # exactly what a round-tripped manifest contains
        d = json.loads(json.dumps(sweep.to_dict()))
        path = self.manifest_path()
        if path.exists():
            existing = json.loads(path.read_text())
            if existing != d:
                raise ValueError(
                    f"store at {self.root} was created for a different sweep "
                    "(manifest mismatch); use a fresh directory per campaign"
                )
            return
        # no sort_keys: axes declaration order IS the grid order, and the
        # manifest round-trip must preserve it cell-for-cell
        self._atomic_write(path, json.dumps(d, indent=2))

    def read_manifest(self) -> SweepSpec:
        path = self.manifest_path()
        if not path.exists():
            raise ValueError(f"store at {self.root} has no manifest — run a sweep into it first")
        return SweepSpec.from_dict(json.loads(path.read_text()))

    # -- per-cell files -----------------------------------------------------
    def records_path(self, cell_id: str) -> Path:
        return self.root / "cells" / f"{cell_id}.jsonl"

    def summary_path(self, cell_id: str) -> Path:
        return self.root / "cells" / f"{cell_id}.summary.json"

    def is_complete(self, cell_id: str) -> bool:
        return self.summary_path(cell_id).exists()

    def append_record(self, fh, rec: RoundRecord) -> None:
        fh.write(json.dumps(rec.to_dict()) + "\n")

    def finalize_cell(self, cell_id: str, summary: dict) -> None:
        """Atomically mark a cell complete with its summary statistics."""
        self._atomic_write(
            self.summary_path(cell_id), json.dumps(summary, sort_keys=True)
        )

    def read_summary(self, cell_id: str) -> dict:
        return json.loads(self.summary_path(cell_id).read_text())

    def read_history(self, cell_id: str) -> History:
        hist = History()
        with open(self.records_path(cell_id)) as fh:
            for line in fh:
                if line.strip():
                    hist.append(RoundRecord.from_dict(json.loads(line)))
        return hist

    def completed(self, cells: list[SweepCell]) -> list[SweepCell]:
        return [c for c in cells if self.is_complete(c.cell_id)]

    def _atomic_write(self, path: Path, text: str) -> None:
        tmp = path.with_suffix(path.suffix + ".tmp")
        tmp.write_text(text)
        os.replace(tmp, path)


# --------------------------------------------------------------------------
# execution
# --------------------------------------------------------------------------
# Datasets rebuilt per cell would dominate tiny-cell sweeps; identical data
# sections (same partitioner, options and derived seed) share one build.
# Bounded so a long alpha × seed campaign cannot hoard partitions.
_DATASET_CACHE: dict[str, object] = {}
_DATASET_CACHE_CAP = 4


def _cell_dataset(spec: ExperimentSpec):
    from repro.fl.experiment import build_dataset

    key = json.dumps(spec.data.to_dict(), sort_keys=True)
    if key not in _DATASET_CACHE:
        if len(_DATASET_CACHE) >= _DATASET_CACHE_CAP:
            _DATASET_CACHE.pop(next(iter(_DATASET_CACHE)))
        _DATASET_CACHE[key] = build_dataset(spec.data)
    return _DATASET_CACHE[key]


def run_cell(store: RunStore, cell: SweepCell) -> dict:
    """Run one cell to completion: stream records to JSONL, then finalize.

    Opens the records file in truncate mode so a rerun after a mid-cell
    kill never leaves stale lines behind; the summary marker lands last
    (atomically), so completeness implies a full, consistent record file.
    """
    from repro.fl.experiment import build_experiment

    ds = _cell_dataset(cell.spec)
    with open(store.records_path(cell.cell_id), "w") as fh:
        with build_experiment(cell.spec, dataset=ds) as srv:
            hist = srv.run(on_round=lambda rec: store.append_record(fh, rec))
    summary = summarize_history(hist, cell.spec.train.n_rounds)
    store.finalize_cell(cell.cell_id, summary)
    return summary


def _pool_run_cell(store_root: str, spec_dict: dict, cell_id: str) -> tuple[str, dict, float]:
    """Process-pool entry point (must be top-level picklable)."""
    store = RunStore(store_root)
    cell = SweepCell(
        cell_id=cell_id, grid_index=-1, seed_index=-1, overrides={},
        spec=ExperimentSpec.from_dict(spec_dict),
    )
    t0 = time.perf_counter()
    summary = run_cell(store, cell)
    return cell_id, summary, time.perf_counter() - t0


def run_sweep(
    sweep: Union[SweepSpec, dict],
    store_dir: Union[str, Path],
    *,
    workers: int = 1,
    on_cell: Optional[Callable[[SweepCell, str, Optional[dict], float], None]] = None,
) -> RunStore:
    """Run (or resume) a whole campaign into ``store_dir``.

    Completed cells are skipped, so re-invoking after a kill finishes only
    the remainder and the store's collated output is bit-identical to an
    uninterrupted run. ``workers > 1`` fans independent cells out over a
    spawn-based process pool (each worker writes its own cell files; the
    parent finalization order doesn't matter because cell files are
    disjoint). ``on_cell(cell, status, summary, seconds)`` streams progress
    with ``status`` in ``{"ran", "skipped"}``.
    """
    sweep = SweepSpec.from_dict(sweep) if isinstance(sweep, dict) else sweep
    store = RunStore(store_dir)
    store.write_manifest(sweep)
    cells = sweep.cells()
    todo = []
    for cell in cells:
        if store.is_complete(cell.cell_id):
            if on_cell is not None:
                on_cell(cell, "skipped", store.read_summary(cell.cell_id), 0.0)
        else:
            todo.append(cell)
    if not todo:
        return store
    if workers <= 1:
        for cell in todo:
            t0 = time.perf_counter()
            summary = run_cell(store, cell)
            if on_cell is not None:
                on_cell(cell, "ran", summary, time.perf_counter() - t0)
        return store

    import concurrent.futures as cf
    import multiprocessing as mp

    by_id = {c.cell_id: c for c in todo}
    # spawn (not fork): the parent may hold jax state + planner threads.
    # Children import repro by module path, so the source tree must be on
    # their PYTHONPATH even when the parent only added it to sys.path.
    src_root = str(Path(__file__).resolve().parents[2])
    old_pp = os.environ.get("PYTHONPATH")
    if src_root not in (old_pp or "").split(os.pathsep):
        os.environ["PYTHONPATH"] = src_root + (os.pathsep + old_pp if old_pp else "")
    try:
        with cf.ProcessPoolExecutor(
            max_workers=workers, mp_context=mp.get_context("spawn")
        ) as pool:
            futs = [
                pool.submit(_pool_run_cell, str(store.root), c.spec.to_dict(), c.cell_id)
                for c in todo
            ]
            for fut in cf.as_completed(futs):
                cell_id, summary, dt = fut.result()
                if on_cell is not None:
                    on_cell(by_id[cell_id], "ran", summary, dt)
    finally:
        if old_pp is None:
            os.environ.pop("PYTHONPATH", None)
        else:
            os.environ["PYTHONPATH"] = old_pp
    return store


# --------------------------------------------------------------------------
# collation
# --------------------------------------------------------------------------
def collate(store: RunStore) -> tuple[list[dict], list[dict]]:
    """Aggregate a completed sweep into tidy rows.

    Returns ``(cell_rows, agg_rows)``: one row per cell (axis columns +
    the :data:`SUMMARY_STATS`), and one row per grid point with mean±std
    over the seed axis (population std, ``ddof=0`` — the replicates *are*
    the population the figure plots). Floats pass through ``repr``-exact
    (stored summary → row), so resumed and uninterrupted runs collate to
    identical bytes.
    """
    sweep = store.read_manifest()
    cells = sweep.cells()
    missing = [c.cell_id for c in cells if not store.is_complete(c.cell_id)]
    if missing:
        raise ValueError(
            f"cannot collate: {len(missing)}/{len(cells)} cells incomplete "
            f"(first missing: {missing[0]}); re-invoke run_sweep on this store"
        )
    axis_cols = list(sweep.axes)
    cell_rows = []
    for c in cells:
        row = {"cell": c.cell_id, "grid": c.grid_index, "seed": c.seed_index}
        for path in axis_cols:
            row[path] = override_label(path, c.overrides[path])
        row.update(store.read_summary(c.cell_id))
        cell_rows.append(row)

    agg_rows = []
    n_grid = len(sweep._grid())
    for gi in range(n_grid):
        group = [r for r in cell_rows if r["grid"] == gi]
        row = {"grid": gi}
        for path in axis_cols:
            row[path] = group[0][path]
        row["n_seeds"] = len(group)
        for stat in SUMMARY_STATS:
            vals = np.array([r[stat] for r in group], dtype=np.float64)
            row[f"{stat}_mean"] = float(vals.mean())
            row[f"{stat}_std"] = float(vals.std())
        agg_rows.append(row)
    return cell_rows, agg_rows


def write_collated(
    store: RunStore, rows: "tuple[list[dict], list[dict]] | None" = None
) -> tuple[Path, Path]:
    """Write ``cells.csv`` + ``summary.csv`` into the store; return paths.

    ``rows`` short-circuits the :func:`collate` call for callers that
    already hold its result.
    """
    cell_rows, agg_rows = collate(store) if rows is None else rows
    cells_csv = store.root / "cells.csv"
    summary_csv = store.root / "summary.csv"
    _write_csv(cells_csv, cell_rows)
    _write_csv(summary_csv, agg_rows)
    return cells_csv, summary_csv


def _write_csv(path: Path, rows: list[dict]) -> None:
    import csv

    with open(path, "w", newline="") as fh:
        if not rows:
            return
        w = csv.DictWriter(fh, fieldnames=list(rows[0]), lineterminator="\n")
        w.writeheader()
        for row in rows:
            w.writerow({k: repr(v) if isinstance(v, float) else v for k, v in row.items()})


__all__ = [
    "SEED_PATHS",
    "SUMMARY_STATS",
    "SweepCell",
    "SweepSpec",
    "RunStore",
    "cell_hash",
    "cell_group_label",
    "override_label",
    "set_by_path",
    "summarize_history",
    "degraded_fraction",
    "availability_weighted_time_to_acc",
    "run_cell",
    "run_sweep",
    "collate",
    "write_collated",
]
