"""Round schedulers: when a round closes and what happens to stragglers.

The batch loop (and PR 6's continuous service) is *synchronous*: a round
ends when every drawn client reports, and a client that misses the implicit
deadline is indistinguishable from one that crashed — its mass falls on the
stale term and its update is discarded. Production FL distinguishes the
two. This module makes the round-closing rule a pluggable policy, a
:data:`SCHEDULERS` registry (:func:`register_scheduler`, mirroring
``CLUSTERERS``/``SKETCHERS``) with three entries:

* ``"sync"`` — today's behaviour, the exact legacy path. Every hook is a
  no-op; a server with a :class:`SyncScheduler` attached trains
  bit-identically to one with no scheduler at all (tier-1 parity gate in
  ``benchmarks/bench_scheduler.py``).
* ``"deadline"`` — rounds close after a fixed deadline against a simulated
  per-client :class:`LatencyModel`, drawn pure in ``(seed, t)`` exactly
  like :mod:`repro.fl.population`'s masks (same ``SeedSequence`` keying,
  disjoint stream tag), so a resumed service replays identical lateness.
  Stragglers are **not dropped**: their aggregation mass falls back on the
  current global model this round (the same eq. 3 stale term mid-round
  drops use), but their computed updates land in a *harvest buffer* and
  scatter into the **next** round's :class:`~repro.fl.gradient_store.
  GradientStore` with a staleness discount — the similarity state keeps
  learning from slow clients instead of forgetting them, which is what
  separates a straggler from a crash. The buffer checkpoints inside
  ``ServerState`` and kills/resumes bit-identically.
* ``"overselect"`` — FedAvg-style overselection: draw ``m · (1 + β)``
  clients, aggregate the first ``m`` draws. The extra draws re-use the
  plan's urns cyclically (draw ``j`` comes from urn ``j mod m``, urn ``k``
  drawn ``c_k`` times) and each draw carries weight ``w_k / c_k`` (``w_k``
  the urn's draw weight: ``1/m`` unconditionally, its share of available
  mass under an availability mask), so the *draw-time* re-weighting stays
  exactly unbiased: ``E[Σ_draws ω_i] = p_i`` for any eq. (8) plan — and
  ``p_i·a_i / Σ_j p_j·a_j`` conditionally (see
  ``ClientSampler.sample_overselect``). The discarded surplus draws'
  realized mass moves to the stale term, the same resolution a mid-round
  drop gets.

The scheduler slots into ``FederatedServer.run_round``'s named phases::

    availability → begin_round (harvest scatter) → draw → resolve
    (lateness) → drop resolution → local work → collect (harvest late
    updates) → observe (on-time survivors only)

and is surfaced declaratively as the ``SchedulerSpec`` section of an
:class:`~repro.fl.experiment.ExperimentSpec`; per-round telemetry lands in
``RoundRecord.n_late`` / ``n_harvested``.
"""
from __future__ import annotations

import inspect
from typing import Optional

import numpy as np

from repro.core.registry import Registry
from repro.core.types import SampleResult
from repro.fl.population import _round_rng

#: SeedSequence stream tag for latency draws — disjoint from the population
#: module's availability (0x41) / dropout (0x44) / phase (0x50) streams, so
#: attaching a deadline scheduler never shifts a scenario's churn.
_LAT_TAG = 0x4C


class LatencyModel:
    """Simulated per-client round latency, pure in ``(seed, t)``.

    Latencies are in units of the round deadline: every client draws a base
    response time ``u ~ U[0, 1)`` and, independently per round, is a
    straggler with probability ``straggle_frac`` — stragglers add
    ``slow_factor``. With the default ``deadline=1.0`` and
    ``slow_factor >= 1`` this makes the split exact: fast clients *never*
    miss the deadline, stragglers *always* do — so under a pure straggler
    model a round can lose every participant to lateness yet must not
    raise ``EmptyRoundError`` (their updates are harvested, not lost).

    Determinism contract: one ``SeedSequence((seed, tag, t))`` generator
    per round, base draw first then the straggler Bernoulli, so a resumed
    service replays the identical lateness trajectory without the model
    appearing in any checkpoint.
    """

    def __init__(
        self,
        n_clients: int,
        *,
        seed: int = 0,
        straggle_frac: float = 0.3,
        slow_factor: float = 2.0,
    ):
        if n_clients <= 0:
            raise ValueError("n_clients must be positive")
        if not 0.0 <= straggle_frac <= 1.0:
            raise ValueError(f"straggle_frac must be in [0, 1], got {straggle_frac}")
        if slow_factor < 0:
            raise ValueError(f"slow_factor must be >= 0, got {slow_factor}")
        self.n_clients = int(n_clients)
        self.seed = int(seed)
        self.straggle_frac = float(straggle_frac)
        self.slow_factor = float(slow_factor)

    def latencies(self, t: int) -> np.ndarray:
        """(n,) f64 latencies for round ``t``, deterministic in (seed, t)."""
        rng = _round_rng(self.seed, _LAT_TAG, t)
        base = rng.random(self.n_clients)
        slow = rng.random(self.n_clients) < self.straggle_frac
        return base + slow * self.slow_factor


class RoundScheduler:
    """Base scheduler: every hook the exact no-op of the legacy sync round.

    Subclasses override the hooks they need; anything left alone keeps the
    legacy semantics, which is why :class:`SyncScheduler` is an empty
    subclass and why a server with the base scheduler attached is
    bit-identical to one with none.
    """

    #: registry / checkpoint identity (cross-scheduler restores fail loudly)
    name: str = "sync"

    def __init__(self, n_clients: int, m: int, *, seed: int = 0):
        if n_clients <= 0 or m <= 0:
            raise ValueError("n_clients and m must be positive")
        self.n_clients = int(n_clients)
        self.m = int(m)
        self.seed = int(seed)

    def required_slots(self, m: int) -> int:
        """Engine slot count — the padded client axis the engine stages."""
        return int(m)

    def begin_round(self, t: int, sampler) -> int:
        """Round prologue; returns how many buffered late updates were
        scattered into the sampler's gradient store (``n_harvested``)."""
        del t, sampler
        return 0

    def draw(self, t: int, sampler, available: Optional[np.ndarray]) -> SampleResult:
        """The round's client draw — the legacy call shape by default.

        The no-mask path stays the one-argument legacy call so custom
        samplers written before availability conditioning keep working.
        """
        return sampler.sample(t) if available is None else sampler.sample(t, available)

    def n_late_extra(self) -> int:
        """Draws discarded at draw time (overselection surplus); 0 here."""
        return 0

    def resolve(
        self, t: int, distinct: np.ndarray, weights: np.ndarray, stale_weight: float
    ) -> tuple[np.ndarray, float, np.ndarray]:
        """Apply the round-closing rule *before* drop resolution.

        Returns ``(weights, stale_weight, late)`` — ``late`` a boolean mask
        over ``distinct`` marking participants whose update misses this
        round's aggregation (weight zeroed, mass gone stale) but will be
        harvested by :meth:`collect`. All-no-op here.
        """
        del t
        return weights, stale_weight, np.zeros(distinct.shape, dtype=bool)

    def collect(self, t: int, client_ids: np.ndarray, updates: np.ndarray) -> None:
        """Buffer the late participants' computed updates for the next round."""
        del t, client_ids, updates

    # -- checkpointable state ------------------------------------------------
    def state_arrays(self) -> dict:
        return {}

    def state_meta(self) -> dict:
        return {"scheduler": self.name}

    def load_state(self, meta: dict, arrays: dict) -> None:
        got = meta.get("scheduler", self.name)
        if got != self.name:
            raise ValueError(
                f"checkpoint was written by scheduler {got!r}; this server "
                f"runs {self.name!r} — a cross-scheduler restore would mix "
                "incompatible harvest/lateness semantics"
            )
        del arrays


class SyncScheduler(RoundScheduler):
    """Today's synchronous rounds — the exact legacy path (every hook no-op)."""

    name = "sync"


class DeadlineScheduler(RoundScheduler):
    """Deadline rounds with straggler harvesting into the next round's store.

    Per round: :meth:`resolve` draws the :class:`LatencyModel` and marks
    participants past ``deadline`` late — their weight is zeroed and falls
    on the stale term (the model does not move for them this round), but
    :meth:`collect` buffers their computed updates and the *next* round's
    :meth:`begin_round` scatters them into the sampler's gradient store
    scaled by ``harvest_discount`` (decay-free: only the harvested rows
    change). Late is therefore graded, not fatal — the similarity state
    keeps tracking slow clients at a discount, and
    ``RoundRecord.n_harvested`` counts the deliveries.
    """

    name = "deadline"

    def __init__(
        self,
        n_clients: int,
        m: int,
        *,
        seed: int = 0,
        deadline: float = 1.0,
        straggle_frac: float = 0.3,
        slow_factor: float = 2.0,
        harvest_discount: float = 0.5,
    ):
        super().__init__(n_clients, m, seed=seed)
        if deadline <= 0:
            raise ValueError(f"deadline must be > 0, got {deadline}")
        if not 0.0 <= harvest_discount <= 1.0:
            raise ValueError(
                f"harvest_discount must be in [0, 1], got {harvest_discount}"
            )
        self.deadline = float(deadline)
        self.harvest_discount = float(harvest_discount)
        self.model = LatencyModel(
            n_clients, seed=seed, straggle_frac=straggle_frac, slow_factor=slow_factor
        )
        self._harvest_ids = np.empty(0, np.int64)
        self._harvest_vals = np.zeros((0, 0), np.float32)

    def begin_round(self, t: int, sampler) -> int:
        del t
        ids, vals = self._harvest_ids, self._harvest_vals
        if ids.size == 0:
            return 0
        self._harvest_ids = np.empty(0, np.int64)
        self._harvest_vals = np.zeros((0, 0), np.float32)
        store = getattr(sampler, "gradient_store", None)
        if store is None:
            # plan-free sampler: nothing consumes late similarity updates
            return 0
        store.scatter_scaled(ids, vals, scale=self.harvest_discount)
        return int(ids.size)

    def resolve(self, t, distinct, weights, stale_weight):
        lat = self.model.latencies(t)[np.asarray(distinct, np.int64)]
        late = lat > self.deadline
        if late.any():
            stale_weight = float(stale_weight + weights[late].sum())
            weights = np.where(late, 0.0, weights)
        return weights, stale_weight, late

    def collect(self, t, client_ids, updates) -> None:
        del t
        # host f32 copies: the buffer must checkpoint (and survive the next
        # engine dispatch) independent of device buffer reuse
        self._harvest_ids = np.asarray(client_ids, np.int64).copy()
        self._harvest_vals = np.asarray(updates, np.float32).copy()

    # -- checkpointable state ------------------------------------------------
    def state_arrays(self) -> dict:
        # keys are always present (0-size when empty): repro.checkpoint
        # restores take tree *keys* from the caller and shapes from disk
        return {
            "harvest_ids": self._harvest_ids,
            "harvest_vals": self._harvest_vals,
        }

    def load_state(self, meta: dict, arrays: dict) -> None:
        super().load_state(meta, {})
        ids = np.asarray(arrays["harvest_ids"], np.int64)
        vals = np.asarray(arrays["harvest_vals"], np.float32)
        if ids.shape[0] != vals.shape[0]:
            raise ValueError(
                f"checkpointed harvest buffer is inconsistent: {ids.shape[0]} "
                f"ids for {vals.shape[0]} update rows"
            )
        self._harvest_ids = ids
        self._harvest_vals = vals


class OverselectScheduler(RoundScheduler):
    """Sample ``m·(1+β)`` clients, aggregate the first ``m`` draws.

    The hedge against non-response: extra draws are made up front so the
    round still carries ``m`` aggregating draws after churn takes its cut.
    Unbiasedness is preserved at *draw time* (see the module docstring and
    ``ClientSampler.sample_overselect``): over all ``m·(1+β)`` weighted
    draws ``E[ω_i]`` equals the scheme's exact target for any eq. (8)
    plan; the surplus draws' realized mass then moves to the stale term —
    the identical resolution a mid-round drop receives, reported as
    ``n_late`` telemetry.
    """

    name = "overselect"

    def __init__(self, n_clients: int, m: int, *, seed: int = 0, beta: float = 0.5):
        super().__init__(n_clients, m, seed=seed)
        if beta <= 0:
            raise ValueError(f"beta must be > 0, got {beta}")
        self.beta = float(beta)
        self.n_extra = max(1, int(np.ceil(beta * m)))
        self._last_discarded = 0

    def required_slots(self, m: int) -> int:
        # thinning happens at draw time, so the engine never sees more than
        # m aggregating draws — the padded slot axis stays at m
        return int(m)

    def draw(self, t, sampler, available):
        res = sampler.sample_overselect(t, self.m + self.n_extra, available)
        if res.draw_weights is None:
            raise RuntimeError(
                f"{type(sampler).__name__}.sample_overselect returned no "
                "per-draw weights; overselection thinning needs them"
            )
        clients, w = res.clients, res.draw_weights
        keep = min(self.m, int(clients.size))
        agg = np.zeros(res.agg_weights.shape[0])
        np.add.at(agg, clients[:keep], w[:keep])
        self._last_discarded = int(clients.size) - keep
        return SampleResult(
            clients=clients[:keep],
            agg_weights=agg,
            stale_weight=float(res.stale_weight + w[keep:].sum()),
            draw_weights=np.asarray(w[:keep]),
        )

    def n_late_extra(self) -> int:
        return self._last_discarded


#: name -> scheduler class with the uniform ``(n_clients, m, *, seed=0,
#: **options)`` constructor; ``SchedulerSpec`` sections resolve through this.
SCHEDULERS = Registry(
    "scheduler",
    {
        "sync": SyncScheduler,
        "deadline": DeadlineScheduler,
        "overselect": OverselectScheduler,
    },
)

register_scheduler = SCHEDULERS.register


def build_scheduler(spec, *, n_clients: int, m: int) -> RoundScheduler:
    """Resolve a :class:`~repro.fl.experiment.SchedulerSpec` (or its dict
    form) through :data:`SCHEDULERS` and construct the scheduler."""
    from repro.fl.experiment import SchedulerSpec

    spec = SchedulerSpec.from_dict(spec) if isinstance(spec, dict) else spec
    factory = SCHEDULERS.get(spec.name)
    accepted = set(inspect.signature(factory).parameters) - {
        "self",
        "n_clients",
        "m",
        "seed",
    }
    unknown = set(spec.options) - accepted
    if unknown:
        raise ValueError(
            f"scheduler {spec.name!r} does not accept option(s) {sorted(unknown)}; "
            f"accepted options: {sorted(accepted)}"
        )
    return factory(n_clients, m, seed=spec.seed, **spec.options)


__all__ = [
    "LatencyModel",
    "RoundScheduler",
    "SyncScheduler",
    "DeadlineScheduler",
    "OverselectScheduler",
    "SCHEDULERS",
    "register_scheduler",
    "build_scheduler",
]
