"""Asynchronous re-clustering planner: plan *building* off the critical path.

The paper's server "overlaps re-clustering with client local work"
(Section 5): while the sampled clients run their N local steps for round
``t+1``, the server rebuilds the Algorithm 2 plan from round ``t``'s
representative gradients. The seed implementation rebuilt synchronously
inside ``observe_updates`` — O(n²d) distances + O(n³) Ward on the round's
critical path.

This module is the producer side of the split:

* :class:`PlanService` owns versioned :class:`SamplingPlan`\\ s and accepts
  *observations* (snapshots of the gradient store) that trigger rebuilds.
* ``mode="sync"`` rebuilds inline — today's numerics, kept as the parity
  reference.
* ``mode="async"`` hands the snapshot to a single background worker and
  returns immediately; the consumer (the sampler) swaps in the freshest
  *completed* plan at each round boundary via :meth:`poll`. Pending
  snapshots are latest-wins: a rebuild that has not started yet is replaced
  by a newer observation, so the worker never queues up stale work.

A plan's ``version`` is the index of the observation it incorporates
(0 = the cold-start plan built before any updates). The *lag* reported by
:meth:`telemetry` is ``observations seen − version of the active plan`` —
0 in sync mode by construction, ≥ 0 under async overlap; it lands in
``RoundRecord.plan_lag_rounds`` since the server observes once per round.

The module is dependency-light (stdlib + ``repro.core.types`` only): the
snapshot is opaque to the service — device arrays pass straight through to
``build_fn`` without a host round-trip. jax arrays are immutable, so a
snapshot read by the worker while the engine scatters new updates into the
store is consistent for free.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Optional

from repro.core.types import SamplingPlan

BuildFn = Callable[[Any], SamplingPlan]


@dataclasses.dataclass(frozen=True)
class VersionedPlan:
    """A sampling plan stamped with the observation index it incorporates."""

    plan: SamplingPlan
    version: int  # number of observations folded in; 0 = cold-start plan


class PlanService:
    """Versioned plan producer, synchronous or overlapped.

    ``build_fn(snapshot) -> SamplingPlan`` is the (expensive) Algorithm 1/2
    plan constructor; ``initial_input`` is the snapshot for the version-0
    cold-start plan, built inline at construction either way.

    ``rebuild_every=k`` sets the re-clustering cadence: only every k-th
    observation triggers a rebuild (the skipped ones still advance the
    observation counter, so :meth:`telemetry` lag — and therefore
    ``RoundRecord.plan_version`` / ``plan_lag_rounds`` — records exactly
    which observation the active plan incorporates and how far it trails).
    Snapshots are cumulative store states, so skipping intermediates loses
    nothing: the k-th snapshot contains every update since the last rebuild.
    """

    MODES = ("sync", "async")

    def __init__(
        self,
        build_fn: BuildFn,
        *,
        mode: str = "sync",
        initial_input: Any = None,
        rebuild_every: int = 1,
    ):
        if mode not in self.MODES:
            raise ValueError(f"unknown planner mode {mode!r}; choose from {self.MODES}")
        if rebuild_every < 1:
            raise ValueError(f"rebuild_every must be >= 1, got {rebuild_every}")
        self.mode = mode
        self.rebuild_every = int(rebuild_every)
        self._build_fn = build_fn
        self._cond = threading.Condition()
        self._current = VersionedPlan(build_fn(initial_input), version=0)
        self._completed: Optional[VersionedPlan] = None  # built, not yet polled
        self._pending: Optional[tuple[int, Any]] = None  # latest-wins snapshot
        self._building = False
        self._closed = False
        self._error: Optional[BaseException] = None
        self._obs_seen = 0
        self._worker: Optional[threading.Thread] = None

    # -- producer side ------------------------------------------------------
    def observe(self, snapshot: Any) -> None:
        """Record one observation and (re)build the plan from ``snapshot``.

        Sync: builds inline; :meth:`poll` returns the fresh plan immediately
        after. Async: enqueues (replacing any not-yet-started snapshot) and
        returns without blocking — the round for ``t+1`` proceeds while the
        worker rebuilds. With ``rebuild_every=k``, observations that are not
        a multiple of k only advance the counter (no rebuild, no snapshot
        retained).
        """
        self._raise_pending_error()
        self._obs_seen += 1
        if self._obs_seen % self.rebuild_every != 0:
            return
        if self.mode == "sync":
            plan = self._build_fn(snapshot)
            with self._cond:
                self._completed = VersionedPlan(plan, self._obs_seen)
            return
        with self._cond:
            if self._closed:
                raise RuntimeError("PlanService is closed")
            self._pending = (self._obs_seen, snapshot)
            if self._worker is None:
                self._worker = threading.Thread(
                    target=self._worker_loop, name="plan-service", daemon=True
                )
                self._worker.start()
            self._cond.notify_all()

    def _worker_loop(self) -> None:
        while True:
            with self._cond:
                while self._pending is None and not self._closed:
                    self._cond.wait()
                if self._closed and self._pending is None:
                    return
                version, snapshot = self._pending
                self._pending = None
                self._building = True
            try:
                plan = self._build_fn(snapshot)
            except BaseException as e:  # surfaced on the next observe/poll/flush
                with self._cond:
                    self._error = e
                    self._building = False
                    self._cond.notify_all()
                continue  # keep servicing newer snapshots (latest-wins)
            with self._cond:
                # one worker + latest-wins pending => versions are monotone
                self._completed = VersionedPlan(plan, version)
                self._building = False
                self._cond.notify_all()

    # -- consumer side ------------------------------------------------------
    def poll(self) -> Optional[VersionedPlan]:
        """Take the freshest *completed* plan, or None if nothing new.

        Called at round boundaries: non-blocking, so an async rebuild still
        in flight simply leaves the previous plan active for one more round.
        """
        self._raise_pending_error()
        with self._cond:
            vp, self._completed = self._completed, None
            if vp is not None:
                self._current = vp
            return vp

    def current(self) -> VersionedPlan:
        """The active (last polled-in) versioned plan."""
        with self._cond:
            return self._current

    def telemetry(self) -> tuple[int, int]:
        """(version of active plan, observations not yet reflected in it)."""
        with self._cond:
            return self._current.version, self._obs_seen - self._current.version

    def observations_seen(self) -> int:
        """Total observations recorded (the rebuild-cadence counter)."""
        with self._cond:
            return self._obs_seen

    def restore(self, plan: VersionedPlan, *, obs_seen: int) -> None:
        """Reinstate a checkpointed (plan, observation-counter) state.

        The checkpoint/resume half of the continuous-service path: the
        sampler was quiesced (flushed) before its state was exported, so
        restoring requires no rebuild to be pending or in flight — the
        service refuses otherwise rather than racing a stale worker build
        against the restored plan.
        """
        with self._cond:
            if self._pending is not None or self._building:
                raise RuntimeError(
                    "cannot restore a PlanService with a rebuild pending or "
                    "in flight; flush() first"
                )
            if obs_seen < plan.version:
                raise ValueError(
                    f"obs_seen={obs_seen} < plan version {plan.version}: a plan "
                    "cannot incorporate observations that never happened"
                )
            self._current = plan
            self._completed = None
            self._obs_seen = int(obs_seen)

    def flush(self, timeout: Optional[float] = None) -> None:
        """Block until no rebuild is pending or in flight.

        ``flush(); poll()`` forces async to the sync fixed point — the
        determinism tests pin async-forced-complete ≡ sync through this.
        """
        with self._cond:
            ok = self._cond.wait_for(
                lambda: (self._pending is None and not self._building) or self._error,
                timeout=timeout,
            )
            if not ok:
                raise TimeoutError("plan rebuild did not complete in time")
        self._raise_pending_error()

    def close(self, timeout: float = 5.0) -> None:
        """Stop the worker; pending snapshots are abandoned."""
        with self._cond:
            self._closed = True
            self._pending = None
            self._cond.notify_all()
            worker, self._worker = self._worker, None
        if worker is not None:
            worker.join(timeout)

    def _raise_pending_error(self) -> None:
        with self._cond:
            err, self._error = self._error, None
        if err is not None:
            raise RuntimeError("plan rebuild failed in the planner worker") from err
