"""Asynchronous re-clustering planner: plan *building* off the critical path.

The paper's server "overlaps re-clustering with client local work"
(Section 5): while the sampled clients run their N local steps for round
``t+1``, the server rebuilds the Algorithm 2 plan from round ``t``'s
representative gradients. The seed implementation rebuilt synchronously
inside ``observe_updates`` — O(n²d) distances + O(n³) Ward on the round's
critical path.

This module is the producer side of the split:

* :class:`PlanService` owns versioned :class:`SamplingPlan`\\ s and accepts
  *observations* (snapshots of the gradient store) that trigger rebuilds.
* ``mode="sync"`` rebuilds inline — today's numerics, kept as the parity
  reference.
* ``mode="async"`` hands the snapshot to a single background worker and
  returns immediately; the consumer (the sampler) swaps in the freshest
  *completed* plan at each round boundary via :meth:`poll`. Pending
  snapshots are latest-wins: a rebuild that has not started yet is replaced
  by a newer observation, so the worker never queues up stale work.

A plan's ``version`` is the index of the observation it incorporates
(0 = the cold-start plan built before any updates). The *lag* reported by
:meth:`telemetry` is ``observations seen − version of the active plan`` —
0 in sync mode by construction, ≥ 0 under async overlap; it lands in
``RoundRecord.plan_lag_rounds`` since the server observes once per round.

Rebuild scheduling is either a fixed cadence (``rebuild_every=k``, the
default) or *measured*: with ``drift_threshold`` set, every observation
computes a cheap on-device drift statistic — the assignment churn of the
fresh representative gradients against the live plan's clusters
(:class:`AssignmentDriftMonitor`) — and a rebuild runs only when it crosses
the threshold. The statistic is O(n·k·d) (one nearest-centroid pass), so
deciding *not* to rebuild costs a vanishing fraction of the O(n²d + n³)
rebuild it skips. Both the drift value and the wall-clock cost of each
rebuild are exposed (:meth:`PlanService.last_drift` /
:meth:`PlanService.last_build_ms`) and land in
``RoundRecord.plan_drift`` / ``plan_build_ms``.

The module is dependency-light (stdlib + numpy + ``repro.core``): the
snapshot is opaque to the service — device arrays pass straight through to
``build_fn`` without a host round-trip (the drift monitor, when enabled,
consumes them on device too). jax arrays are immutable, so a snapshot read
by the worker while the engine scatters new updates into the store is
consistent for free.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Optional

import numpy as np

from repro.core.types import SamplingPlan

BuildFn = Callable[[Any], SamplingPlan]


class AssignmentDriftMonitor:
    """Assignment churn of fresh gradients vs the live plan's clusters.

    At each rebuild the monitor freezes the plan's cluster structure as a
    set of centroids (per-cluster means of the snapshot rows the plan
    grouped, ``plan.cluster_of >= 0``) plus the baseline nearest-centroid
    assignment of that snapshot. :meth:`drift` then measures, for a fresh
    snapshot, the fraction of rows whose nearest centroid changed — 0.0
    when the gradients still sort into the same clusters (identical
    assignments ⇒ identical statistic), growing monotonically with label
    churn. Plans with no cluster structure (all-dedicated urns) and the
    never-baselined cold start report ``inf``: when churn cannot be
    measured, the trigger errs toward rebuilding.

    With availability tracking on, each observation also carries the
    tracker's active-client mask, and :meth:`drift` adds a *churn* term: the
    fraction of clients whose active bit flipped since the baseline. Fleet
    turnover alone (clients aging out of the presence window, newcomers
    crossing the threshold) then triggers a rebuild even when the surviving
    clients' gradients have not drifted — a mask of ``None`` means the full
    fleet, so the term is 0 whenever tracking is off.

    All heavy ops run through :mod:`repro.core.clustering.device`, so a
    device-resident snapshot never round-trips to host (only the scalar
    comes back). State swaps are atomic single-attribute stores, safe for
    the async planner's reader (observe) / writer (worker) threads.
    """

    def __init__(self):
        self._state: Optional[tuple[Any, np.ndarray]] = None  # (centroids, baseline)
        self._active: Optional[np.ndarray] = None  # baseline mask; None = full fleet

    def rebaseline(
        self, snapshot: Any, plan: SamplingPlan, active: Optional[np.ndarray] = None
    ) -> None:
        """Freeze ``plan``'s clusters over ``snapshot`` as the new baseline.

        ``active`` is the availability mask the rebuild was restricted to
        (None = full fleet); it becomes the reference for the churn term.
        """
        from repro.core.clustering.device import (
            cluster_centroids,
            nearest_centroid_labels,
        )

        self._active = None if active is None else np.asarray(active, dtype=bool).copy()
        labels = None if plan.cluster_of is None else np.asarray(plan.cluster_of)
        if labels is None or not (labels >= 0).any():
            self._state = None
            return
        k = int(labels.max()) + 1
        centroids = cluster_centroids(snapshot, labels, k)
        self._state = (centroids, nearest_centroid_labels(snapshot, centroids))

    def _churn(self, active: Optional[np.ndarray]) -> float:
        """Fraction of clients whose active bit flipped since the baseline."""
        if active is None and self._active is None:
            return 0.0
        if self._active is not None:
            ref = self._active
            new = (
                np.ones_like(ref) if active is None else np.asarray(active, dtype=bool)
            )
        else:
            new = np.asarray(active, dtype=bool)
            ref = np.ones_like(new)
        return float(np.mean(new != ref))

    def drift(self, snapshot: Any, active: Optional[np.ndarray] = None) -> float:
        """Assignment churn of ``snapshot`` plus the fleet-turnover term."""
        from repro.core.clustering.device import nearest_centroid_labels

        state = self._state
        if state is None:
            return float("inf")
        centroids, baseline = state
        fresh = nearest_centroid_labels(snapshot, centroids)
        return float(np.mean(fresh != baseline)) + self._churn(active)


@dataclasses.dataclass(frozen=True)
class VersionedPlan:
    """A sampling plan stamped with the observation index it incorporates."""

    plan: SamplingPlan
    version: int  # number of observations folded in; 0 = cold-start plan


class PlanService:
    """Versioned plan producer, synchronous or overlapped.

    ``build_fn(snapshot) -> SamplingPlan`` is the (expensive) Algorithm 1/2
    plan constructor; ``initial_input`` is the snapshot for the version-0
    cold-start plan, built inline at construction either way.

    ``rebuild_every=k`` sets the re-clustering cadence: only every k-th
    observation triggers a rebuild (the skipped ones still advance the
    observation counter, so :meth:`telemetry` lag — and therefore
    ``RoundRecord.plan_version`` / ``plan_lag_rounds`` — records exactly
    which observation the active plan incorporates and how far it trails).
    Snapshots are cumulative store states, so skipping intermediates loses
    nothing: the k-th snapshot contains every update since the last rebuild.

    ``drift_threshold`` replaces the fixed cadence with the measured
    trigger: each observation computes the drift statistic and a rebuild
    fires iff ``drift >= drift_threshold``. A threshold of 0.0 degenerates
    to rebuild-on-any-churn (and, since the cold start reports ``inf``,
    fires on the first observation); thresholds > 1 never fire on a
    measurable plan. Mutually exclusive with a non-default
    ``rebuild_every`` — the two scheduling policies would silently mask
    each other. Requires array-like snapshots (the drift monitor computes
    nearest-centroid assignments over them).
    """

    MODES = ("sync", "async")

    def __init__(
        self,
        build_fn: BuildFn,
        *,
        mode: str = "sync",
        initial_input: Any = None,
        rebuild_every: int = 1,
        drift_threshold: Optional[float] = None,
        drift_monitor: Optional[AssignmentDriftMonitor] = None,
    ):
        if mode not in self.MODES:
            raise ValueError(f"unknown planner mode {mode!r}; choose from {self.MODES}")
        if rebuild_every < 1:
            raise ValueError(f"rebuild_every must be >= 1, got {rebuild_every}")
        if drift_threshold is not None:
            if drift_threshold < 0:
                raise ValueError(
                    f"drift_threshold must be >= 0, got {drift_threshold}"
                )
            if rebuild_every != 1:
                raise ValueError(
                    "drift_threshold and rebuild_every are alternative rebuild "
                    f"schedules; got both (rebuild_every={rebuild_every}) — "
                    "pick one"
                )
        self.mode = mode
        self.rebuild_every = int(rebuild_every)
        self.drift_threshold = None if drift_threshold is None else float(drift_threshold)
        self._build_fn = build_fn
        self._monitor = (
            (drift_monitor or AssignmentDriftMonitor())
            if drift_threshold is not None
            else drift_monitor
        )
        self._cond = threading.Condition()
        self._current = VersionedPlan(self._timed_build(initial_input), version=0)
        if self._monitor is not None:
            self._monitor.rebaseline(initial_input, self._current.plan)
        self._completed: Optional[VersionedPlan] = None  # built, not yet polled
        # latest-wins (version, snapshot, active-mask) awaiting the worker
        self._pending: Optional[tuple[int, Any, Optional[np.ndarray]]] = None
        self._building = False
        self._closed = False
        self._error: Optional[BaseException] = None
        self._obs_seen = 0
        self._rebuilds = 0
        self._last_drift = -1.0
        self._worker: Optional[threading.Thread] = None

    def _timed_build(self, snapshot: Any) -> SamplingPlan:
        """Run ``build_fn`` and record its wall-clock cost (telemetry)."""
        t0 = time.perf_counter()
        plan = self._build_fn(snapshot)
        self._last_build_ms = (time.perf_counter() - t0) * 1e3
        return plan

    # -- producer side ------------------------------------------------------
    def observe(self, snapshot: Any, active: Optional[np.ndarray] = None) -> None:
        """Record one observation and (re)build the plan from ``snapshot``.

        Sync: builds inline; :meth:`poll` returns the fresh plan immediately
        after. Async: enqueues (replacing any not-yet-started snapshot) and
        returns without blocking — the round for ``t+1`` proceeds while the
        worker rebuilds. With ``rebuild_every=k``, observations that are not
        a multiple of k only advance the counter (no rebuild, no snapshot
        retained). With ``drift_threshold`` set, the drift statistic decides
        instead: below threshold the observation only advances the counter.

        ``active`` is the availability tracker's current active-client mask
        (None = full fleet). It feeds the drift monitor's churn term and is
        re-baselined alongside the plan, so fleet turnover counts toward the
        rebuild trigger; the build itself reads its cluster restriction from
        the sampler at build time (tracker buffers are replaced, never
        mutated, so the worker sees a consistent mask).
        """
        self._raise_pending_error()
        self._obs_seen += 1
        if self.drift_threshold is not None:
            self._last_drift = self._monitor.drift(snapshot, active)
            if not self._last_drift >= self.drift_threshold:
                return
        elif self._obs_seen % self.rebuild_every != 0:
            return
        if self.mode == "sync":
            plan = self._timed_build(snapshot)
            if self._monitor is not None:
                self._monitor.rebaseline(snapshot, plan, active)
            with self._cond:
                self._completed = VersionedPlan(plan, self._obs_seen)
                self._rebuilds += 1
            return
        with self._cond:
            if self._closed:
                raise RuntimeError("PlanService is closed")
            self._pending = (self._obs_seen, snapshot, active)
            if self._worker is None:
                self._worker = threading.Thread(
                    target=self._worker_loop, name="plan-service", daemon=True
                )
                self._worker.start()
            self._cond.notify_all()

    def _worker_loop(self) -> None:
        while True:
            with self._cond:
                while self._pending is None and not self._closed:
                    self._cond.wait()
                if self._closed and self._pending is None:
                    return
                version, snapshot, active = self._pending
                self._pending = None
                self._building = True
            try:
                plan = self._timed_build(snapshot)
                if self._monitor is not None:
                    self._monitor.rebaseline(snapshot, plan, active)
            except BaseException as e:  # surfaced on the next observe/poll/flush
                with self._cond:
                    self._error = e
                    self._building = False
                    self._cond.notify_all()
                continue  # keep servicing newer snapshots (latest-wins)
            with self._cond:
                # one worker + latest-wins pending => versions are monotone
                self._completed = VersionedPlan(plan, version)
                self._building = False
                self._rebuilds += 1
                self._cond.notify_all()

    # -- consumer side ------------------------------------------------------
    def poll(self) -> Optional[VersionedPlan]:
        """Take the freshest *completed* plan, or None if nothing new.

        Called at round boundaries: non-blocking, so an async rebuild still
        in flight simply leaves the previous plan active for one more round.
        """
        self._raise_pending_error()
        with self._cond:
            vp, self._completed = self._completed, None
            if vp is not None:
                self._current = vp
            return vp

    def current(self) -> VersionedPlan:
        """The active (last polled-in) versioned plan."""
        with self._cond:
            return self._current

    def telemetry(self) -> tuple[int, int]:
        """(version of active plan, observations not yet reflected in it)."""
        with self._cond:
            return self._current.version, self._obs_seen - self._current.version

    def observations_seen(self) -> int:
        """Total observations recorded (the rebuild-cadence counter)."""
        with self._cond:
            return self._obs_seen

    def rebuilds_done(self) -> int:
        """Completed plan rebuilds, excluding the version-0 cold start."""
        with self._cond:
            return self._rebuilds

    def last_build_ms(self) -> float:
        """Wall-clock ms of the most recent completed ``build_fn`` call."""
        return self._last_build_ms

    def last_drift(self) -> float:
        """Drift statistic of the most recent observation.

        -1.0 until the first observation or when the drift trigger is
        disabled (``drift_threshold=None``); otherwise the assignment-churn
        fraction in [0, 1], or ``inf`` for an unmeasurable plan.
        """
        return self._last_drift

    def restore(self, plan: VersionedPlan, *, obs_seen: int) -> None:
        """Reinstate a checkpointed (plan, observation-counter) state.

        The checkpoint/resume half of the continuous-service path: the
        sampler was quiesced (flushed) before its state was exported, so
        restoring requires no rebuild to be pending or in flight — the
        service refuses otherwise rather than racing a stale worker build
        against the restored plan.
        """
        with self._cond:
            if self._pending is not None or self._building:
                raise RuntimeError(
                    "cannot restore a PlanService with a rebuild pending or "
                    "in flight; flush() first"
                )
            if obs_seen < plan.version:
                raise ValueError(
                    f"obs_seen={obs_seen} < plan version {plan.version}: a plan "
                    "cannot incorporate observations that never happened"
                )
            self._current = plan
            self._completed = None
            self._obs_seen = int(obs_seen)

    def flush(self, timeout: Optional[float] = None) -> None:
        """Block until no rebuild is pending or in flight.

        ``flush(); poll()`` forces async to the sync fixed point — the
        determinism tests pin async-forced-complete ≡ sync through this.
        """
        with self._cond:
            ok = self._cond.wait_for(
                lambda: (self._pending is None and not self._building) or self._error,
                timeout=timeout,
            )
            if not ok:
                raise TimeoutError("plan rebuild did not complete in time")
        self._raise_pending_error()

    def close(self, timeout: float = 5.0) -> None:
        """Stop the worker; pending snapshots are abandoned."""
        with self._cond:
            self._closed = True
            self._pending = None
            self._cond.notify_all()
            worker, self._worker = self._worker, None
        if worker is not None:
            worker.join(timeout)

    def _raise_pending_error(self) -> None:
        with self._cond:
            err, self._error = self._error, None
        if err is not None:
            raise RuntimeError("plan rebuild failed in the planner worker") from err
