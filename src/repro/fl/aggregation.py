"""Server-side model aggregation.

Unbiased schemes (eq. 4): ``θ^{t+1} = Σ_{k} (1/m) θ_{l_k}`` — equivalently a
weighted sum of the *distinct* updated models with the realized weights
``ω_i``. FedAvg-style biased sampling (eq. 3) adds ``stale_weight · θ^t``.

Two backends: pure-jnp tree arithmetic (default, any device) and the Pallas
``aggregate`` kernel over stacked flat updates (TPU hot path).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def weighted_tree_sum(trees: Sequence, weights: np.ndarray):
    """Σ_k w_k · tree_k without stacking (memory-lean host-side default)."""
    if len(trees) != len(weights):
        raise ValueError(f"{len(trees)} trees vs {len(weights)} weights")
    out = jax.tree_util.tree_map(lambda x: jnp.asarray(weights[0], x.dtype) * x, trees[0])
    for w, tree in zip(weights[1:], trees[1:]):
        out = jax.tree_util.tree_map(
            lambda acc, x: acc + jnp.asarray(w, x.dtype) * x, out, tree
        )
    return out


def aggregate_round(
    global_params,
    client_params: Sequence,
    client_weights: np.ndarray,
    stale_weight: float = 0.0,
):
    """Combine distinct client models (+ optional stale global mass)."""
    new = weighted_tree_sum(client_params, np.asarray(client_weights, dtype=np.float64))
    if stale_weight:
        new = jax.tree_util.tree_map(
            lambda a, g: a + jnp.asarray(stale_weight, g.dtype) * g, new, global_params
        )
    return new


def aggregate_stacked(global_params, stacked_params, weights, stale_weight):
    """Device-side eq. 3/4 over a stacked client axis (jit/vmap friendly).

    ``stacked_params`` is the pytree of client models with a leading client
    axis (leaf shape (c, …)); ``weights`` is (c,) — padded slots carry weight
    0 and therefore contribute nothing. ``stale_weight`` adds eq. 3's mass on
    the current global model (traced scalar, 0 for unbiased schemes).
    The reduction runs in f32 and is cast back to each leaf's dtype.
    """
    w = jnp.asarray(weights, jnp.float32)
    sw = jnp.asarray(stale_weight, jnp.float32)
    return jax.tree_util.tree_map(
        lambda stacked, g: (
            jnp.einsum("c,c...->...", w, stacked.astype(jnp.float32))
            + sw * g.astype(jnp.float32)
        ).astype(g.dtype),
        stacked_params,
        global_params,
    )


def flatten_params(tree) -> jnp.ndarray:
    """Flatten a pytree into one vector (representative-gradient plumbing)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate([jnp.ravel(x) for x in leaves])
