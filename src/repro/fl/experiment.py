"""Declarative experiment API: one spec dict → a runnable FL experiment.

The paper's pitch is that clustered sampling drops into standard FL loops;
this module makes that literal. An :class:`ExperimentSpec` names everything
a run needs — the dataset partition, the client-selection scheme, the plan
rebuild cadence, the round engine, the train hyperparameters, and the
client-churn scenario — as a JSON-round-trippable dict of six sections::

    {
      "data":       {"name": "by_class_shards", "options": {"dim": 32}},
      "sampler":    {"name": "algorithm2", "m": 10},
      "planner":    {"mode": "async", "rebuild_every": 2},
      "engine":     {"name": "batched"},
      "train":      {"n_rounds": 25, "lr": 0.05},
      "population": {"name": "poisson", "options": {"leave_rate": 0.2}},
      "scheduler":  {"name": "deadline", "track_availability": true}
    }

``build_experiment(spec)`` resolves every name through a registry
(``repro.core.samplers.SAMPLERS``, ``repro.fl.engine.ENGINES``,
:data:`DATASETS`) and returns a lifecycle-safe
:class:`~repro.fl.server.FederatedServer` — use it as a context manager so
async planner workers are always released::

    with build_experiment(spec) as srv:
        history = srv.run(on_round=print)   # streaming per-round telemetry

Sweeping sampler × planner × engine × mesh is then a matrix of dicts, not
a matrix of hand-wired constructor calls; registering a new scheme
(``register_sampler``) or engine (``register_engine``) makes it reachable
from every benchmark, example and CLI that speaks specs. Whole *campaigns*
— a grid of dotted-path overrides × ``n_seeds`` replicates with a
resumable store and mean±std collation — live one layer up in
:mod:`repro.fl.sweep` (:class:`~repro.fl.sweep.SweepSpec`). Errors are
precise by construction: unknown dict keys name the spec class and the
accepted keys, unknown registry names list what is registered, and sampler
options are checked against the scheme's actual signature.

Everything model-sized stays inferred: ``update_dim`` (the flattened MLP
size Algorithm 2's gradient store needs) and the class count come from the
built model/dataset, so specs carry intent only.
"""
from __future__ import annotations

import dataclasses
import inspect
import json
from typing import Any, Callable, Optional, Union

import numpy as np

from repro.core.registry import Registry
from repro.core.samplers import SAMPLERS
from repro.data.federated import FederatedDataset
from repro.fl.partition import by_class_shards, dirichlet_labels
from repro.fl.server import FederatedServer, FLConfig

#: name -> dataset factory returning a FederatedDataset; the seed entries
#: are the paper's two partitioners. register_dataset plugs in new ones.
DATASETS = Registry(
    "dataset",
    {
        "by_class_shards": by_class_shards,
        "dirichlet_labels": dirichlet_labels,
    },
)

register_dataset = DATASETS.register


# --------------------------------------------------------------------------
# spec dataclasses (frozen, dict-round-trippable)
# --------------------------------------------------------------------------
def _from_dict(cls, d: dict, nested: dict = {}):
    """Shared ``from_dict``: precise unknown-key errors + nested spec parse."""
    if not isinstance(d, dict):
        raise ValueError(f"{cls.__name__}.from_dict expects a dict, got {type(d).__name__}")
    fields = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - fields
    if unknown:
        raise ValueError(
            f"{cls.__name__}.from_dict: unknown key(s) {sorted(unknown)}; "
            f"accepted keys: {sorted(fields)}"
        )
    required = {
        f.name
        for f in dataclasses.fields(cls)
        if f.default is dataclasses.MISSING and f.default_factory is dataclasses.MISSING
    }
    missing = required - set(d)
    if missing:
        raise ValueError(
            f"{cls.__name__}.from_dict: missing required key(s) {sorted(missing)}"
        )
    kw = dict(d)
    for key, sub in nested.items():
        if key in kw and not isinstance(kw[key], sub):
            kw[key] = sub.from_dict(kw[key])
    return cls(**kw)


@dataclasses.dataclass(frozen=True)
class DataSpec:
    """Which federated partition to build (a :data:`DATASETS` name)."""

    name: str
    options: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def from_dict(cls, d: dict) -> "DataSpec":
        return _from_dict(cls, d)

    def to_dict(self) -> dict:
        return {"name": self.name, "options": dict(self.options)}


@dataclasses.dataclass(frozen=True)
class SamplerSpec:
    """Which client-selection scheme to run (a ``SAMPLERS`` name).

    ``options`` passes scheme-specific knobs through (``measure``,
    ``distance_fn``, ``staleness_decay``, ``groups`` …) — keys are checked
    against the scheme's signature at build time. ``update_dim`` may be set
    here to override the inferred flattened-model size.
    """

    name: str
    m: int
    seed: int = 0
    options: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def from_dict(cls, d: dict) -> "SamplerSpec":
        return _from_dict(cls, d)

    def to_dict(self) -> dict:
        return {"name": self.name, "m": self.m, "seed": self.seed, "options": dict(self.options)}


@dataclasses.dataclass(frozen=True)
class PlannerSpec:
    """When — and with what backend — plan-rebuilding samplers re-cluster.

    ``mode="async"`` overlaps Algorithm 2's rebuild with the next round's
    local work; ``rebuild_every=k`` re-clusters only every k observed
    rounds (``RoundRecord.plan_version`` records which observation each
    round's plan incorporates); ``drift_threshold`` replaces the fixed
    cadence with the measured trigger — a rebuild fires only when the
    assignment churn of fresh gradients against the live plan's clusters
    reaches the threshold (``RoundRecord.plan_drift`` records it).
    ``clusterer`` names the grouping backend from
    :data:`repro.core.clustering.backends.CLUSTERERS` (``"ward"`` — the
    paper-faithful default, ``"ward_jit"``, ``"kmeans"``, or anything
    ``register_clusterer`` added). ``sketch``/``sketch_dim`` attach the
    gradient store's device-side sketch stage (a
    :data:`repro.kernels.sketch.SKETCHERS` name — ``"srp"``,
    ``"countsketch"``, or ``"identity"`` for the exact legacy path; a
    compressing sketch needs ``sketch_dim`` = d′), so the store, the
    similarity stage and the drift monitor all scale in d′ instead of the
    model dimension. Ignored by plan-free samplers only when it is the
    default — asking a planless scheme for an async planner is an error,
    not a silent no-op.
    """

    mode: str = "sync"
    rebuild_every: int = 1
    clusterer: str = "ward"
    drift_threshold: Optional[float] = None
    sketch: Optional[str] = None
    sketch_dim: Optional[int] = None

    def __post_init__(self):
        if self.mode not in ("sync", "async"):
            raise ValueError(f"unknown planner mode {self.mode!r}; choose sync | async")
        if self.rebuild_every < 1:
            raise ValueError(f"rebuild_every must be >= 1, got {self.rebuild_every}")
        if self.drift_threshold is not None:
            if self.drift_threshold < 0:
                raise ValueError(
                    f"drift_threshold must be >= 0, got {self.drift_threshold}"
                )
            if self.rebuild_every != 1:
                raise ValueError(
                    "drift_threshold and rebuild_every are alternative rebuild "
                    f"schedules; got both (rebuild_every={self.rebuild_every})"
                )
        if self.sketch_dim is not None:
            if self.sketch is None:
                raise ValueError(
                    f"sketch_dim={self.sketch_dim} without a sketch; set "
                    "PlannerSpec.sketch (e.g. 'srp') or drop sketch_dim"
                )
            if self.sketch_dim < 1:
                raise ValueError(f"sketch_dim must be >= 1, got {self.sketch_dim}")

    @property
    def is_default(self) -> bool:
        return (
            self.mode == "sync"
            and self.rebuild_every == 1
            and self.clusterer == "ward"
            and self.drift_threshold is None
            and self.sketch is None
            and self.sketch_dim is None
        )

    @classmethod
    def from_dict(cls, d: dict) -> "PlannerSpec":
        return _from_dict(cls, d)

    def to_dict(self) -> dict:
        return {
            "mode": self.mode,
            "rebuild_every": self.rebuild_every,
            "clusterer": self.clusterer,
            "drift_threshold": self.drift_threshold,
            "sketch": self.sketch,
            "sketch_dim": self.sketch_dim,
        }


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """Which round executor runs the local work (an ``ENGINES`` name)."""

    name: str = "batched"
    # None | "auto" | "DxM" | (D, M) — see repro.launch.mesh.resolve_fl_mesh
    mesh_spec: Union[str, tuple, None] = None
    max_staged_bytes: int = 2 << 30

    def __post_init__(self):
        if isinstance(self.mesh_spec, list):
            object.__setattr__(self, "mesh_spec", tuple(self.mesh_spec))

    @classmethod
    def from_dict(cls, d: dict) -> "EngineSpec":
        return _from_dict(cls, d)

    def to_dict(self) -> dict:
        mesh = self.mesh_spec
        if mesh is not None and not isinstance(mesh, (str, tuple)):
            raise ValueError(
                f"EngineSpec.mesh_spec {mesh!r} is not dict-serializable; "
                "use None, 'auto', a 'DxM' string or a (D, M) shape"
            )
        return {
            "name": self.name,
            "mesh_spec": list(mesh) if isinstance(mesh, tuple) else mesh,
            "max_staged_bytes": self.max_staged_bytes,
        }


@dataclasses.dataclass(frozen=True)
class PopulationSpec:
    """Which client-churn scenario the service runs under (a
    :data:`~repro.fl.population.POPULATIONS` name).

    The default — ``static`` with no options — is the paper's fixed
    population; ``build_experiment`` then attaches *no* population process
    at all, keeping batch experiments on the exact pre-service code path.
    ``options`` passes scenario knobs through (``join_rate``, ``leave_rate``,
    ``rate``, ``period``, ``duty``, ``drop_rate``, ``straggle_rate``, …),
    checked against the process signature at build time.
    """

    name: str = "static"
    seed: int = 0
    options: dict = dataclasses.field(default_factory=dict)

    @property
    def is_default(self) -> bool:
        return self.name == "static" and not self.options

    @classmethod
    def from_dict(cls, d: dict) -> "PopulationSpec":
        return _from_dict(cls, d)

    def to_dict(self) -> dict:
        return {"name": self.name, "seed": self.seed, "options": dict(self.options)}


@dataclasses.dataclass(frozen=True)
class SchedulerSpec:
    """How rounds close and whether availability history is tracked.

    ``name`` is a :data:`repro.fl.scheduler.SCHEDULERS` entry (``"sync"`` —
    the legacy synchronous round and the default; ``"deadline"`` — straggler
    grading with harvest-into-next-round; ``"overselect"`` — draw
    ``m·(1+β)``, aggregate the first ``m``); ``options`` passes
    scheduler-specific knobs (``deadline``, ``straggle_frac``,
    ``slow_factor``, ``harvest_discount``, ``beta``), checked against the
    scheduler's signature at build time.

    ``track_availability=True`` additionally attaches an
    :class:`~repro.fl.availability.AvailabilityTracker` (knobs:
    ``avail_decay``/``avail_threshold``/``late_credit``) to the server —
    and to the sampler when it is store-backed, restricting plan rebuilds
    to recently-seen clients. The default spec — sync, no options, no
    tracking — attaches *nothing*: batch experiments stay on the exact
    pre-scheduler code path.
    """

    name: str = "sync"
    seed: int = 0
    options: dict = dataclasses.field(default_factory=dict)
    track_availability: bool = False
    avail_decay: float = 0.9
    avail_threshold: float = 0.25
    late_credit: float = 0.5

    @property
    def is_default(self) -> bool:
        return self.name == "sync" and not self.options and not self.track_availability

    @classmethod
    def from_dict(cls, d: dict) -> "SchedulerSpec":
        return _from_dict(cls, d)

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "seed": self.seed,
            "options": dict(self.options),
            "track_availability": self.track_availability,
            "avail_decay": self.avail_decay,
            "avail_threshold": self.avail_threshold,
            "late_credit": self.late_credit,
        }


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    """Round/optimization hyperparameters + the paper's MLP shape.

    ``n_classes=None`` infers the class count from the dataset's labels;
    ``hidden`` are the MLP's hidden widths (the paper's 1×50 by default).
    """

    n_rounds: int = 10
    n_local_steps: int = 10  # N in the paper
    batch_size: int = 50  # B in the paper
    lr: float = 0.05
    momentum: float = 0.0
    fedprox_mu: float = 0.0
    eval_every: int = 1
    seed: int = 0
    hidden: tuple = (50,)
    n_classes: Optional[int] = None
    model_seed: int = 1
    # service cadence: checkpoint the full ServerState every k completed
    # rounds (0 = batch mode, never checkpoint). The checkpoint *path* is a
    # runtime concern — pass it to build_experiment / the fl_service driver,
    # never bake it into a spec (it would poison sweep cell identity).
    checkpoint_every: int = 0

    def __post_init__(self):
        object.__setattr__(self, "hidden", tuple(self.hidden))

    @classmethod
    def from_dict(cls, d: dict) -> "TrainSpec":
        return _from_dict(cls, d)

    def to_dict(self) -> dict:
        out = dataclasses.asdict(self)
        out["hidden"] = list(self.hidden)
        return out


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """The whole experiment as one declarative value."""

    data: DataSpec
    sampler: SamplerSpec
    planner: PlannerSpec = PlannerSpec()
    engine: EngineSpec = EngineSpec()
    train: TrainSpec = TrainSpec()
    population: PopulationSpec = PopulationSpec()
    scheduler: SchedulerSpec = SchedulerSpec()

    _NESTED = {
        "data": DataSpec,
        "sampler": SamplerSpec,
        "planner": PlannerSpec,
        "engine": EngineSpec,
        "train": TrainSpec,
        "population": PopulationSpec,
        "scheduler": SchedulerSpec,
    }

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        return _from_dict(cls, d, nested=cls._NESTED)

    def to_dict(self) -> dict:
        return {name: getattr(self, name).to_dict() for name in self._NESTED}

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))

    @classmethod
    def from_arg(cls, arg: str) -> "ExperimentSpec":
        """Parse a CLI ``--spec`` argument: inline JSON or a JSON file path."""
        return cls.from_dict(load_spec_dict(arg))

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    def build(self, **kw) -> FederatedServer:
        """Alias for :func:`build_experiment` (``spec.build()``)."""
        return build_experiment(self, **kw)


def load_spec_dict(arg: str) -> dict:
    """Read a CLI spec argument — a path to a JSON file, else inline JSON.

    The one place the path-vs-inline disambiguation lives; both
    ``benchmarks.run --spec`` and ``dryrun_fl --spec`` parse through it.
    """
    import os

    raw = open(arg).read() if os.path.exists(arg) else arg
    try:
        d = json.loads(raw)
    except json.JSONDecodeError as e:
        raise ValueError(
            f"--spec argument is neither an existing file nor valid JSON "
            f"({e}); got: {arg[:120]!r}"
        ) from None
    if not isinstance(d, dict):
        raise ValueError(f"--spec JSON must be an object, got {type(d).__name__}")
    return d


# --------------------------------------------------------------------------
# builders
# --------------------------------------------------------------------------
def _checked_kwargs(kind: str, name: str, factory, options: dict) -> inspect.Signature:
    """Validate ``options`` keys against ``factory``'s signature; return it."""
    sig = inspect.signature(factory)
    params = sig.parameters
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()):
        return sig
    accepted = set(params) - {"self", "population", "m"}
    unknown = set(options) - accepted
    if unknown:
        raise ValueError(
            f"{kind} {name!r} does not accept option(s) {sorted(unknown)}; "
            f"accepted options: {sorted(accepted)}"
        )
    return sig


def build_dataset(spec: Union[DataSpec, dict]) -> FederatedDataset:
    """Resolve a :class:`DataSpec` through :data:`DATASETS` and build it."""
    spec = DataSpec.from_dict(spec) if isinstance(spec, dict) else spec
    factory = DATASETS.get(spec.name)
    _checked_kwargs("dataset", spec.name, factory, spec.options)
    return factory(**spec.options)


def build_sampler(
    spec: Union[SamplerSpec, dict],
    population,
    *,
    planner: Optional[PlannerSpec] = None,
    update_dim: Optional[int] = None,
    store_mesh_spec=None,
):
    """Resolve a :class:`SamplerSpec` through ``SAMPLERS`` and construct it.

    ``planner`` feeds the scheme's plan service (only schemes that take a
    ``planner`` kwarg accept a non-default one); ``update_dim`` is the
    flattened model size handed to similarity-based schemes unless the spec
    pins its own in ``options``. ``store_mesh_spec`` (the engine's mesh, in
    practice) shards the scheme's gradient store over its client axis when
    the scheme has one — silently skipped otherwise, since the mesh is an
    engine knob rather than a sampling-scheme choice.
    """
    spec = SamplerSpec.from_dict(spec) if isinstance(spec, dict) else spec
    cls = SAMPLERS.get(spec.name)
    kwargs = dict(spec.options)
    sig = _checked_kwargs("sampler", spec.name, cls, kwargs)
    params = sig.parameters
    if "groups" in kwargs:  # JSON carries lists; samplers want index arrays
        kwargs["groups"] = [np.asarray(g, dtype=np.int64) for g in kwargs["groups"]]
    if "seed" in params:
        kwargs.setdefault("seed", spec.seed)
    if planner is not None:
        if "planner" in params:
            kwargs.setdefault("planner", planner.mode)
            if "rebuild_every" in params:
                kwargs.setdefault("rebuild_every", planner.rebuild_every)
            if "clusterer" in params:
                kwargs.setdefault("clusterer", planner.clusterer)
            elif planner.clusterer != "ward":
                raise ValueError(
                    f"sampler {spec.name!r} accepts no clusterer; "
                    f"PlannerSpec.clusterer={planner.clusterer!r} would be "
                    "silently ignored"
                )
            if "drift_threshold" in params:
                kwargs.setdefault("drift_threshold", planner.drift_threshold)
            elif planner.drift_threshold is not None:
                raise ValueError(
                    f"sampler {spec.name!r} accepts no drift_threshold; "
                    f"PlannerSpec.drift_threshold={planner.drift_threshold} "
                    "would be silently ignored"
                )
            if "sketch" in params:
                kwargs.setdefault("sketch", planner.sketch)
                if "sketch_dim" in params:
                    kwargs.setdefault("sketch_dim", planner.sketch_dim)
            elif planner.sketch is not None:
                raise ValueError(
                    f"sampler {spec.name!r} has no gradient-store sketch "
                    f"stage; PlannerSpec.sketch={planner.sketch!r} would be "
                    "silently ignored"
                )
        elif not planner.is_default:
            raise ValueError(
                f"sampler {spec.name!r} has no plan service; a non-default "
                f"PlannerSpec ({planner.to_dict()}) would be silently ignored "
                "— drop it or pick a plan-rebuilding sampler"
            )
    if "update_dim" in params and "update_dim" not in kwargs:
        if update_dim is None:
            raise ValueError(
                f"sampler {spec.name!r} needs update_dim (the flattened model "
                "size its gradient store holds); pass update_dim=... to "
                "build_sampler or set it in SamplerSpec.options"
            )
        kwargs["update_dim"] = int(update_dim)
    if store_mesh_spec is not None and "store_mesh_spec" in params:
        kwargs.setdefault("store_mesh_spec", store_mesh_spec)
    return cls(population, spec.m, **kwargs)


def _infer_n_classes(dataset: FederatedDataset) -> int:
    return int(max(int(c.y_train.max()) for c in dataset.clients)) + 1


def build_experiment(
    spec: Union[ExperimentSpec, dict],
    *,
    dataset: Optional[FederatedDataset] = None,
    loss_fn: Optional[Callable] = None,
    acc_fn: Optional[Callable] = None,
    checkpoint_path: Optional[str] = None,
) -> FederatedServer:
    """Build the lifecycle-safe server an :class:`ExperimentSpec` describes.

    ``dataset`` short-circuits :func:`build_dataset` so scenario matrices
    sharing one partition build it once. The returned server owns the
    sampler's background resources — run it under ``with`` (or call
    ``close()``) so async planner workers never leak. ``loss_fn``/``acc_fn``
    override the defaults (FedProx is selected automatically when
    ``train.fedprox_mu > 0``). ``checkpoint_path`` is where the service
    cadence (``train.checkpoint_every``) writes ServerState bundles — a
    runtime knob, deliberately not part of the spec.
    """
    from repro.fl.aggregation import flatten_params
    from repro.fl.population import build_population
    from repro.models.simple import accuracy, classification_loss, fedprox_loss, init_mlp
    from repro.optim import sgd

    spec = ExperimentSpec.from_dict(spec) if isinstance(spec, dict) else spec
    ds = dataset if dataset is not None else build_dataset(spec.data)
    tr = spec.train
    feat_shape = ds.clients[0].x_train.shape[1:]
    if len(feat_shape) != 1:
        raise ValueError(
            f"build_experiment's MLP needs flat (n, d) client features, got "
            f"per-sample shape {feat_shape}; pass a custom server for image data"
        )
    n_classes = tr.n_classes if tr.n_classes is not None else _infer_n_classes(ds)
    params = init_mlp((int(feat_shape[0]), *tr.hidden, n_classes), seed=tr.model_seed)
    update_dim = int(flatten_params(params).shape[0])
    sampler = build_sampler(
        spec.sampler,
        ds.population,
        planner=spec.planner,
        update_dim=update_dim,
        store_mesh_spec=spec.engine.mesh_spec,
    )
    cfg = FLConfig(
        n_rounds=tr.n_rounds,
        n_local_steps=tr.n_local_steps,
        batch_size=tr.batch_size,
        fedprox_mu=tr.fedprox_mu,
        eval_every=tr.eval_every,
        seed=tr.seed,
        engine=spec.engine.name,
        max_staged_bytes=spec.engine.max_staged_bytes,
        mesh_spec=spec.engine.mesh_spec,
        checkpoint_every=tr.checkpoint_every,
        checkpoint_path=checkpoint_path,
    )
    # the default spec attaches no process at all: batch experiments stay on
    # the exact fixed-population code path (n_available=-1 telemetry included)
    pop = (
        None
        if spec.population.is_default
        else build_population(spec.population, ds.population.n_clients)
    )
    # same pattern for the round scheduler / availability tracker: the
    # default sync-untracked spec attaches neither, keeping the exact
    # legacy round path (and checkpoint layout)
    scheduler = availability = None
    sched = spec.scheduler
    if not sched.is_default:
        from repro.fl.availability import AvailabilityTracker
        from repro.fl.scheduler import build_scheduler

        if sched.name != "sync" or sched.options:
            scheduler = build_scheduler(
                sched, n_clients=ds.population.n_clients, m=spec.sampler.m
            )
        if sched.track_availability:
            availability = AvailabilityTracker(
                ds.population.n_clients,
                decay=sched.avail_decay,
                threshold=sched.avail_threshold,
                late_credit=sched.late_credit,
            )
            if hasattr(sampler, "attach_availability"):
                sampler.attach_availability(availability)
    lf = loss_fn if loss_fn is not None else (fedprox_loss if tr.fedprox_mu else classification_loss)
    af = acc_fn if acc_fn is not None else accuracy
    return FederatedServer(
        ds, sampler, params, sgd(tr.lr, tr.momentum), cfg, loss_fn=lf, acc_fn=af,
        population=pop, scheduler=scheduler, availability=availability,
    )


__all__ = [
    "DataSpec",
    "SamplerSpec",
    "PlannerSpec",
    "EngineSpec",
    "TrainSpec",
    "PopulationSpec",
    "SchedulerSpec",
    "ExperimentSpec",
    "DATASETS",
    "register_dataset",
    "load_spec_dict",
    "build_dataset",
    "build_sampler",
    "build_experiment",
]
