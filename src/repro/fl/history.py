"""Per-round FL run telemetry.

Serialization round-trips: ``RoundRecord.to_dict``/``from_dict`` and
``History.to_json``/``from_json`` are exact inverses — ``agg_weights``
survives as an optional JSON list of f64 (f64 → repr → f64 is lossless),
so the sweep layer's :class:`~repro.fl.sweep.RunStore` can persist one
record per JSONL line and rebuild the identical ``History`` on read.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np


@dataclasses.dataclass
class RoundRecord:
    round: int
    train_loss: float  # global federated loss (eq. 1) or local-mean proxy
    test_acc: float
    n_distinct_clients: int
    n_distinct_classes: int
    agg_weights: np.ndarray | None = None
    # planner telemetry: version of the sampling plan this round drew from,
    # and how many observed rounds it trailed by (0 under the sync planner;
    # >= 0 when re-clustering overlaps client local work, see fl.planner)
    plan_version: int = 0
    plan_lag_rounds: int = 0
    # rebuild-cost telemetry (plan-rebuilding samplers only): wall-clock ms
    # of the most recent completed plan build, and the drift statistic the
    # planner measured this round (assignment churn in [0, 1], or inf when
    # unmeasurable). -1.0 = not applicable (plan-free sampler / drift
    # trigger disabled).
    plan_build_ms: float = -1.0
    plan_drift: float = -1.0
    # continuous-service telemetry (see repro.fl.population): how many
    # clients the availability mask admitted this round (-1 = no population
    # process, the paper's fixed-n behaviour), how many realized
    # participants vanished mid-round / straggled past the deadline, and
    # the round's resolution: "ok" (everyone reported), "degraded" (>= 1
    # drop, the survivors' zero-weight-slot aggregation went through) or
    # "empty" (a skipped EmptyRound under a service driver's skip policy)
    n_available: int = -1
    n_dropped: int = 0
    # round-scheduler telemetry (see repro.fl.scheduler): participants that
    # straggled past the deadline (plus overselection draws discarded at
    # draw time), and late updates harvested into this round's gradient
    # store from the previous round's stragglers
    n_late: int = 0
    n_harvested: int = 0
    # availability-tracker telemetry: the fleet's weakest presence score
    # after this round's fold (-1.0 = no tracker attached)
    avail_score_min: float = -1.0
    round_status: str = "ok"

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if self.agg_weights is not None:
            d["agg_weights"] = np.asarray(self.agg_weights, dtype=np.float64).tolist()
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "RoundRecord":
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - fields
        if unknown:
            raise ValueError(
                f"RoundRecord.from_dict: unknown key(s) {sorted(unknown)}; "
                f"accepted keys: {sorted(fields)}"
            )
        kw = dict(d)
        if kw.get("agg_weights") is not None:
            kw["agg_weights"] = np.asarray(kw["agg_weights"], dtype=np.float64)
        return cls(**kw)


@dataclasses.dataclass
class History:
    records: list[RoundRecord] = dataclasses.field(default_factory=list)

    def append(self, rec: RoundRecord) -> None:
        self.records.append(rec)

    def series(self, field: str) -> np.ndarray:
        return np.array([getattr(r, field) for r in self.records])

    def rolling(self, field: str, window: int = 50) -> np.ndarray:
        """Rolling mean, as used for the paper's training-loss figures."""
        x = self.series(field)
        if len(x) < 1:
            return x
        kernel = np.ones(min(window, len(x))) / min(window, len(x))
        return np.convolve(x, kernel, mode="valid")

    def to_json(self, *, include_agg_weights: bool = True) -> str:
        recs = [r.to_dict() for r in self.records]
        if not include_agg_weights:
            for d in recs:
                d.pop("agg_weights", None)
        return json.dumps(recs)

    @classmethod
    def from_json(cls, s: str) -> "History":
        recs = json.loads(s)
        if not isinstance(recs, list):
            raise ValueError(f"History.from_json expects a JSON list, got {type(recs).__name__}")
        return cls(records=[RoundRecord.from_dict(d) for d in recs])
