"""Per-round FL run telemetry."""
from __future__ import annotations

import dataclasses
import json

import numpy as np


@dataclasses.dataclass
class RoundRecord:
    round: int
    train_loss: float  # global federated loss (eq. 1) or local-mean proxy
    test_acc: float
    n_distinct_clients: int
    n_distinct_classes: int
    agg_weights: np.ndarray | None = None
    # planner telemetry: version of the sampling plan this round drew from,
    # and how many observed rounds it trailed by (0 under the sync planner;
    # >= 0 when re-clustering overlaps client local work, see fl.planner)
    plan_version: int = 0
    plan_lag_rounds: int = 0


@dataclasses.dataclass
class History:
    records: list[RoundRecord] = dataclasses.field(default_factory=list)

    def append(self, rec: RoundRecord) -> None:
        self.records.append(rec)

    def series(self, field: str) -> np.ndarray:
        return np.array([getattr(r, field) for r in self.records])

    def rolling(self, field: str, window: int = 50) -> np.ndarray:
        """Rolling mean, as used for the paper's training-loss figures."""
        x = self.series(field)
        if len(x) < 1:
            return x
        kernel = np.ones(min(window, len(x))) / min(window, len(x))
        return np.convolve(x, kernel, mode="valid")

    def to_json(self) -> str:
        return json.dumps(
            [
                {k: v for k, v in dataclasses.asdict(r).items() if k != "agg_weights"}
                for r in self.records
            ]
        )
