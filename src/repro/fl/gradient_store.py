"""Device-resident representative-gradient store for Algorithm 2.

The seed sampler kept ``G`` as a host (n, d) f64 array and required every
round's ``θ_i^{t+1} − θ^t`` updates to round-trip through the host before
re-clustering. This store keeps ``G`` as an f32 device buffer and folds the
per-round feedback in as a *scatter*:

* the batched engine's ``updates_flat`` output is a device array — it goes
  straight into ``G.at[ids].set(...)`` with no host copy and no f64 cast;
* staleness decay (the beyond-paper age-out of clients not sampled for many
  rounds) is a device multiply fused into the same jitted update;
* padded / invalid slots are handled by the scatter itself: any id >=
  ``n_clients`` is dropped (``mode="drop"``), so callers can pass a
  fixed-shape slot block with sentinel ids instead of slicing on host.
  Duplicate ids are **last-write-wins** on both backends — the jax path
  scatters host-deduplicated rows and the numpy path assigns them, so the
  semantics are pinned rather than left to backend scatter ordering.

Scaling past sampler-sized models is the *sketch* stage (``sketch=...``):
the engine's (c, d) device updates are compressed to (c, d') by a
:data:`repro.kernels.sketch.SKETCHERS` entry **before** scatter, so the
resident buffer is (n, d') f32 and every downstream consumer — the fused
similarity kernel's d-grid, the jitted clusterers, the drift monitor's
centroids — shrinks by d/d'. ``sketch="identity"`` keeps today's exact
path bit-for-bit; ``sketch=None`` (default) attaches no sketch stage at
all. With ``mesh_spec`` the store's client axis is sharded over the mesh's
batch axes (the PR 2 engine mesh), the scatter is sharding-constrained in
place, and :meth:`gather_rows` all-gathers only the rows a rebuild
actually touches.

jax arrays are immutable, so :meth:`snapshot` is O(1) and yields a
consistent view even while an async planner worker reads it concurrently
with the next round's scatter (see ``repro.fl.planner``).

jax is imported lazily; ``backend="numpy"`` (or jax being absent) selects a
host f32 fallback with identical semantics (sketches run through their
numpy reference), keeping ``repro.core`` samplers constructible in
jax-free environments.
"""
from __future__ import annotations

from typing import Optional, Union

import numpy as np


def _jnp():
    try:
        import jax  # noqa: F401
        import jax.numpy as jnp
    except ImportError:
        return None
    return jnp


def _dedupe_last(ids: np.ndarray) -> np.ndarray:
    """Indices of the *last* occurrence of each id, in stable id-order.

    Pins last-write-wins for duplicate client ids independent of either
    backend's scatter ordering. Returns ``slice(None)`` (no-op indexer)
    when ids are already unique, so the common path — the server feeds the
    round's *distinct* clients — keeps its array shapes (and jit cache
    keys) untouched.
    """
    uniq, last_of_reversed = np.unique(ids[::-1], return_index=True)
    if uniq.size == ids.size:
        return slice(None)
    return ids.size - 1 - last_of_reversed


class GradientStore:
    """(n_clients, dim) f32 buffer of latest representative gradients.

    ``dim`` is the *resident* width: ``update_dim`` when no sketch (or the
    identity sketch) is attached, the sketcher's ``d_out`` otherwise.
    ``update`` implements exactly the seed sampler's semantics: decay the
    whole buffer by ``staleness_decay`` (1.0 = paper behaviour, a no-op),
    sketch the incoming rows, then overwrite the observed clients' rows.
    """

    def __init__(
        self,
        n_clients: int,
        update_dim: int,
        *,
        staleness_decay: float = 1.0,
        backend: str = "auto",
        sketch: Union[str, None, object] = None,
        sketch_dim: Optional[int] = None,
        sketch_seed: int = 0,
        mesh_spec=None,
    ):
        if backend not in ("auto", "jax", "numpy"):
            raise ValueError(f"unknown gradient-store backend {backend!r}")
        from repro.kernels.sketch.ops import resolve_sketcher

        self.n_clients = int(n_clients)
        self.update_dim = int(update_dim)
        self.staleness_decay = float(staleness_decay)
        self.sketch = resolve_sketcher(
            sketch, self.update_dim, sketch_dim, seed=sketch_seed
        )
        #: resident row width — d' under a compressing sketch, d otherwise
        self.dim = self.update_dim if self.sketch is None else self.sketch.d_out
        jnp = _jnp() if backend in ("auto", "jax") else None
        if backend == "jax" and jnp is None:
            raise RuntimeError("gradient-store backend 'jax' requires jax")
        self._jnp = jnp
        self._mesh = None
        self._sharding = None
        if jnp is not None:
            import jax

            if mesh_spec is not None:
                from jax.sharding import NamedSharding, PartitionSpec as P

                from repro.launch.mesh import (
                    data_parallel_degree,
                    leading_batch_spec,
                    resolve_fl_mesh,
                )

                mesh = resolve_fl_mesh(mesh_spec)
                if mesh is not None:
                    self._mesh = mesh
                    # shard the client axis only when it divides the mesh's
                    # data-parallel degree (the engine's staging convention);
                    # replicate otherwise rather than erroring
                    if self.n_clients % data_parallel_degree(mesh) == 0:
                        self._sharding = NamedSharding(
                            mesh, leading_batch_spec(mesh, 2)
                        )
                    else:
                        self._sharding = NamedSharding(mesh, P())
                    self._replicated = NamedSharding(mesh, P())

            sharding = self._sharding

            def scatter(G, ids, vals):
                if self.staleness_decay < 1.0:
                    G = G * np.float32(self.staleness_decay)
                G = G.at[ids].set(vals.astype(jnp.float32), mode="drop")
                if sharding is not None:
                    G = jax.lax.with_sharding_constraint(G, sharding)
                return G

            def scatter_plain(G, ids, vals, scale):
                # decay-free variant: overwrite rows with scale·vals and leave
                # the rest of the buffer untouched (harvest replays must not
                # age the whole fleet a second time)
                G = G.at[ids].set(vals.astype(jnp.float32) * scale, mode="drop")
                if sharding is not None:
                    G = jax.lax.with_sharding_constraint(G, sharding)
                return G

            def gather(G, ids):
                rows = jnp.take(G, ids, axis=0)
                if sharding is not None:
                    rows = jax.lax.with_sharding_constraint(rows, self._replicated)
                return rows

            self._scatter = jax.jit(scatter)
            self._scatter_plain = jax.jit(scatter_plain)
            self._gather = jax.jit(gather)
            G0 = jnp.zeros((self.n_clients, self.dim), jnp.float32)
            self._G = (
                jax.device_put(G0, self._sharding) if self._sharding is not None else G0
            )
        else:
            if mesh_spec is not None:
                raise RuntimeError(
                    "GradientStore(mesh_spec=...) needs the jax backend; the "
                    "numpy fallback has no device mesh to shard over"
                )
            self._scatter = None
            self._G = np.zeros((self.n_clients, self.dim), np.float32)

    @property
    def nbytes(self) -> int:
        """Resident bytes of the (n_clients, dim) f32 buffer."""
        return self.n_clients * self.dim * 4

    def _apply_sketch(self, updates):
        if self.sketch is None:
            return updates
        if self._jnp is None:
            return self.sketch.reference(updates)
        return self.sketch(updates)

    def update(self, client_ids, updates) -> None:
        """Scatter ``updates`` (c, update_dim) into rows ``client_ids`` (c,).

        ``updates`` may be a device array (the engine's round output) or
        numpy; the sketch stage (if any) runs on it *before* scatter, on
        device for device inputs. Ids at or beyond ``n_clients`` are
        dropped, which is how fixed-shape padded slot blocks mark unused
        rows; duplicate ids resolve last-write-wins on both backends.
        """
        if tuple(updates.shape)[1:] != (self.update_dim,):
            raise ValueError(
                f"updates shape {tuple(updates.shape)} != (len(ids), {self.update_dim})"
            )
        if len(client_ids) != updates.shape[0]:
            raise ValueError(
                f"{len(client_ids)} ids for {updates.shape[0]} update rows"
            )
        if self._jnp is not None:
            ids = np.asarray(client_ids, np.int32)
            take = _dedupe_last(ids)
            vals = self._apply_sketch(self._jnp.asarray(updates))
            if not isinstance(take, slice):
                ids, vals = ids[take], vals[np.asarray(take)]
            self._G = self._scatter(self._G, self._jnp.asarray(ids), vals)
        else:
            ids = np.asarray(client_ids, np.int64)
            vals = np.asarray(self._apply_sketch(np.asarray(updates)), np.float32)
            take = _dedupe_last(ids)
            if not isinstance(take, slice):
                ids, vals = ids[take], vals[take]
            keep = ids < self.n_clients
            if self.staleness_decay < 1.0:
                self._G = self._G * np.float32(self.staleness_decay)
            self._G[ids[keep]] = vals[keep]

    def scatter_scaled(self, client_ids, updates, *, scale: float = 1.0) -> None:
        """Overwrite rows ``client_ids`` with ``scale · updates`` — no decay.

        The harvest-replay path (``DeadlineScheduler``): a straggler's update
        delivered after the deadline lands in the *next* round's store,
        discounted by ``scale``, without re-applying the whole-buffer
        staleness decay that :meth:`update` already charged this round.
        Sketching, id-dropping and last-write-wins semantics match
        :meth:`update` exactly; the scale multiplies the sketched rows (the
        sketches are linear, so the order is immaterial).
        """
        if tuple(updates.shape)[1:] != (self.update_dim,):
            raise ValueError(
                f"updates shape {tuple(updates.shape)} != (len(ids), {self.update_dim})"
            )
        if len(client_ids) != updates.shape[0]:
            raise ValueError(
                f"{len(client_ids)} ids for {updates.shape[0]} update rows"
            )
        if len(client_ids) == 0:
            return
        if self._jnp is not None:
            ids = np.asarray(client_ids, np.int32)
            take = _dedupe_last(ids)
            vals = self._apply_sketch(self._jnp.asarray(updates))
            if not isinstance(take, slice):
                ids, vals = ids[take], vals[np.asarray(take)]
            self._G = self._scatter_plain(
                self._G, self._jnp.asarray(ids), vals, np.float32(scale)
            )
        else:
            ids = np.asarray(client_ids, np.int64)
            vals = np.asarray(self._apply_sketch(np.asarray(updates)), np.float32)
            take = _dedupe_last(ids)
            if not isinstance(take, slice):
                ids, vals = ids[take], vals[take]
            keep = ids < self.n_clients
            self._G[ids[keep]] = vals[keep] * np.float32(scale)

    def snapshot(self):
        """The current G — an immutable device array (or a numpy copy)."""
        return self._G if self._jnp is not None else self._G.copy()

    def gather_rows(self, client_ids):
        """Only the requested rows, replicated across the mesh.

        The sharded-store read path for partial rebuilds: a rebuild that
        touches ``c`` rows all-gathers (c, dim) — not the whole (n, dim)
        buffer — across the client-axis shards. Without a mesh this is a
        plain device (or host) row gather.
        """
        if self._jnp is not None:
            ids = self._jnp.asarray(np.asarray(client_ids, np.int32))
            return self._gather(self._G, ids)
        return self._G[np.asarray(client_ids, np.int64)].copy()

    def load(self, G) -> None:
        """Replace the buffer with a checkpointed (n_clients, dim) state.

        Device arrays are adopted *directly* — no host round-trip — after a
        dtype check (a large sketched store must restore where it lives);
        host arrays are cast to f32 as before. Under a mesh the restored
        buffer is re-placed onto the store's client-axis sharding.
        """
        if tuple(G.shape) != (self.n_clients, self.dim):
            raise ValueError(
                f"checkpointed G shape {tuple(G.shape)} != "
                f"({self.n_clients}, {self.dim})"
            )
        if self._jnp is not None and not isinstance(G, np.ndarray):
            import jax

            G = self._jnp.asarray(G)  # no-op for device arrays
            if G.dtype != self._jnp.float32:
                raise ValueError(
                    f"device-resident G must be float32, got {G.dtype}; cast "
                    "on device (or pass a host array) before load()"
                )
            self._G = (
                jax.device_put(G, self._sharding)
                if self._sharding is not None
                else G
            )
            return
        G = np.asarray(G, np.float32)
        if self._jnp is None:
            self._G = G.copy()
            return
        import jax

        dev = self._jnp.asarray(G)
        self._G = (
            jax.device_put(dev, self._sharding) if self._sharding is not None else dev
        )

    def asnumpy(self) -> np.ndarray:
        """Host f32 copy, for inspection and host-side reference builds."""
        return np.asarray(self._G)
