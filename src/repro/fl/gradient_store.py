"""Device-resident representative-gradient store for Algorithm 2.

The seed sampler kept ``G`` as a host (n, d) f64 array and required every
round's ``θ_i^{t+1} − θ^t`` updates to round-trip through the host before
re-clustering. This store keeps ``G`` as an f32 device buffer and folds the
per-round feedback in as a *scatter*:

* the batched engine's ``updates_flat`` output is a device array — it goes
  straight into ``G.at[ids].set(...)`` with no host copy and no f64 cast;
* staleness decay (the beyond-paper age-out of clients not sampled for many
  rounds) is a device multiply fused into the same jitted update;
* padded / invalid slots are handled by the scatter itself: any id >=
  ``n_clients`` is dropped (``mode="drop"``), so callers can pass a
  fixed-shape slot block with sentinel ids instead of slicing on host.

jax arrays are immutable, so :meth:`snapshot` is O(1) and yields a
consistent view even while an async planner worker reads it concurrently
with the next round's scatter (see ``repro.fl.planner``).

jax is imported lazily; ``backend="numpy"`` (or jax being absent) selects a
host f32 fallback with identical semantics, keeping ``repro.core`` samplers
constructible in jax-free environments.
"""
from __future__ import annotations

import numpy as np


def _jnp():
    try:
        import jax  # noqa: F401
        import jax.numpy as jnp
    except ImportError:
        return None
    return jnp


class GradientStore:
    """(n_clients, d) f32 buffer of latest representative gradients.

    ``update`` implements exactly the seed sampler's semantics: decay the
    whole buffer by ``staleness_decay`` (1.0 = paper behaviour, a no-op),
    then overwrite the observed clients' rows.
    """

    def __init__(
        self,
        n_clients: int,
        update_dim: int,
        *,
        staleness_decay: float = 1.0,
        backend: str = "auto",
    ):
        if backend not in ("auto", "jax", "numpy"):
            raise ValueError(f"unknown gradient-store backend {backend!r}")
        self.n_clients = int(n_clients)
        self.update_dim = int(update_dim)
        self.staleness_decay = float(staleness_decay)
        jnp = _jnp() if backend in ("auto", "jax") else None
        if backend == "jax" and jnp is None:
            raise RuntimeError("gradient-store backend 'jax' requires jax")
        self._jnp = jnp
        if jnp is not None:
            import jax

            def scatter(G, ids, vals):
                if self.staleness_decay < 1.0:
                    G = G * np.float32(self.staleness_decay)
                return G.at[ids].set(vals.astype(jnp.float32), mode="drop")

            self._scatter = jax.jit(scatter)
            self._G = jnp.zeros((self.n_clients, self.update_dim), jnp.float32)
        else:
            self._scatter = None
            self._G = np.zeros((self.n_clients, self.update_dim), np.float32)

    def update(self, client_ids, updates) -> None:
        """Scatter ``updates`` (c, d) into rows ``client_ids`` (c,).

        ``updates`` may be a device array (the engine's round output) or
        numpy; ids at or beyond ``n_clients`` are dropped, which is how
        fixed-shape padded slot blocks mark unused rows.
        """
        if tuple(updates.shape)[1:] != (self.update_dim,):
            raise ValueError(
                f"updates shape {tuple(updates.shape)} != (len(ids), {self.update_dim})"
            )
        if len(client_ids) != updates.shape[0]:
            raise ValueError(
                f"{len(client_ids)} ids for {updates.shape[0]} update rows"
            )
        if self._jnp is not None:
            ids = self._jnp.asarray(np.asarray(client_ids, np.int32))
            self._G = self._scatter(self._G, ids, self._jnp.asarray(updates))
        else:
            ids = np.asarray(client_ids, np.int64)
            keep = ids < self.n_clients
            if self.staleness_decay < 1.0:
                self._G = self._G * np.float32(self.staleness_decay)
            self._G[ids[keep]] = np.asarray(updates, np.float32)[keep]

    def snapshot(self):
        """The current G — an immutable device array (or a numpy copy)."""
        return self._G if self._jnp is not None else self._G.copy()

    def load(self, G) -> None:
        """Replace the buffer with a checkpointed (n_clients, d) f32 state."""
        G = np.asarray(G, np.float32)
        if G.shape != (self.n_clients, self.update_dim):
            raise ValueError(
                f"checkpointed G shape {G.shape} != "
                f"({self.n_clients}, {self.update_dim})"
            )
        self._G = self._jnp.asarray(G) if self._jnp is not None else G.copy()

    def asnumpy(self) -> np.ndarray:
        """Host f32 copy, for inspection and host-side reference builds."""
        return np.asarray(self._G)
