"""Client-population processes: who can participate in each round.

The paper assumes a fixed population of ``n`` clients that all answer every
round. Production FL does not: clients arrive, vanish mid-round, and report
late. This module models that as a *population process* layered on top of
the static :class:`repro.core.types.ClientPopulation` (which stays the
source of the ``n_i`` sample counts): per round ``t`` the process yields

* an **availability mask** — which of the ``n`` registered clients can be
  drawn at all this round (the sampler conditions its draw on it, see
  ``ClientSampler.sample(t, available=...)``), and
* a **dropout mask** over the round's realized participants — which of them
  vanish *mid-round* (crash, network loss) or exceed the straggler timeout.
  A dropped client becomes a zero-weight slot in the engine's padded slot
  axis and its eq. 3/4 mass falls back on the current global model.

Determinism contract: every mask is a pure function of ``(seed, t)`` —
processes derive a fresh per-round generator from
``np.random.SeedSequence((seed, tag, t))`` and state-carrying processes
(the Poisson churn chain) replay deterministically from round 0 through an
internal cache. A killed server therefore resumes mid-campaign with the
*identical* availability/dropout realizations without the process ever
appearing in the checkpoint.

Scenario generators are registry entries (:data:`POPULATIONS` /
:func:`register_population`) so ``PopulationSpec`` sections on
:class:`~repro.fl.experiment.ExperimentSpec` — and therefore
:class:`~repro.fl.sweep.SweepSpec` axes — reach them by name:

* ``static``   — everyone always available (optional drop/straggle rates),
* ``poisson``  — discretized Poisson arrival/departure: each client is an
  on/off Markov chain with per-round join/leave probabilities,
* ``periodic`` — diurnal-style availability windows (period/duty/phase),
* ``dropout``  — full availability, Bernoulli mid-round dropout + straggler
  timeout (the classic "x% of participants fail" stress model).
"""
from __future__ import annotations

import abc

import numpy as np

from repro.core.registry import Registry

# SeedSequence stream tags: availability, dropout and static phase draws
# come from disjoint streams, so changing one scenario knob never shifts
# the others.
_AVAIL_TAG = 0x41
_DROP_TAG = 0x44
_PHASE_TAG = 0x50


def _round_rng(seed: int, tag: int, t: int) -> np.random.Generator:
    """The (seed, tag, t)-keyed generator behind the determinism contract."""
    return np.random.default_rng(np.random.SeedSequence((int(seed), tag, int(t))))


class PopulationProcess(abc.ABC):
    """Round-indexed availability + mid-round dropout over ``n_clients``.

    Subclasses implement :meth:`_availability` only; the Bernoulli mid-round
    dropout and straggler-timeout machinery is shared (every scenario can be
    combined with them). ``drop_rate`` is the per-participant probability of
    vanishing mid-round; ``straggle_rate`` the probability of exceeding the
    round deadline — both resolve to the same fate (a zero-weight slot) but
    are drawn from one stream in that order, so the split is reproducible.
    """

    def __init__(
        self,
        n_clients: int,
        *,
        seed: int = 0,
        drop_rate: float = 0.0,
        straggle_rate: float = 0.0,
    ):
        if n_clients <= 0:
            raise ValueError("n_clients must be positive")
        for name, rate in (("drop_rate", drop_rate), ("straggle_rate", straggle_rate)):
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {rate}")
        self.n_clients = int(n_clients)
        self.seed = int(seed)
        self.drop_rate = float(drop_rate)
        self.straggle_rate = float(straggle_rate)

    # -- availability --------------------------------------------------------
    @abc.abstractmethod
    def _availability(self, t: int) -> np.ndarray:
        """Boolean (n,) mask of clients able to participate in round ``t``."""

    def available_mask(self, t: int) -> np.ndarray:
        """The round-``t`` availability mask (deterministic in (seed, t))."""
        mask = np.asarray(self._availability(int(t)), dtype=bool)
        if mask.shape != (self.n_clients,):
            raise ValueError(
                f"{type(self).__name__} produced mask shape {mask.shape}, "
                f"expected ({self.n_clients},)"
            )
        return mask

    # -- mid-round dropout ---------------------------------------------------
    def dropout_mask(self, t: int, client_ids: np.ndarray) -> np.ndarray:
        """True where the realized participant vanishes mid-round.

        ``client_ids`` are the round's *distinct* participants; the draw is
        keyed by (seed, t) and indexed by client id, so the same client has
        the same fate regardless of who else was drawn that round.
        """
        ids = np.asarray(client_ids, dtype=np.int64)
        if self.drop_rate == 0.0 and self.straggle_rate == 0.0:
            return np.zeros(ids.shape, dtype=bool)
        rng = _round_rng(self.seed, _DROP_TAG, t)
        # one (n,) draw per failure mode, indexed by id: per-client fate is
        # independent of the sampled set (a real device crashes on its own)
        crash = rng.random(self.n_clients) < self.drop_rate
        straggle = rng.random(self.n_clients) < self.straggle_rate
        return (crash | straggle)[ids]


class StaticPopulation(PopulationProcess):
    """The paper's fixed population: everyone is available every round."""

    def _availability(self, t: int) -> np.ndarray:
        del t
        return np.ones(self.n_clients, dtype=bool)


class BernoulliDropoutPopulation(StaticPopulation):
    """Full availability, Bernoulli mid-round dropout / straggler timeout.

    ``rate`` aliases ``drop_rate`` to keep the spec surface obvious:
    ``{"name": "dropout", "options": {"rate": 0.2}}``.
    """

    def __init__(
        self,
        n_clients: int,
        *,
        seed: int = 0,
        rate: float = 0.1,
        straggle_rate: float = 0.0,
    ):
        super().__init__(
            n_clients, seed=seed, drop_rate=rate, straggle_rate=straggle_rate
        )


class PoissonChurnPopulation(PopulationProcess):
    """Discretized Poisson arrival/departure churn.

    Each client is an independent on/off Markov chain: an offline client
    comes online with probability ``1 - exp(-join_rate)`` per round, an
    online one leaves with ``1 - exp(-leave_rate)``. The chain starts all-on
    (the paper's state) and is replayed deterministically from round 0, so a
    resumed server sees the identical availability trajectory; the replay is
    cached, so a service running forward pays O(n) per new round.

    ``min_available`` floors the online count — when a step would drop below
    it, the lowest-indexed clients that were online keep their session. A
    fleet where *everyone* left has nothing to train on (the server would
    raise ``EmptyRoundError``), so the floor defaults to 1.
    """

    def __init__(
        self,
        n_clients: int,
        *,
        seed: int = 0,
        join_rate: float = 0.5,
        leave_rate: float = 0.1,
        min_available: int = 1,
        drop_rate: float = 0.0,
        straggle_rate: float = 0.0,
    ):
        super().__init__(
            n_clients, seed=seed, drop_rate=drop_rate, straggle_rate=straggle_rate
        )
        if join_rate < 0 or leave_rate < 0:
            raise ValueError("join_rate / leave_rate must be >= 0")
        if not 0 <= min_available <= n_clients:
            raise ValueError(
                f"min_available must be in [0, {n_clients}], got {min_available}"
            )
        self.p_join = 1.0 - float(np.exp(-join_rate))
        self.p_leave = 1.0 - float(np.exp(-leave_rate))
        self.min_available = int(min_available)
        self._chain: list[np.ndarray] = [np.ones(self.n_clients, dtype=bool)]

    def _availability(self, t: int) -> np.ndarray:
        while len(self._chain) <= t:
            s = len(self._chain)
            prev = self._chain[-1]
            rng = _round_rng(self.seed, _AVAIL_TAG, s)
            join = rng.random(self.n_clients) < self.p_join
            leave = rng.random(self.n_clients) < self.p_leave
            cur = np.where(prev, ~leave, join)
            short = self.min_available - int(cur.sum())
            if short > 0:
                # keep the lowest-indexed previously-online clients connected
                stay = np.flatnonzero(prev & ~cur)[:short]
                cur = cur.copy()
                cur[stay] = True
            self._chain.append(cur)
        return self._chain[t]


class PeriodicAvailabilityPopulation(PopulationProcess):
    """Diurnal-style availability windows.

    Client ``i`` is online while ``(t + phase_i) mod period < duty·period``.
    Phases are staggered evenly by default (``stagger=True``) so some slice
    of the fleet is always on; ``stagger=False`` draws random phases from
    the process seed instead (synchronized outages become possible —
    ``min_available`` floors the online count the same way the churn chain
    does).
    """

    def __init__(
        self,
        n_clients: int,
        *,
        seed: int = 0,
        period: int = 10,
        duty: float = 0.5,
        stagger: bool = True,
        min_available: int = 1,
        drop_rate: float = 0.0,
        straggle_rate: float = 0.0,
    ):
        super().__init__(
            n_clients, seed=seed, drop_rate=drop_rate, straggle_rate=straggle_rate
        )
        if period < 1:
            raise ValueError(f"period must be >= 1, got {period}")
        if not 0.0 < duty <= 1.0:
            raise ValueError(f"duty must be in (0, 1], got {duty}")
        self.period = int(period)
        self.duty = float(duty)
        self.window = max(1, int(np.ceil(duty * period)))
        self.min_available = int(min_available)
        if stagger:
            self._phase = (np.arange(self.n_clients) * self.period) // max(
                self.n_clients, 1
            )
        else:
            self._phase = _round_rng(self.seed, _PHASE_TAG, 0).integers(
                0, self.period, size=self.n_clients
            )

    def _availability(self, t: int) -> np.ndarray:
        mask = ((t + self._phase) % self.period) < self.window
        short = self.min_available - int(mask.sum())
        if short > 0:
            forced = (t + np.arange(short)) % self.n_clients
            mask = mask.copy()
            mask[forced] = True
        return mask


#: name -> factory(n_clients, seed=..., **options) returning a
#: PopulationProcess; PopulationSpec sections resolve through this.
POPULATIONS = Registry(
    "population",
    {
        "static": StaticPopulation,
        "poisson": PoissonChurnPopulation,
        "periodic": PeriodicAvailabilityPopulation,
        "dropout": BernoulliDropoutPopulation,
    },
)

register_population = POPULATIONS.register


def build_population(spec, n_clients: int) -> PopulationProcess:
    """Resolve a :class:`~repro.fl.experiment.PopulationSpec` (or its dict
    form) through :data:`POPULATIONS` and construct the process."""
    import inspect

    from repro.fl.experiment import PopulationSpec

    spec = PopulationSpec.from_dict(spec) if isinstance(spec, dict) else spec
    factory = POPULATIONS.get(spec.name)
    accepted = set(inspect.signature(factory).parameters) - {"self", "n_clients", "seed"}
    unknown = set(spec.options) - accepted
    if unknown:
        raise ValueError(
            f"population {spec.name!r} does not accept option(s) {sorted(unknown)}; "
            f"accepted options: {sorted(accepted)}"
        )
    return factory(n_clients, seed=spec.seed, **spec.options)


__all__ = [
    "PopulationProcess",
    "StaticPopulation",
    "BernoulliDropoutPopulation",
    "PoissonChurnPopulation",
    "PeriodicAvailabilityPopulation",
    "POPULATIONS",
    "register_population",
    "build_population",
]
