from repro.fl.partition import by_class_shards, dirichlet_labels, PAPER_SIZE_PROFILE
from repro.fl.client import local_update, draw_batch_indices
from repro.fl.aggregation import aggregate_round, weighted_tree_sum, flatten_params
from repro.fl.server import FederatedServer, FLConfig
from repro.fl.history import History, RoundRecord

__all__ = [
    "by_class_shards",
    "dirichlet_labels",
    "PAPER_SIZE_PROFILE",
    "local_update",
    "draw_batch_indices",
    "aggregate_round",
    "weighted_tree_sum",
    "flatten_params",
    "FederatedServer",
    "FLConfig",
    "History",
    "RoundRecord",
]
