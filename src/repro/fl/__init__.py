from repro.fl.partition import by_class_shards, dirichlet_labels, PAPER_SIZE_PROFILE
from repro.fl.client import local_update, local_steps, draw_batch_indices
from repro.fl.aggregation import (
    aggregate_round,
    aggregate_stacked,
    weighted_tree_sum,
    flatten_params,
)
from repro.fl.engine import BatchedRoundEngine, batched_round_step
from repro.fl.gradient_store import GradientStore
from repro.fl.planner import PlanService, VersionedPlan
from repro.fl.server import EmptyRoundError, FederatedServer, FLConfig
from repro.fl.history import History, RoundRecord

__all__ = [
    "by_class_shards",
    "dirichlet_labels",
    "PAPER_SIZE_PROFILE",
    "local_update",
    "local_steps",
    "draw_batch_indices",
    "aggregate_round",
    "aggregate_stacked",
    "weighted_tree_sum",
    "flatten_params",
    "BatchedRoundEngine",
    "batched_round_step",
    "GradientStore",
    "PlanService",
    "VersionedPlan",
    "EmptyRoundError",
    "FederatedServer",
    "FLConfig",
    "History",
    "RoundRecord",
]
