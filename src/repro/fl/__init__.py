from repro.fl.partition import by_class_shards, dirichlet_labels, PAPER_SIZE_PROFILE
from repro.fl.client import local_update, local_steps, draw_batch_indices
from repro.fl.aggregation import (
    aggregate_round,
    aggregate_stacked,
    weighted_tree_sum,
    flatten_params,
)
from repro.fl.engine import BatchedRoundEngine, ENGINES, batched_round_step, register_engine
from repro.fl.gradient_store import GradientStore
from repro.fl.planner import PlanService, VersionedPlan
from repro.fl.server import EmptyRoundError, FederatedServer, FLConfig
from repro.fl.history import History, RoundRecord
from repro.fl.experiment import (
    DATASETS,
    DataSpec,
    EngineSpec,
    ExperimentSpec,
    PlannerSpec,
    SamplerSpec,
    TrainSpec,
    build_dataset,
    build_experiment,
    build_sampler,
    register_dataset,
)
from repro.fl.sweep import (
    RunStore,
    SweepCell,
    SweepSpec,
    collate,
    run_sweep,
    summarize_history,
    write_collated,
)

__all__ = [
    "by_class_shards",
    "dirichlet_labels",
    "PAPER_SIZE_PROFILE",
    "local_update",
    "local_steps",
    "draw_batch_indices",
    "aggregate_round",
    "aggregate_stacked",
    "weighted_tree_sum",
    "flatten_params",
    "BatchedRoundEngine",
    "batched_round_step",
    "GradientStore",
    "PlanService",
    "VersionedPlan",
    "EmptyRoundError",
    "FederatedServer",
    "FLConfig",
    "History",
    "RoundRecord",
    "ENGINES",
    "register_engine",
    "DATASETS",
    "register_dataset",
    "DataSpec",
    "SamplerSpec",
    "PlannerSpec",
    "EngineSpec",
    "TrainSpec",
    "ExperimentSpec",
    "build_dataset",
    "build_sampler",
    "build_experiment",
    "SweepSpec",
    "SweepCell",
    "RunStore",
    "run_sweep",
    "collate",
    "write_collated",
    "summarize_history",
]
