"""The FL orchestrator: sample → local work → unbiased aggregation.

Faithful to the paper's protocol:
  * each round, the sampler draws ``l_1..l_m`` (with multiplicity);
  * only the *distinct* sampled clients do local work (a client drawn twice
    trains once and carries weight 2/m — MD/clustered semantics);
  * aggregation is the realized weighted sum (eq. 3/4);
  * similarity-based samplers get the representative gradients
    ``θ_i^{t+1} - θ^t`` of the sampled clients after the round
    (Algorithm 2 line 1's input), never raw data. With the batched engine
    those updates are a *device* array feeding the sampler's gradient store
    by scatter — no per-round host copy; with ``planner="async"`` samplers
    the plan rebuild they trigger overlaps the next round's local work, and
    each ``RoundRecord`` carries ``plan_version`` / ``plan_lag_rounds``.

Two execution engines (``FLConfig.engine``):
  * ``"batched"`` (default) — the whole round is one jitted
    vmap-over-clients step (:mod:`repro.fl.engine`); client data lives on
    device for the entire run.
  * ``"compat"`` — the original per-client Python loop, kept as the
    numerics reference; ``tests/test_round_engine.py`` pins the two paths
    together to fp32 tolerance.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.samplers.base import ClientSampler
from repro.data.federated import FederatedDataset
from repro.fl.aggregation import aggregate_round, flatten_params
from repro.fl.client import draw_batch_indices, local_update
from repro.fl.engine import ENGINES, staged_bytes
from repro.fl.history import History, RoundRecord
from repro.launch.mesh import resolve_fl_mesh
from repro.models.simple import accuracy, classification_loss
from repro.optim.base import Optimizer


@dataclasses.dataclass
class FLConfig:
    n_rounds: int = 100
    n_local_steps: int = 50  # N in the paper
    batch_size: int = 50  # B in the paper
    fedprox_mu: float = 0.0
    eval_every: int = 1
    seed: int = 0
    engine: str = "batched"  # any repro.fl.engine.ENGINES name
    # The batched engine pins every client's (padded) data on device. If that
    # exceeds this budget the server falls back to the memory-lean compat
    # loop with a warning — both paths are numerically equivalent.
    max_staged_bytes: int = 2 << 30
    # Mesh for the batched engine's client axis: None (single-device,
    # default), "auto" (all local devices on "data"), "DxM" / (D, M) host
    # mesh shapes, or a jax.sharding.Mesh. See repro.launch.mesh.
    # resolve_fl_mesh and the engine module docstring. Ignored by "compat".
    mesh_spec: "str | tuple[int, int] | None" = None


class EmptyRoundError(ValueError):
    """The sampler produced nothing to aggregate for a round: zero distinct
    clients, or distinct clients whose realized weights sum to zero."""


class FederatedServer:
    def __init__(
        self,
        dataset: FederatedDataset,
        sampler: ClientSampler,
        init_params,
        optimizer: Optimizer,
        config: FLConfig,
        loss_fn: Callable = classification_loss,
        acc_fn: Callable = accuracy,
    ):
        engine_factory = ENGINES.get(config.engine)  # precise unknown-name error
        self.dataset = dataset
        self.sampler = sampler
        self.params = init_params
        self.opt = optimizer
        self.cfg = config
        self.loss_fn = loss_fn
        self.acc_fn = acc_fn
        self._rng = np.random.default_rng(config.seed)
        self.history = History()
        self._x_test, self._y_test = dataset.global_test()
        # classes each client can contribute — O(total samples) once, so the
        # per-round distinct-class count is a union of tiny class sets
        self._client_classes = [np.unique(c.y_train) for c in dataset.clients]
        mesh = (
            resolve_fl_mesh(config.mesh_spec) if config.engine != "compat" else None
        )
        if config.engine == "batched":
            # budget check against the *per-device* footprint: a mesh that
            # shards the client axis is exactly how huge datasets stay stageable
            need = staged_bytes(
                dataset, sampler.m, config.n_local_steps, config.batch_size, mesh=mesh
            )
            if need > config.max_staged_bytes:
                fmt = lambda b: f"{b / 2**30:.2f} GiB" if b >= 2**30 else f"{b / 2**20:.2f} MiB"
                warnings.warn(
                    f"batched engine would stage {fmt(need)} of padded "
                    f"client data per device (budget {fmt(config.max_staged_bytes)}); "
                    "falling back to the compat loop — raise FLConfig.max_staged_bytes "
                    "or shard further via FLConfig.mesh_spec to override",
                    stacklevel=2,
                )
                engine_factory = ENGINES.get("compat")
        self._engine = engine_factory(dataset, sampler.m, config, mesh)
        self._closed = False

    # ------------------------------------------------------------------
    def _round_compat(self, distinct: np.ndarray, weights: np.ndarray, stale_weight: float):
        """Reference path: one jitted dispatch per distinct client."""
        cfg = self.cfg
        client_models, losses, updates_flat = [], [], []
        for cid in distinct:
            data = self.dataset.clients[int(cid)]
            idx = draw_batch_indices(
                self._rng, data.n_train, cfg.n_local_steps, cfg.batch_size
            )
            new_p, loss = local_update(
                self.params,
                jnp.asarray(data.x_train),
                jnp.asarray(data.y_train),
                idx,
                self.loss_fn,
                self.opt,
                cfg.fedprox_mu,
            )
            client_models.append(new_p)
            losses.append(float(loss))
            updates_flat.append(
                np.asarray(flatten_params(new_p) - flatten_params(self.params))
            )
        new_params = aggregate_round(self.params, client_models, weights, stale_weight)
        return new_params, np.stack(updates_flat), np.asarray(losses)

    def run_round(self, t: int) -> RoundRecord:
        cfg = self.cfg
        result = self.sampler.sample(t)
        # sample() is the round boundary where planner-backed samplers swap
        # in the freshest completed plan — capture what this round drew from
        plan_version, plan_lag = self.sampler.plan_telemetry()
        distinct = result.unique_clients
        if distinct.size == 0:
            raise EmptyRoundError(
                f"round {t}: sampler {type(self.sampler).__name__} returned zero "
                "distinct clients — the plan has no mass anywhere; nothing to "
                "train or aggregate"
            )
        weights = result.agg_weights[distinct]
        if weights.sum() <= 0:
            raise EmptyRoundError(
                f"round {t}: realized aggregation weights of the {distinct.size} "
                "distinct clients sum to zero — aggregating (and averaging the "
                "round loss) over them is undefined"
            )

        if self._engine is not None:
            self.params, updates_flat, losses = self._engine.run_round(
                self.params,
                distinct,
                weights,
                result.stale_weight,
                self._rng,
                self.loss_fn,
                self.opt,
                cfg.fedprox_mu,
            )
        else:
            self.params, updates_flat, losses = self._round_compat(
                distinct, weights, result.stale_weight
            )

        # feed representative gradients back (Algorithm 2's input)
        self.sampler.observe_updates(distinct, updates_flat)

        classes = np.unique(
            np.concatenate([self._client_classes[int(c)] for c in distinct])
        )
        test_acc = (
            float(self.acc_fn(self.params, jnp.asarray(self._x_test), jnp.asarray(self._y_test)))
            if (t % cfg.eval_every == 0)
            else float("nan")
        )
        rec = RoundRecord(
            round=t,
            train_loss=float(np.average(losses, weights=weights)),
            test_acc=test_acc,
            n_distinct_clients=len(distinct),
            n_distinct_classes=len(classes),
            agg_weights=result.agg_weights,
            plan_version=plan_version,
            plan_lag_rounds=plan_lag,
        )
        self.history.append(rec)
        return rec

    def run(self, on_round: Optional[Callable[[RoundRecord], None]] = None) -> History:
        """Run all configured rounds; returns the full :class:`History`.

        ``on_round`` is the streaming telemetry hook: called with each
        :class:`RoundRecord` as it lands, so benchmarks/examples consume
        records as the run progresses instead of re-implementing collection.
        """
        for t in range(self.cfg.n_rounds):
            rec = self.run_round(t)
            if on_round is not None:
                on_round(rec)
        return self.history

    # -- lifecycle ----------------------------------------------------------
    # The server owns the sampler's background resources (async planner
    # workers). ``with build_experiment(spec) as srv: ...`` — or any
    # ``with FederatedServer(...)`` — guarantees they are released; before
    # this, every benchmark that built a planner="async" sampler leaked its
    # worker thread unless it remembered to call sampler.close() itself.
    def close(self) -> None:
        """Release the sampler's background resources; idempotent."""
        if not self._closed:
            self._closed = True
            self.sampler.close()

    def __enter__(self) -> "FederatedServer":
        return self

    def __exit__(self, exc_type, exc_val, exc_tb) -> None:
        self.close()
