"""The FL orchestrator: sample → local work → unbiased aggregation.

Faithful to the paper's protocol:
  * each round, the sampler draws ``l_1..l_m`` (with multiplicity);
  * only the *distinct* sampled clients do local work (a client drawn twice
    trains once and carries weight 2/m — MD/clustered semantics);
  * aggregation is the realized weighted sum (eq. 3/4);
  * similarity-based samplers get the representative gradients
    ``θ_i^{t+1} - θ^t`` of the sampled clients after the round
    (Algorithm 2 line 1's input), never raw data. With the batched engine
    those updates are a *device* array feeding the sampler's gradient store
    by scatter — no per-round host copy; with ``planner="async"`` samplers
    the plan rebuild they trigger overlaps the next round's local work, and
    each ``RoundRecord`` carries ``plan_version`` / ``plan_lag_rounds``.

Two execution engines (``FLConfig.engine``):
  * ``"batched"`` (default) — the whole round is one jitted
    vmap-over-clients step (:mod:`repro.fl.engine`); client data lives on
    device for the entire run.
  * ``"compat"`` — the original per-client Python loop, kept as the
    numerics reference; ``tests/test_round_engine.py`` pins the two paths
    together to fp32 tolerance.

Continuous service (the churn-tolerant path): a
:class:`~repro.fl.population.PopulationProcess` turns the fixed-n batch
loop into a long-running service. Each round runs as named phases —

  draw ← availability mask → local work → drop resolution → aggregate
  → observe

— where the sampler conditions its draw on the round's availability mask
(re-normalized urns, unbiased over the available set), a client that
vanishes mid-round becomes a zero-weight slot in the engine's padded slot
axis with its eq. 3 mass falling back on the current global model, and
``EmptyRoundError`` fires only when *all* realized mass is gone. Crash
tolerance: :meth:`FederatedServer.checkpoint` bundles the full
``ServerState`` (params + server/sampler rng bit-generator state + plan
matrices + gradient store + history cursor) through :mod:`repro.checkpoint`
on a ``checkpoint_every`` cadence, and :meth:`FederatedServer.resume`
reconstructs it so a killed service continues **bit-identically** to an
uninterrupted run (pinned in ``tests/test_service_resume.py``; for
``planner="async"`` the checkpoint first forces the sync fixed point).
"""
from __future__ import annotations

import dataclasses
import json
import warnings
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.samplers.base import ClientSampler
from repro.data.federated import FederatedDataset
from repro.fl.aggregation import aggregate_round, flatten_params
from repro.fl.client import draw_batch_indices, local_update
from repro.fl.engine import ENGINES, staged_bytes
from repro.fl.history import History, RoundRecord
from repro.fl.population import PopulationProcess
from repro.launch.mesh import resolve_fl_mesh
from repro.models.simple import accuracy, classification_loss
from repro.optim.base import Optimizer


@dataclasses.dataclass
class FLConfig:
    n_rounds: int = 100
    n_local_steps: int = 50  # N in the paper
    batch_size: int = 50  # B in the paper
    fedprox_mu: float = 0.0
    eval_every: int = 1
    seed: int = 0
    engine: str = "batched"  # any repro.fl.engine.ENGINES name
    # The batched engine pins every client's (padded) data on device. If that
    # exceeds this budget the server falls back to the memory-lean compat
    # loop with a warning — both paths are numerically equivalent.
    max_staged_bytes: int = 2 << 30
    # Mesh for the batched engine's client axis: None (single-device,
    # default), "auto" (all local devices on "data"), "DxM" / (D, M) host
    # mesh shapes, or a jax.sharding.Mesh. See repro.launch.mesh.
    # resolve_fl_mesh and the engine module docstring. Ignored by "compat".
    mesh_spec: "str | tuple[int, int] | None" = None
    # Crash tolerance: every `checkpoint_every` completed rounds (and on a
    # service stop request) the full ServerState bundle is written to
    # `checkpoint_path` through repro.checkpoint. 0 / None disables.
    checkpoint_every: int = 0
    checkpoint_path: Optional[str] = None


class EmptyRoundError(ValueError):
    """The sampler produced nothing to aggregate for a round: zero distinct
    clients, or distinct clients whose realized weights sum to zero."""


class FederatedServer:
    def __init__(
        self,
        dataset: FederatedDataset,
        sampler: ClientSampler,
        init_params,
        optimizer: Optimizer,
        config: FLConfig,
        loss_fn: Callable = classification_loss,
        acc_fn: Callable = accuracy,
        population: Optional[PopulationProcess] = None,
        scheduler=None,
        availability=None,
    ):
        """``scheduler`` (a :class:`~repro.fl.scheduler.RoundScheduler`,
        optional) makes the round-closing rule pluggable — None keeps the
        legacy synchronous round exactly. ``availability`` (an
        :class:`~repro.fl.availability.AvailabilityTracker`, optional) folds
        each round's mask + participant outcomes into per-client presence
        scores; attach it to the sampler too
        (``StoreBackedSampler.attach_availability``) to restrict plan
        rebuilds to the recently-seen fleet. Both checkpoint inside
        ``ServerState`` when present."""
        engine_factory = ENGINES.get(config.engine)  # precise unknown-name error
        self.dataset = dataset
        self.sampler = sampler
        self.params = init_params
        self.opt = optimizer
        self.cfg = config
        self.loss_fn = loss_fn
        self.acc_fn = acc_fn
        self.population = population
        self.scheduler = scheduler
        self.availability = availability
        self._rng = np.random.default_rng(config.seed)
        self.history = History()
        self._x_test, self._y_test = dataset.global_test()
        # classes each client can contribute — O(total samples) once, so the
        # per-round distinct-class count is a union of tiny class sets
        self._client_classes = [np.unique(c.y_train) for c in dataset.clients]
        mesh = (
            resolve_fl_mesh(config.mesh_spec) if config.engine != "compat" else None
        )
        # the scheduler owns the engine's padded slot count (all built-ins
        # keep it at m — overselection thins at draw time — but the contract
        # lets a custom scheduler stage wider rounds)
        slots = (
            sampler.m if scheduler is None else int(scheduler.required_slots(sampler.m))
        )
        if config.engine == "batched":
            # budget check against the *per-device* footprint: a mesh that
            # shards the client axis is exactly how huge datasets stay stageable
            need = staged_bytes(
                dataset, slots, config.n_local_steps, config.batch_size, mesh=mesh
            )
            if need > config.max_staged_bytes:
                fmt = lambda b: f"{b / 2**30:.2f} GiB" if b >= 2**30 else f"{b / 2**20:.2f} MiB"
                warnings.warn(
                    f"batched engine would stage {fmt(need)} of padded "
                    f"client data per device (budget {fmt(config.max_staged_bytes)}); "
                    "falling back to the compat loop — raise FLConfig.max_staged_bytes "
                    "or shard further via FLConfig.mesh_spec to override",
                    stacklevel=2,
                )
                engine_factory = ENGINES.get("compat")
                mesh = None  # the compat loop never shards; a stale mesh here
                # would be handed to the factory and pin devices for nothing
        self._engine = engine_factory(dataset, slots, config, mesh)
        # service cursor: the next round to run. run()/resume() maintain it so
        # a restored server continues exactly where the checkpoint left off.
        self._start_round = 0
        self._round_cursor = 0
        self._closed = False

    # ------------------------------------------------------------------
    def _round_compat(self, distinct: np.ndarray, weights: np.ndarray, stale_weight: float):
        """Reference path: one jitted dispatch per distinct client."""
        cfg = self.cfg
        client_models, losses, updates_flat = [], [], []
        for cid in distinct:
            data = self.dataset.clients[int(cid)]
            idx = draw_batch_indices(
                self._rng, data.n_train, cfg.n_local_steps, cfg.batch_size
            )
            new_p, loss = local_update(
                self.params,
                jnp.asarray(data.x_train),
                jnp.asarray(data.y_train),
                idx,
                self.loss_fn,
                self.opt,
                cfg.fedprox_mu,
            )
            client_models.append(new_p)
            losses.append(float(loss))
            updates_flat.append(
                np.asarray(flatten_params(new_p) - flatten_params(self.params))
            )
        new_params = aggregate_round(self.params, client_models, weights, stale_weight)
        return new_params, np.stack(updates_flat), np.asarray(losses)

    # -- round phases --------------------------------------------------------
    # run_round = availability → draw → drop resolution → local work +
    # aggregate → observe. The phases are separate methods so the continuous
    # service's failure points are named and individually testable. Drop
    # resolution happens *before* engine dispatch because the engine fuses
    # local work and aggregation into one jitted step: a dropped client still
    # occupies its padded slot (stable shapes, stable rng stream) but its
    # aggregation weight is zeroed and its mass falls back on the current
    # global model (eq. 3's stale term) — exactly "the device computed, the
    # result never arrived".

    def _phase_availability(self, t: int) -> tuple[Optional[np.ndarray], int]:
        """(mask, n_available); (None, -1) without a population process."""
        if self.population is None:
            return None, -1
        mask = self.population.available_mask(t)
        n_avail = int(mask.sum())
        if n_avail == 0:
            raise EmptyRoundError(
                f"round {t}: availability mask admits zero of "
                f"{self.population.n_clients} clients — nobody can be drawn"
            )
        return mask, n_avail

    def _phase_draw(self, t: int, available: Optional[np.ndarray]):
        """Sampler draw conditioned on availability; fails on empty draws."""
        if self.scheduler is not None:
            # the scheduler owns the draw shape (overselection draws
            # m·(1+β) and thins); its base draw is exactly the legacy call
            result = self.scheduler.draw(t, self.sampler, available)
        else:
            # no mask → the legacy one-argument call, so custom samplers
            # written before availability conditioning keep working untouched
            result = (
                self.sampler.sample(t)
                if available is None
                else self.sampler.sample(t, available)
            )
        # sample() is the round boundary where planner-backed samplers swap
        # in the freshest completed plan — capture what this round drew from
        plan_version, plan_lag = self.sampler.plan_telemetry()
        distinct = result.unique_clients
        if distinct.size == 0:
            raise EmptyRoundError(
                f"round {t}: sampler {type(self.sampler).__name__} returned zero "
                "distinct clients — the plan has no mass anywhere"
                + (" on the available set" if available is not None else "")
                + "; nothing to train or aggregate"
            )
        weights = result.agg_weights[distinct]
        if weights.sum() <= 0:
            raise EmptyRoundError(
                f"round {t}: realized aggregation weights of the {distinct.size} "
                "distinct clients sum to zero — aggregating (and averaging the "
                "round loss) over them is undefined"
            )
        return result, distinct, weights, plan_version, plan_lag

    def _phase_drop_resolution(
        self,
        t: int,
        distinct: np.ndarray,
        weights: np.ndarray,
        stale_weight: float,
        late: Optional[np.ndarray] = None,
    ) -> tuple[np.ndarray, float, np.ndarray]:
        """Zero dropped participants' weights; their mass goes stale.

        Returns ``(weights, stale_weight, dropped)`` — ``dropped`` is the
        boolean mask over ``distinct``. Raises :class:`EmptyRoundError` when
        every realized participant dropped (all realized mass is gone) —
        unless ``late`` marks scheduler-resolved stragglers among the
        survivors: their updates are merely delayed (harvested next round),
        so a round that lost all its mass to *lateness* proceeds as a
        stale-only aggregation instead of dying.
        """
        if self.population is None:
            return weights, stale_weight, np.zeros(distinct.shape, dtype=bool)
        dropped = self.population.dropout_mask(t, distinct)
        if not dropped.any():
            return weights, stale_weight, dropped
        live = weights[~dropped].sum()
        if live <= 0 and not (late is not None and (late & ~dropped).any()):
            raise EmptyRoundError(
                f"round {t}: all {distinct.size} realized participants dropped "
                "mid-round (or the survivors carry zero weight) — every bit of "
                "realized aggregation mass is gone; nothing arrived to aggregate"
            )
        # the aggregation is a plain weighted sum (no re-normalization), so a
        # dropped client's ω_i must land somewhere: it falls back on the
        # current global model, the same eq. 3 stale term uniform sampling uses
        stale_weight = float(stale_weight + weights[dropped].sum())
        weights = np.where(dropped, 0.0, weights)
        return weights, stale_weight, dropped

    def _phase_local_work(self, distinct, weights, stale_weight):
        """Local training + aggregation — one fused engine dispatch."""
        if self._engine is not None:
            return self._engine.run_round(
                self.params,
                distinct,
                weights,
                stale_weight,
                self._rng,
                self.loss_fn,
                self.opt,
                self.cfg.fedprox_mu,
            )
        return self._round_compat(distinct, weights, stale_weight)

    def run_round(self, t: int) -> RoundRecord:
        cfg = self.cfg
        available, n_available = self._phase_availability(t)
        # scheduler prologue: flush last round's harvested straggler updates
        # into the gradient store *before* this round draws from it
        n_harvested = (
            int(self.scheduler.begin_round(t, self.sampler))
            if self.scheduler is not None
            else 0
        )
        result, distinct, weights, plan_version, plan_lag = self._phase_draw(
            t, available
        )
        stale_weight = result.stale_weight
        if self.scheduler is not None:
            # round-closing rule: mark stragglers late (weight → stale term,
            # update harvested below) before mid-round drops resolve
            weights, stale_weight, late = self.scheduler.resolve(
                t, distinct, weights, stale_weight
            )
        else:
            late = np.zeros(distinct.shape, dtype=bool)
        weights, stale_weight, dropped = self._phase_drop_resolution(
            t, distinct, weights, stale_weight, late=late
        )
        n_dropped = int(dropped.sum())
        # a participant that both straggled and crashed is a crash: the
        # result never arrived, so there is nothing to harvest either
        late = late & ~dropped
        n_late = int(late.sum())

        self.params, updates_flat, losses = self._phase_local_work(
            distinct, weights, stale_weight
        )

        if n_late and self.scheduler is not None:
            # harvest: late updates were computed (the engine ran their
            # padded slots) — buffer host copies for next round's store
            self.scheduler.collect(t, distinct[late], updates_flat[np.asarray(late)])

        # observe: feed representative gradients back (Algorithm 2's input) —
        # on-time survivors only; a dropped client's update never reached the
        # server and a straggler's arrives next round via the harvest path,
        # so neither refreshes the similarity state here
        keep = ~(dropped | late)
        contributing = distinct[keep]
        if contributing.size:
            self.sampler.observe_updates(
                contributing, updates_flat[np.asarray(keep)]
            )

        # rebuild-cost telemetry is read *after* observe_updates: the drift
        # statistic (and any sync rebuild) for this round happens there
        plan_build_ms, plan_drift = self.sampler.plan_cost_telemetry()

        # availability fold: the mask plus this round's graded outcomes —
        # on-time 1.0, late late_credit, crashed 0.0 (see fl.availability)
        if self.availability is not None:
            self.availability.update(
                available,
                on_time=contributing,
                late=distinct[late],
                crashed=distinct[dropped],
            )
            avail_score_min = self.availability.min_score()
        else:
            avail_score_min = -1.0

        classes = (
            np.unique(
                np.concatenate([self._client_classes[int(c)] for c in contributing])
            )
            if contributing.size
            else np.empty(0, np.int64)
        )
        test_acc = (
            float(self.acc_fn(self.params, jnp.asarray(self._x_test), jnp.asarray(self._y_test)))
            if (t % cfg.eval_every == 0)
            else float("nan")
        )
        agg_weights = result.agg_weights
        if n_dropped or n_late:
            agg_weights = np.array(agg_weights, dtype=np.float64, copy=True)
            agg_weights[distinct[dropped | late]] = 0.0
        live_mass = float(weights.sum())
        rec = RoundRecord(
            round=t,
            # dropped/late participants carry zero weight, so the round loss
            # averages over on-time survivors only; a round that lost every
            # participant to lateness aggregated stale-only mass — no loss
            train_loss=(
                float(np.average(losses, weights=weights))
                if live_mass > 0
                else float("nan")
            ),
            test_acc=test_acc,
            n_distinct_clients=len(distinct),
            n_distinct_classes=len(classes),
            agg_weights=agg_weights,
            plan_version=plan_version,
            plan_lag_rounds=plan_lag,
            plan_build_ms=plan_build_ms,
            plan_drift=plan_drift,
            n_available=n_available,
            n_dropped=n_dropped,
            # n_late also counts draws the scheduler discarded at draw time
            # (overselection surplus); round_status tracks actual stragglers
            # and crashes only — planned surplus is not degradation
            n_late=n_late
            + (self.scheduler.n_late_extra() if self.scheduler is not None else 0),
            n_harvested=n_harvested,
            avail_score_min=avail_score_min,
            round_status="degraded" if (n_dropped or n_late) else "ok",
        )
        self.history.append(rec)
        self._round_cursor = t + 1
        return rec

    def run(
        self,
        on_round: Optional[Callable[[RoundRecord], None]] = None,
        *,
        should_stop: Optional[Callable[[], bool]] = None,
        skip_empty: bool = False,
    ) -> History:
        """Run rounds ``[start, n_rounds)``; returns the full :class:`History`.

        ``start`` is 0 for a fresh server and the checkpointed cursor after
        :meth:`resume`. ``on_round`` is the streaming telemetry hook: called
        with each :class:`RoundRecord` as it lands, so benchmarks/examples
        consume records as the run progresses instead of re-implementing
        collection.

        Service semantics: with ``FLConfig.checkpoint_every > 0`` (and a
        ``checkpoint_path``) the full server state is checkpointed on that
        cadence of completed rounds. ``should_stop`` is polled after each
        round — a SIGTERM-style stop flag; when it trips, a final checkpoint
        is written and the loop exits cleanly. ``skip_empty=True`` converts
        :class:`EmptyRoundError` rounds (everyone offline / everyone dropped)
        into placeholder ``round_status="empty"`` records instead of raising
        — a long-running service rides out a dead fleet; a batch experiment
        should still fail loudly.
        """
        cfg = self.cfg
        every = int(cfg.checkpoint_every or 0)
        for t in range(self._start_round, cfg.n_rounds):
            try:
                rec = self.run_round(t)
            except EmptyRoundError:
                if not skip_empty:
                    raise
                n_avail = (
                    int(self.population.available_mask(t).sum())
                    if self.population is not None
                    else -1
                )
                rec = RoundRecord(
                    round=t,
                    train_loss=float("nan"),
                    test_acc=float("nan"),
                    n_distinct_clients=0,
                    n_distinct_classes=0,
                    n_available=n_avail,
                    round_status="empty",
                )
                self.history.append(rec)
                self._round_cursor = t + 1
            if on_round is not None:
                on_round(rec)
            if every and cfg.checkpoint_path and (t + 1) % every == 0:
                self.checkpoint()
            if should_stop is not None and should_stop():
                if cfg.checkpoint_path:
                    self.checkpoint()
                break
        return self.history

    # -- crash tolerance -----------------------------------------------------
    # ServerState = params + server rng + sampler state (rng, plan matrices,
    # gradient store, plan version/history cursor) + round history. Arrays
    # ride in the checkpoint's .npz pytree; JSON-shaped state (rng
    # bit-generator dicts, the history records) rides in its `extra`
    # side-channel. The population process is deliberately absent: its masks
    # are pure functions of (seed, t), so a resumed server replays the
    # identical availability/dropout trajectory for free.

    def _state_tree(self) -> dict:
        tree = {"params": self.params, "sampler": self.sampler.state_arrays()}
        # optional subsystems checkpoint as their own sections, present only
        # when attached — a scheduler-free server's bundle is unchanged, and
        # restoring a bundle into a differently-configured server fails on
        # the missing/extra key instead of silently dropping state
        if self.scheduler is not None:
            tree["scheduler"] = self.scheduler.state_arrays()
        if self.availability is not None:
            tree["availability"] = self.availability.state_arrays()
        return tree

    def checkpoint(self, path: Optional[str] = None) -> str:
        """Write the full ServerState bundle; returns the path written.

        ``path`` defaults to ``FLConfig.checkpoint_path``. The sampler is
        quiesced first (:meth:`ClientSampler.prepare_state` — async planners
        flush their in-flight rebuild to the sync fixed point), so the
        bundle is always a consistent cut.
        """
        from repro.checkpoint import save_checkpoint

        path = path or self.cfg.checkpoint_path
        if not path:
            raise ValueError(
                "no checkpoint path: pass one or set FLConfig.checkpoint_path"
            )
        self.sampler.prepare_state()
        extra = {
            "server_rng": self._rng.bit_generator.state,
            "sampler": self.sampler.state_meta(),
            "history": json.loads(self.history.to_json()),
        }
        if self.scheduler is not None:
            extra["scheduler"] = self.scheduler.state_meta()
        if self.availability is not None:
            extra["availability"] = self.availability.state_meta()
        save_checkpoint(path, self._state_tree(), step=self._round_cursor, extra=extra)
        return path

    def resume(self, path: Optional[str] = None) -> int:
        """Reconstruct mid-campaign state from a :meth:`checkpoint` bundle.

        Restores params, server rng, the sampler's full state and the round
        history, and positions :meth:`run` at the checkpointed cursor.
        Returns the round the server will run next. For deterministic
        (sync/static-plan) samplers the continuation is bit-identical to the
        uninterrupted run; async planners restore the exact sync fixed point
        the checkpoint captured, though their rebuild timing stays a race
        (plan_lag telemetry may differ, as it does between any two async
        runs). Both pinned in ``tests/test_service_resume.py``.
        """
        from repro.checkpoint import peek_meta, restore_checkpoint

        path = path or self.cfg.checkpoint_path
        if not path:
            raise ValueError(
                "no checkpoint path: pass one or set FLConfig.checkpoint_path"
            )
        # provenance first: a bundle written by a scheduler-/tracker-free
        # server must fail with WHY, not with a generic missing-leaf error
        # from the structural restore below
        _, preview = peek_meta(path)
        if self.scheduler is not None and "scheduler" not in preview:
            raise ValueError(
                "this server has a round scheduler attached but the "
                "checkpoint carries no scheduler section — it was written "
                "by a scheduler-free server"
            )
        if self.availability is not None and "availability" not in preview:
            raise ValueError(
                "this server tracks availability but the checkpoint "
                "carries no availability section — it was written by a "
                "tracker-free server"
            )
        # the scheduler subtree is variable-shaped (the harvest buffer holds
        # however many late updates the killed round produced; a fresh
        # build's reference buffer is empty) — exempt it from the shape guard
        tree, step, extra = restore_checkpoint(
            path,
            self._state_tree(),
            dynamic_prefixes=("scheduler/",) if self.scheduler is not None else (),
        )
        self.params = tree["params"]
        self._rng.bit_generator.state = extra["server_rng"]
        self.sampler.load_state(extra["sampler"], tree["sampler"])
        if self.scheduler is not None:
            self.scheduler.load_state(extra["scheduler"], tree.get("scheduler", {}))
        if self.availability is not None:
            self.availability.load_state(
                extra["availability"], tree.get("availability", {})
            )
        self.history = History.from_json(json.dumps(extra["history"]))
        self._start_round = self._round_cursor = int(step)
        return int(step)

    # -- lifecycle ----------------------------------------------------------
    # The server owns the sampler's background resources (async planner
    # workers). ``with build_experiment(spec) as srv: ...`` — or any
    # ``with FederatedServer(...)`` — guarantees they are released; before
    # this, every benchmark that built a planner="async" sampler leaked its
    # worker thread unless it remembered to call sampler.close() itself.
    def close(self) -> None:
        """Release the sampler's background resources; idempotent."""
        if not self._closed:
            self._closed = True
            self.sampler.close()

    def __enter__(self) -> "FederatedServer":
        return self

    def __exit__(self, exc_type, exc_val, exc_tb) -> None:
        self.close()
