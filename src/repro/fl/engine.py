"""Batched on-device FL round engine.

The paper's protocol runs ``m`` sampled clients per round. The seed server
trained them one-by-one in a Python loop — m jitted dispatches plus m
host-side parameter copies per round, so wall-clock grows linearly in m.
This engine runs the *whole round* as ONE jitted step:

  1. every client's train set is padded to a common length and stacked once
     into a device-resident (n, n_pad, …) block at construction time;
  2. per round, the distinct sampled clients are gathered *on device* by
     slot index, and all local updates run as ``vmap(local_steps)`` — the
     same ``lax.scan`` body as the ``compat`` path, so the two paths agree
     to fp32 tolerance (FedProx proximal term included);
  3. the weighted aggregation (eq. 3/4 incl. ``stale_weight``) and the
     flattened representative gradients ``θ_i^{t+1} − θ^t`` (Algorithm 2
     line 1's input, fed to ``sampler.observe_updates``) are computed in the
     same jitted step — nothing round-trips through the host except the
     (m, N, B) batch-index block and the scalar losses.

Shapes are static across the run: the client axis is always padded to
``m_slots`` (zero weight ⇒ zero contribution for unused slots), so the
engine compiles exactly once per FL run regardless of how many *distinct*
clients each round realizes. Per-round padding waste is ``m_slots −
n_distinct`` client-updates — small, because clustered sampling exists
precisely to keep the draws distinct.

RNG discipline matches the compat loop exactly: batch indices are drawn
from the server's host rng per distinct client, in distinct order, and
padded slots consume no randomness — so the same seed yields the same
realized batches on both paths.

Mesh sharding (``mesh=`` on the engine / ``batched_round_step``): the round
is embarrassingly parallel over clients — each data-parallel group plays
one sampled client (the ``launch.fl_train`` pattern). With a mesh, the
``m_slots`` client axis (slot ids, batch indices, weights, the gathered
per-client data blocks and the vmapped per-client models) is constrained
onto the mesh's batch axes; the staged dataset is sharded over its client
axis so per-device pinned bytes shrink with mesh size; the eq. 3/4 weighted
aggregation is the single cross-client collective and the new global model
comes back replicated. ``mesh=None`` (default) places no constraints —
bit-for-bit the single-device behavior.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.registry import Registry
from repro.fl.aggregation import aggregate_stacked, flatten_params
from repro.fl.client import LossFn, local_steps
from repro.launch.mesh import data_parallel_degree, leading_batch_spec
from repro.optim.base import Optimizer


def _staged_dtypes(dataset) -> tuple[np.dtype, np.dtype]:
    """Dtypes the engine actually stages for ``dataset``.

    Floating features at or below 4 bytes keep their dtype; everything else
    (f64 — which jax would silently downcast anyway — and integer image
    bytes, which the dense matmul needs as floats) becomes f32. Integer
    labels at or below 4 bytes keep their dtype; wider ones become i32.
    """
    xd = np.dtype(dataset.clients[0].x_train.dtype)
    yd = np.dtype(dataset.clients[0].y_train.dtype)
    feat = xd if (xd.kind == "f" and xd.itemsize <= 4) else np.dtype(np.float32)
    lab = yd if (yd.kind in "iu" and yd.itemsize <= 4) else np.dtype(np.int32)
    return feat, lab


def staged_bytes(
    dataset, m_slots: int = 0, n_steps: int = 0, batch_size: int = 0, mesh=None
) -> int:
    """*Per-device* bytes the engine pins for ``dataset``: every client
    padded to the largest client, in the dtypes the engine actually stages
    (see :func:`_staged_dtypes`), plus the per-round ``(m_slots, n_steps,
    batch_size)`` i32 batch-index block the server ships each round.

    With ``mesh``, each term shrinks by the data-parallel degree when its
    leading axis divides it — mirroring how the engine actually shards (it
    stages replicated on uneven client counts)."""
    n_pad = max(c.n_train for c in dataset.clients)
    feat = int(np.prod(dataset.clients[0].x_train.shape[1:]))
    feat_dt, label_dt = _staged_dtypes(dataset)
    data = dataset.n_clients * n_pad * (feat * feat_dt.itemsize + label_dt.itemsize)
    idx = m_slots * n_steps * batch_size * np.dtype(np.int32).itemsize
    if mesh is not None:
        n_dp = data_parallel_degree(mesh)
        if dataset.n_clients % n_dp == 0:
            data //= n_dp
        if m_slots % n_dp == 0:
            idx //= n_dp
    return data + idx


def _client_spec(mesh, ndim: int) -> NamedSharding:
    """Leading axis on the mesh's batch axes, trailing dims replicated."""
    return NamedSharding(mesh, leading_batch_spec(mesh, ndim))


@functools.partial(jax.jit, static_argnames=("loss_fn", "opt", "fedprox_mu", "mesh"))
def batched_round_step(
    global_params,
    x_all: jnp.ndarray,  # (n, n_pad, …) stacked client features
    y_all: jnp.ndarray,  # (n, n_pad) stacked client labels
    slot_ids: jnp.ndarray,  # (m_slots,) client id per slot (0 for padding)
    batch_idx: jnp.ndarray,  # (m_slots, N, B) per-slot batch indices
    weights: jnp.ndarray,  # (m_slots,) realized ω, 0 for padded slots
    stale_weight: jnp.ndarray,  # scalar, eq. 3 mass on θ^t
    *,
    loss_fn: LossFn,
    opt: Optimizer,
    fedprox_mu: float = 0.0,
    mesh=None,
):
    """One full FL round on device.

    Returns (new_global_params, (m_slots, d) flat updates, (m_slots,) mean
    local losses). Padded slots train on client 0's data with weight 0 —
    their outputs are discarded by the caller.

    ``mesh`` (a static :class:`jax.sharding.Mesh`, or ``None``) shards the
    ``m_slots`` client axis over the mesh's batch axes via sharding
    constraints: every per-slot array — and the vmapped per-client model
    copies — lives on its data-parallel group, the weighted aggregation is
    the one cross-client collective, and the aggregated model plus the
    global params stay replicated over the model axes.
    """
    if mesh is None:
        cl = lambda a: a
        repl = cl
    else:
        cl = lambda a: jax.lax.with_sharding_constraint(a, _client_spec(mesh, a.ndim))
        repl = lambda a: jax.lax.with_sharding_constraint(a, NamedSharding(mesh, P()))
    slot_ids, batch_idx, weights = cl(slot_ids), cl(batch_idx), cl(weights)
    x = cl(x_all[slot_ids])
    y = cl(y_all[slot_ids])

    def one_client(xc, yc, idxc):
        return local_steps(global_params, xc, yc, idxc, loss_fn, opt, fedprox_mu)

    client_params, losses = jax.vmap(one_client)(x, y, batch_idx)
    client_params = jax.tree_util.tree_map(cl, client_params)
    losses = cl(losses)
    new_params = aggregate_stacked(global_params, client_params, weights, stale_weight)
    new_params = jax.tree_util.tree_map(repl, new_params)
    flat_global = flatten_params(global_params)
    updates = cl(jax.vmap(lambda cp: flatten_params(cp) - flat_global)(client_params))
    return new_params, updates, losses


class BatchedRoundEngine:
    """Stages a :class:`~repro.data.federated.FederatedDataset` once and runs
    rounds through :func:`batched_round_step`.

    ``m_slots`` fixes the padded client axis (normally the sampler's m).
    ``mesh`` shards the staged dataset over its client axis (when the client
    count divides the mesh's data-parallel degree; replicated otherwise) and
    runs every round with the slot axis sharded — see the module docstring.
    """

    def __init__(self, dataset, m_slots: int, n_steps: int, batch_size: int, *, mesh=None):
        if m_slots <= 0:
            raise ValueError("m_slots must be positive")
        self.m_slots = int(m_slots)
        self.n_steps = int(n_steps)
        self.batch_size = int(batch_size)
        self.mesh = mesh
        self._n_train = np.array([c.n_train for c in dataset.clients])
        n_pad = int(self._n_train.max())
        feat = dataset.clients[0].x_train.shape[1:]
        feat_dt, label_dt = _staged_dtypes(dataset)
        x_all = np.zeros((dataset.n_clients, n_pad) + feat, dtype=feat_dt)
        y_all = np.zeros((dataset.n_clients, n_pad), dtype=label_dt)
        for i, c in enumerate(dataset.clients):
            x_all[i, : c.n_train] = c.x_train
            y_all[i, : c.n_train] = c.y_train
        # device-resident for the whole run; per-round traffic is indices only
        if mesh is None:
            self._x_all = jnp.asarray(x_all)
            self._y_all = jnp.asarray(y_all)
        else:
            n_dp = data_parallel_degree(mesh)
            if dataset.n_clients % n_dp == 0:
                x_sh = _client_spec(mesh, x_all.ndim)
                y_sh = _client_spec(mesh, y_all.ndim)
            else:  # uneven client count: stage replicated, still shard the round
                x_sh = NamedSharding(mesh, P())
                y_sh = NamedSharding(mesh, P())
            self._x_all = jax.device_put(x_all, x_sh)
            self._y_all = jax.device_put(y_all, y_sh)

    def per_device_staged_bytes(self) -> int:
        """Measured bytes the busiest device pins for the staged dataset.

        The per-round batch-index block is a transient, not counted here —
        :func:`staged_bytes` is the planning-time estimate that includes it.
        """
        per_device: dict = {}
        for arr in (self._x_all, self._y_all):
            for shard in arr.addressable_shards:
                per_device[shard.device] = per_device.get(shard.device, 0) + shard.data.nbytes
        return max(per_device.values())

    def run_round(
        self,
        params,
        distinct: np.ndarray,
        weights: np.ndarray,
        stale_weight: float,
        rng: np.random.Generator,
        loss_fn: LossFn,
        opt: Optimizer,
        fedprox_mu: float = 0.0,
    ):
        """Returns (new_params, (c, d) flat updates, (c,) losses) for the
        ``c = len(distinct)`` realized clients."""
        c = len(distinct)
        if c == 0 or c > self.m_slots:
            raise ValueError(f"got {c} distinct clients for {self.m_slots} slots")
        slot_ids = np.zeros(self.m_slots, dtype=np.int32)
        slot_ids[:c] = distinct
        idx = np.zeros((self.m_slots, self.n_steps, self.batch_size), dtype=np.int32)
        for i, cid in enumerate(distinct):
            # same rng stream as the compat loop's draw_batch_indices, drawn
            # host-side (one device transfer for the whole block below)
            idx[i] = rng.integers(
                0, int(self._n_train[int(cid)]), size=(self.n_steps, self.batch_size)
            )
        w = np.zeros(self.m_slots, dtype=np.float32)
        w[:c] = weights
        new_params, updates, losses = batched_round_step(
            params,
            self._x_all,
            self._y_all,
            jnp.asarray(slot_ids),
            jnp.asarray(idx),
            jnp.asarray(w),
            jnp.asarray(stale_weight, jnp.float32),
            loss_fn=loss_fn,
            opt=opt,
            fedprox_mu=fedprox_mu,
            mesh=self.mesh,
        )
        # updates stay a device array: the gradient store scatters them back
        # into G without a host round-trip (the (m_slots, d) -> (c, d) slice
        # compiles one tiny gather per distinct-count, c <= m_slots of them)
        return new_params, updates[:c], np.asarray(losses)[:c]


# --------------------------------------------------------------------------
# engine registry: FLConfig.engine resolves through this, so alternative
# round executors plug into the server (and the spec layer) by name
# --------------------------------------------------------------------------
def _batched_engine(dataset, m: int, config, mesh):
    return BatchedRoundEngine(
        dataset, m, config.n_local_steps, config.batch_size, mesh=mesh
    )


def _compat_engine(dataset, m: int, config, mesh):
    """The per-client reference loop lives in the server; no engine object."""
    del dataset, m, config, mesh
    return None


#: name -> factory(dataset, m, config, mesh) returning an object with
#: ``run_round(params, distinct, weights, stale_weight, rng, loss_fn, opt,
#: fedprox_mu)`` — or None to select the server's compat per-client loop.
ENGINES = Registry("engine", {"batched": _batched_engine, "compat": _compat_engine})

register_engine = ENGINES.register
