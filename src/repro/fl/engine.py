"""Batched on-device FL round engine.

The paper's protocol runs ``m`` sampled clients per round. The seed server
trained them one-by-one in a Python loop — m jitted dispatches plus m
host-side parameter copies per round, so wall-clock grows linearly in m.
This engine runs the *whole round* as ONE jitted step:

  1. every client's train set is padded to a common length and stacked once
     into a device-resident (n, n_pad, …) block at construction time;
  2. per round, the distinct sampled clients are gathered *on device* by
     slot index, and all local updates run as ``vmap(local_steps)`` — the
     same ``lax.scan`` body as the ``compat`` path, so the two paths agree
     to fp32 tolerance (FedProx proximal term included);
  3. the weighted aggregation (eq. 3/4 incl. ``stale_weight``) and the
     flattened representative gradients ``θ_i^{t+1} − θ^t`` (Algorithm 2
     line 1's input, fed to ``sampler.observe_updates``) are computed in the
     same jitted step — nothing round-trips through the host except the
     (m, N, B) batch-index block and the scalar losses.

Shapes are static across the run: the client axis is always padded to
``m_slots`` (zero weight ⇒ zero contribution for unused slots), so the
engine compiles exactly once per FL run regardless of how many *distinct*
clients each round realizes. Per-round padding waste is ``m_slots −
n_distinct`` client-updates — small, because clustered sampling exists
precisely to keep the draws distinct.

RNG discipline matches the compat loop exactly: batch indices are drawn
from the server's host rng per distinct client, in distinct order, and
padded slots consume no randomness — so the same seed yields the same
realized batches on both paths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.aggregation import aggregate_stacked, flatten_params
from repro.fl.client import LossFn, local_steps
from repro.optim.base import Optimizer


def staged_bytes(dataset) -> int:
    """Device bytes the engine pins for ``dataset``: every client padded to
    the largest client (f32 features + i32 labels)."""
    n_pad = max(c.n_train for c in dataset.clients)
    feat = int(np.prod(dataset.clients[0].x_train.shape[1:]))
    return dataset.n_clients * n_pad * (feat * 4 + 4)


@functools.partial(jax.jit, static_argnames=("loss_fn", "opt", "fedprox_mu"))
def batched_round_step(
    global_params,
    x_all: jnp.ndarray,  # (n, n_pad, …) stacked client features
    y_all: jnp.ndarray,  # (n, n_pad) stacked client labels
    slot_ids: jnp.ndarray,  # (m_slots,) client id per slot (0 for padding)
    batch_idx: jnp.ndarray,  # (m_slots, N, B) per-slot batch indices
    weights: jnp.ndarray,  # (m_slots,) realized ω, 0 for padded slots
    stale_weight: jnp.ndarray,  # scalar, eq. 3 mass on θ^t
    *,
    loss_fn: LossFn,
    opt: Optimizer,
    fedprox_mu: float = 0.0,
):
    """One full FL round on device.

    Returns (new_global_params, (m_slots, d) flat updates, (m_slots,) mean
    local losses). Padded slots train on client 0's data with weight 0 —
    their outputs are discarded by the caller.
    """
    x = x_all[slot_ids]
    y = y_all[slot_ids]

    def one_client(xc, yc, idxc):
        return local_steps(global_params, xc, yc, idxc, loss_fn, opt, fedprox_mu)

    client_params, losses = jax.vmap(one_client)(x, y, batch_idx)
    new_params = aggregate_stacked(global_params, client_params, weights, stale_weight)
    flat_global = flatten_params(global_params)
    updates = jax.vmap(lambda cp: flatten_params(cp) - flat_global)(client_params)
    return new_params, updates, losses


class BatchedRoundEngine:
    """Stages a :class:`~repro.data.federated.FederatedDataset` once and runs
    rounds through :func:`batched_round_step`.

    ``m_slots`` fixes the padded client axis (normally the sampler's m).
    """

    def __init__(self, dataset, m_slots: int, n_steps: int, batch_size: int):
        if m_slots <= 0:
            raise ValueError("m_slots must be positive")
        self.m_slots = int(m_slots)
        self.n_steps = int(n_steps)
        self.batch_size = int(batch_size)
        self._n_train = np.array([c.n_train for c in dataset.clients])
        n_pad = int(self._n_train.max())
        feat = dataset.clients[0].x_train.shape[1:]
        x_all = np.zeros((dataset.n_clients, n_pad) + feat, dtype=np.float32)
        y_all = np.zeros((dataset.n_clients, n_pad), dtype=np.int32)
        for i, c in enumerate(dataset.clients):
            x_all[i, : c.n_train] = c.x_train
            y_all[i, : c.n_train] = c.y_train
        # device-resident for the whole run; per-round traffic is indices only
        self._x_all = jnp.asarray(x_all)
        self._y_all = jnp.asarray(y_all)

    def run_round(
        self,
        params,
        distinct: np.ndarray,
        weights: np.ndarray,
        stale_weight: float,
        rng: np.random.Generator,
        loss_fn: LossFn,
        opt: Optimizer,
        fedprox_mu: float = 0.0,
    ):
        """Returns (new_params, (c, d) flat updates, (c,) losses) for the
        ``c = len(distinct)`` realized clients."""
        c = len(distinct)
        if c == 0 or c > self.m_slots:
            raise ValueError(f"got {c} distinct clients for {self.m_slots} slots")
        slot_ids = np.zeros(self.m_slots, dtype=np.int32)
        slot_ids[:c] = distinct
        idx = np.zeros((self.m_slots, self.n_steps, self.batch_size), dtype=np.int32)
        for i, cid in enumerate(distinct):
            # same rng stream as the compat loop's draw_batch_indices, drawn
            # host-side (one device transfer for the whole block below)
            idx[i] = rng.integers(
                0, int(self._n_train[int(cid)]), size=(self.n_steps, self.batch_size)
            )
        w = np.zeros(self.m_slots, dtype=np.float32)
        w[:c] = weights
        new_params, updates, losses = batched_round_step(
            params,
            self._x_all,
            self._y_all,
            jnp.asarray(slot_ids),
            jnp.asarray(idx),
            jnp.asarray(w),
            jnp.asarray(stale_weight, jnp.float32),
            loss_fn=loss_fn,
            opt=opt,
            fedprox_mu=fedprox_mu,
        )
        # slice on the host: device slicing with the round-varying c would
        # trigger a fresh compile per distinct-count
        return new_params, np.asarray(updates)[:c], np.asarray(losses)[:c]
