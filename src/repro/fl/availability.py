"""Availability history: per-client presence scores driving plan rebuilds.

PR 6 made the *draw* availability-aware (re-normalized urns, unbiased over
the available set); the plan itself was still clustered over the full
fleet, so a client that vanished weeks ago kept shaping the similarity
groups. This module closes that gap the FedSTaS way (Slessor et al., 2024):
restratify on the *observed* population. An :class:`AvailabilityTracker`
folds each round's availability mask plus the drawn participants' response
outcomes — on-time, late (straggled past the deadline but delivered), or
crashed — into one exponentially-decayed presence score per client::

    score_i  ←  decay · score_i + (1 − decay) · signal_i

where ``signal_i`` is the availability mask (0/1) for undrawn clients and,
for drawn participants, the graded response outcome: 1.0 on-time,
``late_credit`` late, 0.0 crashed. Scores start at 1.0 (optimistic cold
start: the version-0 plan clusters everyone, exactly the paper's setting).

Consumers:

* :meth:`active_mask` (``score ≥ threshold``) restricts which clients the
  *clustering* step of a plan rebuild groups by similarity
  (``build_plan_algorithm2(cluster_mask=...)``). The plan itself still
  covers every client with its exact eq. (8) mass — low-score clients are
  packed into capacity-feasible filler groups instead of being clustered —
  so every drawn plan stays exactly unbiased over whatever clients turn
  out to be available (the ``conditional_plan`` guarantee needs eq. (8)
  and nothing else; property-tested in ``tests/test_statistics_property``).
* :class:`~repro.fl.planner.AssignmentDriftMonitor` takes the mask as its
  churn term, so fleet turnover alone can trigger a rebuild even when the
  surviving clients' gradients have not drifted.

The score buffer is device-resident when jax is present (one jitted fused
multiply-add per round, mirroring :class:`~repro.fl.gradient_store.
GradientStore`'s backend split) with a bit-identical numpy fallback, and
checkpoints inside ``ServerState`` (:meth:`state_arrays`/:meth:`state_meta`
ride the server's .npz pytree / JSON sidecar) so a killed service resumes
its presence history mid-decay, bit-identically.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def _jnp():
    try:
        import jax  # noqa: F401
        import jax.numpy as jnp
    except ImportError:
        return None
    return jnp


class AvailabilityTracker:
    """Exponentially-decayed per-client presence scores in [0, 1].

    ``decay`` is the history half-life knob (0.9 ≈ the last ~10 rounds
    dominate); ``threshold`` is the :meth:`active_mask` cut; ``late_credit``
    is the graded signal a straggler earns — between a crash (0.0) and an
    on-time report (1.0), so a persistently-slow client decays toward
    ``late_credit`` instead of toward dead.
    """

    def __init__(
        self,
        n_clients: int,
        *,
        decay: float = 0.9,
        threshold: float = 0.25,
        late_credit: float = 0.5,
        backend: str = "auto",
    ):
        if n_clients <= 0:
            raise ValueError("n_clients must be positive")
        if not 0.0 <= decay < 1.0:
            raise ValueError(f"decay must be in [0, 1), got {decay}")
        if not 0.0 <= threshold <= 1.0:
            raise ValueError(f"threshold must be in [0, 1], got {threshold}")
        if not 0.0 <= late_credit <= 1.0:
            raise ValueError(f"late_credit must be in [0, 1], got {late_credit}")
        if backend not in ("auto", "jax", "numpy"):
            raise ValueError(f"unknown availability backend {backend!r}")
        self.n_clients = int(n_clients)
        self.decay = float(decay)
        self.threshold = float(threshold)
        self.late_credit = float(late_credit)
        self.rounds_seen = 0
        jnp = _jnp() if backend in ("auto", "jax") else None
        if backend == "jax" and jnp is None:
            raise RuntimeError("availability backend 'jax' requires jax")
        self._jnp = jnp
        if jnp is not None:
            import jax

            d = np.float32(self.decay)

            def fold(scores, signal):
                return d * scores + (np.float32(1.0) - d) * signal

            self._fold = jax.jit(fold)
            self._scores = jnp.ones(self.n_clients, jnp.float32)
        else:
            self._fold = None
            self._scores = np.ones(self.n_clients, np.float32)

    # -- per-round update ----------------------------------------------------
    def update(
        self,
        mask: Optional[np.ndarray],
        *,
        on_time: Optional[np.ndarray] = None,
        late: Optional[np.ndarray] = None,
        crashed: Optional[np.ndarray] = None,
    ) -> None:
        """Fold one round's availability + response outcomes into the scores.

        ``mask`` is the round's availability mask ((n,) bool; ``None`` = the
        fixed-population all-available case). ``on_time``/``late``/
        ``crashed`` are disjoint id arrays over the round's drawn
        participants; their graded outcome overrides the mask signal — a
        drawn client that crashed mid-round scores 0.0 even though the
        availability mask admitted it.
        """
        signal = (
            np.ones(self.n_clients, np.float32)
            if mask is None
            else np.asarray(mask, dtype=bool).astype(np.float32)
        )
        if signal.shape != (self.n_clients,):
            raise ValueError(
                f"availability mask shape {signal.shape} != ({self.n_clients},)"
            )
        for ids, value in (
            (on_time, 1.0),
            (late, self.late_credit),
            (crashed, 0.0),
        ):
            if ids is not None and len(ids):
                signal[np.asarray(ids, np.int64)] = np.float32(value)
        if self._jnp is not None:
            self._scores = self._fold(self._scores, self._jnp.asarray(signal))
        else:
            self._scores = (
                np.float32(self.decay) * self._scores
                + np.float32(1.0 - self.decay) * signal
            )
        self.rounds_seen += 1

    # -- consumers -----------------------------------------------------------
    def scores(self) -> np.ndarray:
        """Host f32 copy of the (n,) presence scores."""
        return np.asarray(self._scores)

    def active_mask(self, threshold: Optional[float] = None) -> np.ndarray:
        """Boolean (n,) mask of clients worth clustering: score ≥ threshold."""
        thr = self.threshold if threshold is None else float(threshold)
        return self.scores() >= np.float32(thr)

    def min_score(self) -> float:
        """The fleet's weakest presence score (``RoundRecord.avail_score_min``)."""
        return float(self.scores().min())

    # -- checkpointable state ------------------------------------------------
    def state_arrays(self) -> dict:
        return {"avail_scores": self.scores()}

    def state_meta(self) -> dict:
        return {
            "decay": self.decay,
            "threshold": self.threshold,
            "late_credit": self.late_credit,
            "rounds_seen": self.rounds_seen,
        }

    def load_state(self, meta: dict, arrays: dict) -> None:
        """Restore a checkpointed score buffer; bit-exact continuation.

        The decay constants are identity: restoring a history folded under
        different knobs would silently re-grade the whole fleet, so a
        mismatch raises instead.
        """
        have = (self.decay, self.threshold, self.late_credit)
        want = (
            float(meta["decay"]),
            float(meta["threshold"]),
            float(meta["late_credit"]),
        )
        if have != want:
            raise ValueError(
                f"checkpointed availability knobs (decay, threshold, "
                f"late_credit)={want} != this tracker's {have}; the decayed "
                "history is only meaningful under the knobs that produced it"
            )
        scores = np.asarray(arrays["avail_scores"], np.float32)
        if scores.shape != (self.n_clients,):
            raise ValueError(
                f"checkpointed scores shape {scores.shape} != ({self.n_clients},)"
            )
        self._scores = self._jnp.asarray(scores) if self._jnp is not None else scores.copy()
        self.rounds_seen = int(meta["rounds_seen"])


__all__ = ["AvailabilityTracker"]
