"""Federated partitioners reproducing the paper's experimental settings.

* ``by_class_shards`` — the controlled MNIST setting of Fig. 1: each client
  owns exactly one digit; 10 clients per digit; balanced sample counts.
* ``dirichlet_labels`` — the CIFAR10 setting of Fig. 2 / Appendix D: each
  client's class mixture drawn from Dir(alpha); unbalanced sizes with the
  paper's profile 10×100, 30×250, 30×500, 20×750, 10×1000 train samples and
  test = train/5.
"""
from __future__ import annotations

import numpy as np

from repro.data.federated import ClientData, FederatedDataset
from repro.data.synthetic import make_classification_data

PAPER_SIZE_PROFILE: tuple[tuple[int, int], ...] = (
    (10, 100),
    (30, 250),
    (30, 500),
    (20, 750),
    (10, 1000),
)


def by_class_shards(
    n_classes: int = 10,
    clients_per_class: int = 10,
    train_per_client: int = 500,
    test_per_client: int = 100,
    dim: int = 64,
    noise: float = 1.0,
    seed: int = 0,
) -> FederatedDataset:
    """Fig. 1 setting: client c owns only class ``c // clients_per_class``."""
    clients = []
    for c in range(n_classes * clients_per_class):
        cls = c // clients_per_class
        ytr = np.full(train_per_client, cls)
        yte = np.full(test_per_client, cls)
        xtr, ytr = make_classification_data(
            len(ytr), n_classes, dim, noise, seed=seed * 100003 + 2 * c, class_of=ytr
        )
        xte, yte = make_classification_data(
            len(yte), n_classes, dim, noise, seed=seed * 100003 + 2 * c + 1, class_of=yte
        )
        clients.append(ClientData(xtr, ytr, xte, yte))
    return FederatedDataset(clients)


def dirichlet_class_mixtures(
    n_clients: int, n_classes: int, alpha: float, seed: int
) -> np.ndarray:
    """Per-client class mixture π_c ~ Dir(alpha·1). alpha=0 -> one-hot."""
    rng = np.random.default_rng(seed)
    if alpha <= 0:
        mixtures = np.zeros((n_clients, n_classes))
        mixtures[np.arange(n_clients), rng.integers(0, n_classes, n_clients)] = 1.0
        return mixtures
    return rng.dirichlet(np.full(n_classes, alpha), size=n_clients)


def dirichlet_labels(
    alpha: float,
    n_classes: int = 10,
    size_profile: tuple[tuple[int, int], ...] = PAPER_SIZE_PROFILE,
    dim: int = 64,
    noise: float = 1.0,
    seed: int = 0,
) -> FederatedDataset:
    """Fig. 2 setting: Dir(alpha) class mixtures over the unbalanced profile."""
    sizes = [n for count, n in size_profile for _ in range(count)]
    n_clients = len(sizes)
    mixtures = dirichlet_class_mixtures(n_clients, n_classes, alpha, seed)
    rng = np.random.default_rng(seed + 1)
    clients = []
    for c, n_train in enumerate(sizes):
        n_test = max(n_train // 5, 1)
        ytr = rng.choice(n_classes, size=n_train, p=mixtures[c])
        yte = rng.choice(n_classes, size=n_test, p=mixtures[c])
        xtr, ytr = make_classification_data(
            n_train, n_classes, dim, noise, seed=seed * 100003 + 2 * c, class_of=ytr
        )
        xte, yte = make_classification_data(
            n_test, n_classes, dim, noise, seed=seed * 100003 + 2 * c + 1, class_of=yte
        )
        clients.append(ClientData(xtr, ytr, xte, yte))
    return FederatedDataset(clients)
