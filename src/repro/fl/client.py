"""Client-side local work: N SGD steps from the received global model.

``local_steps`` is the un-jitted scan body shared by two callers:

* ``local_update`` — the jitted single-client entry point used by the
  ``compat`` (looped) server path; shapes are static ((N, B) index matrix),
  so one compile covers the whole FL run.
* ``repro.fl.engine`` — the batched round engine vmaps ``local_steps`` over
  a stacked client axis so every sampled client's round runs in one jit.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer, apply_updates

LossFn = Callable[..., jnp.ndarray]  # (params, x, y, [global_params]) -> scalar


def local_steps(
    params,
    x: jnp.ndarray,
    y: jnp.ndarray,
    batch_idx: jnp.ndarray,  # (N, B) int32 rows into x/y
    loss_fn: LossFn,
    opt: Optimizer,
    fedprox_mu: float = 0.0,
):
    """Run N local steps; returns (updated params, mean local loss)."""
    global_params = params

    def step(carry, idx):
        p, opt_state, t = carry
        xb, yb = x[idx], y[idx]
        if fedprox_mu:
            loss, grads = jax.value_and_grad(
                lambda q: loss_fn(q, xb, yb, global_params, fedprox_mu)
            )(p)
        else:
            loss, grads = jax.value_and_grad(lambda q: loss_fn(q, xb, yb))(p)
        updates, opt_state = opt.update(grads, opt_state, p, t)
        return (apply_updates(p, updates), opt_state, t + 1), loss

    init = (params, opt.init(params), jnp.zeros((), jnp.int32))
    (new_params, _, _), losses = jax.lax.scan(step, init, batch_idx)
    return new_params, losses.mean()


@functools.partial(jax.jit, static_argnames=("loss_fn", "opt", "fedprox_mu"))
def local_update(
    params,
    x: jnp.ndarray,
    y: jnp.ndarray,
    batch_idx: jnp.ndarray,
    loss_fn: LossFn,
    opt: Optimizer,
    fedprox_mu: float = 0.0,
):
    """Jitted single-client round (the ``compat`` reference path)."""
    return local_steps(params, x, y, batch_idx, loss_fn, opt, fedprox_mu)


def draw_batch_indices(rng, n_data: int, n_steps: int, batch_size: int) -> jnp.ndarray:
    """Pre-draw the (N, B) batch index matrix for one client round."""
    return jnp.asarray(
        rng.integers(0, n_data, size=(n_steps, batch_size)), dtype=jnp.int32
    )
