"""deepseek-v2-lite-16b [moe] — MLA + fine-grained MoE (arXiv:2405.04434).

27L d_model=2048 16H d_ff_expert=1408 vocab=102400. MLA kv_lora_rank=512
(+64 rotary dims -> 576-dim compressed cache). Layer 0 is a dense MLP
(d_ff 10944 per the model card); layers 1..26 are MoE with 2 shared +
64 routed experts, top-6.

NOTE: the assignment header says "MoE 64e top-6" while its bracket note
says "160 routed" (the full DeepSeek-V2). We follow the header — 64 routed —
and record the discrepancy here and in DESIGN.md §Arch-applicability.
"""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=10944,  # dense layer 0 (model card); experts use moe.d_ff_expert
        vocab_size=102400,
        first_blocks=(("mla", "mlp"),),
        pattern=(("mla", "moe"),),
        rope_theta=10_000.0,
        moe=MoEConfig(
            n_routed=64,
            n_shared=2,
            top_k=6,
            d_ff_expert=1408,
            group_size=2048,
            capacity_factor=1.25,
        ),
        mla=MLAConfig(
            kv_lora_rank=512,
            rope_head_dim=64,
            nope_head_dim=128,
            v_head_dim=128,
            decode_mode="naive",  # paper-faithful; 'absorbed' is the §Perf variant
        ),
        source="arXiv:2405.04434",
    )
