"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2:1 (arXiv:2402.19427).

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000. Griffin pattern:
(recurrent, recurrent, local-attention) repeating; 38 = 12*3 + 2 trailing
recurrent blocks. Local attention window 2048 with RoPE.
"""
from repro.models.config import ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256_000,
        pattern=(("rglru", "mlp"), ("rglru", "mlp"), ("local", "mlp")),
        tail_blocks=(("rglru", "mlp"), ("rglru", "mlp")),
        sliding_window=2048,
        rope_theta=10_000.0,
        act="gelu",
        tie_embeddings=True,
        source="arXiv:2402.19427",
    )
