"""xlstm-125m [ssm] — sLSTM + mLSTM blocks (arXiv:2405.04517).

12L d_model=768 4H (kv=4) d_ff=0 vocab=50304. d_ff=0: xLSTM blocks carry
their own up/down projections; there is no separate FFN. Blocks alternate
mLSTM (matrix memory, parallel-form training) and sLSTM (scalar memory,
sequential scan) 1:1.
"""
from repro.models.config import ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        pattern=(("mlstm", "none"), ("slstm", "none")),
        mlstm_proj_factor=2.0,
        slstm_proj_factor=1.333334,
        tie_embeddings=True,
        source="arXiv:2405.04517",
    )
