"""qwen2.5-32b [dense] — GQA with QKV bias (hf:Qwen/Qwen2.5 family).

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064. The memory-pressure
stress case of the zoo (params must be FSDP-sharded to fit).
"""
from repro.models.config import ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=27648,
        vocab_size=152064,
        pattern=(("attn", "mlp"),),
        qkv_bias=True,
        rope_theta=1e6,
        sliding_window=8192,
        source="hf:Qwen/Qwen2.5-0.5B",
    )
