"""qwen3-0.6b [dense] — qk-norm, GQA (hf:Qwen/Qwen3-8B family conventions).

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936. Qwen3 uses an
explicit head_dim=128 (attention width 2048 != d_model), per-head RMS
qk-norm, no QKV bias, rope theta 1e6.
"""
from repro.models.config import ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b",
        family="dense",
        n_layers=28,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=3072,
        vocab_size=151936,
        pattern=(("attn", "mlp"),),
        qk_norm=True,
        qkv_bias=False,
        rope_theta=1e6,
        sliding_window=8192,  # long_500k sliding-window decode variant
        tie_embeddings=True,
        source="hf:Qwen/Qwen3-8B",
    )
