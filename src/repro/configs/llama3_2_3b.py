"""llama3.2-3b [dense] — small llama3 (hf:meta-llama/Llama-3.2-1B family).

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256, rope theta 5e5.
"""
from repro.models.config import ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=128256,
        pattern=(("attn", "mlp"),),
        qkv_bias=False,
        rope_theta=500_000.0,
        sliding_window=8192,
        tie_embeddings=True,
        source="hf:meta-llama/Llama-3.2-1B",
    )
