"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution (arXiv:2409.12191).

qwen2-1.5b text backbone + 3-section rotary (t/h/w). The vision tower is a
STUB per the brief: ``input_specs`` supplies precomputed patch embeddings
(B, n_vision_tokens, d_model) that replace the leading token slots; text
tokens use t = h = w positions exactly as Qwen2-VL does.
"""
from repro.models.config import ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        family="vlm",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        pattern=(("attn", "mlp"),),
        qkv_bias=True,
        rope_theta=1e6,
        mrope=True,
        mrope_sections=(16, 24, 24),  # pairs per t/h/w stream (head_dim 128)
        sliding_window=8192,
        frontend="vision",
        n_vision_tokens=256,
        tie_embeddings=True,
        source="arXiv:2409.12191",
    )
