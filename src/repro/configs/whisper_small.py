"""whisper-small [audio] — encoder-decoder (arXiv:2212.04356).

12L (decoder) d_model=768 12H (kv=12) d_ff=3072 vocab=51865, plus a 12-layer
bidirectional encoder over 1500 stubbed conv-frontend frames. The
mel-spectrogram + conv feature extractor is a STUB per the brief:
``input_specs`` supplies (B, 1500, d_model) frame embeddings.

Simplifications recorded in DESIGN.md: RMSNorm instead of LayerNorm,
computed sinusoidal decoder positions instead of learned (whisper's decoder
positions are learned and capped at 448 — the assigned decode shapes exceed
that by design of the shape grid, so a computed encoding is used).
"""
from repro.models.config import EncoderConfig, ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="audio",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab_size=51865,
        pattern=(("attn", "mlp"),),
        qkv_bias=True,
        act="gelu",
        encoder=EncoderConfig(n_layers=12, n_frames=1500),
        frontend="audio",
        sliding_window=8192,
        tie_embeddings=True,
        source="arXiv:2212.04356",
    )
