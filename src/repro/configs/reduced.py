"""Reduced variants of each architecture family for CPU smoke tests.

Per the brief: <= 2-ish layers (one period + required first/tail structure),
d_model <= 512, <= 4 experts; same family/block structure as the full config
so the smoke test exercises the identical code path.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import EncoderConfig, MLAConfig, ModelConfig, MoEConfig


def make_reduced(cfg: ModelConfig, *, d_model: int = 128, vocab: int = 512) -> ModelConfig:
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    head_dim = 32 if cfg.head_dim else 0
    # one period + structural prefix/suffix
    n_layers = len(cfg.first_blocks) + len(cfg.pattern) + len(cfg.tail_blocks)

    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(
            moe, n_routed=4, n_shared=min(moe.n_shared, 1), top_k=2,
            d_ff_expert=64, group_size=64, capacity_factor=2.0,
        )
    mla = cfg.mla
    if mla is not None:
        mla = dataclasses.replace(
            mla, kv_lora_rank=32, rope_head_dim=16, nope_head_dim=32, v_head_dim=32
        )
    enc = cfg.encoder
    if enc is not None:
        enc = EncoderConfig(n_layers=2, n_frames=16)

    hd = head_dim or d_model // n_heads
    sections = (hd // 2 - 2 * (hd // 6), hd // 6, hd // 6)  # t/h/w pairs, sums to hd//2

    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=vocab,
        sliding_window=16,
        mrope_sections=sections,
        moe=moe,
        mla=mla,
        encoder=enc,
        n_vision_tokens=4,
        dtype="float32",  # CPU numerics for smoke assertions
        remat=False,
    )
