"""Architecture registry: ``get_config(name)`` / ``--arch <id>``."""
from __future__ import annotations

from repro.configs import (
    deepseek_v2_lite_16b,
    llama3_2_3b,
    qwen2_1_5b,
    qwen2_5_32b,
    qwen2_moe_a2_7b,
    qwen2_vl_2b,
    qwen3_0_6b,
    recurrentgemma_9b,
    whisper_small,
    xlstm_125m,
)
from repro.configs.reduced import make_reduced
from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig

_MODULES = {
    "xlstm-125m": xlstm_125m,
    "qwen3-0.6b": qwen3_0_6b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "qwen2-1.5b": qwen2_1_5b,
    "qwen2.5-32b": qwen2_5_32b,
    "llama3.2-3b": llama3_2_3b,
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b,
    "qwen2-vl-2b": qwen2_vl_2b,
    "whisper-small": whisper_small,
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str, *, reduced: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    cfg = _MODULES[name].build()
    cfg.validate()
    return make_reduced(cfg) if reduced else cfg


__all__ = [
    "get_config",
    "make_reduced",
    "ARCH_NAMES",
    "INPUT_SHAPES",
    "InputShape",
    "ModelConfig",
]
