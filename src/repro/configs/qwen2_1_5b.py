"""qwen2-1.5b [dense] — GQA with QKV bias (arXiv:2407.10671).

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
"""
from repro.models.config import ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b",
        family="dense",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        pattern=(("attn", "mlp"),),
        qkv_bias=True,
        rope_theta=1e6,
        sliding_window=8192,
        tie_embeddings=True,
        source="arXiv:2407.10671",
    )
