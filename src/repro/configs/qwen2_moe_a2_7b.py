"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed, top-4 (hf:Qwen/Qwen1.5-MoE-A2.7B).

24L d_model=2048 16H (kv=16) d_ff_expert=1408 vocab=151936. Every layer is
MoE (Qwen1.5-MoE layout); shared experts are always-on.
"""
from repro.models.config import ModelConfig, MoEConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=151936,
        pattern=(("attn", "moe"),),
        qkv_bias=True,
        rope_theta=1e6,
        sliding_window=8192,
        moe=MoEConfig(
            n_routed=60,
            n_shared=4,
            top_k=4,
            d_ff_expert=1408,
            group_size=2048,
            capacity_factor=1.25,
        ),
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    )
