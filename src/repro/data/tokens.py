"""Synthetic LM token pipeline for the production tier.

Deterministic on-the-fly batch synthesis (no corpus offline): a hash-mixed
counter stream mapped into the vocab, with next-token structure injected so
the loss actually decreases (target = affine function of current token mod
vocab). Enough signal for end-to-end driver runs and overfit tests; shapes
and dtypes match a real pipeline exactly.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenBatch:
    tokens: np.ndarray  # (B, S) int32 inputs
    targets: np.ndarray  # (B, S) int32 next tokens
    # loss mask left implicit (all ones) — synthetic stream has no padding


class TokenPipeline:
    def __init__(self, vocab_size: int, batch_size: int, seq_len: int, seed: int = 0):
        self.vocab_size = int(vocab_size)
        self.batch_size = int(batch_size)
        self.seq_len = int(seq_len)
        self._rng = np.random.default_rng(seed)

    def next_batch(self) -> TokenBatch:
        b, s, v = self.batch_size, self.seq_len, self.vocab_size
        base = self._rng.integers(0, v, size=(b, 1), dtype=np.int64)
        pos = np.arange(s, dtype=np.int64)[None, :]
        # structured stream: token_t = (base + 31*t) mod v -> learnable
        toks = (base + 31 * pos) % v
        tgts = (toks * 1 + 31) % v  # next token in the same progression
        return TokenBatch(toks.astype(np.int32), tgts.astype(np.int32))

    def __iter__(self):
        while True:
            yield self.next_batch()
