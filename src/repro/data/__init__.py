from repro.data.synthetic import make_classification_data
from repro.data.federated import ClientData, FederatedDataset
from repro.data.tokens import TokenBatch, TokenPipeline

__all__ = [
    "make_classification_data",
    "ClientData",
    "FederatedDataset",
    "TokenBatch",
    "TokenPipeline",
]
