"""Federated dataset container: per-client train/test arrays + population."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.types import ClientPopulation


@dataclasses.dataclass
class ClientData:
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def n_train(self) -> int:
        return len(self.y_train)


@dataclasses.dataclass
class FederatedDataset:
    clients: list[ClientData]

    @property
    def n_clients(self) -> int:
        return len(self.clients)

    @property
    def population(self) -> ClientPopulation:
        return ClientPopulation(np.array([c.n_train for c in self.clients]))

    def global_test(self) -> tuple[np.ndarray, np.ndarray]:
        xs = np.concatenate([c.x_test for c in self.clients])
        ys = np.concatenate([c.y_test for c in self.clients])
        return xs, ys

    def class_of_client(self) -> np.ndarray:
        """Majority class per client (used by oracle 'target' grouping)."""
        return np.array(
            [np.bincount(c.y_train, minlength=10).argmax() for c in self.clients]
        )
