"""Synthetic datasets standing in for MNIST / CIFAR10 (offline container).

The paper's claims concern *sampling statistics and convergence shape* under
heterogeneous federated partitions, not pixel statistics — we reproduce the
exact federated structure (100 clients, 10 classes, the unbalanced size
profile, Dirichlet partitioning) over class-conditional Gaussian mixtures
whose class overlap is controlled by ``noise``. Recorded in EXPERIMENTS.md
next to each figure.
"""
from __future__ import annotations

import numpy as np


def make_classification_data(
    n_samples: int,
    n_classes: int = 10,
    dim: int = 64,
    noise: float = 1.0,
    seed: int = 0,
    class_of: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Class-conditional Gaussians: x ~ N(mu_c, noise² I), mu_c ~ N(0, I).

    Returns float32 features (n_samples, dim) and int32 labels. ``class_of``
    optionally fixes each sample's label (used by the partitioners, which
    decide labels first and then materialize features).
    """
    rng = np.random.default_rng(seed)
    # class means drawn once from a fixed RNG so every client shares geometry
    mu = np.random.default_rng(12345).normal(size=(n_classes, dim)) * 2.0
    if class_of is None:
        class_of = rng.integers(0, n_classes, size=n_samples)
    y = np.asarray(class_of, dtype=np.int32)
    x = mu[y] + noise * rng.normal(size=(len(y), dim))
    return x.astype(np.float32), y
