"""Model configuration for the assigned architecture zoo.

One :class:`ModelConfig` describes any of the 10 assigned architectures.
A model is a stack of *blocks*; each block is a ``(mixer, ffn)`` pair.
The stack is ``first_blocks`` (unstacked prefix, e.g. DeepSeek's dense
layer 0) + ``pattern`` repeated ``n_repeats`` times (lax.scan over stacked
params) + ``tail_blocks`` (unstacked remainder, e.g. RecurrentGemma's
38 = 12*3 + 2).

Mixer kinds:  attn | local | mla | mlstm | slstm | rglru | bidir (encoder)
FFN kinds:    mlp | moe | none
"""
from __future__ import annotations

import dataclasses
from typing import Optional

BlockSpec = tuple[str, str]  # (mixer, ffn)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    n_shared: int
    top_k: int
    d_ff_expert: int
    # GShard-style capacity dispatch: tokens per group and capacity factor
    group_size: int = 2048
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128
    # decode path: "naive" re-expands the compressed cache each step;
    # "absorbed" folds W_UK into the query (beyond-paper §Perf variant)
    decode_mode: str = "naive"


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder consuming stubbed conv-frontend embeddings."""

    n_layers: int = 12
    n_frames: int = 1500  # 30 s of audio at 10 ms hop / 2 (conv stride)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | ssm | hybrid | moe | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # --- stack structure ---
    pattern: tuple[BlockSpec, ...] = (("attn", "mlp"),)
    first_blocks: tuple[BlockSpec, ...] = ()
    tail_blocks: tuple[BlockSpec, ...] = ()
    # --- attention options ---
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mrope: bool = False  # Qwen2-VL 3-section rotary
    mrope_sections: tuple[int, int, int] = (16, 24, 24)  # t/h/w halves of head_dim
    sliding_window: int = 4096  # for "local" mixers & long-context dense decode
    logit_softcap: float = 0.0
    # --- recurrent options ---
    rglru_conv_width: int = 4
    lru_width: int = 0  # 0 -> d_model
    mlstm_chunk: int = 0  # >0: chunkwise-recurrent mLSTM (O(S·chunk), §Perf)
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 1.333334
    # --- other substructure ---
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[str] = None  # 'audio' | 'vision' (stubbed embeddings)
    n_vision_tokens: int = 256  # VLM: prefix patch-embedding slots
    # --- numerics ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"
    dtype: str = "bfloat16"  # activation compute dtype
    param_dtype: str = "float32"
    # memory knobs (exercised by §Perf; defaults = paper-faithful baseline)
    attn_block_q: int = 0  # 0 -> vanilla attention; >0 -> blockwise online-softmax
    remat: bool = True
    # Megatron-SP style sequence-parallel residual stream: the hidden states
    # between blocks are sharded over ("model", seq) so per-layer TP traffic
    # becomes all-gather/reduce-scatter pairs on bf16 activations instead of
    # f32 all-reduces of activation gradients (§Perf collective lever).
    seq_parallel_residual: bool = False
    # lax.scan over layer repeats (runtime default). The dry-run unrolls
    # (scan_layers=False): XLA's cost_analysis counts while-loop bodies ONCE,
    # so scanned-layer FLOPs/bytes/collectives would be undercounted by
    # n_repeats× (verified empirically; see EXPERIMENTS.md §Dry-run notes).
    scan_layers: bool = True
    fused_ce: bool = False  # chunked cross-entropy (never materialize full logits)
    source: str = ""  # citation for the config

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def n_repeats(self) -> int:
        body = self.n_layers - len(self.first_blocks) - len(self.tail_blocks)
        if body % len(self.pattern):
            raise ValueError(
                f"{self.name}: body layers {body} not divisible by pattern "
                f"period {len(self.pattern)}"
            )
        return body // len(self.pattern)

    @property
    def all_blocks(self) -> tuple[BlockSpec, ...]:
        return self.first_blocks + self.pattern * self.n_repeats + self.tail_blocks

    def validate(self) -> None:
        assert self.n_heads % self.n_kv_heads == 0, "GQA requires n_heads % n_kv_heads == 0"
        assert len(self.all_blocks) == self.n_layers
        for mixer, ffn in self.pattern + self.first_blocks + self.tail_blocks:
            assert mixer in ("attn", "local", "mla", "mlstm", "slstm", "rglru", "bidir"), mixer
            assert ffn in ("mlp", "moe", "none"), ffn
        if any(f == "moe" for _, f in self.all_blocks):
            assert self.moe is not None
        if any(m == "mla" for m, _ in self.all_blocks):
            assert self.mla is not None


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned workload shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
