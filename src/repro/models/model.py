"""Decoder-LM / encoder-decoder assembly over the block zoo.

Layer stacking: ``first_blocks`` and ``tail_blocks`` are plain python loops;
the repeating ``pattern`` body is a ``lax.scan`` over parameter stacks with a
leading ``n_repeats`` axis (keeps HLO size O(period), not O(depth) — the
40-combination dry-run matrix depends on this). ``jax.checkpoint`` wraps the
scan body when ``cfg.remat``.

Three entry points:
  * ``forward``     — full-sequence (train / prefill); returns hidden states,
                      refreshed caches (when given) and the MoE aux loss.
  * ``decode_step`` — one token against a cache pytree.
  * ``loss_fn``     — next-token CE; ``cfg.fused_ce`` computes it in vocab
                      chunks over the sequence without materializing the
                      (B, S, V) logits (§Perf memory lever).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import blocks as blk
from repro.models.config import ModelConfig
from repro.models.layers.norms import init_rmsnorm, rmsnorm
from repro.models.layers.rotary import mrope_angles, rope_angles
from repro.models.sharding_hints import constrain

Params = dict[str, Any]
Cache = dict[str, Any]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key) -> Params:
    cfg.validate()
    keys = jax.random.split(key, 8)
    d, v = cfg.d_model, cfg.vocab_size
    cross = cfg.encoder is not None
    params: Params = {
        "embed": (jax.random.normal(keys[0], (v, d)) * d**-0.5).astype(jnp.float32),
        "final_norm": init_rmsnorm(d),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(keys[1], (d, v)) * d**-0.5).astype(jnp.float32)

    params["first"] = tuple(
        blk.init_block(cfg, kind, jax.random.fold_in(keys[2], i), cross=cross)
        for i, kind in enumerate(cfg.first_blocks)
    )
    period = cfg.pattern

    def init_period(k):
        return {
            f"pos{i}": blk.init_block(cfg, kind, jax.random.fold_in(k, i), cross=cross)
            for i, kind in enumerate(period)
        }

    rep_keys = jax.random.split(keys[3], cfg.n_repeats)
    params["stack"] = jax.vmap(init_period)(rep_keys)
    params["tail"] = tuple(
        blk.init_block(cfg, kind, jax.random.fold_in(keys[4], i), cross=cross)
        for i, kind in enumerate(cfg.tail_blocks)
    )

    if cfg.encoder is not None:
        enc = cfg.encoder

        def init_enc_layer(k):
            return {"pos0": blk.init_block(cfg, ("bidir", "mlp"), k)}

        params["encoder"] = {
            "stack": jax.vmap(init_enc_layer)(jax.random.split(keys[5], enc.n_layers)),
            "final_norm": init_rmsnorm(d),
        }
    return params


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


# --------------------------------------------------------------------------
# positions
# --------------------------------------------------------------------------
def make_angles(cfg: ModelConfig, positions: jnp.ndarray) -> Optional[jnp.ndarray]:
    """positions (S,) -> rope angles (S, bands) or None for rope-free archs."""
    if cfg.encoder is not None:  # whisper: sinusoidal adds, no rotary
        return None
    if cfg.mla is not None:
        hd = cfg.mla.rope_head_dim
    else:
        hd = cfg.resolved_head_dim
    if not any(m in ("attn", "local", "mla") for m, _ in cfg.all_blocks):
        return None  # pure-recurrent archs (xLSTM)
    if cfg.mrope:
        pos3 = jnp.stack([positions] * 3)  # text stream: t = h = w
        return mrope_angles(pos3, hd, cfg.rope_theta, cfg.mrope_sections)
    return rope_angles(positions, hd, cfg.rope_theta)


def sinusoidal(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    """Whisper-style sinusoidal position encodings (computed, any length)."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# encoder (whisper)
# --------------------------------------------------------------------------
def encode(cfg: ModelConfig, params: Params, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: stubbed conv-frontend output (B, F, D) -> encoder states."""
    enc = params["encoder"]
    x = frames.astype(cfg.dtype)
    x = x + sinusoidal(jnp.arange(x.shape[1]), cfg.d_model).astype(x.dtype)

    def body(carry, layer_p):
        h, _ = blk.block_apply(
            cfg, ("bidir", "mlp"), layer_p["pos0"], carry, angles=None, mode="full"
        )[:2]
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, enc["stack"])
    else:
        x, _ = _unrolled_scan(body, x, enc["stack"], cfg.encoder.n_layers)
    return rmsnorm(enc["final_norm"], x, cfg.norm_eps)


# --------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# --------------------------------------------------------------------------
def forward(
    cfg: ModelConfig,
    params: Params,
    tokens: jnp.ndarray,  # (B, S) int32
    *,
    vision_embeds: Optional[jnp.ndarray] = None,  # (B, P, D) VLM stub
    frames: Optional[jnp.ndarray] = None,  # (B, F, D) audio stub
    caches: Optional[Cache] = None,
    decode_window: int = 0,
) -> tuple[jnp.ndarray, Optional[Cache], jnp.ndarray]:
    """Returns (hidden (B,S,D), caches', aux_loss)."""
    b, s = tokens.shape
    dt = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"].astype(dt), tokens, axis=0)
    if vision_embeds is not None:
        p = vision_embeds.shape[1]
        x = jnp.concatenate([vision_embeds.astype(dt), x[:, p:]], axis=1)
    enc_out = None
    if cfg.encoder is not None:
        enc_out = encode(cfg, params, frames)
        x = x + sinusoidal(jnp.arange(s), cfg.d_model).astype(dt)

    angles = make_angles(cfg, jnp.arange(s))
    aux = jnp.zeros((), jnp.float32)

    kw = dict(angles=angles, mode="full", enc_out=enc_out, decode_window=decode_window)

    def residual_constraint(h):
        if cfg.seq_parallel_residual:
            return constrain(h, "dp", "model", None)
        return h

    x = residual_constraint(x)
    new_first = []
    for i, kind in enumerate(cfg.first_blocks):
        c = caches["first"][i] if caches is not None else None
        x, nc, a = blk.block_apply(cfg, kind, params["first"][i], x, cache=c, **kw)
        x = residual_constraint(x)
        new_first.append(nc)
        aux = aux + a

    period = cfg.pattern

    def body(carry, xs):
        x, aux = carry
        layer_p = xs[0] if caches is not None else xs
        layer_c = xs[1] if caches is not None else None
        new_cs = {}
        for i, kind in enumerate(period):
            c = layer_c[f"pos{i}"] if layer_c is not None else None
            x, nc, a = blk.block_apply(cfg, kind, layer_p[f"pos{i}"], x, cache=c, **kw)
            x = residual_constraint(x)
            new_cs[f"pos{i}"] = nc
            aux = aux + a
        return (x, aux), (new_cs if caches is not None else None)

    if cfg.remat:
        body = jax.checkpoint(body)
    xs = (params["stack"], caches["stack"]) if caches is not None else params["stack"]
    if cfg.scan_layers:
        (x, aux), new_stack = jax.lax.scan(body, (x, aux), xs)
    else:
        (x, aux), new_stack = _unrolled_scan(body, (x, aux), xs, cfg.n_repeats)

    new_tail = []
    for i, kind in enumerate(cfg.tail_blocks):
        c = caches["tail"][i] if caches is not None else None
        x, nc, a = blk.block_apply(cfg, kind, params["tail"][i], x, cache=c, **kw)
        new_tail.append(nc)
        aux = aux + a

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    new_caches = None
    if caches is not None:
        new_caches = {
            "first": tuple(new_first),
            "stack": new_stack,
            "tail": tuple(new_tail),
            "pos": jnp.asarray(s, jnp.int32),
        }
    return x, new_caches, aux


def _unrolled_scan(body, carry, xs, length: int):
    """lax.scan semantics with a static python loop (dry-run cost accounting:
    XLA counts while-loop bodies once, so the roofline pass unrolls)."""
    ys = []
    for i in range(length):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree_util.tree_map(lambda *zs: jnp.stack(zs), *ys)
    else:
        stacked = None
    return carry, stacked


def logits_from_hidden(cfg: ModelConfig, params: Params, x: jnp.ndarray) -> jnp.ndarray:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    if logits.ndim == 3:
        return constrain(logits, "dp", None, "model")
    return constrain(logits, "dp", "model")


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------
def decode_step(
    cfg: ModelConfig,
    params: Params,
    token: jnp.ndarray,  # (B, 1) int32
    caches: Cache,
    *,
    decode_window: int = 0,
    input_embed: Optional[jnp.ndarray] = None,  # (B, 1, D) overrides the token
) -> tuple[jnp.ndarray, Cache]:
    """One-token serve step. Returns (logits (B, V), caches')."""
    dt = jnp.dtype(cfg.dtype)
    pos = caches["pos"]
    if input_embed is not None:
        x = input_embed.astype(dt)
    else:
        x = jnp.take(params["embed"].astype(dt), token, axis=0)
    if cfg.encoder is not None:
        x = x + sinusoidal(pos[None], cfg.d_model).astype(dt)
    angles = make_angles(cfg, pos[None])

    kw = dict(angles=angles, mode="decode", enc_out=None, decode_window=decode_window)

    new_first = []
    for i, kind in enumerate(cfg.first_blocks):
        x, nc, _ = blk.block_apply(cfg, kind, params["first"][i], x, cache=caches["first"][i], **kw)
        new_first.append(nc)

    period = cfg.pattern

    def body(x, xs):
        layer_p, layer_c = xs
        new_cs = {}
        for i, kind in enumerate(period):
            x, nc, _ = blk.block_apply(cfg, kind, layer_p[f"pos{i}"], x, cache=layer_c[f"pos{i}"], **kw)
            new_cs[f"pos{i}"] = nc
        return x, new_cs

    if cfg.scan_layers:
        x, new_stack = jax.lax.scan(body, x, (params["stack"], caches["stack"]))
    else:
        x, new_stack = _unrolled_scan(
            body, x, (params["stack"], caches["stack"]), cfg.n_repeats
        )

    new_tail = []
    for i, kind in enumerate(cfg.tail_blocks):
        x, nc, _ = blk.block_apply(cfg, kind, params["tail"][i], x, cache=caches["tail"][i], **kw)
        new_tail.append(nc)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, x)[:, 0, :]
    new_caches = {
        "first": tuple(new_first),
        "stack": new_stack,
        "tail": tuple(new_tail),
        "pos": pos + 1,
    }
    return logits, new_caches


def init_cache(
    cfg: ModelConfig,
    batch: int,
    cache_len: int,
    dtype=None,
    *,
    decode_window: int = 0,
) -> Cache:
    """Zero decode-state pytree for every block."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    cross_len = cfg.encoder.n_frames if cfg.encoder is not None else 0
    mk = lambda kind: blk.init_block_cache(
        cfg, kind, batch, cache_len, dtype, decode_window=decode_window, cross_len=cross_len
    )
    period = cfg.pattern

    def stack_caches(_):
        return {f"pos{i}": mk(kind) for i, kind in enumerate(period)}

    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (cfg.n_repeats,) + x.shape),
        stack_caches(None),
    )
    return {
        "first": tuple(mk(k) for k in cfg.first_blocks),
        "stack": stacked,
        "tail": tuple(mk(k) for k in cfg.tail_blocks),
        "pos": jnp.zeros((), jnp.int32),
    }


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------
def loss_fn(
    cfg: ModelConfig,
    params: Params,
    tokens: jnp.ndarray,
    targets: jnp.ndarray,
    *,
    vision_embeds=None,
    frames=None,
    aux_weight: float = 0.01,
) -> tuple[jnp.ndarray, dict]:
    hidden, _, aux = forward(
        cfg, params, tokens, vision_embeds=vision_embeds, frames=frames
    )
    if cfg.fused_ce:
        ce = _chunked_ce(cfg, params, hidden, targets)
    else:
        logits = logits_from_hidden(cfg, params, hidden).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ce = -jnp.take_along_axis(logp, targets[..., None].astype(jnp.int32), axis=-1).mean()
    total = ce + aux_weight * aux
    return total, {"ce": ce, "aux": aux}


def _chunked_ce(cfg: ModelConfig, params: Params, hidden: jnp.ndarray, targets) -> jnp.ndarray:
    """CE over sequence chunks — never materializes (B, S, V) at once.

    Static python loop (not lax.map) so the dry-run's cost analysis counts
    every chunk; chunk logits are rematerialized in the backward pass.
    """
    b, s, d = hidden.shape
    n_chunks = max(1, min(16, s // 512)) if s >= 512 else 1
    while s % n_chunks:
        n_chunks -= 1
    cs = s // n_chunks
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])

    @jax.checkpoint
    def one(h, t):
        logits = (h @ head.astype(h.dtype)).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(logp, t[..., None].astype(jnp.int32), axis=-1).sum()

    tot = jnp.zeros((), jnp.float32)
    for i in range(n_chunks):
        h = jax.lax.dynamic_slice_in_dim(hidden, i * cs, cs, axis=1)
        t = jax.lax.dynamic_slice_in_dim(targets, i * cs, cs, axis=1)
        tot = tot + one(h, t)
    return tot / (b * s)
