"""Small classifiers for the paper-faithful FL experiments.

The paper uses a 1-hidden-layer (50 units) fully connected net on MNIST and
the FedAvg CNN on CIFAR10. Both are expressed here as functional
(init, apply) pairs over plain dicts so the FL loop stays model-agnostic.
The "CNN" is an MLP with two hidden layers when features are flat synthetic
vectors (see data.synthetic rationale).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_mlp(dims: tuple[int, ...], seed: int = 0) -> dict:
    """dims = (in, hidden..., out); He-initialized dense stack."""
    params = {}
    keys = jax.random.split(jax.random.PRNGKey(seed), len(dims) - 1)
    for i, (d_in, d_out) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"w{i}"] = jax.random.normal(keys[i], (d_in, d_out)) * jnp.sqrt(2.0 / d_in)
        params[f"b{i}"] = jnp.zeros((d_out,))
    return params


def apply_mlp(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    n_layers = len(params) // 2
    h = x
    for i in range(n_layers):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def classification_loss(params: dict, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return softmax_xent(apply_mlp(params, x), y)


def accuracy(params: dict, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return (apply_mlp(params, x).argmax(-1) == y).mean()


def fedprox_loss(
    params: dict, x: jnp.ndarray, y: jnp.ndarray, global_params: dict, mu: float
) -> jnp.ndarray:
    """Local loss + (mu/2)||θ - θ_global||² (Appendix D.5, Li et al. 2018)."""
    base = classification_loss(params, x, y)
    prox = sum(
        jnp.sum(jnp.square(p - g))
        for p, g in zip(
            jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(global_params)
        )
    )
    return base + 0.5 * mu * prox
