"""Block init/apply dispatch: (mixer, ffn) pairs with pre-norm residuals.

One block =
    x = x + mixer(rmsnorm(x))          [+ cross-attention for enc-dec decoders]
    x = x + ffn(rmsnorm(x))            (ffn may be 'none' — xLSTM blocks)

``block_apply`` runs in two modes: ``full`` (train/prefill — whole sequence,
builds cache seeds) and ``decode`` (one token against per-block state).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.config import BlockSpec, ModelConfig
from repro.models.layers import attention as attn_lib
from repro.models.layers import mla as mla_lib
from repro.models.layers import moe as moe_lib
from repro.models.layers import rglru as rglru_lib
from repro.models.layers import xlstm as xlstm_lib
from repro.models.layers.mlp import init_mlp, mlp
from repro.models.layers.norms import init_rmsnorm, rmsnorm


def init_block(cfg: ModelConfig, kind: BlockSpec, key, *, cross: bool = False) -> dict:
    mixer, ffn = kind
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict[str, Any] = {"norm1": init_rmsnorm(cfg.d_model)}
    if mixer in ("attn", "local", "bidir"):
        p["attn"] = attn_lib.init_attention(cfg, k1)
    elif mixer == "mla":
        p["attn"] = mla_lib.init_mla(cfg, k1)
    elif mixer == "rglru":
        p["rec"] = rglru_lib.init_rglru_block(cfg, k1)
    elif mixer == "mlstm":
        p["rec"] = xlstm_lib.init_mlstm_block(cfg, k1)
    elif mixer == "slstm":
        p["rec"] = xlstm_lib.init_slstm_block(cfg, k1)
    else:
        raise ValueError(f"unknown mixer {mixer}")
    if cross:
        p["cross_norm"] = init_rmsnorm(cfg.d_model)
        p["cross"] = attn_lib.init_attention(cfg, k3)
    if ffn == "mlp":
        p["ffn_norm"] = init_rmsnorm(cfg.d_model)
        p["mlp"] = init_mlp(cfg.d_model, cfg.d_ff, k2)
    elif ffn == "moe":
        p["ffn_norm"] = init_rmsnorm(cfg.d_model)
        p["moe"] = moe_lib.init_moe(cfg, k2)
    return p


def init_block_cache(
    cfg: ModelConfig,
    kind: BlockSpec,
    batch: int,
    cache_len: int,
    dtype,
    *,
    decode_window: int = 0,
    cross_len: int = 0,
) -> dict:
    """Decode-state for one block. ``decode_window`` ring-buffers 'attn' blocks."""
    mixer, _ = kind
    cache: dict[str, Any] = {}
    if mixer in ("attn", "bidir"):
        length = min(cache_len, decode_window) if decode_window else cache_len
        cache = attn_lib.init_kv_cache(cfg, batch, length, dtype)
    elif mixer == "local":
        cache = attn_lib.init_kv_cache(cfg, batch, min(cache_len, cfg.sliding_window), dtype)
    elif mixer == "mla":
        cache = mla_lib.init_mla_cache(cfg, batch, cache_len, dtype)
    elif mixer == "rglru":
        cache = rglru_lib.init_rglru_state(cfg, batch, dtype)
    elif mixer == "mlstm":
        cache = xlstm_lib.init_mlstm_state(cfg, batch)
    elif mixer == "slstm":
        cache = xlstm_lib.init_slstm_state(cfg, batch)
    if cross_len:
        kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        cache["ck"] = jnp.zeros((batch, cross_len, kvh, hd), dtype)
        cache["cv"] = jnp.zeros((batch, cross_len, kvh, hd), dtype)
    return cache


def _mixer_window(cfg: ModelConfig, mixer: str, decode_window: int) -> int:
    if mixer == "local":
        return cfg.sliding_window
    if mixer == "attn":
        return decode_window
    return 0


def block_apply(
    cfg: ModelConfig,
    kind: BlockSpec,
    params: dict,
    x: jnp.ndarray,
    *,
    angles: Optional[jnp.ndarray],
    mode: str,  # 'full' | 'decode'
    cache: Optional[dict] = None,
    enc_out: Optional[jnp.ndarray] = None,
    decode_window: int = 0,
) -> tuple[jnp.ndarray, Optional[dict], jnp.ndarray]:
    """Returns (x, new_cache, aux_loss)."""
    mixer, ffn = kind
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    new_cache = cache

    if mixer in ("attn", "local", "bidir"):
        window = _mixer_window(cfg, mixer, decode_window)
        if mode == "full":
            y, kv = attn_lib.attention_full(
                cfg, params["attn"], h, angles, window=window, bidirectional=(mixer == "bidir")
            )
            if cache is not None:
                new_cache = dict(cache)
                new_cache.update(
                    pack_kv_cache(kv, cache["k"].shape[1], window, cache["k"].dtype)
                )
        else:
            sub = {k: cache[k] for k in ("k", "v", "pos")}
            y, upd = attn_lib.attention_decode(
                cfg, params["attn"], h, angles, sub, window=window
            )
            new_cache = dict(cache)
            new_cache.update(upd)
    elif mixer == "mla":
        if mode == "full":
            y, seed = mla_lib.mla_full(cfg, params["attn"], h, angles)
            if cache is not None:
                new_cache = dict(cache)
                s = seed["c"].shape[1]
                new_cache["c"] = jax.lax.dynamic_update_slice_in_dim(
                    cache["c"], seed["c"].astype(cache["c"].dtype), 0, axis=1
                )
                new_cache["k_rope"] = jax.lax.dynamic_update_slice_in_dim(
                    cache["k_rope"], seed["k_rope"].astype(cache["k_rope"].dtype), 0, axis=1
                )
                new_cache["pos"] = jnp.asarray(s, jnp.int32)
        else:
            y, new_cache = mla_lib.mla_decode(cfg, params["attn"], h, angles, cache)
    elif mixer == "rglru":
        y, st = rglru_lib.rglru_block(cfg, params["rec"], h, None if mode == "full" else cache)
        new_cache = st if (cache is not None or mode == "decode") else None
    elif mixer == "mlstm":
        y, st = xlstm_lib.mlstm_block(cfg, params["rec"], h, None if mode == "full" else cache)
        new_cache = st if (cache is not None or mode == "decode") else None
    elif mixer == "slstm":
        y, st = xlstm_lib.slstm_block(cfg, params["rec"], h, None if mode == "full" else cache)
        new_cache = st if (cache is not None or mode == "decode") else None
    else:
        raise ValueError(mixer)

    x = x + y

    if "cross" in params:
        hc = rmsnorm(params["cross_norm"], x, cfg.norm_eps)
        q, _, _ = attn_lib.qkv(cfg, params["cross"], hc, None)
        if mode == "full":
            assert enc_out is not None, "encoder output required for full-mode cross-attn"
            ck, cv = cross_kv(cfg, params["cross"], enc_out)
            if new_cache is not None:
                new_cache = dict(new_cache)
                new_cache["ck"], new_cache["cv"] = (
                    ck.astype(new_cache["ck"].dtype),
                    cv.astype(new_cache["cv"].dtype),
                )
        else:
            ck = cache["ck"].astype(x.dtype)
            cv = cache["cv"].astype(x.dtype)
        yc = attn_lib.attend(cfg, q, ck, cv, None)
        yc = yc @ params["cross"]["wo"].astype(x.dtype)
        x = x + yc

    if ffn == "mlp":
        hf = rmsnorm(params["ffn_norm"], x, cfg.norm_eps)
        x = x + mlp(cfg, params["mlp"], hf)
    elif ffn == "moe":
        hf = rmsnorm(params["ffn_norm"], x, cfg.norm_eps)
        y, aux_moe = moe_lib.moe_ffn(cfg, params["moe"], hf)
        x = x + y
        aux = aux + aux_moe
    return x, new_cache, aux


def cross_kv(cfg: ModelConfig, params: dict, enc_out: jnp.ndarray):
    """Project encoder output to cross-attention k/v (no rope, no qk-norm)."""
    b, f, _ = enc_out.shape
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = enc_out.dtype
    k = (enc_out @ params["wk"].astype(dt)).reshape(b, f, kvh, hd)
    v = (enc_out @ params["wv"].astype(dt)).reshape(b, f, kvh, hd)
    if cfg.qkv_bias:
        k = k + params["bk"].astype(dt).reshape(kvh, hd)
        v = v + params["bv"].astype(dt).reshape(kvh, hd)
    return k, v


def pack_kv_cache(kv: dict, cache_len: int, window: int, dtype) -> dict:
    """Seed a decode cache from prefill k/v (ring-rolled for windowed caches).

    Ring invariant: slot ``p % window`` holds position ``p``. After a prefill
    of length S the last ``window`` positions S-w..S-1 land at slots
    ``(S-w+i) % w`` — i.e. the chronological tail rolled by ``S % w``.
    """
    k, v = kv["k"], kv["v"]
    s = k.shape[1]
    if window and s > window:
        k, v = k[:, -window:], v[:, -window:]
        shift = s % window
        k = jnp.roll(k, shift, axis=1)
        v = jnp.roll(v, shift, axis=1)
        pad = 0
    else:
        pad = cache_len - k.shape[1]
    if pad > 0:
        zeros = lambda u: jnp.concatenate(
            [u, jnp.zeros((u.shape[0], pad) + u.shape[2:], u.dtype)], axis=1
        )
        k, v = zeros(k), zeros(v)
    return {"k": k.astype(dtype), "v": v.astype(dtype), "pos": jnp.asarray(s, jnp.int32)}
