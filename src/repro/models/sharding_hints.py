"""Activation-sharding hints for GSPMD, usable from pure model code.

GSPMD does not propagate tensor-parallel sharding through the GQA reshape
chain (verified in the dry-run: per-chip HLO carried all heads — attention
replicated 16× across the model axis). Constraints are therefore placed on
the big attention/MoE intermediates directly.

Design: head counts in the zoo (40, 24, 16, 12, 1 kv …) don't uniformly
divide the model axis, so the portable scheme is *sequence-parallel*
attention — scores are sharded over the query-sequence dim for full passes
and over the key/cache dim for single-token decode. Both divide 16 for
every assigned shape (4096, 32768, window 8192, 524288).

Model code calls ``constrain(x, "dp", None, "model", ...)``; the tokens
"dp" / "model" are resolved against the active hint set by the launch layer
(``with sharding_hints(...)``). Without hints (unit tests, FL tier) every
call is a no-op, keeping the model code mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _current():
    return getattr(_state, "hints", None)


@contextlib.contextmanager
def sharding_hints(dp_axes, model_axis: str = "model"):
    """dp_axes: axis name or tuple ('pod','data') sharding batch/seq-ish dims."""
    prev = _current()
    _state.hints = (dp_axes, model_axis)
    try:
        yield
    finally:
        _state.hints = prev


def constrain(x, *dims):
    """dims entries: 'dp' | 'model' | None. No-op when no hints are active
    or a dimension does not divide the axis size."""
    hints = _current()
    if hints is None:
        return x
    dp, model = hints
    mesh = _active_mesh_shape()
    spec = []
    for d, size in zip(dims, x.shape):
        if d == "dp":
            n = _axes_size(mesh, dp)
            spec.append(dp if n and size % n == 0 and size >= n else None)
        elif d == "model":
            n = _axes_size(mesh, model)
            spec.append(model if n and size % n == 0 and size >= n else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _active_mesh_shape():
    env = jax.sharding.get_abstract_mesh() if hasattr(jax.sharding, "get_abstract_mesh") else None
    try:
        if env is not None and env.axis_names:
            return dict(zip(env.axis_names, env.axis_sizes))
    except Exception:  # noqa: BLE001
        pass
    # fall back to the physical mesh context
    try:
        from jax.interpreters.pxla import thread_resources

        m = thread_resources.env.physical_mesh
        if m is not None and m.axis_names:
            return dict(m.shape)
    except Exception:  # noqa: BLE001
        pass
    return None


def _axes_size(mesh_shape, axes):
    if mesh_shape is None:
        return None
    if isinstance(axes, str):
        return mesh_shape.get(axes)
    n = 1
    for a in axes:
        if a not in mesh_shape:
            return None
        n *= mesh_shape[a]
    return n
