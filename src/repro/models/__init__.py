from repro.models.config import (
    INPUT_SHAPES,
    EncoderConfig,
    InputShape,
    MLAConfig,
    ModelConfig,
    MoEConfig,
)
from repro.models.model import (
    decode_step,
    forward,
    init_cache,
    init_params,
    logits_from_hidden,
    loss_fn,
    param_count,
)

__all__ = [
    "ModelConfig",
    "MoEConfig",
    "MLAConfig",
    "EncoderConfig",
    "InputShape",
    "INPUT_SHAPES",
    "init_params",
    "forward",
    "decode_step",
    "init_cache",
    "loss_fn",
    "logits_from_hidden",
    "param_count",
]
