"""RG-LRU recurrence + temporal conv (RecurrentGemma / Griffin, arXiv:2402.19427).

The Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(x_t W_r + b_r)              (recurrence gate)
    i_t = sigmoid(x_t W_i + b_i)              (input gate)
    a_t = exp(-c * softplus(Λ) * r_t)         (diagonal decay, c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

A *diagonal linear* recurrence with input-dependent coefficients — on TPU we
evaluate training/prefill with ``jax.lax.associative_scan`` (log-depth, no
sequential bottleneck; this is the TPU-native adaptation of the paper's
CUDA linear-scan kernel) and decode with the O(1) single-step update.

The recurrent block wraps the RG-LRU with the Griffin structure:
x → (linear → conv1d(width 4) → RG-LRU) ⊙ gelu(linear) → out-proj.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

_A_SCALE = 8.0


def init_rglru_block(cfg: ModelConfig, key) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or cfg.d_model
    cw = cfg.rglru_conv_width
    ks = jax.random.split(key, 6)
    s = d**-0.5
    return {
        "w_x": (jax.random.normal(ks[0], (d, w)) * s).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (d, w)) * s).astype(jnp.float32),
        "conv_w": (jax.random.normal(ks[2], (cw, w)) * cw**-0.5).astype(jnp.float32),
        "conv_b": jnp.zeros((w,), jnp.float32),
        "w_r": (jax.random.normal(ks[3], (w, w)) * w**-0.5).astype(jnp.float32),
        "b_r": jnp.zeros((w,), jnp.float32),
        "w_i": (jax.random.normal(ks[4], (w, w)) * w**-0.5).astype(jnp.float32),
        "b_i": jnp.zeros((w,), jnp.float32),
        # Λ parametrized so a ~ U(0.9, 0.999)-ish at init
        "lam": jnp.log(jnp.expm1(jnp.linspace(0.001, 0.1, w)) ) .astype(jnp.float32),
        "w_out": (jax.random.normal(ks[5], (w, d)) * w**-0.5).astype(jnp.float32),
    }


def _rglru_coeffs(params: dict, x: jnp.ndarray):
    """Gate computation shared by scan and step. x: (..., w)."""
    dt = jnp.float32
    xf = x.astype(dt)
    r = jax.nn.sigmoid(xf @ params["w_r"] + params["b_r"])
    i = jax.nn.sigmoid(xf @ params["w_i"] + params["b_i"])
    log_a = -_A_SCALE * jax.nn.softplus(params["lam"]) * r  # (..., w), <= 0
    a = jnp.exp(log_a)
    gated_x = i * xf
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * gated_x


def rglru_scan(params: dict, x: jnp.ndarray, h0: jnp.ndarray | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Parallel evaluation over (B, S, w) via associative scan; returns (y, h_last)."""
    a, b = _rglru_coeffs(params, x)  # (B, S, w) each

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    if h0 is not None:
        b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(b.dtype))
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1, :]


def rglru_step(params: dict, x_t: jnp.ndarray, h_prev: jnp.ndarray) -> jnp.ndarray:
    """Decode step: x_t (B, w), h_prev (B, w) -> h_t (B, w) in f32."""
    a, b = _rglru_coeffs(params, x_t)
    return a * h_prev.astype(jnp.float32) + b


def conv1d_causal(params: dict, x: jnp.ndarray, tail: jnp.ndarray | None = None) -> jnp.ndarray:
    """Depthwise causal temporal conv. x (B,S,w); tail (B,cw-1,w) history."""
    cw = params["conv_w"].shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * params["conv_w"][i].astype(x.dtype)
        for i in range(cw)
    )
    return out + params["conv_b"].astype(x.dtype)


def rglru_block(cfg: ModelConfig, params: dict, x: jnp.ndarray, state: dict | None):
    """Full Griffin recurrent block.

    state = {"h": (B,w) f32, "conv": (B,cw-1,w)} or None for training.
    Returns (y (B,S,D), new_state).
    """
    dt = x.dtype
    main = x @ params["w_x"].astype(dt)
    gate = jax.nn.gelu(x @ params["w_gate"].astype(dt))
    if state is None:
        conv_out = conv1d_causal(params, main)
        h, h_last = rglru_scan(params, conv_out)
        new_state = {
            "h": h_last.astype(jnp.float32),
            "conv": main[:, -(cfg.rglru_conv_width - 1) :, :],
        }
    else:
        conv_out = conv1d_causal(params, main, tail=state["conv"])
        h_t = rglru_step(params, conv_out[:, 0, :], state["h"])
        h = h_t[:, None, :].astype(dt)
        new_state = {
            "h": h_t,
            "conv": jnp.concatenate([state["conv"][:, 1:, :], main], axis=1),
        }
    y = (h.astype(dt) * gate) @ params["w_out"].astype(dt)
    return y, new_state


def init_rglru_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.rglru_conv_width - 1, w), dtype),
    }
