"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV activations are compressed into a shared ``kv_lora_rank`` latent ``c``
plus one shared rotary key head; per-head keys/values are up-projected from
``c``. The decode cache stores only ``(c, k_rope)`` — 576 dims/token for the
assigned config versus 16·2·128 = 4096 for vanilla GQA — MLA *is* the
sub-quadratic-memory mechanism that lets deepseek run ``long_500k``.

Two decode paths:
  * ``naive``  — re-expand k/v from the cached latent every step
    (paper-faithful formulation, O(T · kv_lora · H · hd) per token);
  * ``absorbed`` — fold W_UK into the query and W_UV into the output so
    attention runs directly in latent space (the §Perf beyond-baseline
    variant; same math, O(T · kv_lora) per token per head).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers.attention import NEG_INF, causal_mask
from repro.models.layers.norms import init_rmsnorm, rmsnorm
from repro.models.layers.rotary import apply_rope
from repro.models.sharding_hints import constrain


def init_mla(cfg: ModelConfig, key) -> dict:
    mla = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qd = mla.nope_head_dim + mla.rope_head_dim
    ks = jax.random.split(key, 6)
    s = d**-0.5
    sl = mla.kv_lora_rank**-0.5
    return {
        "wq": (jax.random.normal(ks[0], (d, h * qd)) * s).astype(jnp.float32),
        "w_dkv": (jax.random.normal(ks[1], (d, mla.kv_lora_rank)) * s).astype(jnp.float32),
        "w_kr": (jax.random.normal(ks[2], (d, mla.rope_head_dim)) * s).astype(jnp.float32),
        "kv_norm": init_rmsnorm(mla.kv_lora_rank),
        "w_uk": (
            jax.random.normal(ks[3], (mla.kv_lora_rank, h, mla.nope_head_dim)) * sl
        ).astype(jnp.float32),
        "w_uv": (
            jax.random.normal(ks[4], (mla.kv_lora_rank, h, mla.v_head_dim)) * sl
        ).astype(jnp.float32),
        "wo": (
            jax.random.normal(ks[5], (h * mla.v_head_dim, d)) * (h * mla.v_head_dim) ** -0.5
        ).astype(jnp.float32),
    }


def _mla_q(cfg: ModelConfig, params: dict, x: jnp.ndarray, angles: jnp.ndarray):
    mla = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    qd = mla.nope_head_dim + mla.rope_head_dim
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, s, h, qd)
    q_nope, q_rope = q[..., : mla.nope_head_dim], q[..., mla.nope_head_dim :]
    q_rope = apply_rope(q_rope, angles)
    return q_nope, q_rope


def _mla_latent(cfg: ModelConfig, params: dict, x: jnp.ndarray, angles: jnp.ndarray):
    """Compressed latent + shared rotary key. c (B,S,R); k_rope (B,S,rd)."""
    c = rmsnorm(params["kv_norm"], x @ params["w_dkv"].astype(x.dtype), cfg.norm_eps)
    k_rope = x @ params["w_kr"].astype(x.dtype)  # single shared head
    k_rope = apply_rope(k_rope[:, :, None, :], angles)[:, :, 0, :]
    return c, k_rope


def _mla_attend(cfg, q_nope, q_rope, k_nope, k_rope, v, mask):
    """q_nope (B,S,H,nd), k_nope (B,T,H,nd), k_rope (B,T,rd) shared head."""
    mla = cfg.mla
    scale = (mla.nope_head_dim + mla.rope_head_dim) ** -0.5
    scores = jnp.einsum("bshd,bthd->bhst", q_nope, k_nope).astype(jnp.float32)
    scores = scores + jnp.einsum("bshd,btd->bhst", q_rope, k_rope).astype(jnp.float32)
    scores = scores * scale
    # sequence-parallel TP (see attention.py): query-seq for full, cache for decode
    if scores.shape[2] > 1:
        scores = constrain(scores, "dp", None, "model", None)
    else:
        scores = constrain(scores, "dp", None, None, "model")
    if mask is not None:
        scores = jnp.where(mask[:, None] if mask.ndim == 3 else mask[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bthd->bshd", p, v)


def mla_full(cfg: ModelConfig, params: dict, x: jnp.ndarray, angles: jnp.ndarray):
    """Training/prefill. Returns (y, cache seed {c, k_rope})."""
    mla = cfg.mla
    b, s, _ = x.shape
    q_nope, q_rope = _mla_q(cfg, params, x, angles)
    c, k_rope = _mla_latent(cfg, params, x, angles)
    k_nope = jnp.einsum("btr,rhd->bthd", c, params["w_uk"].astype(x.dtype))
    v = jnp.einsum("btr,rhd->bthd", c, params["w_uv"].astype(x.dtype))
    out = _mla_attend(cfg, q_nope, q_rope, k_nope, k_rope, v, causal_mask(s, s, 0))
    y = out.reshape(b, s, -1) @ params["wo"].astype(x.dtype)
    return y, {"c": c, "k_rope": k_rope}


def mla_decode(cfg: ModelConfig, params: dict, x: jnp.ndarray, angles, cache: dict):
    """Single-token decode against the compressed cache {c, k_rope, pos}."""
    mla = cfg.mla
    b = x.shape[0]
    pos = cache["pos"]
    q_nope, q_rope = _mla_q(cfg, params, x, angles)
    c_new, kr_new = _mla_latent(cfg, params, x, angles)
    c = jax.lax.dynamic_update_slice_in_dim(cache["c"], c_new.astype(cache["c"].dtype), pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), pos, axis=1
    )
    t = c.shape[1]
    mask = (jnp.arange(t) <= pos)[None, :]
    cdt = c.astype(x.dtype)

    if mla.decode_mode == "absorbed":
        # fold W_UK into q, W_UV into the output: attention in latent space
        scale = (mla.nope_head_dim + mla.rope_head_dim) ** -0.5
        q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, params["w_uk"].astype(x.dtype))
        scores = jnp.einsum("bshr,btr->bhst", q_lat, cdt).astype(jnp.float32)
        scores = scores + jnp.einsum(
            "bshd,btd->bhst", q_rope, k_rope.astype(x.dtype)
        ).astype(jnp.float32)
        scores = scores * scale
        scores = constrain(scores, "dp", None, None, "model")
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        lat_out = jnp.einsum("bhst,btr->bshr", p, cdt)  # (B,1,H,R)
        out = jnp.einsum("bshr,rhd->bshd", lat_out, params["w_uv"].astype(x.dtype))
    else:
        k_nope = jnp.einsum("btr,rhd->bthd", cdt, params["w_uk"].astype(x.dtype))
        v = jnp.einsum("btr,rhd->bthd", cdt, params["w_uv"].astype(x.dtype))
        out = _mla_attend(cfg, q_nope, q_rope, k_nope, k_rope.astype(x.dtype), v, mask)

    y = out.reshape(b, 1, -1) @ params["wo"].astype(x.dtype)
    return y, {"c": c, "k_rope": k_rope, "pos": pos + 1}


def init_mla_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> dict:
    mla = cfg.mla
    return {
        "c": jnp.zeros((batch, cache_len, mla.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, cache_len, mla.rope_head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
