"""GQA attention: full / sliding-window, qk-norm, QKV-bias, blockwise option.

All dense archs in the zoo share this module; differences are pure config
(n_kv_heads, qk_norm, qkv_bias, rope theta / M-RoPE, window). The blockwise
path (``attn_block_q > 0``) processes query chunks with ``lax.map`` so the
(S × T) score tensor is never fully materialized — the §Perf memory-term
lever; numerics are identical (same f32 softmax over the full key axis).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers.norms import rms_head_norm
from repro.models.layers.rotary import apply_rope
from repro.models.sharding_hints import constrain

NEG_INF = -2.0e38


def init_attention(cfg: ModelConfig, key) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = d**-0.5
    p = {
        "wq": (jax.random.normal(k1, (d, h * hd)) * scale).astype(jnp.float32),
        "wk": (jax.random.normal(k2, (d, kv * hd)) * scale).astype(jnp.float32),
        "wv": (jax.random.normal(k3, (d, kv * hd)) * scale).astype(jnp.float32),
        "wo": (jax.random.normal(k4, (h * hd, d)) * (h * hd) ** -0.5).astype(jnp.float32),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((kv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((kv * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def qkv(cfg: ModelConfig, params: dict, x: jnp.ndarray, angles: jnp.ndarray):
    """Project + normalize + rotate. x: (B,S,D) -> q (B,S,H,hd), k/v (B,S,KV,hd)."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    wdt = x.dtype
    q = x @ params["wq"].astype(wdt)
    k = x @ params["wk"].astype(wdt)
    v = x @ params["wv"].astype(wdt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(wdt)
        k = k + params["bk"].astype(wdt)
        v = v + params["bv"].astype(wdt)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rms_head_norm(params["q_norm"], q, cfg.norm_eps)
        k = rms_head_norm(params["k_norm"], k, cfg.norm_eps)
    if angles is not None:
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)
    return q, k, v


def attend(
    cfg: ModelConfig,
    q: jnp.ndarray,  # (B, S, H, hd)
    k: jnp.ndarray,  # (B, T, KV, hd)
    v: jnp.ndarray,  # (B, T, KV, hd)
    mask: Optional[jnp.ndarray],  # (S, T) or (B, S, T) bool, True = attend
) -> jnp.ndarray:
    """Grouped-query scaled dot-product attention, f32 softmax."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) * (hd**-0.5)
    # Sequence-parallel TP: shard scores over query-seq (full pass) or over
    # the key/cache dim (decode, s == 1) — head counts in the zoo don't
    # divide the model axis uniformly, sequence dims always do.
    if s > 1:
        scores = constrain(scores, "dp", None, None, "model", None)
    else:
        scores = constrain(scores, "dp", None, None, None, "model")
    if cfg.logit_softcap:
        scores = cfg.logit_softcap * jnp.tanh(scores / cfg.logit_softcap)
    if mask is not None:
        m = mask if mask.ndim == 3 else mask[None]
        scores = jnp.where(m[:, None, None, :, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v)
    out = constrain(out, "dp", "model" if s > 1 else None, None, None, None)
    return out.reshape(b, s, h * hd)


def causal_mask(s: int, t: int, offset: int, window: int = 0) -> jnp.ndarray:
    """(s, t) mask; query i sits at absolute position offset + i.

    ``window > 0`` additionally bounds lookback (sliding window): key j is
    visible iff q_pos - window < j <= q_pos.
    """
    q_pos = offset + jnp.arange(s)[:, None]
    k_pos = jnp.arange(t)[None, :]
    m = k_pos <= q_pos
    if window > 0:
        m &= k_pos > q_pos - window
    return m


def attention_full(
    cfg: ModelConfig,
    params: dict,
    x: jnp.ndarray,
    angles: jnp.ndarray,
    *,
    window: int = 0,
    bidirectional: bool = False,
) -> tuple[jnp.ndarray, dict]:
    """Training/prefill attention over the whole sequence.

    Returns (output (B,S,D), kv dict for cache construction).
    """
    b, s, _ = x.shape
    q, k, v = qkv(cfg, params, x, angles)
    mask = None if bidirectional else causal_mask(s, s, 0, window)

    block_q = cfg.attn_block_q
    if block_q and s % block_q == 0 and s > block_q:
        # Static python loop over query blocks (so dry-run cost analysis
        # counts every block; XLA counts while bodies once). Each block only
        # materializes (bq × T) scores; with remat the backward recomputes.
        n_blocks = s // block_q

        @jax.checkpoint
        def one_block(qi, off):
            mi = None if bidirectional else causal_mask(block_q, s, off, window)
            return attend(cfg, qi, k, v, mi)

        outs = [
            one_block(q[:, i * block_q : (i + 1) * block_q], i * block_q)
            for i in range(n_blocks)
        ]
        out = jnp.concatenate(outs, axis=1)
    else:
        out = attend(cfg, q, k, v, mask)

    y = out @ params["wo"].astype(x.dtype)
    return y, {"k": k, "v": v}


def attention_decode(
    cfg: ModelConfig,
    params: dict,
    x: jnp.ndarray,  # (B, 1, D)
    angles: jnp.ndarray,  # (1, hd//2) for the current position
    cache: dict,  # {"k": (B, C, KV, hd), "v": ..., "pos": scalar int32}
    *,
    window: int = 0,
) -> tuple[jnp.ndarray, dict]:
    """Single-token decode against a (possibly ring-buffered) KV cache.

    ``window > 0`` means the cache is a ring buffer of that length; the new
    entry lands at ``pos % window`` and all slots are attendable (positions
    differ by < window by construction). For full caches the new entry lands
    at ``pos`` and slots ``> pos`` are masked out.
    """
    q, k_new, v_new = qkv(cfg, params, x, angles)
    cache_len = cache["k"].shape[1]
    pos = cache["pos"]
    slot = pos % window if window > 0 else pos
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
    # Valid slots: before the ring fills (or for full caches, always) only
    # entries written so far are attendable; a full ring is wholly visible.
    mask = (jnp.arange(cache_len) <= pos)[None, :]  # (1, C)
    out = attend(cfg, q, k.astype(x.dtype), v.astype(x.dtype), mask)
    y = out @ params["wo"].astype(x.dtype)
    return y, {"k": k, "v": v, "pos": pos + 1}


def init_kv_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> dict:
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, cache_len, kvh, hd), dtype),
        "v": jnp.zeros((batch, cache_len, kvh, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
