"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE splits the head_dim frequency bands into (temporal, height, width)
sections, each rotated by its own position stream. For pure-text tokens the
three streams coincide (t = h = w = token index), which is exactly how
Qwen2-VL treats text — so the text-only backbone uses the *mechanism*
faithfully while the vision stub supplies only embeddings.
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies for each rotation pair, shape (head_dim//2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float) -> jnp.ndarray:
    """positions (...,) -> angles (..., head_dim//2) in float32."""
    inv = rope_freqs(head_dim, theta)
    return positions.astype(jnp.float32)[..., None] * inv


def mrope_angles(
    positions: jnp.ndarray, head_dim: int, theta: float, sections: tuple[int, int, int]
) -> jnp.ndarray:
    """M-RoPE: positions (3, ...) t/h/w streams -> angles (..., head_dim//2).

    ``sections`` counts rotation *pairs* per stream and must sum to
    head_dim // 2.
    """
    if sum(sections) != head_dim // 2:
        raise ValueError(f"mrope sections {sections} must sum to head_dim//2 = {head_dim // 2}")
    inv = rope_freqs(head_dim, theta)  # (head_dim//2,)
    stream_of = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=head_dim // 2
    )
    pos = positions.astype(jnp.float32)  # (3, ...)
    pos_per_band = jnp.take(pos, stream_of, axis=0)  # (hd//2 bands pick their stream)
    # pos_per_band: (hd//2, ...) -> move band axis last
    pos_per_band = jnp.moveaxis(pos_per_band, 0, -1)
    return pos_per_band * inv


def apply_rope(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """Rotate pairs. x: (..., S, n_heads, head_dim); angles: (..., S, head_dim//2).

    Pairs are (x[2i], x[2i+1]) — interleaved convention.
    """
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    x1 = x32[..., 0::2]
    x2 = x32[..., 1::2]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(dtype)
