"""Gated (SwiGLU/GeGLU) and plain MLP blocks."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def init_mlp(d_model: int, d_ff: int, key, *, gated: bool = True) -> dict:
    ks = jax.random.split(key, 3)
    s_in, s_out = d_model**-0.5, d_ff**-0.5
    p = {
        "w_up": (jax.random.normal(ks[0], (d_model, d_ff)) * s_in).astype(jnp.float32),
        "w_down": (jax.random.normal(ks[1], (d_ff, d_model)) * s_out).astype(jnp.float32),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(ks[2], (d_model, d_ff)) * s_in).astype(jnp.float32)
    return p


def mlp(cfg: ModelConfig, params: dict, x: jnp.ndarray) -> jnp.ndarray:
    act = ACTS[cfg.act]
    dt = x.dtype
    up = x @ params["w_up"].astype(dt)
    if "w_gate" in params:
        up = act(x @ params["w_gate"].astype(dt)) * up
    else:
        up = act(up)
    return up @ params["w_down"].astype(dt)
