"""Normalization layers (functional)."""
from __future__ import annotations

import jax.numpy as jnp


def init_rmsnorm(dim: int) -> dict:
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 / jnp.sqrt(var + eps)
    return (out * params["scale"]).astype(dtype)


def init_layernorm(dim: int) -> dict:
    return {"scale": jnp.ones((dim,), jnp.float32), "bias": jnp.zeros((dim,), jnp.float32)}


def layernorm(params: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mean = x32.mean(axis=-1, keepdims=True)
    var = x32.var(axis=-1, keepdims=True)
    out = (x32 - mean) / jnp.sqrt(var + eps)
    return (out * params["scale"] + params["bias"]).astype(dtype)


def rms_head_norm(scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """Per-head RMS norm over the trailing head_dim (Qwen3 qk-norm)."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 / jnp.sqrt(var + eps) * scale).astype(dtype)
