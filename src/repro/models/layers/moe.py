"""Mixture-of-Experts FFN with GShard-style capacity dispatch.

Used by deepseek-v2-lite (2 shared + 64 routed, top-6) and qwen2-moe
(4 shared + 60 routed, top-4). Tokens are processed in fixed-size groups;
each group dispatches to experts through a one-hot (s, e, c) tensor so the
expert matmuls are dense MXU work over ``e × c`` slots — the TPU-native
formulation (a CUDA implementation would scatter; on TPU the einsum
dispatch pipelines through the MXU and shards cleanly over the model axis).

Tokens over capacity are dropped (standard GShard semantics); capacity
``c = group_size * top_k / n_routed * capacity_factor`` keeps the drop rate
low at the paper-typical load-balance levels. The auxiliary load-balance
loss follows Switch/GShard: ``n_e * Σ_e f_e · P_e``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers.mlp import init_mlp, mlp
from repro.models.sharding_hints import constrain


def expert_capacity(moe) -> int:
    cap = int(moe.group_size * moe.top_k / moe.n_routed * moe.capacity_factor)
    return max(cap, moe.top_k)


def init_moe(cfg: ModelConfig, key) -> dict:
    moe = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    s = d**-0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, moe.n_routed)) * s).astype(jnp.float32),
        # routed experts: stacked gated MLPs (E, d, d_ff) / (E, d_ff, d)
        "e_gate": (jax.random.normal(ks[1], (moe.n_routed, d, moe.d_ff_expert)) * s).astype(jnp.float32),
        "e_up": (jax.random.normal(ks[2], (moe.n_routed, d, moe.d_ff_expert)) * s).astype(jnp.float32),
        "e_down": (
            jax.random.normal(ks[3], (moe.n_routed, moe.d_ff_expert, d)) * moe.d_ff_expert**-0.5
        ).astype(jnp.float32),
    }
    if moe.n_shared:
        p["shared"] = init_mlp(d, moe.d_ff_expert * moe.n_shared, jax.random.fold_in(key, 7))
    return p


def _route_group(cfg: ModelConfig, params: dict, xg: jnp.ndarray):
    """One group: xg (s, d) -> (out (s, d), aux loss scalar)."""
    moe = cfg.moe
    s, d = xg.shape
    e, k, c = moe.n_routed, moe.top_k, expert_capacity(moe)
    dt = xg.dtype

    logits = (xg @ params["router"].astype(dt)).astype(jnp.float32)  # (s, e)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (s, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    sel = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)  # (s, k, e)
    mask = sel.sum(1)  # (s, e) in {0,1} (top-k indices are distinct)

    # position of each token in its expert's queue, capacity-capped
    pos_in_expert = jnp.cumsum(mask, axis=0) - 1.0  # (s, e)
    keep = (pos_in_expert < c) * mask
    gate_se = (gate_vals[:, :, None] * sel).sum(1) * keep  # (s, e)

    disp = keep[..., None] * jax.nn.one_hot(pos_in_expert, c, dtype=jnp.float32)  # (s,e,c)
    comb = gate_se[..., None] * jax.nn.one_hot(pos_in_expert, c, dtype=jnp.float32)

    xe = jnp.einsum("sec,sd->ecd", disp.astype(dt), xg)  # (e, c, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["e_gate"].astype(dt))) * jnp.einsum(
        "ecd,edf->ecf", xe, params["e_up"].astype(dt)
    )
    ye = jnp.einsum("ecf,efd->ecd", h, params["e_down"].astype(dt))  # (e, c, d)
    out = jnp.einsum("sec,ecd->sd", comb.astype(dt), ye)

    # Switch-style load-balance loss
    frac_tokens = mask.mean(axis=0)  # f_e
    frac_probs = probs.mean(axis=0)  # P_e
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return out, aux


def moe_ffn(cfg: ModelConfig, params: dict, x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, D) -> (out, aux_loss). Groups = flattened token blocks."""
    moe = cfg.moe
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    gs = min(moe.group_size, b * s)
    n_groups, rem = divmod(b * s, gs)
    if rem:  # pad the tail group (masked tokens route but are dropped on combine)
        pad = gs - rem
        flat = jnp.concatenate([flat, jnp.zeros((pad, d), flat.dtype)], axis=0)
        n_groups += 1
    groups = flat.reshape(n_groups, gs, d)
    groups = constrain(groups, "dp", None, None)  # token groups over batch axes

    out, aux = jax.vmap(lambda g: _route_group(cfg, params, g))(groups)
    out = out.reshape(-1, d)[: b * s].reshape(b, s, d)

    if moe.n_shared:
        out = out + mlp(cfg, params["shared"], x)
    return out, aux.mean()
