"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

arXiv:2405.04517. TPU adaptation notes:

* mLSTM's matrix-memory recurrence is evaluated in its *parallel* form for
  training/prefill — a decay-masked attention-like quadratic form that maps
  straight onto the MXU — and in its O(1) recurrent form for decode
  (state C ∈ R^{h×d×d}). Exponential gating is stabilized with the running
  max ``m`` exactly as in the paper.
* sLSTM has genuine recurrent connections (block-diagonal R per head), so
  it cannot be parallelized over time; we run a ``lax.scan`` — on TPU this
  is the honest structure (the paper's CUDA kernel fuses the same sequential
  dependency).
* The causal-conv front of the official blocks is omitted (noted in
  DESIGN.md); projection/gating structure follows the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.sharding_hints import constrain


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------
def _mlstm_dims(cfg: ModelConfig) -> tuple[int, int]:
    d_in = int(cfg.d_model * cfg.mlstm_proj_factor)
    return d_in, d_in // cfg.n_heads


def init_mlstm_block(cfg: ModelConfig, key) -> dict:
    d = cfg.d_model
    d_in, hd = _mlstm_dims(cfg)
    h = cfg.n_heads
    ks = jax.random.split(key, 8)
    s, si = d**-0.5, d_in**-0.5
    return {
        "w_up": (jax.random.normal(ks[0], (d, d_in)) * s).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (d, d_in)) * s).astype(jnp.float32),
        "wq": (jax.random.normal(ks[2], (d_in, d_in)) * si).astype(jnp.float32),
        "wk": (jax.random.normal(ks[3], (d_in, d_in)) * si).astype(jnp.float32),
        "wv": (jax.random.normal(ks[4], (d_in, d_in)) * si).astype(jnp.float32),
        "w_i": (jax.random.normal(ks[5], (d_in, h)) * si).astype(jnp.float32),
        "b_i": jnp.zeros((h,), jnp.float32),
        "w_f": (jax.random.normal(ks[6], (d_in, h)) * si).astype(jnp.float32),
        "b_f": jnp.full((h,), 3.0, jnp.float32),  # forget-gate bias init
        "out_norm": jnp.ones((hd,), jnp.float32),
        "w_down": (jax.random.normal(ks[7], (d_in, d)) * si).astype(jnp.float32),
    }


def _mlstm_qkv_gates(cfg: ModelConfig, params: dict, z: jnp.ndarray):
    """z: (B, S, d_in) -> q,k,v (B,S,H,hd); i,f pre-activations (B,S,H) f32."""
    b, s, d_in = z.shape
    h = cfg.n_heads
    hd = d_in // h
    dt = z.dtype
    q = (z @ params["wq"].astype(dt)).reshape(b, s, h, hd)
    k = (z @ params["wk"].astype(dt)).reshape(b, s, h, hd)
    v = (z @ params["wv"].astype(dt)).reshape(b, s, h, hd)
    zf = z.astype(jnp.float32)
    i_pre = zf @ params["w_i"] + params["b_i"]
    f_pre = zf @ params["w_f"] + params["b_f"]
    return q, k, v, i_pre, f_pre


def _head_rmsnorm(scale: jnp.ndarray, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 / jnp.sqrt(var + eps) * scale).astype(x.dtype)


def mlstm_parallel(cfg: ModelConfig, params: dict, z: jnp.ndarray):
    """Stabilized parallel (quadratic) mLSTM over the full sequence.

    Returns (output (B,S,d_in), final recurrent state) — the state equals
    what the step recurrence would produce after S steps (same stabilizer),
    so prefill can seed decode.

    ``cfg.attn_block_q > 0`` evaluates the quadratic form in query-row
    blocks (static python loop): the (B,S,S,H) decay/score tensors shrink
    to (B,bq,S,H) — the §Perf memory lever for mLSTM prefill, numerics
    identical (each row block sees the full key axis).
    """
    q, k, v, i_pre, f_pre = _mlstm_qkv_gates(cfg, params, z)
    b, s, h, hd = q.shape
    log_f = jax.nn.log_sigmoid(f_pre)  # (B,S,H)
    F = jnp.cumsum(log_f, axis=1)  # (B,S,H) cumulative log forget
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def rows(q_blk, F_blk, off, bq):
        """Row block of the stabilized decay-weighted attention."""
        # D̃[t, τ] = F_t - F_τ + ĩ_τ  for τ <= t
        Dt = F_blk[:, :, None, :] - F[:, None, :, :] + i_pre[:, None, :, :]  # (B,bq,S,H)
        Dt = constrain(Dt, "dp", "model", None, None)  # sequence-parallel TP
        t_pos = off + jnp.arange(bq)[:, None]
        causal = jnp.arange(s)[None, :] <= t_pos
        Dt = jnp.where(causal[None, :, :, None], Dt, -jnp.inf)
        m = jnp.max(Dt, axis=2)  # (B,bq,H)
        D = jnp.exp(Dt - m[:, :, None, :])
        scores = jnp.einsum("bshd,bthd->bsth", q_blk.astype(jnp.float32), kf)
        scores = constrain(scores, "dp", "model", None, None)
        scores = scores * (hd**-0.5) * D
        norm = jnp.maximum(jnp.abs(scores.sum(axis=2)), jnp.exp(-m))
        return jnp.einsum("bsth,bthd->bshd", scores / norm[:, :, None, :], vf)

    bq = cfg.attn_block_q
    if bq and s > bq and s % bq == 0:
        blk = jax.checkpoint(lambda qb, Fb, off: rows(qb, Fb, off, bq))
        out = jnp.concatenate(
            [
                blk(q[:, i * bq : (i + 1) * bq], F[:, i * bq : (i + 1) * bq], i * bq)
                for i in range(s // bq)
            ],
            axis=1,
        )
    else:
        out = rows(q, F, 0, s)
    out = _head_rmsnorm(params["out_norm"], out.astype(z.dtype), cfg.norm_eps)

    # final state: w_τ = F_S - F_τ + ĩ_τ, m_S = max_τ w_τ  (matches the
    # step recurrence by induction on m_t = max(log f_t + m_{t-1}, ĩ_t))
    w = F[:, -1:, :] - F + i_pre  # (B,S,H)
    m_last = w.max(axis=1)  # (B,H)
    e = jnp.exp(w - m_last[:, None, :])  # (B,S,H)
    k_sc = k.astype(jnp.float32) * (hd**-0.5)
    C = jnp.einsum("bth,bthd,bthk->bhdk", e, v.astype(jnp.float32), k_sc)
    n = jnp.einsum("bth,bthd->bhd", e, k_sc)
    state = {"C": C, "n": n, "m": m_last}
    return out.reshape(b, s, h * hd), state


def mlstm_chunkwise(cfg: ModelConfig, params: dict, z: jnp.ndarray, chunk: int):
    """Chunkwise-recurrent mLSTM: parallel within chunks, O(1) recurrent
    state between chunks — O(S·chunk·d) instead of O(S²·d) (§Perf variant;
    the TPU-native adaptation of xLSTM's chunkwise kernel). Exactly matches
    the parallel form (same stabilized arithmetic; property-tested).

    Static python loop over chunks (dry-run cost accounting, see model.py).
    """
    q, k, v, i_pre, f_pre = _mlstm_qkv_gates(cfg, params, z)
    b, s, h, hd = q.shape
    assert s % chunk == 0, "pad sequence to a chunk multiple"
    log_f = jax.nn.log_sigmoid(f_pre)  # (B,S,H)
    qf, kf, vf = (u.astype(jnp.float32) for u in (q, k, v))
    k_sc = kf * (hd**-0.5)

    C = jnp.zeros((b, h, hd, hd), jnp.float32)
    n = jnp.zeros((b, h, hd), jnp.float32)
    m_run = jnp.full((b, h), -1e30, jnp.float32)
    outs = []
    for c0 in range(0, s, chunk):
        sl = slice(c0, c0 + chunk)
        lf = log_f[:, sl]  # (B,L,H)
        ip = i_pre[:, sl]
        F = jnp.cumsum(lf, axis=1)  # local cumulative log-forget
        # intra-chunk decay D̃[t,τ] = F_t - F_τ + ĩ_τ (τ <= t)
        Dt = F[:, :, None, :] - F[:, None, :, :] + ip[:, None, :, :]  # (B,L,L,H)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        Dt = jnp.where(causal[None, :, :, None], Dt, -jnp.inf)
        # inter-chunk decay: state enters token t with weight F_t + m_run
        w_in = F + m_run[:, None, :]  # (B,L,H)
        m_t = jnp.maximum(jnp.max(Dt, axis=2), w_in)  # (B,L,H)
        D = jnp.exp(Dt - m_t[:, :, None, :])
        e_in = jnp.exp(w_in - m_t)  # (B,L,H)

        qc, kc, vc = qf[:, sl], k_sc[:, sl], vf[:, sl]
        scores = jnp.einsum("bshd,bthd->bsth", qc, kc) * D  # (B,L,L,H)
        num = jnp.einsum("bsth,bthd->bshd", scores, vc)
        num = num + e_in[..., None] * jnp.einsum("bhdk,bshk->bshd", C, qc)
        den = scores.sum(axis=2) + e_in * jnp.einsum("bhk,bshk->bsh", n, qc)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        outs.append((num / den[..., None]).astype(z.dtype))

        # state update across the chunk (same stabilizer algebra)
        F_L = F[:, -1, :]  # (B,H) total log-forget of the chunk
        w_tau = F_L[:, None, :] - F + ip  # (B,L,H): decay from τ to chunk end
        m_new = jnp.maximum(F_L + m_run, jnp.max(w_tau, axis=1))
        e_tau = jnp.exp(w_tau - m_new[:, None, :])  # (B,L,H)
        carry = jnp.exp(F_L + m_run - m_new)  # (B,H)
        C = carry[..., None, None] * C + jnp.einsum("bth,bthd,bthk->bhdk", e_tau, vc, kc)
        n = carry[..., None] * n + jnp.einsum("bth,bthd->bhd", e_tau, kc)
        m_run = m_new

    out = jnp.concatenate(outs, axis=1)  # (B,S,H,hd)
    out = _head_rmsnorm(params["out_norm"], out, cfg.norm_eps)
    return out.reshape(b, s, h * hd), {"C": C, "n": n, "m": m_run}


def mlstm_step(cfg: ModelConfig, params: dict, z_t: jnp.ndarray, state: dict):
    """Recurrent decode step. z_t (B, 1, d_in); state {C,n,m}."""
    q, k, v, i_pre, f_pre = _mlstm_qkv_gates(cfg, params, z_t)
    b, _, h, hd = q.shape
    q, k, v = (u[:, 0].astype(jnp.float32) for u in (q, k, v))  # (B,H,hd)
    i_pre, f_pre = i_pre[:, 0], f_pre[:, 0]  # (B,H)
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + state["m"], i_pre)
    f_sc = jnp.exp(log_f + state["m"] - m_new)[..., None]
    i_sc = jnp.exp(i_pre - m_new)[..., None]
    k_sc = k * (hd**-0.5)
    C = f_sc[..., None] * state["C"] + i_sc[..., None] * (v[..., :, None] * k_sc[..., None, :])
    n = f_sc * state["n"] + i_sc * k_sc
    num = jnp.einsum("bhdk,bhk->bhd", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), jnp.exp(-m_new))
    out = (num / den[..., None]).astype(z_t.dtype)  # (B,H,hd)
    out = _head_rmsnorm(params["out_norm"], out, cfg.norm_eps)
    return out.reshape(b, 1, h * hd), {"C": C, "n": n, "m": m_new}


def mlstm_block(cfg: ModelConfig, params: dict, x: jnp.ndarray, state: dict | None):
    dt = x.dtype
    z = x @ params["w_up"].astype(dt)
    gate = jax.nn.silu(x @ params["w_gate"].astype(dt))
    if state is None:
        chunk = cfg.mlstm_chunk
        if chunk and x.shape[1] > chunk and x.shape[1] % chunk == 0:
            cell, new_state = mlstm_chunkwise(cfg, params, z, chunk)
        else:
            cell, new_state = mlstm_parallel(cfg, params, z)
    else:
        cell, new_state = mlstm_step(cfg, params, z, state)
    y = (cell * gate) @ params["w_down"].astype(dt)
    return y, new_state


def init_mlstm_state(cfg: ModelConfig, batch: int) -> dict:
    d_in, hd = _mlstm_dims(cfg)
    h = cfg.n_heads
    return {
        "C": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------
def _slstm_dims(cfg: ModelConfig) -> tuple[int, int]:
    h = cfg.n_heads
    d_in = int(cfg.d_model * cfg.slstm_proj_factor)
    d_in = (d_in // h) * h  # divisible by heads
    return d_in, d_in // h


def init_slstm_block(cfg: ModelConfig, key) -> dict:
    d = cfg.d_model
    d_in, hd = _slstm_dims(cfg)
    h = cfg.n_heads
    ks = jax.random.split(key, 8)
    s = d**-0.5
    shd = hd**-0.5
    gates = {}
    for name, k_ in zip(("z", "i", "f", "o"), ks[2:6]):
        gates[f"w_{name}"] = (jax.random.normal(k_, (d_in, d_in)) * d_in**-0.5).astype(jnp.float32)
        # block-diagonal recurrent connections, one dense matrix per head
        gates[f"r_{name}"] = (
            jax.random.normal(jax.random.fold_in(k_, 1), (h, hd, hd)) * shd
        ).astype(jnp.float32)
        gates[f"b_{name}"] = jnp.zeros((d_in,), jnp.float32)
    gates["b_f"] = jnp.full((d_in,), 3.0, jnp.float32)
    return {
        "w_up": (jax.random.normal(ks[0], (d, d_in)) * s).astype(jnp.float32),
        "w_down": (jax.random.normal(ks[1], (d_in, d)) * d_in**-0.5).astype(jnp.float32),
        "out_norm": jnp.ones((d_in,), jnp.float32),
        **gates,
    }


def _slstm_cell(params: dict, x_proj: dict, state: dict, h_heads: int):
    """One time step. ``x_proj`` holds the *pre-computed* input projections
    ``x_t @ W_* + b_*`` (hoisted out of the time scan so they run as one big
    MXU matmul over the whole sequence — and so dry-run cost analysis counts
    them; only the genuinely sequential recurrent matmuls stay inside).
    state {c,n,m,h} each (B, d_in) f32."""
    b, d_in = x_proj["z"].shape
    hd = d_in // h_heads
    h_prev = state["h"].reshape(b, h_heads, hd)

    def rec(name):
        # block-diagonal recurrent contribution per head
        return jnp.einsum("bhk,hkj->bhj", h_prev, params[f"r_{name}"]).reshape(b, d_in)

    z = jnp.tanh(x_proj["z"] + rec("z"))
    i_pre = x_proj["i"] + rec("i")
    f_pre = x_proj["f"] + rec("f")
    o = jax.nn.sigmoid(x_proj["o"] + rec("o"))
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + state["m"], i_pre)
    i_sc = jnp.exp(i_pre - m_new)
    f_sc = jnp.exp(log_f + state["m"] - m_new)
    c = f_sc * state["c"] + i_sc * z
    n = f_sc * state["n"] + i_sc
    h_new = o * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "m": m_new, "h": h_new}


def slstm_block(cfg: ModelConfig, params: dict, x: jnp.ndarray, state: dict | None):
    """x (B,S,D). Training: scan over time. Decode: single step with state."""
    dt = x.dtype
    b, s, _ = x.shape
    d_in, _ = _slstm_dims(cfg)
    h = cfg.n_heads
    z_in = (x @ params["w_up"].astype(dt)).astype(jnp.float32)
    # input projections for all timesteps at once (hoisted out of the scan)
    proj = {g: z_in @ params[f"w_{g}"] + params[f"b_{g}"] for g in ("z", "i", "f", "o")}

    if state is None:
        st = init_slstm_state(cfg, b)

        def step(carry, p_t):
            new = _slstm_cell(params, p_t, carry, h)
            return new, new["h"]

        proj_t = jax.tree_util.tree_map(lambda a: jnp.moveaxis(a, 1, 0), proj)
        new_state, hs = jax.lax.scan(step, st, proj_t)
        out = jnp.moveaxis(hs, 0, 1)  # (B, S, d_in)
    else:
        new_state = _slstm_cell(
            params, jax.tree_util.tree_map(lambda a: a[:, 0], proj), state, h
        )
        out = new_state["h"][:, None, :]

    out = _head_rmsnorm_flat(params["out_norm"], out, d_in // h, cfg.norm_eps)
    y = out.astype(dt) @ params["w_down"].astype(dt)
    return y, new_state


def _head_rmsnorm_flat(scale: jnp.ndarray, x: jnp.ndarray, hd: int, eps: float):
    """Group-norm over heads for flat (..., d_in) activations."""
    shape = x.shape
    xh = x.reshape(*shape[:-1], shape[-1] // hd, hd).astype(jnp.float32)
    var = jnp.mean(jnp.square(xh), axis=-1, keepdims=True)
    xh = xh / jnp.sqrt(var + eps)
    return (xh.reshape(shape) * scale).astype(x.dtype)


def init_slstm_state(cfg: ModelConfig, batch: int) -> dict:
    d_in, _ = _slstm_dims(cfg)
    return {
        "c": jnp.zeros((batch, d_in), jnp.float32),
        "n": jnp.zeros((batch, d_in), jnp.float32),
        "m": jnp.full((batch, d_in), -1e30, jnp.float32),
        "h": jnp.zeros((batch, d_in), jnp.float32),
    }
