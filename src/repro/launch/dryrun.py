import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combination.

The two lines above MUST stay the first statements of this module — jax
locks the device count on first initialization, and the production meshes
need 512 placeholder host devices (2 pods × 16 × 16).

Per combination this script:
  1. builds the step function (train_step / prefill / serve_step),
  2. jits it with the sharding rules of ``repro.launch.sharding``,
  3. ``.lower(**input_specs).compile()`` against ShapeDtypeStructs
     (no allocation),
  4. records ``memory_analysis()`` (fits-per-chip proof),
     ``cost_analysis()`` (FLOPs / bytes) and the parsed collective
     schedule into experiments/dryrun/*.json for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --all                    # 40 baselines
  python -m repro.launch.dryrun --all --multi-pod        # 512-chip pass
  python -m repro.launch.dryrun ... --variant fused_ce --variant absorbed_mla
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import ARCH_NAMES, get_config
from repro.launch import roofline as rl
from repro.launch.mesh import batch_axes, make_production_mesh, mesh_chips
from repro.models.sharding_hints import sharding_hints
from repro.launch.sharding import (
    batch_shardings,
    cache_shardings,
    opt_state_shardings,
    param_shardings,
    replicated,
)
from repro.launch.steps import (
    abstract_params,
    abstract_train_state,
    default_optimizer,
    input_specs,
    make_step,
)
from repro.models import model as mdl
from repro.models.config import INPUT_SHAPES

VARIANTS = (
    "fused_ce",
    "absorbed_mla",
    "block_attn",
    "expert_parallel",
    "no_remat",
    "mlstm_chunk",
    "sp_residual",
)


def apply_variants(cfg, variants: list[str]):
    if "fused_ce" in variants:
        cfg = dataclasses.replace(cfg, fused_ce=True)
    if "absorbed_mla" in variants and cfg.mla is not None:
        cfg = dataclasses.replace(cfg, mla=dataclasses.replace(cfg.mla, decode_mode="absorbed"))
    if "block_attn" in variants:
        cfg = dataclasses.replace(cfg, attn_block_q=512)
    if "no_remat" in variants:
        cfg = dataclasses.replace(cfg, remat=False)
    if "mlstm_chunk" in variants:
        cfg = dataclasses.replace(cfg, mlstm_chunk=2048)
    if "sp_residual" in variants:
        cfg = dataclasses.replace(cfg, seq_parallel_residual=True)
    return cfg


def build_shardings(cfg, shape, mesh, step_kind, opt, *, expert_parallel=False):
    """(in_shardings tuple, out_shardings) for the jitted step."""
    specs = input_specs(cfg, shape)
    p_sh = param_shardings(mesh, abstract_params(cfg), expert_parallel=expert_parallel)

    if step_kind == "train":
        state_shape = abstract_train_state(cfg, opt)
        state_sh = {
            "params": p_sh,
            "opt_state": opt_state_shardings(mesh, state_shape["opt_state"], p_sh),
            "step": replicated(mesh, state_shape["step"]),
        }
        batch_sh = batch_shardings(mesh, specs)
        metrics_sh = replicated(
            mesh,
            jax.eval_shape(
                lambda: {
                    "loss": jax.numpy.zeros(()),
                    "grad_norm": jax.numpy.zeros(()),
                    "ce": jax.numpy.zeros(()),
                    "aux": jax.numpy.zeros(()),
                }
            ),
        )
        return (state_sh, batch_sh), (state_sh, metrics_sh), (state_shape, specs)

    # prefill / decode
    batch_sh = {}
    for k, v in specs.items():
        if k == "caches":
            batch_sh[k] = cache_shardings(mesh, v, cfg)
        else:
            batch_sh[k] = batch_shardings(mesh, {k: v})[k]
    params_shape = abstract_params(cfg)
    if step_kind == "prefill":
        # outputs: (last logits, caches)
        cache_shape = jax.eval_shape(
            lambda: mdl.init_cache(
                cfg, shape.global_batch, shape.seq_len, jax.numpy.dtype(cfg.dtype)
            )
        )
        logits_sh = batch_shardings(
            mesh,
            jax.ShapeDtypeStruct((shape.global_batch, cfg.vocab_size), jax.numpy.dtype(cfg.dtype)),
        )
        out_sh = (logits_sh, cache_shardings(mesh, cache_shape, cfg))
    else:
        logits_sh = batch_shardings(
            mesh,
            jax.ShapeDtypeStruct((shape.global_batch, cfg.vocab_size), jax.numpy.dtype(cfg.dtype)),
        )
        out_sh = (logits_sh, batch_sh["caches"])
    return (p_sh, batch_sh), out_sh, (params_shape, specs)


def _with_repeats(cfg, n: int):
    """A structurally-identical config with ``n`` pattern repeats (and a
    matching encoder depth for enc-dec archs)."""
    n_layers = len(cfg.first_blocks) + len(cfg.pattern) * n + len(cfg.tail_blocks)
    enc = cfg.encoder
    if enc is not None:
        enc = dataclasses.replace(enc, n_layers=n)
    return dataclasses.replace(cfg, n_layers=n_layers, encoder=enc, scan_layers=False)


def _compile(cfg, shape, mesh, *, expert_parallel: bool):
    opt = default_optimizer()
    step_fn, kind = make_step(cfg, shape, opt)
    in_sh, out_sh, (state_shape, specs) = build_shardings(
        cfg, shape, mesh, kind, opt, expert_parallel=expert_parallel
    )
    with mesh, sharding_hints(batch_axes(mesh)):
        jitted = jax.jit(
            step_fn,
            in_shardings=in_sh,
            out_shardings=out_sh,
            donate_argnums=0 if kind == "train" else (),
        )
        compiled = jitted.lower(state_shape, specs).compile()
    return compiled, kind, state_shape


def normalize_cost_analysis(cost):
    """``Compiled.cost_analysis()`` drifted from per-device [dict] to dict
    across jax versions — normalize to the dict (shared with dryrun_fl)."""
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost


def _costs(compiled):
    cost = normalize_cost_analysis(compiled.cost_analysis())
    colls = rl.parse_collectives(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "colls": colls,
    }


def _slstm_correction_flops(cfg, shape, kind: str, chips: int) -> float:
    """sLSTM time-steps run inside a lax.scan whose body XLA counts once;
    add the analytically-exact recurrent matmul flops for the missing
    S-1 steps (4 gates × per-head hd² matmuls). Global flops / chips."""
    n_slstm = sum(1 for mx, _ in cfg.all_blocks if mx == "slstm")
    if n_slstm == 0 or kind == "decode":
        return 0.0
    from repro.models.layers.xlstm import _slstm_dims

    d_in, hd = _slstm_dims(cfg)
    steps = shape.seq_len - 1  # body counted once already
    per_step = 4 * cfg.n_heads * hd * hd * 2 * shape.global_batch
    mult = 3.0 if kind == "train" else 1.0  # fwd + bwd(2x)
    return n_slstm * steps * per_step * mult / chips


def run_one(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool,
    variants: list[str],
    out_dir: str,
    lower_only: bool = False,
):
    t0 = time.time()
    shape = INPUT_SHAPES[shape_name]
    cfg = apply_variants(get_config(arch), variants)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chips(mesh)
    ep = "expert_parallel" in variants

    # (a) production artifact: full depth, scan-over-layers — proves the
    # (arch × shape × mesh) combination lowers+compiles; memory analysis.
    compiled, kind, state_shape = _compile(cfg, shape, mesh, expert_parallel=ep)
    mem = compiled.memory_analysis()

    if lower_only:
        # multi-pod pass: the lowering + memory proof only (roofline terms
        # are reported on the single-pod mesh per the brief)
        c1 = c2 = _costs(compiled)
        r_full = 1
    else:
        # (b) cost accounting: XLA counts while-loop bodies once, so flops /
        # bytes / collectives come from two small UNROLLED compiles (1 and 2
        # pattern repeats) extrapolated linearly — exact for homogeneous stacks.
        r_full = cfg.n_repeats
        c1 = _costs(_compile(_with_repeats(cfg, 1), shape, mesh, expert_parallel=ep)[0])
        c2 = (
            _costs(_compile(_with_repeats(cfg, 2), shape, mesh, expert_parallel=ep)[0])
            if r_full > 1
            else c1
        )

    def extrap(f1: float, f2: float) -> float:
        return f1 + (r_full - 1) * (f2 - f1)

    flops = extrap(c1["flops"], c2["flops"]) + _slstm_correction_flops(cfg, shape, kind, chips)
    bytes_ = extrap(c1["bytes"], c2["bytes"])
    colls = {
        k: {
            "count": int(extrap(c1["colls"][k]["count"], c2["colls"][k]["count"])),
            "bytes": extrap(c1["colls"][k]["bytes"], c2["colls"][k]["bytes"]),
        }
        for k in c1["colls"]
    }
    cost = {"flops": flops, "bytes accessed": bytes_}
    total_coll = sum(v["bytes"] for v in colls.values())

    params_shape = state_shape["params"] if kind == "train" else state_shape
    n_total, n_active = rl.active_params(params_shape, cfg)
    tokens = shape.tokens if kind != "decode" else shape.global_batch  # 1 new token each
    mf = rl.model_flops(n_active, tokens, kind)

    roof = rl.Roofline(
        arch=arch,
        shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16",
        chips=chips,
        flops_per_chip=float(cost.get("flops", 0.0)),
        bytes_per_chip=float(cost.get("bytes accessed", 0.0)),
        coll_bytes_per_chip=float(total_coll),
        coll_detail=colls,
        model_flops_global=mf,
        arg_bytes_per_chip=mem.argument_size_in_bytes,
        temp_bytes_per_chip=mem.temp_size_in_bytes,
        out_bytes_per_chip=mem.output_size_in_bytes,
    )
    rec = roof.to_dict()
    rec.update(
        n_params=n_total,
        n_params_active=n_active,
        variants=variants,
        kind=kind,
        lower_only=lower_only,
        compile_s=round(time.time() - t0, 1),
        hbm_per_chip_gb=round(
            (mem.argument_size_in_bytes + mem.temp_size_in_bytes + mem.output_size_in_bytes)
            / 2**30, 3,
        ),
    )
    os.makedirs(out_dir, exist_ok=True)
    tag = "+".join(variants) if variants else "baseline"
    fname = f"{arch}__{shape_name}__{rec['mesh']}__{tag}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=1)

    print(
        f"[OK] {arch:22s} {shape_name:12s} mesh={rec['mesh']:8s} {tag:14s} "
        f"args={mem.argument_size_in_bytes/2**30:6.2f}GiB temp={mem.temp_size_in_bytes/2**30:7.2f}GiB "
        f"flops/chip={rec['flops_per_chip']:.3e} coll/chip={total_coll/2**20:9.1f}MiB "
        f"tc={roof.t_compute*1e3:8.2f}ms tm={roof.t_memory*1e3:8.2f}ms "
        f"tx={roof.t_collective*1e3:8.2f}ms dom={roof.dominant:10s} "
        f"util={roof.utility_ratio:5.2f} ({rec['compile_s']}s)",
        flush=True,
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="all (arch × shape) baselines")
    ap.add_argument("--variant", action="append", default=[], choices=VARIANTS)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument(
        "--lower-only",
        action="store_true",
        help="skip the cost-accounting compiles (multi-pod lowering pass)",
    )
    args = ap.parse_args()

    combos = (
        [(a, s) for a in ARCH_NAMES for s in INPUT_SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    failures = []
    for arch, shape in combos:
        try:
            run_one(
                arch,
                shape,
                multi_pod=args.multi_pod,
                variants=args.variant,
                out_dir=args.out,
                lower_only=args.lower_only,
            )
        except Exception as e:  # noqa: BLE001 - report and continue the matrix
            failures.append((arch, shape, repr(e)))
            print(f"[FAIL] {arch} {shape}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")
    print("dry-run complete: all combinations lowered and compiled.")


if __name__ == "__main__":
    main()
