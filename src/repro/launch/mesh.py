"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state — the dry-run sets XLA_FLAGS *before* any jax initialization
and only then calls this.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis carries only data-parallel/FSDP traffic (DCN-friendly), "model" stays
inside a pod (ICI).
"""
from __future__ import annotations

import inspect

import jax

# ``AxisType`` (and ``jax.make_mesh(..., axis_types=...)``) only exist on
# newer jax. Auto axes are also the default there, so on older jax we simply
# omit the kwarg — semantics are identical.
try:
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _axis_types_kwargs(n_axes: int) -> dict:
    if AxisType is None:
        return {}
    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over the locally available devices (tests)."""
    return jax.make_mesh((data, model), ("data", "model"), **_axis_types_kwargs(2))


def batch_axes(mesh) -> tuple[str, ...]:
    """Axes that shard the batch / FSDP dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def mesh_chips(mesh) -> int:
    return mesh.devices.size
