"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state — the dry-run sets XLA_FLAGS *before* any jax initialization
and only then calls this.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis carries only data-parallel/FSDP traffic (DCN-friendly), "model" stays
inside a pod (ICI).
"""
from __future__ import annotations

import inspect

import jax

# ``AxisType`` (and ``jax.make_mesh(..., axis_types=...)``) only exist on
# newer jax. Auto axes are also the default there, so on older jax we simply
# omit the kwarg — semantics are identical.
try:
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _axis_types_kwargs(n_axes: int) -> dict:
    if AxisType is None:
        return {}
    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over the locally available devices (tests)."""
    return jax.make_mesh((data, model), ("data", "model"), **_axis_types_kwargs(2))


def resolve_fl_mesh(spec):
    """Map ``FLConfig.mesh_spec`` to a mesh (or ``None``).

    * ``None`` — no mesh: the engine's single-device behavior.
    * ``"auto"`` — every locally visible device on the "data" axis.
    * ``"DxM"`` (e.g. ``"4x1"``) or ``(D, M)`` — host mesh with D-way
      data parallelism and M-way model parallelism.
    * a ``jax.sharding.Mesh`` — used as-is.
    """
    if spec is None:
        return None
    if isinstance(spec, jax.sharding.Mesh):
        return spec
    if isinstance(spec, str):
        if spec == "auto":
            return make_host_mesh(jax.local_device_count(), 1)
        parts = spec.lower().split("x")
        if len(parts) in (1, 2) and all(p.isdigit() and p for p in parts):
            return make_host_mesh(int(parts[0]), int(parts[1]) if len(parts) == 2 else 1)
    elif isinstance(spec, (tuple, list)) and len(spec) in (1, 2):
        data, *rest = spec
        return make_host_mesh(int(data), int(rest[0]) if rest else 1)
    raise ValueError(
        f"bad mesh_spec {spec!r}; expected None, 'auto', 'DxM', (D, M), or a Mesh"
    )


def batch_axes(mesh) -> tuple[str, ...]:
    """Axes that shard the batch / FSDP dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_parallel_degree(mesh) -> int:
    """Total device count across the batch axes."""
    size = 1
    for a in batch_axes(mesh):
        size *= mesh.shape[a]
    return size


def leading_batch_spec(mesh, ndim: int):
    """PartitionSpec placing an array's leading axis on the mesh's batch
    axes, trailing dims replicated — the one convention for "per-client /
    per-batch-element" arrays, shared by the FL engine's runtime constraints
    and the launch-layer lowering shardings."""
    from jax.sharding import PartitionSpec as P

    dp = batch_axes(mesh)
    lead = dp if len(dp) > 1 else dp[0]
    return P(lead, *([None] * (ndim - 1)))


def mesh_chips(mesh) -> int:
    return mesh.devices.size
