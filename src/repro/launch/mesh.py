"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state — the dry-run sets XLA_FLAGS *before* any jax initialization
and only then calls this.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis carries only data-parallel/FSDP traffic (DCN-friendly), "model" stays
inside a pod (ICI).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over the locally available devices (tests)."""
    return jax.make_mesh(
        (data, model), ("data", "model"), axis_types=(AxisType.Auto, AxisType.Auto)
    )


def batch_axes(mesh) -> tuple[str, ...]:
    """Axes that shard the batch / FSDP dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def mesh_chips(mesh) -> int:
    return mesh.devices.size
