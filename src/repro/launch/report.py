"""Render EXPERIMENTS.md tables from experiments/dryrun/*.json.

Usage:  PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
Prints the §Dry-run and §Roofline markdown tables.
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_: str) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def roofline_table(recs: list[dict], *, mesh: str = "16x16", variants: str = "baseline") -> str:
    rows = [
        r
        for r in recs
        if r["mesh"] == mesh
        and not r.get("lower_only")
        and r.get("kind") != "fl_round"
        and ("+".join(r.get("variants") or []) or "baseline") == variants
    ]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9)))
    out = [
        "| arch | shape | t_compute | t_memory | t_collective | dominant | "
        "MODEL/HLO | HBM/chip | coll/chip |",
        "|---|---|---:|---:|---:|---|---:|---:|---:|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute'] * 1e3:.2f} ms "
            f"| {r['t_memory'] * 1e3:.2f} ms | {r['t_collective'] * 1e3:.2f} ms "
            f"| **{r['dominant']}** | {r['utility_ratio']:.2f} "
            f"| {r['hbm_per_chip_gb']:.2f} GiB "
            f"| {r['coll_bytes_per_chip'] / 2**30:.2f} GiB |"
        )
    return "\n".join(out)


def dryrun_table(recs: list[dict], *, variants: str = "baseline") -> str:
    rows = [
        r
        for r in recs
        if r.get("kind") != "fl_round"
        and ("+".join(r.get("variants") or []) or "baseline") == variants
    ]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9), r["mesh"]))
    out = [
        "| arch | shape | mesh | kind | params | active | flops/chip | "
        "bytes/chip | AR/AG/RS/A2A counts | compile |",
        "|---|---|---|---|---:|---:|---:|---:|---|---:|",
    ]
    for r in rows:
        cd = r["coll_detail"]
        counts = "/".join(
            str(cd[k]["count"])
            for k in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all")
        )
        kind = r["kind"] + (" (lower-only)" if r.get("lower_only") else "")
        flops = "—" if r.get("lower_only") else f"{r['flops_per_chip']:.2e}"
        byts = "—" if r.get("lower_only") else f"{r['bytes_per_chip']:.2e}"
        cnts = "—" if r.get("lower_only") else counts
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {kind} "
            f"| {r['n_params'] / 1e9:.2f}B | {r['n_params_active'] / 1e9:.2f}B "
            f"| {flops} | {byts} | {cnts} | {r['compile_s']:.0f}s |"
        )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--variants", default="baseline")
    args = ap.parse_args()
    recs = load(args.dir)
    print(f"# {len(recs)} dry-run records\n")
    print("## §Dry-run\n")
    print(dryrun_table(recs, variants=args.variants))
    print("\n## §Roofline\n")
    print(roofline_table(recs, mesh=args.mesh, variants=args.variants))


if __name__ == "__main__":
    main()
