"""Jittable step functions + ShapeDtypeStruct input specs per workload shape.

``input_specs(cfg, shape)`` follows the shannon/kernels pattern: weak-type-
correct ShapeDtypeStructs, shardable, zero device allocation — the dry-run
lowers against these directly.

Decode shapes lower ``serve_step`` (ONE token against a ``seq_len`` cache).
``long_500k`` uses the sliding-window decode variant for full-attention
archs (DESIGN.md §5): the ring cache is ``sliding_window`` long while the
position counter sits at 524288.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import config as mcfg
from repro.models import model as mdl
from repro.models.config import InputShape, ModelConfig
from repro.optim import adamw, clip_by_global_norm
from repro.optim.base import Optimizer, apply_updates


# --------------------------------------------------------------------------
# shapes & specs
# --------------------------------------------------------------------------
def decode_window_for(cfg: ModelConfig, shape: InputShape) -> int:
    """Sliding-window decode applies to 'attn' blocks in long_500k only."""
    has_full_attn = any(m == "attn" for m, _ in cfg.all_blocks)
    if shape.name == "long_500k" and has_full_attn and cfg.mla is None:
        return cfg.sliding_window
    return 0


def cache_len_for(cfg: ModelConfig, shape: InputShape) -> int:
    window = decode_window_for(cfg, shape)
    return min(shape.seq_len, window) if window else shape.seq_len


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every step input."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    act = jnp.dtype(cfg.dtype)
    specs: dict[str, Any] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        specs["targets"] = jax.ShapeDtypeStruct((b, s), i32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    else:  # decode
        specs["token"] = jax.ShapeDtypeStruct((b, 1), i32)
        cl = cache_len_for(cfg, shape)
        dw = decode_window_for(cfg, shape)
        specs["caches"] = jax.eval_shape(
            lambda: mdl.init_cache(cfg, b, cl, act, decode_window=dw)
        )
    if cfg.frontend == "vision" and shape.kind != "decode":
        specs["vision_embeds"] = jax.ShapeDtypeStruct((b, cfg.n_vision_tokens, cfg.d_model), act)
    if cfg.frontend == "audio" and shape.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder.n_frames, cfg.d_model), act)
    return specs


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: mdl.init_params(cfg, jax.random.PRNGKey(0)))


def abstract_train_state(cfg: ModelConfig, opt: Optimizer):
    params = abstract_params(cfg)

    def mk():
        p = mdl.init_params(cfg, jax.random.PRNGKey(0))
        return {"params": p, "opt_state": opt.init(p), "step": jnp.zeros((), jnp.int32)}

    del params
    return jax.eval_shape(mk)


def default_optimizer() -> Optimizer:
    return adamw(3e-4, weight_decay=0.1)


# --------------------------------------------------------------------------
# steps
# --------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, opt: Optimizer, *, clip_norm: float = 1.0):
    def train_step(state, batch):
        def lf(p):
            return mdl.loss_fn(
                cfg,
                p,
                batch["tokens"],
                batch["targets"],
                vision_embeds=batch.get("vision_embeds"),
                frames=batch.get("frames"),
            )

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(state["params"])
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = opt.update(grads, state["opt_state"], state["params"], state["step"])
        params = apply_updates(state["params"], updates)
        new_state = {"params": params, "opt_state": opt_state, "step": state["step"] + 1}
        return new_state, {"loss": loss, "grad_norm": gnorm, **metrics}

    return train_step


def make_prefill_step(cfg: ModelConfig, shape: InputShape):
    b = shape.global_batch
    cl = shape.seq_len

    def prefill_step(params, batch):
        caches = mdl.init_cache(cfg, b, cl, jnp.dtype(cfg.dtype))
        hidden, caches, _ = forward_with_extras(cfg, params, batch, caches)
        logits = mdl.logits_from_hidden(cfg, params, hidden[:, -1:, :])[:, 0]
        return logits, caches

    return prefill_step


def forward_with_extras(cfg, params, batch, caches):
    return mdl.forward(
        cfg,
        params,
        batch["tokens"],
        vision_embeds=batch.get("vision_embeds"),
        frames=batch.get("frames"),
        caches=caches,
    )


def make_serve_step(cfg: ModelConfig, shape: InputShape):
    dw = decode_window_for(cfg, shape)

    def serve_step(params, batch):
        logits, caches = mdl.decode_step(
            cfg, params, batch["token"], batch["caches"], decode_window=dw
        )
        return logits, caches

    return serve_step


def make_step(cfg: ModelConfig, shape: InputShape, opt: Optional[Optimizer] = None):
    """(step_fn, kind) for an (arch, shape) pair."""
    if shape.kind == "train":
        return make_train_step(cfg, opt or default_optimizer()), "train"
    if shape.kind == "prefill":
        return make_prefill_step(cfg, shape), "prefill"
    return make_serve_step(cfg, shape), "decode"


# --------------------------------------------------------------------------
# batched FL round engine (repro.fl.engine) — lowering hooks
# --------------------------------------------------------------------------
def fl_engine_input_specs(
    n_clients: int,
    m_slots: int,
    n_pad: int,
    feat_shape: "int | tuple[int, ...]",
    n_steps: int,
    batch_size: int,
) -> dict[str, Any]:
    """ShapeDtypeStructs for one :func:`repro.fl.engine.batched_round_step`.

    Mirrors :func:`input_specs`: zero device allocation, shardable — the
    client axis (``m_slots``) is the natural data-parallel axis (each group
    plays one sampled client, as in ``launch.fl_train``). ``feat_shape`` is
    the per-sample feature shape: an int for flat feature vectors, a tuple
    (e.g. ``(32, 32, 3)``) for image-shaped clients."""
    fs = (feat_shape,) if isinstance(feat_shape, int) else tuple(feat_shape)
    f32, i32 = jnp.float32, jnp.int32
    return {
        "x_all": jax.ShapeDtypeStruct((n_clients, n_pad, *fs), f32),
        "y_all": jax.ShapeDtypeStruct((n_clients, n_pad), i32),
        "slot_ids": jax.ShapeDtypeStruct((m_slots,), i32),
        "batch_idx": jax.ShapeDtypeStruct((m_slots, n_steps, batch_size), i32),
        "weights": jax.ShapeDtypeStruct((m_slots,), f32),
        "stale_weight": jax.ShapeDtypeStruct((), f32),
    }


def fl_engine_shardings(mesh, specs: dict[str, Any]) -> dict[str, Any]:
    """NamedShardings for :func:`fl_engine_input_specs` on ``mesh``.

    The client-count axis of the staged data and the ``m_slots`` slot axes
    ride the mesh's batch axes (replicated when they don't divide the
    data-parallel degree); scalars replicate — the same layout
    ``BatchedRoundEngine(..., mesh=...)`` stages at runtime."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import data_parallel_degree, leading_batch_spec

    n_dp = data_parallel_degree(mesh)
    out = {}
    for key, spec in specs.items():
        if spec.shape and spec.shape[0] % n_dp == 0:
            out[key] = NamedSharding(mesh, leading_batch_spec(mesh, len(spec.shape)))
        else:
            out[key] = NamedSharding(mesh, P())
    return out


def make_fl_engine_step(
    loss_fn, opt: Optional[Optimizer] = None, *, fedprox_mu: float = 0.0, mesh=None
):
    """(params, batch) wrapper around the batched FL round for lowering.

    ``mesh`` is forwarded to :func:`repro.fl.engine.batched_round_step` so
    the dry-run / lowering harness exercises the sharded round exactly as
    the server runs it."""
    from repro.fl.engine import batched_round_step

    o = opt or default_optimizer()

    def fl_engine_step(params, batch):
        return batched_round_step(
            params,
            batch["x_all"],
            batch["y_all"],
            batch["slot_ids"],
            batch["batch_idx"],
            batch["weights"],
            batch["stale_weight"],
            loss_fn=loss_fn,
            opt=o,
            fedprox_mu=fedprox_mu,
            mesh=mesh,
        )

    return fl_engine_step
