"""Three-term roofline model from compiled dry-run artifacts.

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

``cost_analysis()`` on the SPMD-partitioned module reports *per-device*
flops/bytes, so the per-chip division is already applied; collective bytes
are parsed out of the optimized HLO (per-device buffer sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (given by the brief).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s per chip
LINK_BW = 50e9  # B/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
_SHAPE_RE = re.compile(r"([a-z]+[0-9a-z]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> dict[str, dict[str, float]]:
    """Sum per-device output bytes of every collective instruction, by kind."""
    out: dict[str, dict[str, float]] = {
        k: {"count": 0, "bytes": 0.0} for k in _COLLECTIVES
    }
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        lhs, _, rhs = stripped.partition("=")
        kind = None
        rl = rhs.lstrip()
        for k in _COLLECTIVES:
            # match `bf16[...] all-reduce(` or `(f32[..],..) all-reduce-start(`
            if re.search(rf"(^|\)\s|\]\S*\s){re.escape(k)}(-start)?\(", rl):
                kind = k
                break
        if kind is None:
            continue
        # output shapes sit between '=' and the op name
        head = rl.split(kind)[0]
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))
        out[kind]["count"] += 1
        out[kind]["bytes"] += nbytes
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_detail: dict
    model_flops_global: float
    arg_bytes_per_chip: float = 0.0
    temp_bytes_per_chip: float = 0.0
    out_bytes_per_chip: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def utility_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS — how much compiled compute is 'useful'."""
        hlo = self.flops_per_chip * self.chips
        return self.model_flops_global / hlo if hlo else float("nan")

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(
            t_compute=self.t_compute,
            t_memory=self.t_memory,
            t_collective=self.t_collective,
            dominant=self.dominant,
            utility_ratio=self.utility_ratio,
        )
        return d


def model_flops(n_params_active: int, tokens: int, kind: str) -> float:
    """6·N·D for training, 2·N·D for forward-only (prefill / decode)."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens


def active_params(params_shape, cfg) -> tuple[int, int]:
    """(total, active) parameter counts; MoE experts count at top_k/n_routed."""
    import jax

    total = 0
    active = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shape)[0]:
        name = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
        size = 1
        for s in leaf.shape:
            size *= s
        total += size
        if cfg.moe is not None and name in ("e_gate", "e_up", "e_down"):
            active += size * (cfg.moe.top_k / cfg.moe.n_routed)
        else:
            active += size
    return total, int(active)
