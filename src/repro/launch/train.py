"""Synchronous LM trainer driver (single host; production = same step jit'd
with the production mesh — the dry-run proves that lowering).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
      --steps 100 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import ARCH_NAMES, get_config
from repro.data.tokens import TokenPipeline
from repro.launch.steps import make_train_step
from repro.models import model as mdl
from repro.optim import adamw, linear_warmup_cosine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", help="CPU-sized variant")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    opt = adamw(linear_warmup_cosine(args.lr, args.steps // 10 + 1, args.steps))
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=0)

    params = mdl.init_params(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt_state": opt.init(params), "step": jnp.zeros((), jnp.int32)}
    pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq, seed=0)

    extras = {}
    if cfg.frontend == "vision":
        extras["vision_embeds"] = jnp.zeros((args.batch, cfg.n_vision_tokens, cfg.d_model), cfg.dtype)
    if cfg.frontend == "audio":
        extras["frames"] = jnp.zeros((args.batch, cfg.encoder.n_frames, cfg.d_model), cfg.dtype)

    t0 = time.time()
    losses = []
    for i in range(args.steps):
        b = pipe.next_batch()
        batch = {"tokens": jnp.asarray(b.tokens), "targets": jnp.asarray(b.targets), **extras}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if i % args.log_every == 0 or i == args.steps - 1:
            dt = time.time() - t0
            print(
                f"step {i:5d} loss {losses[-1]:.4f} ce {float(metrics['ce']):.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} ({dt:.1f}s)",
                flush=True,
            )
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    print(f"loss: first5={first:.4f} last5={last:.4f} (improved: {last < first})")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, state, step=args.steps)
        print(f"checkpoint -> {args.checkpoint}")


if __name__ == "__main__":
    main()
