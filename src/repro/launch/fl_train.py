"""Clustered-sampling FL as a first-class distributed training mode.

This is the paper's communication pattern mapped onto the pod (DESIGN.md
§4): each data-parallel group plays one *sampled client* for the round —

  1. the host-side sampler (MD / Algorithm 1 / Algorithm 2) draws
     ``m = data-parallel degree`` clients and their aggregation weights,
  2. ``fl_round_step`` = vmap(local_sgd) over the client axis (sharded over
     the batch axes) → every group runs N *unsynchronized* local steps,
  3. the weighted parameter combine ``Σ_k ω_k θ_k`` is one collective over
     the client axis — the sampler literally programs the collective.

Versus synchronous data-parallel training this trades the per-step gradient
all-reduce for a per-round parameter all-reduce: collective bytes drop by
~N× (quantified in EXPERIMENTS.md §Perf).

The round step is jit/shard_map-free pure jnp + vmap: GSPMD maps the client
axis onto ("pod","data"), the model dims onto "model" via the usual rules.

Similarity-based sampling (``FLLMConfig.sampler="algorithm2"``) closes the
loop: the round step also emits the flattened per-client updates, which feed
the sampler's device-resident gradient store; ``FLLMConfig.planner="async"``
rebuilds the Algorithm 2 plan on a background worker while the next round's
clients train (repro.fl.planner).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.samplers.base import ClientSampler
from repro.models import model as mdl
from repro.models.config import ModelConfig


def make_local_sgd(cfg: ModelConfig, lr: float, n_local_steps: int):
    """One client's round: N SGD steps on its own token stream."""

    def local_sgd(params, tokens, targets):
        # tokens: (N, B_local, S) — pre-drawn local batches

        def step(p, batch):
            tb, gb = batch

            def lf(q):
                loss, _ = mdl.loss_fn(cfg, q, tb, gb)
                return loss

            loss, grads = jax.value_and_grad(lf)(p)
            p = jax.tree_util.tree_map(lambda w, g: w - lr * g.astype(w.dtype), p, grads)
            return p, loss

        new_params, losses = jax.lax.scan(step, params, (tokens, targets))
        return new_params, losses.mean()

    return local_sgd


def make_fl_round_step(cfg: ModelConfig, lr: float, n_local_steps: int, *, with_updates: bool = False):
    """``with_updates=True`` additionally returns the flattened per-client
    representative gradients ``θ_k^{t+1} − θ^t`` (m, d) — Algorithm 2 line
    1's input, produced inside the same jitted round so the planner's
    gradient store is fed from device without an extra pass."""
    local_sgd = make_local_sgd(cfg, lr, n_local_steps)

    def fl_round_step(params, client_tokens, client_targets, weights):
        """params: global model; client_tokens/targets: (m, N, B, S) sharded
        over the batch axes; weights: (m,) realized aggregation weights."""
        client_params, losses = jax.vmap(local_sgd, in_axes=(None, 0, 0))(
            params, client_tokens, client_targets
        )
        # θ^{t+1} = Σ_k ω_k θ_k  — eq. (4), one weighted collective
        new_params = jax.tree_util.tree_map(
            lambda stacked: jnp.einsum(
                "m,m...->...", weights.astype(jnp.float32), stacked.astype(jnp.float32)
            ).astype(stacked.dtype),
            client_params,
        )
        if not with_updates:
            return new_params, losses.mean()
        from repro.fl.aggregation import flatten_params

        flat_global = flatten_params(params).astype(jnp.float32)
        updates = jax.vmap(
            lambda cp: flatten_params(cp).astype(jnp.float32) - flat_global
        )(client_params)
        return new_params, losses.mean(), updates

    return fl_round_step


def fl_input_specs(cfg: ModelConfig, m: int, n_local: int, batch: int, seq: int):
    i32 = jnp.int32
    return {
        "client_tokens": jax.ShapeDtypeStruct((m, n_local, batch, seq), i32),
        "client_targets": jax.ShapeDtypeStruct((m, n_local, batch, seq), i32),
        "weights": jax.ShapeDtypeStruct((m,), jnp.float32),
    }


def fl_round_shardings(mesh):
    """NamedShardings for :func:`fl_round_step`'s batch: the client axis on
    the mesh's batch axes (each data-parallel group plays one sampled
    client), weights replicated — shared by the dry-run and the host driver."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import leading_batch_spec

    return {
        "client_tokens": NamedSharding(mesh, leading_batch_spec(mesh, 4)),
        "client_targets": NamedSharding(mesh, leading_batch_spec(mesh, 4)),
        "weights": NamedSharding(mesh, P(None)),
    }


# --------------------------------------------------------------------------
# host-side driver (single process; production path is the same jit with a
# production mesh — exercised by the dry-run's fl_round mode)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class FLLMConfig:
    n_clients: int = 32
    m: int = 8
    n_rounds: int = 10
    n_local_steps: int = 4
    local_batch: int = 4
    seq_len: int = 64
    lr: float = 0.05
    # A registry name, a spec dict, or a repro.fl.experiment.SamplerSpec —
    # all three resolve through the shared SamplerSpec path (the spec's
    # m/seed default to this config's when given as a bare name).
    sampler: "str | dict | object" = "algorithm1"
    seed: int = 0
    # Plan-rebuild scheduling for similarity-based samplers: "sync" | "async"
    # mode string, a spec dict, or a PlannerSpec ({"mode": "async",
    # "rebuild_every": k} overlaps + throttles re-clustering, repro.fl.planner).
    planner: "str | dict | object" = "sync"

    def sampler_spec(self):
        from repro.fl.experiment import SamplerSpec

        s = self.sampler
        if isinstance(s, dict):
            # a dict may omit m/seed — they default to this config's
            s = SamplerSpec.from_dict({"m": self.m, "seed": self.seed, **s})
        if not isinstance(s, SamplerSpec):
            return SamplerSpec(name=s, m=self.m, seed=self.seed)
        if s.m != self.m:
            raise ValueError(
                f"SamplerSpec.m={s.m} contradicts FLLMConfig.m={self.m} — the "
                "LM driver sizes every round's client axis (and its mesh "
                "sharding) by fl.m, so the sampler must draw exactly that many"
            )
        return s

    def planner_spec(self):
        from repro.fl.experiment import PlannerSpec

        p = self.planner
        if isinstance(p, PlannerSpec):
            return p
        if isinstance(p, dict):
            return PlannerSpec.from_dict(p)
        return PlannerSpec(mode=p)


def make_lm_sampler(fl: FLLMConfig, population, update_dim: int) -> ClientSampler:
    """Build ``fl.sampler`` for the LM driver via the shared SamplerSpec path.

    ``update_dim`` is the flattened model size — Algorithm 2's gradient
    store holds (n_clients, update_dim) f32 on device, and its plan service
    runs under ``fl.planner``. Any scheme registered in
    ``repro.core.samplers.SAMPLERS`` is reachable by name.
    """
    from repro.fl.experiment import build_sampler

    return build_sampler(
        fl.sampler_spec(),
        population,
        planner=fl.planner_spec(),
        update_dim=update_dim or None,
    )


def run_federated_lm(
    cfg: ModelConfig, fl: FLLMConfig, sampler: ClientSampler, *, mesh=None
) -> list[float]:
    """Federated LM training over synthetic per-client token streams.

    Each client owns a token stream with a client-specific structure (stride
    pattern) — heterogeneous in the same sense as the paper's non-iid
    labels. Returns the per-round mean local loss.

    With ``mesh``, the jit pins the client axis of every round's batch onto
    the mesh's batch axes via :func:`fl_round_shardings` (params replicated
    across them; the data-parallel degree must divide ``fl.m`` so every
    group plays at least one whole client) — the same placement the
    pod-scale dry-run (``launch.dryrun_fl``) lowers.
    """
    from repro.data.tokens import TokenPipeline

    rng = np.random.default_rng(fl.seed)
    pipes = [
        TokenPipeline(cfg.vocab_size, fl.local_batch, fl.seq_len, seed=1000 + 17 * c)
        for c in range(fl.n_clients)
    ]
    params = mdl.init_params(cfg, jax.random.PRNGKey(fl.seed))
    # similarity-based samplers need the per-client representative gradients
    # back — the round step then also emits the (m, d) flat updates, which
    # feed the sampler's device-resident gradient store / plan service
    feedback = getattr(sampler, "consumes_updates", False)
    step_fn = make_fl_round_step(cfg, fl.lr, fl.n_local_steps, with_updates=feedback)
    if mesh is None:
        round_step = jax.jit(step_fn)
    else:
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.launch.mesh import data_parallel_degree

        n_dp = data_parallel_degree(mesh)
        if fl.m % n_dp != 0:
            raise ValueError(
                f"fl.m={fl.m} must be a multiple of the mesh's data-parallel "
                f"degree {n_dp} — the jit shards the client axis over it, so "
                "each data group must play a whole number of clients"
            )
        batch_sh = fl_round_shardings(mesh)
        repl = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), params)
        round_step = jax.jit(
            step_fn,
            in_shardings=(
                repl,
                batch_sh["client_tokens"],
                batch_sh["client_targets"],
                batch_sh["weights"],
            ),
        )

    del rng
    losses = []
    for t in range(fl.n_rounds):
        res = sampler.sample(t)
        # fixed-shape round: all m draws participate with weight 1/m (eq. 4);
        # a client drawn twice appears twice — identical aggregate, one compile
        toks = np.stack(
            [
                np.stack([pipes[int(c)].next_batch().tokens for _ in range(fl.n_local_steps)])
                for c in res.clients
            ]
        )
        tgts = (toks * 1 + 31) % cfg.vocab_size  # same structure as TokenPipeline
        weights = np.full(len(res.clients), 1.0 / len(res.clients), np.float32)
        out = round_step(
            params, jnp.asarray(toks), jnp.asarray(tgts), jnp.asarray(weights)
        )
        if feedback:
            params, loss, updates = out
            # a client drawn twice trained twice on different batches here —
            # keep the first slot's update so the scatter is deterministic
            ids, first = np.unique(np.asarray(res.clients), return_index=True)
            sampler.observe_updates(ids.astype(np.int64), updates[first])
        else:
            params, loss = out
        losses.append(float(loss))
    return losses
