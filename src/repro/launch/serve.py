"""Batched serving driver: prefill a prompt batch, then greedy decode.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.models import model as mdl


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    params = mdl.init_params(cfg, jax.random.PRNGKey(0))
    cache_len = args.prompt_len + args.gen

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    extras = {}
    if cfg.frontend == "vision":
        extras["vision_embeds"] = jnp.zeros((args.batch, cfg.n_vision_tokens, cfg.d_model), cfg.dtype)
    if cfg.frontend == "audio":
        extras["frames"] = jnp.zeros((args.batch, cfg.encoder.n_frames, cfg.d_model), cfg.dtype)

    @jax.jit
    def prefill(params, tokens):
        caches = mdl.init_cache(cfg, args.batch, cache_len)
        hidden, caches, _ = mdl.forward(cfg, params, tokens, caches=caches, **extras)
        logits = mdl.logits_from_hidden(cfg, params, hidden[:, -1:, :])[:, 0]
        return logits, caches

    @jax.jit
    def decode(params, token, caches):
        return mdl.decode_step(cfg, params, token, caches)

    t0 = time.time()
    logits, caches = prefill(params, prompts)
    print(f"prefill ({args.batch}x{args.prompt_len}) in {time.time() - t0:.2f}s")

    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, caches = decode(params, tok, caches)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"decoded {args.gen - 1} x {args.batch} tokens in {dt:.2f}s "
          f"({(args.gen - 1) * args.batch / max(dt, 1e-9):.1f} tok/s)")
    print("generations (token ids):")
    for row in gen[: min(4, args.batch)]:
        print("  ", list(map(int, row)))


if __name__ == "__main__":
    main()
