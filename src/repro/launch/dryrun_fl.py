import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run for the PAPER'S round step: clustered-sampling FL at pod scale.

Lowers ``fl_round_step`` (m clients × N unsynchronized local steps ×
weighted parameter combine) on the production mesh and records the same
cost/collective analysis as the synchronous ``train_step`` dry-run — the
head-to-head that quantifies the paper's communication claim on TPU
collectives (EXPERIMENTS.md §Perf).

Usage:
  python -m repro.launch.dryrun_fl --arch qwen3-0.6b --local-steps 8
"""
import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config
from repro.launch import roofline as rl
from repro.launch.fl_train import fl_input_specs, fl_round_shardings, make_fl_round_step
from repro.launch.mesh import data_parallel_degree, make_production_mesh, mesh_chips
from repro.launch.sharding import param_shardings, replicated
from repro.launch.steps import abstract_params
from repro.models.config import INPUT_SHAPES
from repro.models.sharding_hints import sharding_hints


def run_fl_round(
    arch: str,
    *,
    n_local: int,
    multi_pod: bool = False,
    seq_len: int = 4096,
    global_batch: int = 256,
    out_dir: str = "experiments/dryrun",
    variants: list[str] | None = None,
    planner: str = "none",
):
    from repro.launch.dryrun import apply_variants  # shares variant plumbing

    if planner not in ("none", "sync", "async"):
        raise ValueError(f"unknown planner {planner!r}; choose none | sync | async")
    t0 = time.time()
    cfg = apply_variants(get_config(arch), variants or [])
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chips(mesh)
    m = data_parallel_degree(mesh)  # one client per data group
    local_batch = global_batch // m

    # with a planner, the round also emits the (m, d) flat representative
    # gradients that feed Algorithm 2's device-resident store — lower that
    # variant so its extra output (and shardings) are part of the analysis
    with_updates = planner != "none"
    step_fn = make_fl_round_step(
        cfg, lr=1e-2, n_local_steps=n_local, with_updates=with_updates
    )
    specs = fl_input_specs(cfg, m, n_local, local_batch, seq_len)

    # cross-silo layout: params replicated over the client/data axes
    # (each client trains its own copy), tensor-parallel over "model"
    p_sh = param_shardings(mesh, abstract_params(cfg))
    p_repl = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, P(*[e if e == "model" else None for e in s.spec])),
        p_sh,
    )
    batch_sh = fl_round_shardings(mesh)
    loss_sh = replicated(mesh, jax.eval_shape(lambda: jnp.zeros(())))

    # NOTE: the in-model sequence-parallel constraints (sharding_hints) are
    # NOT active here — combining them with the vmapped client axis trips an
    # XLA SPMD partitioner CHECK (device-group mismatch, observed with jax
    # 0.8.2). Attention TP inside a client therefore relies on GSPMD
    # propagation only; the quantity under study — the *client-axis*
    # collective schedule (per-round weighted combine vs per-step gradient
    # all-reduce) — is unaffected.
    from repro.launch.mesh import leading_batch_spec

    d_model_flat = sum(
        int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(abstract_params(cfg))
    )
    # planner feed: flat updates sharded over the client axis like the batch
    upd_sh = NamedSharding(mesh, leading_batch_spec(mesh, 2))
    out_sh = (p_repl, loss_sh, upd_sh) if with_updates else (p_repl, loss_sh)
    with mesh:
        jitted = jax.jit(
            lambda p, b: step_fn(p, b["client_tokens"], b["client_targets"], b["weights"]),
            in_shardings=(p_repl, batch_sh),
            out_shardings=out_sh,
        )
        compiled = jitted.lower(abstract_params(cfg), specs).compile()

    from repro.launch.dryrun import normalize_cost_analysis

    mem = compiled.memory_analysis()
    cost = normalize_cost_analysis(compiled.cost_analysis())
    colls = rl.parse_collectives(compiled.as_text())
    # NOTE: the model body runs under vmap+scan(local steps) — while-loop
    # body counted once, so per-LOCAL-STEP cost ≈ reported cost directly;
    # the collective combine happens ONCE per round (outside the scan) and
    # is correctly counted once.
    total_coll = sum(v["bytes"] for v in colls.values())
    rec = {
        "arch": arch,
        "shape": f"fl_round_N{n_local}",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": "fl_round",
        "m_clients": m,
        "n_local_steps": n_local,
        "flops_per_chip_per_local_step": float(cost.get("flops", 0.0)),
        "coll_bytes_per_chip_per_round": float(total_coll),
        "coll_bytes_per_chip_per_step": float(total_coll) / n_local,
        "coll_detail": colls,
        "t_collective_per_step": float(total_coll) / n_local / rl.LINK_BW,
        "hbm_per_chip_gb": round(
            (mem.argument_size_in_bytes + mem.temp_size_in_bytes + mem.output_size_in_bytes)
            / 2**30, 3,
        ),
        # async keeps the rebuild off the round's critical path entirely; the
        # device-side cost of feeding it is the (m, d) f32 updates output
        "planner": planner,
        "planner_feed_bytes": (m * d_model_flat * 4) if with_updates else 0,
        "variants": variants or [],
        "compile_s": round(time.time() - t0, 1),
    }
    os.makedirs(out_dir, exist_ok=True)
    tag = "+".join(variants or []) or "baseline"
    if planner != "none":
        tag += f"+planner-{planner}"
    with open(
        os.path.join(out_dir, f"{arch}__fl_round_N{n_local}__{rec['mesh']}__{tag}.json"), "w"
    ) as f:
        json.dump(rec, f, indent=1)
    print(
        f"[OK] {arch} fl_round N={n_local} mesh={rec['mesh']} "
        f"coll/round={total_coll / 2**20:.1f}MiB coll/step={total_coll / n_local / 2**20:.1f}MiB "
        f"tx/step={rec['t_collective_per_step'] * 1e3:.2f}ms hbm={rec['hbm_per_chip_gb']}GB "
        f"({rec['compile_s']}s)",
        flush=True,
    )
    return rec


def planner_from_spec(spec_arg: str) -> str:
    """Derive the planner variant to lower from an experiment-spec JSON.

    ``spec_arg`` is inline JSON or a path to a JSON file with (at least)
    ``sampler`` / ``planner`` sections (``repro.fl.experiment`` schema). A
    sampler that consumes representative gradients lowers the planner-fed
    round in the spec's planner mode; plan-free samplers lower the plain
    round (``"none"``).
    """
    from repro.core.samplers import SAMPLERS
    from repro.fl.experiment import PlannerSpec, SamplerSpec, load_spec_dict

    d = load_spec_dict(spec_arg)
    sampler = SamplerSpec.from_dict(d.get("sampler", {"name": "algorithm2", "m": 1}))
    planner = PlannerSpec.from_dict(d.get("planner", {}))
    consumes = getattr(SAMPLERS.get(sampler.name), "consumes_updates", False)
    return planner.mode if consumes else "none"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen3-0.6b")
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument(
        "--planner", choices=("none", "sync", "async"), default="none",
        help="lower the planner-fed round variant (emits the (m, d) flat "
        "representative gradients Algorithm 2's gradient store consumes)",
    )
    ap.add_argument(
        "--spec", default=None,
        help="experiment-spec JSON (inline or a file path); its sampler/"
        "planner sections pick the round variant to lower (overrides --planner)",
    )
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    planner = planner_from_spec(args.spec) if args.spec else args.planner
    run_fl_round(
        args.arch, n_local=args.local_steps, multi_pod=args.multi_pod,
        out_dir=args.out, planner=planner,
    )


if __name__ == "__main__":
    main()
