"""Sharding rules: parameter / activation / cache PartitionSpecs.

Scheme (DESIGN.md §6): FSDP on the batch axes × tensor-parallel on "model".

* column-parallel 2D weights (d_in, d_out): P(fsdp, "model")
* row-parallel    2D weights (names below): P("model", fsdp)
* embedding (V, D): P("model", fsdp) — vocab-sharded so tied logits land
  P(batch, None, "model"); lm_head (D, V): P(fsdp, "model").
* MoE expert stacks (E, d, f): baseline shards the *ffn* dim on "model"
  (tensor-parallel experts). Expert-parallel (E on "model") is the §Perf
  variant, toggled by ``expert_parallel=True``.
* norms / small vectors / scalars: replicated.
* leaves under the scan "stack" get a leading None for the repeat dim.

Uneven shardings (e.g. whisper's 51865 vocab over 16) are allowed — GSPMD
pads — so every assigned architecture lowers with the same rules.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes

# row-parallel: input dim carries the "model" shard
_ROW_PARALLEL = ("w_down", "w_out")
_REPLICATED_1D = ("scale", "bias", "lam", "out_norm", "q_norm", "k_norm")
# Attention-family projections are FSDP-only (d_in sharded over batch axes,
# d_out replicated): attention compute is *sequence-parallel* over the model
# axis (see repro.models.sharding_hints), and head-sharded projections would
# force an expensive reshard before every score einsum (verified: SPMD
# "involuntary full rematerialization" + 4× collective bytes). The weights
# are small (4·d² vs 3·d·d_ff for the TP'd MLP), so FSDP storage suffices.
_FSDP_ONLY = ("wq", "wk", "wv", "wo", "w_dkv", "w_kr")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(
    path: str, ndim: int, fsdp: tuple[str, ...], *, expert_parallel: bool = False
) -> P:
    """PartitionSpec for one parameter leaf (trailing dims; stack handled by caller)."""
    name = path.split("/")[-1]
    fs = tuple(fsdp) if len(fsdp) > 1 else fsdp[0] if fsdp else None

    if name == "embed":
        return P("model", fs)
    if name == "lm_head":
        return P(fs, "model")
    if name in ("e_gate", "e_up"):  # (E, d, f)
        return P("model", fs, None) if expert_parallel else P(None, fs, "model")
    if name == "e_down":  # (E, f, d)
        return P("model", None, fs) if expert_parallel else P(None, "model", fs)
    if name in ("w_uk", "w_uv"):  # MLA (R, H, hd) — replicated (seq-parallel attn)
        return P(None, None, None)
    if name in _FSDP_ONLY:
        return P(fs, None) if ndim == 2 else P(*([None] * ndim))
    if name.startswith("r_"):  # sLSTM per-head recurrent (H, hd, hd)
        return P(None, None, None)
    if name == "conv_w":  # (cw, w)
        return P(None, "model")
    if ndim == 2:
        if name in _ROW_PARALLEL:
            return P("model", fs)
        return P(fs, "model")
    if ndim == 1:
        if name in _REPLICATED_1D or name.startswith("b_"):
            return P(None)
        return P("model")  # attention biases bq/bk/bv etc.
    return P(*([None] * ndim))


def param_shardings(
    mesh, params_shape: Any, *, expert_parallel: bool = False
) -> Any:
    """Build the NamedSharding pytree for a params (or grads/updates) tree."""
    fsdp = batch_axes(mesh)

    def axes_size(entry) -> int:
        if entry is None:
            return 1
        names = (entry,) if isinstance(entry, str) else entry
        n = 1
        for a in names:
            n *= mesh.shape[a]
        return n

    def one(path, leaf):
        pstr = _path_str(path)
        ndim = len(leaf.shape)
        stacked = "/stack/" in f"/{pstr}/" or pstr.startswith("stack/")
        eff_ndim = ndim - 1 if stacked else ndim
        spec = param_spec(pstr, eff_ndim, fsdp, expert_parallel=expert_parallel)
        if stacked:
            spec = P(None, *spec)
        if len(spec) < ndim:
            spec = P(*spec, *([None] * (ndim - len(spec))))
        # never shard a dim that does not divide its mesh axes (GSPMD would
        # pad — wasteful and confusing for the roofline numbers)
        clean = [
            e if dim % axes_size(e) == 0 else None for e, dim in zip(spec, leaf.shape)
        ]
        return NamedSharding(mesh, P(*clean))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_state_shardings(mesh, opt_state_shape, params_shardings) -> Any:
    """Adam moments mirror the param shardings; scalars are replicated."""

    def one(path, leaf):
        # moments live under mu/nu with the same sub-path as params
        pstr = _path_str(path)
        if pstr.startswith(("mu/", "nu/")):
            sub = pstr.split("/", 1)[1]
            ref = _lookup(params_shardings, sub)
            if ref is not None:
                return ref
        return NamedSharding(mesh, P(*([None] * len(leaf.shape))))

    return jax.tree_util.tree_map_with_path(one, opt_state_shape)


def _lookup(tree, path_str: str):
    node = tree
    for part in path_str.split("/"):
        if isinstance(node, dict) and part in node:
            node = node[part]
        elif isinstance(node, (list, tuple)) and part.isdigit() and int(part) < len(node):
            node = node[int(part)]
        else:
            return None
    return node if isinstance(node, NamedSharding) else None


# --------------------------------------------------------------------------
# activations / batches / caches
# --------------------------------------------------------------------------
def batch_shardings(mesh, batch_shape: Any) -> Any:
    """Token batches: shard the leading (global batch) dim over batch axes."""
    fsdp = batch_axes(mesh)
    dp = tuple(fsdp) if len(fsdp) > 1 else fsdp[0]
    n_batch = int(np.prod([mesh.shape[a] for a in fsdp]))

    def one(leaf):
        if leaf.shape and leaf.shape[0] % n_batch == 0:
            spec = P(dp, *([None] * (len(leaf.shape) - 1)))
        else:
            spec = P(*([None] * len(leaf.shape)))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(one, batch_shape)


def cache_shardings(mesh, cache_shape: Any, cfg) -> Any:
    """Decode caches: batch on batch-axes when divisible; else length dim on
    "model"; kv-head dim on "model" when divisible; recurrent states get
    (batch, "model") on their width dim."""
    fsdp = batch_axes(mesh)
    dp = tuple(fsdp) if len(fsdp) > 1 else fsdp[0]
    n_batch = int(np.prod([mesh.shape[a] for a in fsdp]))
    n_model = mesh.shape["model"]

    def one(path, leaf):
        shape = leaf.shape
        pstr = _path_str(path)
        stacked = "/stack/" in f"/{pstr}/" or pstr.startswith("stack/")
        dims: list = [None] * len(shape)
        off = 1 if stacked else 0
        eff = shape[off:]
        name = pstr.split("/")[-1]
        if not eff:  # pos scalars
            return NamedSharding(mesh, P(*dims))
        # leading effective dim is batch for all cache kinds
        used_model = False
        if eff[0] % n_batch == 0 and eff[0] >= n_batch:
            dims[off] = dp
        if name in ("k", "v", "ck", "cv") and len(eff) == 4:
            # length-sharded to match the sequence-parallel decode constraint
            if eff[1] % n_model == 0:
                dims[off + 1] = "model"
                used_model = True
            elif eff[2] % n_model == 0:  # fall back to kv heads
                dims[off + 2] = "model"
                used_model = True
        elif name in ("c", "k_rope") and len(eff) == 3:
            if eff[1] % n_model == 0:
                dims[off + 1] = "model"
                used_model = True
        elif len(eff) >= 2 and eff[-1] % n_model == 0:
            dims[off + len(eff) - 1] = "model"  # recurrent width
            used_model = True
        del used_model
        return NamedSharding(mesh, P(*dims))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def replicated(mesh, tree_shape: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(mesh, P(*([None] * len(leaf.shape)))), tree_shape
    )
