"""Campaign launcher: one SweepSpec JSON → a resumable, collated RunStore.

The CLI face of :mod:`repro.fl.sweep` — point it at a sweep JSON (inline
or a file) and a store directory; re-invoking the same pair resumes a
killed campaign (completed cells are skipped) and the collated CSVs come
out bit-identical to an uninterrupted run.

Usage:
  python -m repro.launch.sweep sweep.json --store runs/fig2 [--workers 4]
  python -m repro.launch.sweep sweep.json --store runs/fig2 --list-cells
"""
from __future__ import annotations

import argparse
import sys

from repro.fl.sweep import SweepSpec, cell_group_label, run_sweep, write_collated


def main(argv: "list[str] | None" = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("sweep", help="SweepSpec JSON (inline or a file path)")
    ap.add_argument("--store", default=None,
                    help="RunStore directory (resumable; required unless --list-cells)")
    ap.add_argument("--workers", type=int, default=1, help="process-pool fan-out for independent cells")
    ap.add_argument("--no-collate", action="store_true", help="skip writing cells.csv / summary.csv")
    ap.add_argument("--list-cells", action="store_true", help="print the expanded grid and exit")
    args = ap.parse_args(sys.argv[1:] if argv is None else argv)

    sweep = SweepSpec.from_arg(args.sweep)
    cells = sweep.cells()
    if args.list_cells:
        for c in cells:
            label = cell_group_label(c.overrides) or "base"
            print(f"{c.cell_id}  grid={c.grid_index} seed={c.seed_index}  {label}")
        print(f"# {len(cells)} cells = {len(cells) // sweep.n_seeds} grid points x {sweep.n_seeds} seeds")
        return
    if not args.store:
        ap.error("--store is required unless --list-cells")

    def on_cell(cell, status, summary, dt):
        label = cell_group_label(cell.overrides) or "base"
        extra = f" loss={summary['final_loss']:.4f}" if summary else ""
        print(f"[{status}] {cell.cell_id} seed={cell.seed_index} {label}"
              f"{extra} ({dt:.1f}s)", flush=True)

    store = run_sweep(sweep, args.store, workers=args.workers, on_cell=on_cell)
    if not args.no_collate:
        cells_csv, summary_csv = write_collated(store)
        print(f"# collated: {cells_csv}")
        print(f"# collated: {summary_csv}")


if __name__ == "__main__":
    main()
