"""Continuous FL service driver: churn-tolerant, SIGTERM-safe, resumable.

Runs an :class:`~repro.fl.experiment.ExperimentSpec` as a *service* instead
of a batch job: the population process decides who is reachable each round,
the server checkpoints its full ServerState on the configured cadence, and
SIGTERM/SIGINT request a clean stop — the current round finishes, a final
checkpoint is written, and the process exits 0. A later invocation with
``--resume`` reconstructs mid-campaign and continues **bit-identically** to
the run that was never killed (``tests/test_service_resume.py`` pins this;
``scripts/tier1.sh`` kills and resumes a real process as a smoke test).

Usage::

    python -m repro.launch.fl_service --spec spec.json \
        --checkpoint runs/svc.npz --history runs/history.json
    # ... SIGTERM lands, process exits cleanly ...
    python -m repro.launch.fl_service --spec spec.json \
        --checkpoint runs/svc.npz --history runs/history.json --resume

The spec's ``train.checkpoint_every`` sets the cadence (the driver defaults
it to 10 if the spec leaves it at 0 — a service without checkpoints is a
batch job wearing a trench coat). ``--throttle`` sleeps between rounds,
making small smoke runs long enough for a signal to land mid-campaign.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import signal
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="run an ExperimentSpec as a crash-safe continuous FL service"
    )
    ap.add_argument("--spec", required=True, help="ExperimentSpec JSON (inline or file path)")
    ap.add_argument("--checkpoint", required=True, help="ServerState bundle path (.npz)")
    ap.add_argument("--history", default=None, help="write the run History JSON here on exit")
    ap.add_argument("--resume", action="store_true", help="restore from --checkpoint and continue")
    ap.add_argument(
        "--skip-empty", action="store_true",
        help="ride out all-offline / all-dropped rounds as round_status='empty' "
        "records instead of failing the service",
    )
    ap.add_argument(
        "--throttle", type=float, default=0.0,
        help="seconds to sleep after each round (smoke tests: keeps short "
        "campaigns alive long enough for a SIGTERM to land mid-run)",
    )
    ap.add_argument(
        "--status-every", type=int, default=1, metavar="N",
        help="print the per-round status line only every N rounds (default 1: "
        "every round); the service summary always prints",
    )
    args = ap.parse_args(argv)
    if args.status_every < 1:
        ap.error(f"--status-every must be >= 1, got {args.status_every}")

    from repro.fl.experiment import ExperimentSpec, load_spec_dict

    spec = ExperimentSpec.from_dict(load_spec_dict(args.spec))
    if spec.train.checkpoint_every <= 0:
        spec = dataclasses.replace(
            spec, train=dataclasses.replace(spec.train, checkpoint_every=10)
        )

    # SIGTERM/SIGINT → finish the in-flight round, checkpoint, exit cleanly.
    # A plain flag (not an exception) so the signal can land anywhere —
    # including inside a jitted engine dispatch — without corrupting state.
    stop = {"flag": False, "signal": None}

    def _request_stop(signum, frame):
        del frame
        stop["flag"] = True
        stop["signal"] = signum

    old = {s: signal.signal(s, _request_stop) for s in (signal.SIGTERM, signal.SIGINT)}

    done_this_run = {"n": 0}

    def on_round(rec):
        done_this_run["n"] += 1
        if rec.round % args.status_every == 0:
            late = f" late={rec.n_late} harvested={rec.n_harvested}" if (
                rec.n_late or rec.n_harvested
            ) else ""
            print(
                f"[round {rec.round}] status={rec.round_status} "
                f"loss={rec.train_loss:.4f} acc={rec.test_acc:.4f} "
                f"avail={rec.n_available} dropped={rec.n_dropped}{late} "
                f"drift={rec.plan_drift:.3f} build_ms={rec.plan_build_ms:.1f}",
                flush=True,
            )
        if args.throttle > 0:
            time.sleep(args.throttle)

    try:
        with spec.build(checkpoint_path=args.checkpoint) as srv:
            if args.resume:
                if not os.path.exists(args.checkpoint):
                    print(f"error: --resume but no checkpoint at {args.checkpoint}", file=sys.stderr)
                    return 2
                start = srv.resume()
                print(f"resuming at round {start} from {args.checkpoint}", flush=True)
            t0 = time.time()
            history = srv.run(
                on_round, should_stop=lambda: stop["flag"], skip_empty=args.skip_empty
            )
            wall = time.time() - t0
            if stop["flag"]:
                # run() already wrote the stop checkpoint; make the cut
                # explicit in the log for operators (and the tier-1 smoke)
                print(
                    f"stop requested (signal {stop['signal']}); "
                    f"checkpointed at round cursor {srv._round_cursor} "
                    f"to {args.checkpoint}",
                    flush=True,
                )
            elif spec.train.checkpoint_every:
                srv.checkpoint()  # final state, even off-cadence
            if args.history:
                os.makedirs(os.path.dirname(os.path.abspath(args.history)), exist_ok=True)
                with open(args.history, "w") as f:
                    f.write(history.to_json())
            n = done_this_run["n"]
            rps = n / wall if wall > 0 else float("inf")
            ok = sum(r.round_status == "ok" for r in history.records)
            deg = sum(r.round_status == "degraded" for r in history.records)
            emp = sum(r.round_status == "empty" for r in history.records)
            print(
                f"service summary: {n} rounds this invocation "
                f"({len(history.records)} total: {ok} ok / {deg} degraded / {emp} empty), "
                f"sustained {rps:.2f} rounds/s",
                flush=True,
            )
    finally:
        for s, h in old.items():
            signal.signal(s, h)
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:
        # A downstream reader (`| grep -q ...`, `| head`) closed our stdout.
        # The service's durable state is the checkpoint, not the log stream:
        # point stdout at devnull so the interpreter's shutdown flush doesn't
        # raise again, and exit cleanly.
        devnull = os.open(os.devnull, os.O_WRONLY)
        os.dup2(devnull, sys.stdout.fileno())
        sys.exit(0)
