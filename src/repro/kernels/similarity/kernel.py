"""Pallas TPU kernels for pairwise client-similarity (Algorithm 2 line 2).

The O(n²d) hot-spot of the paper: ``n`` clients × ``d`` model parameters →
(n, n) similarity. Two kernels:

* ``gram``  — G Gᵀ via MXU-tiled (bi × bd)·(bd × bj) accumulation; arccos and
  L2 distances derive from the Gram matrix on the host side (ops.py).
* ``l1``    — Σ_k |G_i,k - G_j,k|, VPU elementwise tiles, same grid.

Grid: (n/bi, n/bj, d/bd) with the d-axis innermost; an f32 VMEM scratch
accumulates across d-blocks and flushes to the output block on the last
step. Block sizes default to 128 — MXU-aligned (128×128 systolic tiles) and
a bounded VMEM footprint: 2·(128·128)·4 B inputs + 128·128·4 B acc ≈ 192 KiB.

Both ops are exact sums over the d axis, which is what lets
``ops.pairwise_distances_streamed`` call this kernel on (n, d_chunk) slabs
and add the partial outputs — the zero padding below then only ever applies
to one slab, not the whole model-sized (n, d) block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gram_kernel(a_ref, b_ref, o_ref, acc):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        a_ref[...],
        b_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc[...]


def _l1_kernel(a_ref, b_ref, o_ref, acc):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    a = a_ref[...]  # (bi, bd)
    b = b_ref[...]  # (bj, bd)
    acc[...] += jnp.abs(a[:, None, :] - b[None, :, :]).sum(axis=-1)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc[...]


@functools.partial(jax.jit, static_argnames=("block_n", "block_d", "op", "interpret"))
def pairwise_kernel(
    G: jnp.ndarray,
    *,
    op: str = "gram",
    block_n: int = 128,
    block_d: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """G (n, d) f32 -> (n, n): Gram matrix or L1 distance matrix.

    n and d are padded to tile multiples (zero padding is exact for both
    ops); the caller slices back.
    """
    n, d = G.shape
    bn = min(block_n, max(8, n))
    bd = min(block_d, max(8, d))
    n_pad = -n % bn
    d_pad = -d % bd
    Gp = jnp.pad(G.astype(jnp.float32), ((0, n_pad), (0, d_pad)))
    np_, dp = Gp.shape

    kernel = _gram_kernel if op == "gram" else _l1_kernel
    out = pl.pallas_call(
        kernel,
        grid=(np_ // bn, np_ // bn, dp // bd),
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bd), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bn), jnp.float32)],
        interpret=interpret,
    )(Gp, Gp)
    return out[:n, :n]
