"""Pallas TPU kernels for pairwise client-similarity (Algorithm 2 line 2).

The O(n²d) hot-spot of the paper: ``n`` clients × ``d`` model parameters →
(n, n) similarity. Two kernels:

* ``gram``  — G Gᵀ via MXU-tiled (bi × bd)·(bd × bj) accumulation; arccos and
  L2 distances derive from the Gram matrix on the host side (ops.py).
* ``l1``    — Σ_k |G_i,k - G_j,k|, VPU elementwise tiles, same grid.

Grid: (n/bi, n/bj, d/bd) with the d-axis innermost; an f32 VMEM scratch
accumulates across d-blocks and flushes to the output block on the last
step. Block sizes default to 128 — MXU-aligned (128×128 systolic tiles) and
a bounded VMEM footprint: 2·(128·128)·4 B inputs + 128·128·4 B acc ≈ 192 KiB.

Two entry points share the kernels:

* :func:`pairwise_kernel` — pads G to tile multiples up front (zero padding
  is exact for both ops); the right call for sampler-sized ``d`` where the
  padded copy is cheap.
* :func:`pairwise_kernel_fused` — **no padding at all**: G stays the exact
  (n, d) HBM buffer it arrives as (for the planner pipeline, the gradient
  store's live device array), the grid ceil-divides both axes, and the
  ragged tail blocks are masked *inside* the kernel with iota row/column
  masks. The (n, n) accumulator is the kernel's own HBM output — each
  (i, j) block accumulates across the d-grid in VMEM scratch and flushes
  once — so the whole d-streamed distance computation is one ``pallas_call``
  with no host chunk loop and no padded (n, d) block anywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gram_kernel(a_ref, b_ref, o_ref, acc):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        a_ref[...],
        b_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc[...]


def _l1_kernel(a_ref, b_ref, o_ref, acc):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    a = a_ref[...]  # (bi, bd)
    b = b_ref[...]  # (bj, bd)
    acc[...] += jnp.abs(a[:, None, :] - b[None, :, :]).sum(axis=-1)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc[...]


@functools.partial(jax.jit, static_argnames=("block_n", "block_d", "op", "interpret"))
def pairwise_kernel(
    G: jnp.ndarray,
    *,
    op: str = "gram",
    block_n: int = 128,
    block_d: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """G (n, d) f32 -> (n, n): Gram matrix or L1 distance matrix.

    n and d are padded to tile multiples (zero padding is exact for both
    ops); the caller slices back.
    """
    n, d = G.shape
    bn = min(block_n, max(8, n))
    bd = min(block_d, max(8, d))
    n_pad = -n % bn
    d_pad = -d % bd
    Gp = jnp.pad(G.astype(jnp.float32), ((0, n_pad), (0, d_pad)))
    np_, dp = Gp.shape

    kernel = _gram_kernel if op == "gram" else _l1_kernel
    out = pl.pallas_call(
        kernel,
        grid=(np_ // bn, np_ // bn, dp // bd),
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bd), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bn), jnp.float32)],
        interpret=interpret,
    )(Gp, Gp)
    return out[:n, :n]


def _masked_fused_kernel(op: str, n: int, d: int, bn: int, bd: int):
    """Kernel body zeroing the ragged row/column tails of unpadded inputs.

    The last blocks along each axis may read out of bounds (garbage on TPU,
    implementation-defined elsewhere); the iota masks force those lanes to
    zero, which is exact for both the Gram and the L1 sum. Row-tail rows of
    the *output* land in the padded output buffer and are sliced away by the
    caller, so only the d mask affects retained values.
    """

    def kernel(a_ref, b_ref, o_ref, acc):
        @pl.when(pl.program_id(2) == 0)
        def _init():
            acc[...] = jnp.zeros_like(acc)

        col = jax.lax.broadcasted_iota(jnp.int32, (bn, bd), 1) + pl.program_id(2) * bd
        row = jax.lax.broadcasted_iota(jnp.int32, (bn, bd), 0)
        a = jnp.where((col < d) & (row + pl.program_id(0) * bn < n), a_ref[...], 0.0)
        b = jnp.where((col < d) & (row + pl.program_id(1) * bn < n), b_ref[...], 0.0)
        if op == "gram":
            acc[...] += jax.lax.dot_general(
                a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
            )
        else:
            acc[...] += jnp.abs(a[:, None, :] - b[None, :, :]).sum(axis=-1)

        @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
        def _flush():
            o_ref[...] = acc[...]

    return kernel


@functools.partial(jax.jit, static_argnames=("block_n", "block_d", "op", "interpret"))
def pairwise_kernel_fused(
    G: jnp.ndarray,
    *,
    op: str = "gram",
    block_n: int = 128,
    block_d: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """G (n, d) f32 -> (n, n), one launch, **no padded copy of G**.

    The full d-streamed accumulation of :func:`pairwise_kernel` as a single
    ``pallas_call``: the grid ceil-divides (n, n, d), ragged tail blocks are
    masked in-kernel (:func:`_masked_fused_kernel`), and the only
    full-width array ever allocated is the (⌈n/bn⌉·bn)² f32 output the
    accumulator blocks flush into. Replaces the host-side d-chunk Python
    loop of the streamed backend — the device never holds more than G
    itself plus the (n, n) accumulator.
    """
    if op not in ("gram", "l1"):
        raise ValueError(f"unknown op {op!r}; choose gram | l1")
    G = G.astype(jnp.float32)
    n, d = G.shape
    bn = min(block_n, max(8, n))
    bd = min(block_d, max(8, d))
    gn = -(-n // bn)
    gd = -(-d // bd)
    out = pl.pallas_call(
        _masked_fused_kernel(op, n, d, bn, bd),
        grid=(gn, gn, gd),
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bd), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gn * bn, gn * bn), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bn), jnp.float32)],
        interpret=interpret,
    )(G, G)
    return out[:n, :n]
