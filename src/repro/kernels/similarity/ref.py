"""Pure-jnp oracle for the similarity kernels."""
from __future__ import annotations

import jax.numpy as jnp


def gram_ref(G: jnp.ndarray) -> jnp.ndarray:
    G = G.astype(jnp.float32)
    return G @ G.T


def l1_ref(G: jnp.ndarray) -> jnp.ndarray:
    G = G.astype(jnp.float32)
    return jnp.abs(G[:, None, :] - G[None, :, :]).sum(axis=-1)


def distances_from_gram(gram: jnp.ndarray, measure: str) -> jnp.ndarray:
    """Derive arccos / l2 distances from the Gram matrix (f32, symmetric)."""
    sq = jnp.diagonal(gram)
    if measure == "l2":
        d2 = sq[:, None] + sq[None, :] - 2.0 * gram
        dist = jnp.sqrt(jnp.maximum(d2, 0.0))
    elif measure == "arccos":
        norms = jnp.sqrt(jnp.maximum(sq, 0.0))
        safe = jnp.where(norms > 0, norms, 1.0)
        cos = gram / (safe[:, None] * safe[None, :])
        zero = norms == 0
        both = zero[:, None] & zero[None, :]
        either = zero[:, None] ^ zero[None, :]
        cos = jnp.where(both, 1.0, cos)
        cos = jnp.where(either, 0.0, cos)
        dist = jnp.arccos(jnp.clip(cos, -1.0, 1.0))
    else:
        raise ValueError(measure)
    dist = jnp.where(jnp.eye(dist.shape[0], dtype=bool), 0.0, dist)
    return jnp.maximum(dist, dist.T)
