"""Jitted public API: pairwise client distances on device.

Drop-in replacement for ``repro.core.clustering.similarity.pairwise_distances``
(numpy) — Algorithm 2 passes ``distance_fn=pallas_pairwise_distances`` to run
the O(n²d) stage on TPU. On CPU builds, set ``interpret=True`` (tests do).

Two entry points:

* :func:`pairwise_distances_device` — one kernel launch over the full
  (n, d) block, padded to tile multiples. Right for sampler-sized ``d``.
* :func:`pairwise_distances_streamed` — accumulates the Gram / L1 matrix
  over ``d``-chunks of G, so for model-sized ``d`` only an (n, d_chunk)
  slab is ever padded (and, for host inputs, ever device-resident) at once;
  the (n, n) accumulator is the only full-width array.
"""
from __future__ import annotations


import jax.numpy as jnp
import numpy as np

from repro.kernels.similarity.kernel import pairwise_kernel
from repro.kernels.similarity.ref import distances_from_gram

#: d above which the "auto" backend switches to the streamed accumulation.
STREAM_D_THRESHOLD = 8192


def _l1_postprocess(d: jnp.ndarray) -> jnp.ndarray:
    d = jnp.where(jnp.eye(d.shape[0], dtype=bool), 0.0, d)
    return jnp.maximum(d, d.T)


def pairwise_distances_device(
    G,
    measure: str = "arccos",
    *,
    block_n: int = 128,
    block_d: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """(n, d) representative gradients -> (n, n) distance matrix."""
    G = jnp.asarray(G, jnp.float32)
    if measure in ("arccos", "l2"):
        gram = pairwise_kernel(G, op="gram", block_n=block_n, block_d=block_d, interpret=interpret)
        return distances_from_gram(gram, measure)
    if measure == "l1":
        d = pairwise_kernel(G, op="l1", block_n=block_n, block_d=block_d, interpret=interpret)
        return _l1_postprocess(d)
    raise ValueError(f"unknown measure {measure!r}")


def pairwise_distances_streamed(
    G,
    measure: str = "arccos",
    *,
    block_n: int = 128,
    block_d: int = 128,
    d_chunk: int = STREAM_D_THRESHOLD,
    interpret: bool = False,
) -> jnp.ndarray:
    """(n, d) -> (n, n) distances, accumulated over ``d``-chunks of G.

    Both the Gram matrix and the L1 distance are sums over coordinates, so
    per-chunk kernel outputs add exactly. The kernel pads each (n, chunk)
    slab independently — the padded (n, d) block of the one-shot path is
    never materialized. Host (numpy) G is additionally *transferred* one
    chunk at a time, so the device never holds the full model-sized block.
    Matches :func:`pairwise_distances_device` to fp32 accumulation-order
    tolerance.
    """
    if measure not in ("arccos", "l2", "l1"):
        raise ValueError(f"unknown measure {measure!r}")
    n, d = G.shape
    if d == 0:
        raise ValueError("need at least one gradient coordinate")
    d_chunk = max(int(d_chunk), 1)
    op = "l1" if measure == "l1" else "gram"
    acc = jnp.zeros((n, n), jnp.float32)
    for lo in range(0, d, d_chunk):
        chunk = jnp.asarray(G[:, lo : lo + d_chunk], jnp.float32)
        acc = acc + pairwise_kernel(
            chunk, op=op, block_n=block_n, block_d=block_d, interpret=interpret
        )
    if op == "gram":
        return distances_from_gram(acc, measure)
    return _l1_postprocess(acc)


def make_distance_fn(*, interpret: bool = False, streamed: bool = False, d_chunk: int = STREAM_D_THRESHOLD):
    """Adapter matching ``repro.core.samplers.algorithm2.DistanceFn``.

    ``streamed=True`` always streams; otherwise the one-shot kernel is used
    up to ``d_chunk`` coordinates and streaming kicks in beyond it, so
    model-sized ``d`` never pays the padded full-width copy.
    """

    def fn(G, measure: str) -> np.ndarray:
        if streamed or G.shape[1] > d_chunk:
            out = pairwise_distances_streamed(
                G, measure, d_chunk=d_chunk, interpret=interpret
            )
        else:
            out = pairwise_distances_device(G, measure, interpret=interpret)
        return np.asarray(out)

    return fn


def resolve_distance_backend(backend: str = "auto"):
    """Pick the pairwise-distance backend for Algorithm 2's O(n²d) stage.

    * ``"auto"``     — compiled Pallas kernel on TPU, interpret-mode Pallas
      everywhere else — including GPU (same code path, jax-ops execution;
      the kernel's ``pltpu.VMEM`` scratch / mosaic block specs are
      TPU-only, so there is no compiled GPU path). Streams automatically
      once ``d`` exceeds :data:`STREAM_D_THRESHOLD`.
    * ``"pallas"``   — compiled Pallas kernel; TPU only, errors elsewhere.
    * ``"pallas-interpret"`` — interpret-mode Pallas anywhere (tests).
    * ``"streamed"`` — always the chunked accumulation (compiled on TPU,
      interpret elsewhere); for model-sized ``d``.
    * ``"numpy"``    — the f64 host reference
      (:func:`repro.core.clustering.similarity.pairwise_distances`).
    """
    if backend == "numpy":
        from repro.core.clustering.similarity import pairwise_distances

        return pairwise_distances
    if backend == "auto":
        import jax

        return make_distance_fn(interpret=jax.default_backend() != "tpu")
    if backend == "streamed":
        import jax

        return make_distance_fn(
            interpret=jax.default_backend() != "tpu", streamed=True
        )
    if backend == "pallas":
        import jax

        if jax.default_backend() != "tpu":
            raise RuntimeError(
                "distance backend 'pallas' requires a TPU — the kernel's "
                "pltpu.VMEM scratch and mosaic block specs do not lower on "
                f"{jax.default_backend()!r}; use 'auto' (interpret-mode "
                "fallback) or 'pallas-interpret' instead"
            )
        return make_distance_fn(interpret=False)
    if backend == "pallas-interpret":
        return make_distance_fn(interpret=True)
    raise ValueError(
        f"unknown distance backend {backend!r}; "
        "choose from auto | pallas | pallas-interpret | streamed | numpy"
    )
