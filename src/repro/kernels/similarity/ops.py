"""Jitted public API: pairwise client distances on device.

Drop-in replacement for ``repro.core.clustering.similarity.pairwise_distances``
(numpy) — Algorithm 2 passes ``distance_fn=pallas_pairwise_distances`` to run
the O(n²d) stage on TPU. On CPU builds, set ``interpret=True`` (tests do).
"""
from __future__ import annotations


import jax.numpy as jnp
import numpy as np

from repro.kernels.similarity.kernel import pairwise_kernel
from repro.kernels.similarity.ref import distances_from_gram


def pairwise_distances_device(
    G,
    measure: str = "arccos",
    *,
    block_n: int = 128,
    block_d: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """(n, d) representative gradients -> (n, n) distance matrix."""
    G = jnp.asarray(G, jnp.float32)
    if measure in ("arccos", "l2"):
        gram = pairwise_kernel(G, op="gram", block_n=block_n, block_d=block_d, interpret=interpret)
        return distances_from_gram(gram, measure)
    if measure == "l1":
        d = pairwise_kernel(G, op="l1", block_n=block_n, block_d=block_d, interpret=interpret)
        d = jnp.where(jnp.eye(d.shape[0], dtype=bool), 0.0, d)
        return jnp.maximum(d, d.T)
    raise ValueError(f"unknown measure {measure!r}")


def make_distance_fn(*, interpret: bool = False):
    """Adapter matching ``repro.core.samplers.algorithm2.DistanceFn``."""

    def fn(G: np.ndarray, measure: str) -> np.ndarray:
        return np.asarray(pairwise_distances_device(G, measure, interpret=interpret))

    return fn


def resolve_distance_backend(backend: str = "auto"):
    """Pick the pairwise-distance backend for Algorithm 2's O(n²d) stage.

    * ``"auto"``     — compiled Pallas kernel on TPU, interpret-mode Pallas
      everywhere else — including GPU (same code path, jax-ops execution;
      the kernel's ``pltpu.VMEM`` scratch / mosaic block specs are
      TPU-only, so there is no compiled GPU path).
    * ``"pallas"``   — compiled Pallas kernel; TPU only, errors elsewhere.
    * ``"pallas-interpret"`` — interpret-mode Pallas anywhere (tests).
    * ``"numpy"``    — the f64 host reference
      (:func:`repro.core.clustering.similarity.pairwise_distances`).
    """
    if backend == "numpy":
        from repro.core.clustering.similarity import pairwise_distances

        return pairwise_distances
    if backend == "auto":
        import jax

        return make_distance_fn(interpret=jax.default_backend() != "tpu")
    if backend == "pallas":
        import jax

        if jax.default_backend() != "tpu":
            raise RuntimeError(
                "distance backend 'pallas' requires a TPU — the kernel's "
                "pltpu.VMEM scratch and mosaic block specs do not lower on "
                f"{jax.default_backend()!r}; use 'auto' (interpret-mode "
                "fallback) or 'pallas-interpret' instead"
            )
        return make_distance_fn(interpret=False)
    if backend == "pallas-interpret":
        return make_distance_fn(interpret=True)
    raise ValueError(
        f"unknown distance backend {backend!r}; "
        "choose from auto | pallas | pallas-interpret | numpy"
    )
