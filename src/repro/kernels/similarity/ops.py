"""Jitted public API: pairwise client distances on device.

Drop-in replacement for ``repro.core.clustering.similarity.pairwise_distances``
(numpy) — Algorithm 2 passes ``distance_fn=pallas_pairwise_distances`` to run
the O(n²d) stage on TPU. On CPU builds, set ``interpret=True`` (tests do).

Three entry points:

* :func:`pairwise_distances_device` — one kernel launch over the full
  (n, d) block, padded to tile multiples. Right for sampler-sized ``d``.
* :func:`pairwise_distances_streamed` — the **fused** streamed path: one
  ``pallas_call`` whose grid ceil-divides the d axis, accumulating the
  Gram / L1 matrix in per-block VMEM scratch flushed into the HBM (n, n)
  output. No host chunk loop and no padded (n, d) block — G enters the
  kernel as the exact buffer it arrives as (for the planner pipeline, the
  gradient store's live device array).
* :func:`pairwise_distances_chunked` — the pre-fusion host-side d-chunk
  Python loop, kept as a parity reference and for host (numpy) G where
  transferring one (n, d_chunk) slab at a time bounds device memory.
"""
from __future__ import annotations


import jax.numpy as jnp
import numpy as np

from repro.kernels.similarity.kernel import pairwise_kernel, pairwise_kernel_fused
from repro.kernels.similarity.ref import distances_from_gram

#: d above which the "auto" backend switches to the fused streamed kernel.
STREAM_D_THRESHOLD = 8192


def _l1_postprocess(d: jnp.ndarray) -> jnp.ndarray:
    d = jnp.where(jnp.eye(d.shape[0], dtype=bool), 0.0, d)
    return jnp.maximum(d, d.T)


def _check_measure(measure: str) -> str:
    if measure not in ("arccos", "l2", "l1"):
        raise ValueError(f"unknown measure {measure!r}")
    return "l1" if measure == "l1" else "gram"


def pairwise_distances_device(
    G,
    measure: str = "arccos",
    *,
    block_n: int = 128,
    block_d: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """(n, d) representative gradients -> (n, n) distance matrix."""
    G = jnp.asarray(G, jnp.float32)
    if measure in ("arccos", "l2"):
        gram = pairwise_kernel(G, op="gram", block_n=block_n, block_d=block_d, interpret=interpret)
        return distances_from_gram(gram, measure)
    if measure == "l1":
        d = pairwise_kernel(G, op="l1", block_n=block_n, block_d=block_d, interpret=interpret)
        return _l1_postprocess(d)
    raise ValueError(f"unknown measure {measure!r}")


def pairwise_distances_streamed(
    G,
    measure: str = "arccos",
    *,
    block_n: int = 128,
    block_d: int = 128,
    d_chunk: int = STREAM_D_THRESHOLD,
    interpret: bool = False,
) -> jnp.ndarray:
    """(n, d) -> (n, n) distances in **one fused kernel launch**.

    The d-streamed accumulation runs entirely inside the kernel's grid
    (:func:`~repro.kernels.similarity.kernel.pairwise_kernel_fused`): the
    (n, n) accumulator lives in HBM as the kernel output, each block
    accumulating over the d-grid in VMEM scratch, and ragged tails are
    masked in-kernel — G is never padded and no host chunk loop runs.
    ``d_chunk`` only caps the per-step tile width (``block_d``), so
    existing call sites tuned for the chunked path keep their footprint.
    Matches :func:`pairwise_distances_device` and the numpy reference to
    fp32 accumulation-order tolerance.
    """
    op = _check_measure(measure)
    n, d = G.shape
    if d == 0:
        raise ValueError("need at least one gradient coordinate")
    bd = min(block_d, max(int(d_chunk), 1))
    acc = pairwise_kernel_fused(
        jnp.asarray(G), op=op, block_n=block_n, block_d=bd, interpret=interpret
    )
    if op == "gram":
        return distances_from_gram(acc, measure)
    return _l1_postprocess(acc)


def pairwise_distances_chunked(
    G,
    measure: str = "arccos",
    *,
    block_n: int = 128,
    block_d: int = 128,
    d_chunk: int = STREAM_D_THRESHOLD,
    interpret: bool = False,
) -> jnp.ndarray:
    """(n, d) -> (n, n) distances, accumulated over host-side ``d``-chunks.

    The pre-fusion streamed path, kept as the fused kernel's parity
    reference. Both the Gram matrix and the L1 distance are sums over
    coordinates, so per-chunk kernel outputs add exactly. Host (numpy) G is
    *transferred* one chunk at a time, so the device never holds the full
    model-sized block — the right path when G does not already live on
    device.
    """
    op = _check_measure(measure)
    n, d = G.shape
    if d == 0:
        raise ValueError("need at least one gradient coordinate")
    d_chunk = max(int(d_chunk), 1)
    acc = jnp.zeros((n, n), jnp.float32)
    for lo in range(0, d, d_chunk):
        chunk = jnp.asarray(G[:, lo : lo + d_chunk], jnp.float32)
        acc = acc + pairwise_kernel(
            chunk, op=op, block_n=block_n, block_d=block_d, interpret=interpret
        )
    if op == "gram":
        return distances_from_gram(acc, measure)
    return _l1_postprocess(acc)


def make_distance_fn(
    *,
    interpret: bool = False,
    streamed: bool = False,
    d_chunk: int = STREAM_D_THRESHOLD,
    chunked: bool = False,
    as_numpy: bool = True,
):
    """Adapter matching ``repro.core.samplers.algorithm2.DistanceFn``.

    ``streamed=True`` always takes the fused streamed kernel; otherwise the
    one-shot kernel is used up to ``d_chunk`` coordinates and the fused
    kernel kicks in beyond it, so model-sized ``d`` never pays the padded
    full-width copy. ``chunked=True`` selects the legacy host-side chunk
    loop instead of the fused kernel (parity reference). ``as_numpy=False``
    returns the device array untouched — the clustering backends that run
    on device (``ward_jit``, ``kmeans``) consume it without a host copy.
    """

    def fn(G, measure: str):
        if chunked:
            out = pairwise_distances_chunked(
                G, measure, d_chunk=d_chunk, interpret=interpret
            )
        elif streamed or G.shape[1] > d_chunk:
            out = pairwise_distances_streamed(
                G, measure, d_chunk=d_chunk, interpret=interpret
            )
        else:
            out = pairwise_distances_device(G, measure, interpret=interpret)
        return np.asarray(out) if as_numpy else out

    return fn


def resolve_distance_backend(backend: str = "auto", *, as_numpy: bool = True):
    """Pick the pairwise-distance backend for Algorithm 2's O(n²d) stage.

    * ``"auto"``     — compiled Pallas kernel on TPU, interpret-mode Pallas
      everywhere else — including GPU (same code path, jax-ops execution;
      the kernel's ``pltpu.VMEM`` scratch / mosaic block specs are
      TPU-only, so there is no compiled GPU path). Switches to the fused
      streamed kernel once ``d`` exceeds :data:`STREAM_D_THRESHOLD`.
    * ``"pallas"``   — compiled Pallas kernel; TPU only, errors elsewhere.
    * ``"pallas-interpret"`` — interpret-mode Pallas anywhere (tests).
    * ``"streamed"`` — always the fused streamed kernel (one launch, d-grid
      in-kernel, no padded (n, d) block); for model-sized ``d``.
    * ``"chunked"``  — the legacy host-side d-chunk accumulation loop, the
      fused kernel's parity reference.
    * ``"numpy"``    — the f64 host reference
      (:func:`repro.core.clustering.similarity.pairwise_distances`).

    ``as_numpy=False`` keeps device backends' output on device (the numpy
    reference is host-side either way).
    """
    if backend == "numpy":
        from repro.core.clustering.similarity import pairwise_distances

        return pairwise_distances
    if backend == "auto":
        import jax

        return make_distance_fn(
            interpret=jax.default_backend() != "tpu", as_numpy=as_numpy
        )
    if backend == "streamed":
        import jax

        return make_distance_fn(
            interpret=jax.default_backend() != "tpu", streamed=True, as_numpy=as_numpy
        )
    if backend == "chunked":
        import jax

        return make_distance_fn(
            interpret=jax.default_backend() != "tpu", chunked=True, as_numpy=as_numpy
        )
    if backend == "pallas":
        import jax

        if jax.default_backend() != "tpu":
            raise RuntimeError(
                "distance backend 'pallas' requires a TPU — the kernel's "
                "pltpu.VMEM scratch and mosaic block specs do not lower on "
                f"{jax.default_backend()!r}; use 'auto' (interpret-mode "
                "fallback) or 'pallas-interpret' instead"
            )
        return make_distance_fn(interpret=False, as_numpy=as_numpy)
    if backend == "pallas-interpret":
        return make_distance_fn(interpret=True, as_numpy=as_numpy)
    raise ValueError(
        f"unknown distance backend {backend!r}; "
        "choose from auto | pallas | pallas-interpret | streamed | chunked | numpy"
    )
