"""Pallas TPU kernel for server-side weighted model aggregation.

``θ^{t+1} = Σ_k ω_k · U_k`` over the stacked flat updates of the sampled
clients (eq. 3/4 of the paper). A (k × bp) tile of updates is contracted
against the weight vector per grid step — a skinny matvec that streams the
update matrix through VMEM exactly once (the op is purely
memory-bound: 1 FLOP per 2 bytes read, so the tiling goal is full HBM
streaming with no re-reads, not MXU utilization).

Grid: (p / bp,). Block: (k, bp) updates + (1, k) weights (whole weight row
in every step; k = sampled clients ≤ a few hundred — a few KiB of VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _agg_kernel(w_ref, u_ref, o_ref):
    # (1, k) @ (k, bp) -> (1, bp)
    o_ref[...] = jax.lax.dot_general(
        w_ref[...],
        u_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def aggregate_kernel(
    updates: jnp.ndarray,  # (k, p) f32
    weights: jnp.ndarray,  # (k,) f32
    *,
    block_p: int = 4096,
    interpret: bool = False,
) -> jnp.ndarray:
    k, p = updates.shape
    bp = min(block_p, p)
    pad = -p % bp
    up = jnp.pad(updates.astype(jnp.float32), ((0, 0), (0, pad)))
    w = weights.astype(jnp.float32).reshape(1, k)
    out = pl.pallas_call(
        _agg_kernel,
        grid=(up.shape[1] // bp,),
        in_specs=[
            pl.BlockSpec((1, k), lambda j: (0, 0)),
            pl.BlockSpec((k, bp), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bp), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, up.shape[1]), jnp.float32),
        interpret=interpret,
    )(w, up)
    return out[0, :p]
