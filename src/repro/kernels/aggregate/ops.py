"""Jitted public API: aggregate stacked client updates (flat or pytree)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.aggregate.kernel import aggregate_kernel


def aggregate_flat(updates, weights, *, interpret: bool = False) -> jnp.ndarray:
    """(k, p) stacked flat updates × (k,) weights -> (p,)."""
    return aggregate_kernel(jnp.asarray(updates), jnp.asarray(weights), interpret=interpret)


def aggregate_trees(trees: list, weights: np.ndarray, *, interpret: bool = False):
    """Weighted sum of identically-structured pytrees through the kernel.

    Leaves are flattened and concatenated once (single kernel launch —
    aggregation is bandwidth-bound, so one long stream beats per-leaf
    launches), then split back.
    """
    leaves0, treedef = jax.tree_util.tree_flatten(trees[0])
    sizes = [x.size for x in leaves0]
    shapes = [x.shape for x in leaves0]
    dtypes = [x.dtype for x in leaves0]

    def flatten(tree):
        leaves = jax.tree_util.tree_leaves(tree)
        return jnp.concatenate([jnp.ravel(x).astype(jnp.float32) for x in leaves])

    stacked = jnp.stack([flatten(t) for t in trees])
    flat = aggregate_flat(stacked, jnp.asarray(weights), interpret=interpret)
    out, off = [], 0
    for size, shape, dtype in zip(sizes, shapes, dtypes):
        out.append(flat[off : off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)
