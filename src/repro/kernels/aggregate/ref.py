"""Pure-jnp oracle for the aggregation kernel."""
from __future__ import annotations

import jax.numpy as jnp


def aggregate_ref(updates: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """(k, p), (k,) -> (p,) in f32."""
    return jnp.einsum(
        "k,kp->p", weights.astype(jnp.float32), updates.astype(jnp.float32)
    )
