from repro.kernels.sketch.ops import (
    SKETCHERS,
    Sketcher,
    register_sketcher,
    resolve_sketcher,
)

__all__ = ["SKETCHERS", "Sketcher", "register_sketcher", "resolve_sketcher"]
