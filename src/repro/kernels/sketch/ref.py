"""Host reference for the gradient sketch + the shared sign/bucket hash.

The sketch stage compresses a client's representative gradient from ``d``
model coordinates to ``d_prime`` sketch coordinates *before* it is ever
scattered into the gradient store, so everything downstream of the engine —
store memory, the fused similarity kernel's d-grid, the drift monitor's
centroids — scales in ``d_prime`` instead of ``d``. Two constructions, both
unbiased for inner products (E[<s(x), s(y)>] = <x, y>), which is what the
arccos / L2 plan distances are built from:

* **signed random projection** (``srp``): y = X @ S with S a (d, d_prime)
  Rademacher matrix scaled by 1/sqrt(d_prime). S is *never materialized*:
  each (block_d, d_prime) block is regenerated on the fly from a
  counter-based integer hash of (seed, coordinate, output column), so the
  projection costs O(block_d · d_prime) memory however large d is, and the
  same seed always regenerates the identical matrix — on device, on host,
  and after a checkpoint restore.
* **counting sketch** (``countsketch``): each input coordinate k is hashed
  to one bucket h(k) with a sign s(k); y[:, h(k)] += s(k) · X[:, k]. O(d)
  state (the bucket/sign vectors), one scatter-add, no matmul.

Everything here is pure numpy and hash-deterministic; the jitted / Pallas
device paths (:mod:`repro.kernels.sketch.kernel`, ``ops``) reuse the same
hash helpers via the ``xp`` parameter so device and host agree on *which*
random matrix they apply (outputs match to f32 accumulation tolerance).
"""
from __future__ import annotations

import numpy as np

# murmur3-style multiplicative mixing constants (uint32 arithmetic, wraps)
_C1 = 0x85EBCA6B
_C2 = 0xC2B2AE35
_K_SALT = 0x9E3779B1  # golden-ratio odd constants decorrelate the
_J_SALT = 0x7FEB352D  # coordinate and output-column streams
_SEED_SALT = 0x165667B1


def _mix32(h, xp):
    """murmur3 fmix32 finalizer over a uint32 array (numpy or jnp)."""
    h = h ^ (h >> 16)
    h = h * xp.uint32(_C1)
    h = h ^ (h >> 13)
    h = h * xp.uint32(_C2)
    h = h ^ (h >> 16)
    return h


def _hash_coords(k, j, seed: int, xp):
    """Deterministic uint32 hash of (coordinate k, output column j, seed).

    ``k`` and ``j`` are broadcast-compatible uint32 arrays; the result is
    the per-entry key both sketch constructions draw their bits from.
    """
    s = xp.uint32((seed * _SEED_SALT) & 0xFFFFFFFF)
    h = (k * xp.uint32(_K_SALT)) ^ (j * xp.uint32(_J_SALT)) ^ s
    return _mix32(h, xp)


def srp_sign_block(seed: int, k0: int, bd: int, d_prime: int, d_total: int, xp=np):
    """One (bd, d_prime) f32 block of the scaled Rademacher matrix S.

    Rows are global coordinates ``k0 .. k0+bd``; rows at or beyond
    ``d_total`` are zeroed (the ragged-tail mask the blockwise apply and
    the Pallas kernel share). Entries are ±1/sqrt(d_prime).
    """
    k = (xp.arange(bd, dtype=xp.uint32) + xp.uint32(k0))[:, None]
    j = xp.arange(d_prime, dtype=xp.uint32)[None, :]
    return srp_sign_entries(k, j, seed, d_total, d_prime, xp)


def srp_sign_entries(k, j, seed: int, d_total: int, d_prime: int, xp=np):
    """Sign entries for explicit (k, j) uint32 index arrays (kernel path)."""
    h = _hash_coords(k, j, seed, xp)
    scale = xp.float32(1.0 / np.sqrt(float(d_prime)))
    sign = xp.where((h & xp.uint32(1)) == 1, scale, -scale)
    return xp.where(k < xp.uint32(d_total), sign, xp.float32(0.0))


def countsketch_params(d: int, d_prime: int, seed: int, xp=np):
    """(bucket, sign) vectors of the seeded counting sketch.

    ``bucket`` is (d,) int32 in [0, d_prime); ``sign`` is (d,) f32 ±1.
    Both are pure functions of (d, d_prime, seed) — regenerating after a
    checkpoint restore yields the identical sketch.
    """
    k = xp.arange(d, dtype=xp.uint32)
    h = _hash_coords(k, xp.uint32(0), seed, xp)
    bucket = (h >> 1) % xp.uint32(d_prime)
    sign = xp.where((h & xp.uint32(1)) == 1, xp.float32(1.0), xp.float32(-1.0))
    return bucket.astype(xp.int32), sign


def sketch_srp_reference(
    X, d_prime: int, seed: int, *, block_d: int = 512
) -> np.ndarray:
    """Blockwise y = X @ S on host — the device kernel's parity oracle.

    The (d, d_prime) projection is regenerated one (block_d, d_prime) block
    at a time, so host memory stays O(n·d_prime + block_d·d_prime) no
    matter how large d grows.
    """
    X = np.asarray(X, np.float32)
    n, d = X.shape
    out = np.zeros((n, int(d_prime)), np.float32)
    for k0 in range(0, d, block_d):
        bd = min(block_d, d - k0)
        S = srp_sign_block(seed, k0, bd, d_prime, d, np)
        out += X[:, k0 : k0 + bd] @ S
    return out


def sketch_countsketch_reference(X, d_prime: int, seed: int) -> np.ndarray:
    """Seeded counting sketch on host (unbuffered scatter-add)."""
    X = np.asarray(X, np.float32)
    d = X.shape[1]
    bucket, sign = countsketch_params(d, int(d_prime), seed, np)
    acc = np.zeros((int(d_prime), X.shape[0]), np.float32)
    np.add.at(acc, bucket, (X * sign[None, :]).T)
    return acc.T
