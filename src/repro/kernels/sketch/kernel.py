"""Pallas TPU kernel for the blockwise signed-random-projection sketch.

y = X @ S with X (n, d) and S a (d, d_prime) scaled Rademacher matrix that
is **never materialized**: each grid step regenerates its (block_d,
d_prime) slice of S inside the kernel from the counter-based hash shared
with the host reference (:mod:`repro.kernels.sketch.ref`), multiplies it
against the matching (block_n, block_d) X tile on the MXU, and accumulates
into a (block_n, d_prime) VMEM scratch that flushes to the HBM output on
the last d-step. Structure mirrors
:func:`repro.kernels.similarity.kernel.pairwise_kernel_fused`:

* grid (⌈n/bn⌉, ⌈d/bd⌉), d innermost; X is consumed as the exact HBM
  buffer it arrives as — no padded (n, d) copy ever exists;
* the ragged d-tail is masked *inside* the sign generation (rows of S at
  or beyond d are zero, exact for the matmul); ragged n-tail rows land in
  the padded output buffer and are sliced away by the caller;
* VMEM footprint per step: bn·bd X tile + bd·d_prime sign tile + bn·d_prime
  accumulator — ~(128·512 + 512·64 + 128·64)·4 B ≈ 420 KiB at defaults.

``interpret=True`` runs the identical program as jax ops on CPU/GPU
(the same convention as the similarity kernels).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.sketch.ref import srp_sign_entries


def _srp_kernel(seed: int, d: int, d_prime: int, bn: int, bd: int):
    def kernel(x_ref, o_ref, acc):
        @pl.when(pl.program_id(1) == 0)
        def _init():
            acc[...] = jnp.zeros_like(acc)

        # regenerate this step's (bd, d_prime) slice of the projection from
        # the hash — global coordinate k = d-step * bd + local row
        k = (
            jax.lax.broadcasted_iota(jnp.uint32, (bd, d_prime), 0)
            + jnp.uint32(pl.program_id(1) * bd)
        )
        j = jax.lax.broadcasted_iota(jnp.uint32, (bd, d_prime), 1)
        signs = srp_sign_entries(k, j, seed, d, d_prime, jnp)
        # d-tail columns of the X tile hit zeroed sign rows, so OOB lanes
        # of the *input* read must be zeroed too (garbage · 0 is still
        # defined, but garbage may be NaN — mask it away)
        col = jax.lax.broadcasted_iota(jnp.int32, (bn, bd), 1) + pl.program_id(1) * bd
        x = jnp.where(col < d, x_ref[...], 0.0)
        acc[...] += jax.lax.dot_general(
            x, signs, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

        @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
        def _flush():
            o_ref[...] = acc[...]

    return kernel


@functools.partial(
    jax.jit, static_argnames=("d_prime", "seed", "block_n", "block_d", "interpret")
)
def srp_sketch_kernel(
    X: jnp.ndarray,
    *,
    d_prime: int,
    seed: int = 0,
    block_n: int = 128,
    block_d: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """X (n, d) f32 -> (n, d_prime) sketch, one launch, no padded X copy.

    Matches :func:`repro.kernels.sketch.ref.sketch_srp_reference` to f32
    accumulation-order tolerance (same hash, same blockwise ordering when
    ``block_d`` agrees).
    """
    X = X.astype(jnp.float32)
    n, d = X.shape
    dp = int(d_prime)
    if dp < 1:
        raise ValueError(f"d_prime must be >= 1, got {d_prime}")
    bn = min(block_n, max(8, n))
    bd = min(block_d, max(8, d))
    gn = -(-n // bn)
    gd = -(-d // bd)
    out = pl.pallas_call(
        _srp_kernel(int(seed), d, dp, bn, bd),
        grid=(gn, gd),
        in_specs=[pl.BlockSpec((bn, bd), lambda i, k: (i, k))],
        out_specs=pl.BlockSpec((bn, dp), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((gn * bn, dp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, dp), jnp.float32)],
        interpret=interpret,
    )(X)
    return out[:n]
