"""Sketch stage public API: the ``SKETCHERS`` registry + device dispatch.

The gradient store compresses every incoming representative gradient
``θ_i^{t+1} − θ^t`` from the model dimension ``d`` to a sketch dimension
``d_prime`` *before* scatter, so the resident buffer — and everything the
plan-rebuild pipeline touches downstream — scales in ``d_prime``. This
module names the available sketch constructions, mirroring
:data:`repro.core.clustering.backends.CLUSTERERS`:

    sketcher = SKETCHERS.get(name)(d_in, d_prime, seed=0)
    y = sketcher(x)            # device path when jax is present
    y = sketcher.reference(x)  # numpy host reference (jax-free)

Built-ins:

* ``"identity"`` — pass-through (``d_out == d_in``, the input object is
  returned *unchanged*, not copied or cast). This is the exact legacy
  store path: a store built with ``sketch="identity"`` is bit-for-bit the
  unsketched store, which is what the tier-1 parity gate pins.
* ``"srp"``      — signed random projection to ``d_prime`` via the
  blockwise Pallas kernel (:func:`repro.kernels.sketch.kernel.
  srp_sketch_kernel`): the (d, d_prime) Rademacher matrix is regenerated
  (block_d, d_prime) at a time from a seeded counter-based hash, never
  materialized. Inner products are preserved in expectation with JL-style
  concentration — the right default for arccos/L2 plan distances.
* ``"countsketch"`` — seeded counting sketch (one bucket + sign per input
  coordinate, O(d) state, one scatter-add); cheaper than ``srp`` per
  update, heavier-tailed distance error.

``register_sketcher("mine", factory)`` plugs a new construction into every
spec-driven experiment via ``PlannerSpec(sketch="mine")``. jax is imported
lazily — the registry, the ``identity`` sketcher and every ``reference``
path work in jax-free environments, keeping ``repro.core`` samplers
constructible there.
"""
from __future__ import annotations

from typing import Optional, Union

import numpy as np

from repro.core.registry import Registry
from repro.kernels.sketch.ref import (
    countsketch_params,
    sketch_countsketch_reference,
    sketch_srp_reference,
)

#: default d-tile of the blockwise projection (kernel and host reference
#: share it so their accumulation order — and f32 sums — line up).
SKETCH_BLOCK_D = 512


def _jax():
    try:
        import jax  # noqa: F401
    except ImportError:
        return None
    return jax


class Sketcher:
    """A fitted sketch: ``d_in`` model coordinates -> ``d_out`` sketch ones.

    Instances are cheap, stateless-on-data objects: the projection is a
    pure function of ``(name, d_in, d_out, seed)``, so a sketcher rebuilt
    from those four values (e.g. after a checkpoint restore) applies the
    *identical* compression. ``__call__`` takes the device path when jax
    is importable (device arrays in, device array out — no host copy);
    :meth:`reference` is the numpy host path the jax-free store fallback
    uses.
    """

    name = "base"

    def __init__(self, d_in: int, d_out: int, seed: int):
        self.d_in = int(d_in)
        self.d_out = int(d_out)
        self.seed = int(seed)

    def __call__(self, X):
        raise NotImplementedError

    def reference(self, X) -> np.ndarray:
        raise NotImplementedError

    def __repr__(self):
        return (
            f"{type(self).__name__}(d_in={self.d_in}, d_out={self.d_out}, "
            f"seed={self.seed})"
        )


class IdentitySketcher(Sketcher):
    """The exact legacy path: X comes back untouched (same object)."""

    name = "identity"

    def __call__(self, X):
        return X

    def reference(self, X):
        return X


class SRPSketcher(Sketcher):
    """Blockwise signed random projection (Pallas kernel on device)."""

    name = "srp"

    def __init__(self, d_in: int, d_out: int, seed: int, block_d: int = SKETCH_BLOCK_D):
        super().__init__(d_in, d_out, seed)
        self.block_d = int(block_d)

    def __call__(self, X):
        jax = _jax()
        if jax is None:
            return self.reference(X)
        import jax.numpy as jnp

        from repro.kernels.sketch.kernel import srp_sketch_kernel

        return srp_sketch_kernel(
            jnp.asarray(X),
            d_prime=self.d_out,
            seed=self.seed,
            block_d=self.block_d,
            interpret=jax.default_backend() != "tpu",
        )

    def reference(self, X) -> np.ndarray:
        return sketch_srp_reference(X, self.d_out, self.seed, block_d=self.block_d)


class CountSketcher(Sketcher):
    """Seeded counting sketch: one jitted scatter-add, O(d) hash state."""

    name = "countsketch"

    def __call__(self, X):
        jax = _jax()
        if jax is None:
            return self.reference(X)
        import jax.numpy as jnp

        bucket, sign = countsketch_params(self.d_in, self.d_out, self.seed, jnp)
        return _countsketch_apply(jnp.asarray(X), bucket, sign, self.d_out)

    def reference(self, X) -> np.ndarray:
        return sketch_countsketch_reference(X, self.d_out, self.seed)


def _countsketch_apply(X, bucket, sign, d_out: int):
    import jax
    import jax.numpy as jnp

    @jax.jit
    def apply(X, bucket, sign):
        y = jnp.zeros((X.shape[0], d_out), jnp.float32)
        return y.at[:, bucket].add(X.astype(jnp.float32) * sign[None, :])

    return apply(X, bucket, sign)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------
def _need_dim(name: str, d_prime: Optional[int], d_in: int) -> int:
    if d_prime is None:
        raise ValueError(
            f"sketcher {name!r} needs a sketch dimension; pass sketch_dim "
            "(PlannerSpec.sketch_dim / GradientStore(sketch_dim=...))"
        )
    d_prime = int(d_prime)
    if not 1 <= d_prime <= d_in:
        raise ValueError(
            f"sketch_dim must satisfy 1 <= d_prime <= d={d_in}, got {d_prime}"
        )
    return d_prime


def make_identity(d_in: int, d_prime: Optional[int] = None, *, seed: int = 0):
    if d_prime is not None and int(d_prime) != int(d_in):
        raise ValueError(
            f"sketch 'identity' keeps every coordinate; sketch_dim={d_prime} "
            f"!= update_dim={d_in} — drop sketch_dim or pick a compressing "
            "sketcher ('srp', 'countsketch')"
        )
    return IdentitySketcher(d_in, d_in, seed)


def make_srp(d_in: int, d_prime: Optional[int] = None, *, seed: int = 0):
    return SRPSketcher(d_in, _need_dim("srp", d_prime, d_in), seed)


def make_countsketch(d_in: int, d_prime: Optional[int] = None, *, seed: int = 0):
    return CountSketcher(d_in, _need_dim("countsketch", d_prime, d_in), seed)


#: name -> sketcher factory ``(d_in, d_prime, seed=0) -> Sketcher``.
SKETCHERS = Registry(
    "sketcher",
    {
        "identity": make_identity,
        "srp": make_srp,
        "countsketch": make_countsketch,
    },
)

register_sketcher = SKETCHERS.register


def resolve_sketcher(
    sketch: Union[str, Sketcher, None],
    d_in: int,
    d_prime: Optional[int] = None,
    *,
    seed: int = 0,
) -> Optional[Sketcher]:
    """Map a sketch argument to a fitted :class:`Sketcher` (or ``None``).

    ``None`` means *no sketch stage at all* (the store keeps the raw
    ``(n, d)`` buffer, exactly the pre-sketch code path); a string names a
    :data:`SKETCHERS` entry; an already-fitted :class:`Sketcher` passes
    through after a dimension check.
    """
    if sketch is None:
        return None
    if isinstance(sketch, Sketcher):
        if sketch.d_in != int(d_in):
            raise ValueError(
                f"sketcher expects d_in={sketch.d_in}, store has "
                f"update_dim={d_in}"
            )
        return sketch
    return SKETCHERS.get(sketch)(d_in, d_prime, seed=seed)
