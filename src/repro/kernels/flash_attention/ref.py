"""Pure-jnp oracle: causal GQA attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *, causal: bool = True
) -> jnp.ndarray:
    """q (B,S,H,hd), k/v (B,T,KV,hd) -> (B,S,H,hd), f32 softmax."""
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) * hd**-0.5
    if causal:
        mask = jnp.arange(t)[None, :] <= jnp.arange(s)[:, None]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v)
    return out.reshape(b, s, h, hd)
