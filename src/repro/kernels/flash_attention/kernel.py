"""Pallas TPU causal GQA flash-attention forward (online softmax).

Grid (B·H, S/bq, T/bk), k-axis innermost. Per-(head, q-block) running
statistics (row max ``m``, denominator ``l``, weighted accumulator ``acc``)
live in VMEM scratch and carry across k-blocks; the output block is divided
by ``l`` and written on the final k-step.

GQA is expressed in the BlockSpec index maps: query head ``h`` reads kv head
``h // (H // KV)`` — no host-side ``repeat`` of k/v (saves the (B,T,H,hd)
materialization XLA's naive GQA does).

VMEM budget per step (f32): q (bq·hd) + k,v (2·bk·hd) + scores (bq·bk) +
acc (bq·hd) + m,l (2·bq) ≈ 4·(128·128)·4 B ≈ 256 KiB at the default 128
tiles — comfortably inside the ~16 MiB/core budget; bq/bk are multiples of
the MXU's 128 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, bq, bk, scale, causal):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]  # (bq, hd)
    k = k_ref[0]  # (bk, hd)
    v = v_ref[0]  # (bk, hd)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)

    if causal:
        q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG)

    m_prev = m_scr[...]  # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)  # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ik == pl.num_programs(2) - 1)
    def _flush():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jnp.ndarray,  # (B, S, H, hd)
    k: jnp.ndarray,  # (B, T, KV, hd)
    v: jnp.ndarray,  # (B, T, KV, hd)
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    assert h % kv == 0
    group = h // kv
    bq = min(block_q, s)
    bk = min(block_k, t)
    assert s % bq == 0 and t % bk == 0, "pad seq to block multiples"
    scale = hd**-0.5

    qr = jnp.moveaxis(q, 2, 1).reshape(b * h, s, hd)
    kr = jnp.moveaxis(k, 2, 1).reshape(b * kv, t, hd)
    vr = jnp.moveaxis(v, 2, 1).reshape(b * kv, t, hd)

    def kv_index(bh, iq, ik):
        return ((bh // h) * kv + (bh % h) // group, ik, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, bq=bq, bk=bk, scale=scale, causal=causal
        ),
        grid=(b * h, s // bq, t // bk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, hd), kv_index),
            pl.BlockSpec((1, bk, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return jnp.moveaxis(out.reshape(b, h, s, hd), 1, 2)
