"""Jitted public API for the flash-attention kernel.

``flash_attention_padded`` pads S/T up to block multiples (masking the pad
keys) so arbitrary sequence lengths work; the model layer calls this when
``attn_impl='flash'`` on real TPU runs.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention


def flash_attention_padded(
    q, k, v, *, causal: bool = True, block_q: int = 128, block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, s, h, hd = q.shape
    t = k.shape[1]
    bq = min(block_q, max(8, s))
    bk = min(block_k, max(8, t))
    ps = -s % bq
    pt = -t % bk
    if ps:
        q = jnp.pad(q, ((0, 0), (0, ps), (0, 0), (0, 0)))
    if pt:
        k = jnp.pad(k, ((0, 0), (0, pt), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pt), (0, 0), (0, 0)))
    # causal masking already hides pad keys (they sit at positions > any
    # real query); for non-causal, pad keys would need an explicit mask —
    # callers use causal=True in this framework.
    out = flash_attention(
        q, k, v, causal=causal, block_q=bq, block_k=bk, interpret=interpret
    )
    return out[:, :s] if ps else out
