from repro.optim.base import Optimizer, OptState, apply_updates
from repro.optim.sgd import sgd
from repro.optim.adamw import adamw
from repro.optim.schedule import constant, cosine_decay, linear_warmup_cosine
from repro.optim.clip import clip_by_global_norm, global_norm

__all__ = [
    "Optimizer",
    "OptState",
    "apply_updates",
    "sgd",
    "adamw",
    "constant",
    "cosine_decay",
    "linear_warmup_cosine",
    "clip_by_global_norm",
    "global_norm",
]
