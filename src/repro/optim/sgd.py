"""SGD with optional momentum — the paper's client-side optimizer."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer, as_schedule


def sgd(lr, momentum: float = 0.0) -> Optimizer:
    lr_fn = as_schedule(lr)

    if momentum == 0.0:

        def init(params):
            return ()

        def update(grads, state, params, step):
            del params
            eta = lr_fn(step)
            return jax.tree_util.tree_map(lambda g: -eta * g, grads), state

    else:

        def init(params):
            return jax.tree_util.tree_map(jnp.zeros_like, params)

        def update(grads, state, params, step):
            del params
            eta = lr_fn(step)
            new_v = jax.tree_util.tree_map(lambda v, g: momentum * v + g, state, grads)
            return jax.tree_util.tree_map(lambda v: -eta * v, new_v), new_v

    return Optimizer(init=init, update=update)
