"""AdamW for the production LM trainer.

Moments are stored in fp32 regardless of param dtype; with FSDP-sharded
params the moment trees inherit the same sharding (ZeRO-style) for free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer, as_schedule


def adamw(
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    lr_fn = as_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, dtype=jnp.float32)
        return {
            "mu": jax.tree_util.tree_map(zeros, params),
            "nu": jax.tree_util.tree_map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, step):
        del step
        count = state["count"] + 1
        f32 = lambda g: g.astype(jnp.float32)
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * f32(g), state["mu"], grads
        )
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(f32(g)), state["nu"], grads
        )
        c = count.astype(jnp.float32)
        mu_hat_scale = 1.0 / (1 - b1**c)
        nu_hat_scale = 1.0 / (1 - b2**c)
        eta = lr_fn(count)

        def upd(m, v, p):
            step_ = m * mu_hat_scale / (jnp.sqrt(v * nu_hat_scale) + eps)
            if weight_decay:
                step_ = step_ + weight_decay * p.astype(jnp.float32)
            return (-eta * step_).astype(p.dtype)

        updates = jax.tree_util.tree_map(upd, mu, nu, params)
        return updates, {"mu": mu, "nu": nu, "count": count}

    return Optimizer(init=init, update=update)
