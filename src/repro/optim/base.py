"""Minimal optax-style optimizer core (no optax offline).

An :class:`Optimizer` is an ``(init, update)`` pair over pytrees:
  state = opt.init(params)
  updates, state = opt.update(grads, state, params, step)
  params = apply_updates(params, updates)

Kept deliberately optax-shaped so the FL client loop, the LM trainer and the
dry-run all share one interface.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

OptState = Any
Schedule = Callable[[Any], Any]  # step -> lr (jnp scalar ok)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any, Any], tuple[Any, OptState]]


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype), params, updates)


def as_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: lr
