from repro.checkpoint.io import peek_meta, restore_checkpoint, save_checkpoint

__all__ = ["save_checkpoint", "restore_checkpoint", "peek_meta"]
