"""Flat-dict ``.npz`` checkpointing with step metadata.

Pytrees are flattened to ``a/b/c`` path keys; restore rebuilds against a
reference tree (structure is authoritative from the caller, arrays from
disk). Atomic via write-to-temp + rename. Good enough for single-host
drivers; a real deployment would swap in tensorstore/orbax behind the same
two functions.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub?":  # ml_dtypes (bf16, fp8) -> fp32 (lossless up-cast)
            arr = np.asarray(leaf).astype(np.float32)
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(path: str, tree: Any, *, step: int = 0, extra: dict | None = None) -> None:
    flat = _flatten(tree)
    meta = {"step": int(step), "extra": extra or {}}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)), suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __meta__=np.frombuffer(json.dumps(meta).encode(), dtype=np.uint8), **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def peek_meta(path: str) -> tuple[int, dict]:
    """Read just ``(step, extra)`` from a bundle, no array restore.

    Lets callers validate a bundle's provenance (which subsystems wrote it)
    and raise their own domain-specific errors *before* the structural
    restore turns a missing section into a generic missing-leaf failure.
    """
    with np.load(path) as data:
        meta = json.loads(bytes(data["__meta__"]).decode())
    return int(meta["step"]), dict(meta.get("extra") or {})


def restore_checkpoint(
    path: str, reference: Any, *, dynamic_prefixes: tuple[str, ...] = ()
) -> tuple[Any, int, dict]:
    """Restore arrays into the structure of ``reference``.

    Returns ``(tree, step, extra)`` — ``extra`` is the JSON side-channel
    ``save_checkpoint`` was given (rng states, history cursors, …; ``{}``
    when none was saved). The reference is authoritative for structure AND
    residence: a leaf that is a host ``np.ndarray`` in ``reference`` is
    restored as one (dtype-exact — f64 sampler state must not round-trip
    through jax's default-f32 device path); everything else comes back as a
    device array cast to the reference dtype. Missing leaves, shape
    mismatches and leaves present in the ``.npz`` but absent from the
    reference are all errors — a silently-ignored leaf is state that a
    resumed run would quietly lose.

    ``dynamic_prefixes`` exempts designated subtrees from the shape guard:
    a leaf whose path key starts with one of the prefixes takes its shape
    from disk (dtype and residence still from the reference). This is for
    genuinely variable-shaped state — a straggler harvest buffer holds
    however many late updates the killed round produced, while a fresh
    server's reference buffer is empty — where the reference shape is not a
    meaningful contract. Structural keys are still required either way.
    """
    with np.load(path) as data:
        meta = json.loads(bytes(data["__meta__"]).decode())
        flat = {k: data[k] for k in data.files if k != "__meta__"}
    leaves_ref, _ = jax.tree_util.tree_flatten_with_path(reference)
    leaves, seen = [], set()
    for path_keys, ref_leaf in leaves_ref:
        key = "/".join(_path_str(p) for p in path_keys)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        seen.add(key)
        arr = flat[key]
        dynamic = any(key.startswith(p) for p in dynamic_prefixes)
        if not dynamic and arr.shape != ref_leaf.shape:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != expected {ref_leaf.shape}")
        if isinstance(ref_leaf, np.ndarray):
            leaves.append(np.asarray(arr, dtype=ref_leaf.dtype))
        else:
            leaves.append(jax.numpy.asarray(arr).astype(np.asarray(ref_leaf).dtype))
    unknown = set(flat) - seen
    if unknown:
        raise KeyError(
            f"checkpoint holds leaf(s) {sorted(unknown)} that the reference "
            "tree does not — refusing to silently drop state on restore"
        )
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(reference), leaves
    )
    return tree, int(meta["step"]), dict(meta.get("extra") or {})
