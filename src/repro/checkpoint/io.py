"""Flat-dict ``.npz`` checkpointing with step metadata.

Pytrees are flattened to ``a/b/c`` path keys; restore rebuilds against a
reference tree (structure is authoritative from the caller, arrays from
disk). Atomic via write-to-temp + rename. Good enough for single-host
drivers; a real deployment would swap in tensorstore/orbax behind the same
two functions.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub?":  # ml_dtypes (bf16, fp8) -> fp32 (lossless up-cast)
            arr = np.asarray(leaf).astype(np.float32)
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(path: str, tree: Any, *, step: int = 0, extra: dict | None = None) -> None:
    flat = _flatten(tree)
    meta = {"step": int(step), "extra": extra or {}}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)), suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __meta__=np.frombuffer(json.dumps(meta).encode(), dtype=np.uint8), **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def restore_checkpoint(path: str, reference: Any) -> tuple[Any, int]:
    """Restore arrays into the structure of ``reference``; returns (tree, step)."""
    with np.load(path) as data:
        meta = json.loads(bytes(data["__meta__"]).decode())
        flat = {k: data[k] for k in data.files if k != "__meta__"}
    leaves_ref, treedef = jax.tree_util.tree_flatten_with_path(reference)
    leaves = []
    for path_keys, ref_leaf in leaves_ref:
        key = "/".join(_path_str(p) for p in path_keys)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if arr.shape != ref_leaf.shape:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != expected {ref_leaf.shape}")
        leaves.append(jax.numpy.asarray(arr).astype(ref_leaf.dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(reference), leaves
    )
    return tree, int(meta["step"])
