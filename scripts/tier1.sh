#!/usr/bin/env bash
# Tier-1 verification: the full test suite + benchmark smoke runs.
#
# Collection errors (missing optional deps, jax API drift) take down whole
# test modules silently under plain `pytest path` invocations — this script
# is the one entry point CI and humans share, so such regressions fail fast.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -q

echo "== tier-1: benchmark smoke (import + run sanity) =="
python -m benchmarks.bench_sampler_cost --smoke
python -m benchmarks.bench_round_engine --smoke
python -m benchmarks.bench_engine_sharded --smoke
python -m benchmarks.bench_async_planner --smoke

echo "tier-1 OK"
