#!/usr/bin/env bash
# Tier-1 verification: the full test suite + benchmark smoke runs.
#
# Collection errors (missing optional deps, jax API drift) take down whole
# test modules silently under plain `pytest path` invocations — this script
# is the one entry point CI and humans share, so such regressions fail fast.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -q

echo "== tier-1: benchmark smoke (import + run sanity) =="
python -m benchmarks.bench_sampler_cost --smoke
python -m benchmarks.bench_round_engine --smoke
python -m benchmarks.bench_engine_sharded --smoke
python -m benchmarks.bench_async_planner --smoke

echo "== tier-1: spec-driven experiment smoke (registry + spec parsing) =="
python -m benchmarks.run --spec '{
  "data": {"name": "by_class_shards",
           "options": {"n_classes": 4, "clients_per_class": 3, "dim": 8,
                        "train_per_client": 40, "test_per_client": 8, "seed": 0}},
  "sampler": {"name": "algorithm2", "m": 4},
  "planner": {"mode": "async", "rebuild_every": 2},
  "train": {"n_rounds": 3, "n_local_steps": 4, "batch_size": 16, "hidden": [16]}
}'

echo "tier-1 OK"
