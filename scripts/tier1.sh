#!/usr/bin/env bash
# Tier-1 verification: the full test suite + benchmark smoke runs.
#
# Collection errors (missing optional deps, jax API drift) take down whole
# test modules silently under plain `pytest path` invocations — this script
# is the one entry point CI and humans share, so such regressions fail fast.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -q

echo "== tier-1: benchmark smoke (import + run sanity) =="
python -m benchmarks.bench_sampler_cost --smoke
python -m benchmarks.bench_round_engine --smoke
python -m benchmarks.bench_engine_sharded --smoke
python -m benchmarks.bench_async_planner --smoke --drift
python -m benchmarks.bench_service_churn --smoke
python -m benchmarks.bench_store_scale --smoke
# asserts sync ≡ scheduler-free bit-parity + deadline harvest internally
python -m benchmarks.bench_scheduler --smoke

echo "== tier-1: fused streamed kernel parity vs numpy (ragged-chunk shape) =="
# 13x101 is ragged against both the 8-row and 16-column tiles AND the
# 32-wide d-chunk — the in-kernel masking path the fused grid must get right
python - <<'EOF'
import numpy as np
from repro.core.clustering import pairwise_distances
from repro.kernels.similarity.ops import pairwise_distances_streamed
rng = np.random.default_rng(0)
G = rng.normal(size=(13, 101)).astype(np.float32)
for measure in ("arccos", "l2", "l1"):
    ref = pairwise_distances(G, measure)
    fused = np.asarray(pairwise_distances_streamed(
        G, measure, block_n=8, block_d=16, d_chunk=32, interpret=True))
    np.testing.assert_allclose(fused, ref, atol=1e-4, err_msg=measure)
print("fused streamed == numpy reference (13x101 ragged, all measures)")
EOF

echo "== tier-1: identity-sketch == legacy store bit-parity gate =="
# sketch="identity" engages the sketch stage yet must train byte-for-byte
# like the store with no sketch stage at all (plan_build_ms is wall-clock
# telemetry and is normalized out, exactly as in tests/test_service_resume)
python - <<'EOF'
import json
from repro.fl.experiment import ExperimentSpec, build_experiment

BASE = {
    "data": {"name": "by_class_shards",
             "options": {"n_classes": 4, "clients_per_class": 2, "dim": 8,
                          "train_per_client": 40, "test_per_client": 8, "seed": 0}},
    "sampler": {"name": "algorithm2", "m": 4, "seed": 3},
    "train": {"n_rounds": 4, "n_local_steps": 3, "batch_size": 10, "hidden": [16]},
}

def run(planner):
    spec = ExperimentSpec.from_dict({**BASE, **({"planner": planner} if planner else {})})
    with build_experiment(spec) as srv:
        recs = json.loads(srv.run().to_json())
    for r in recs:
        r["plan_build_ms"] = -1.0
    return json.dumps(recs)

assert run(None) == run({"sketch": "identity"}), "identity sketch broke bit-parity"
print("identity sketch == legacy store (bit-identical history)")
EOF

echo "== tier-1: sweep smoke (2 cells x 2 seeds, then resume on the same store) =="
SWEEP_STORE="$(mktemp -d)"
trap 'rm -rf "$SWEEP_STORE"' EXIT
SWEEP_JSON='{
  "base": {"data": {"name": "by_class_shards",
                    "options": {"n_classes": 4, "clients_per_class": 3, "dim": 8,
                                "train_per_client": 40, "test_per_client": 8}},
           "sampler": {"name": "md", "m": 4},
           "train": {"n_rounds": 3, "n_local_steps": 4, "batch_size": 16, "hidden": [16]}},
  "axes": {"sampler.name": ["md", "algorithm1"]},
  "n_seeds": 2,
  "root_seed": 7
}'
python -m benchmarks.run --sweep "$SWEEP_JSON" --store "$SWEEP_STORE"
# re-invoking the same store must resume (all 4 cells skip, collation intact)
python -m benchmarks.run --sweep "$SWEEP_JSON" --store "$SWEEP_STORE" \
  | tee /dev/stderr | grep -c "status=skipped" | grep -qx 4
test -s "$SWEEP_STORE/cells.csv" && test -s "$SWEEP_STORE/summary.csv"

echo "== tier-1: scheme race smoke (2 schemes x 2 seeds, then resume) =="
RACE_STORE="$(mktemp -d)"
trap 'rm -rf "$SWEEP_STORE" "$RACE_STORE"' EXIT
python -m benchmarks.scheme_race --smoke --store "$RACE_STORE"
# re-invoking the same store must resume (all 4 cells skip, collation intact)
python -m benchmarks.scheme_race --smoke --store "$RACE_STORE" \
  | tee /dev/stderr | grep -c "status=skipped" | grep -qx 4
test -s "$RACE_STORE/summary.csv"
# summary.csv must carry the race columns (time-to-accuracy + weight variance)
head -1 "$RACE_STORE/summary.csv" | grep -q "rounds_to_acc_mean"
head -1 "$RACE_STORE/summary.csv" | grep -q "agg_weight_var_mean"

echo "== tier-1: md == importance(mix=1.0) bit-parity gate =="
# importance with a size-proportional proposal (mix=1.0) must train
# byte-for-byte like md — the scheme zoo's degenerate-case anchor
python -m benchmarks.scheme_race --parity

echo "== tier-1: registry discoverability (--list) =="
python -m benchmarks.run --list

echo "== tier-1: spec-driven experiment smoke (registry + spec parsing) =="
python -m benchmarks.run --spec '{
  "data": {"name": "by_class_shards",
           "options": {"n_classes": 4, "clients_per_class": 3, "dim": 8,
                        "train_per_client": 40, "test_per_client": 8, "seed": 0}},
  "sampler": {"name": "algorithm2", "m": 4},
  "planner": {"mode": "async", "rebuild_every": 2},
  "train": {"n_rounds": 3, "n_local_steps": 4, "batch_size": 16, "hidden": [16]}
}'

echo "== tier-1: continuous-service smoke (SIGTERM mid-campaign, then resume) =="
SVC_DIR="$(mktemp -d)"
trap 'rm -rf "$SWEEP_STORE" "$RACE_STORE" "$SVC_DIR"' EXIT
SVC_SPEC='{
  "data": {"name": "by_class_shards",
           "options": {"n_classes": 4, "clients_per_class": 2, "dim": 8,
                        "train_per_client": 40, "test_per_client": 8, "seed": 0}},
  "sampler": {"name": "algorithm1", "m": 4},
  "train": {"n_rounds": 30, "n_local_steps": 3, "batch_size": 10,
            "hidden": [16], "checkpoint_every": 1},
  "population": {"name": "dropout", "options": {"rate": 0.2}}
}'
python -m repro.launch.fl_service --spec "$SVC_SPEC" \
  --checkpoint "$SVC_DIR/svc.npz" --history "$SVC_DIR/history.json" \
  --throttle 0.2 > "$SVC_DIR/run1.log" 2>&1 &
SVC_PID=$!
sleep 4  # throttled rounds: the campaign is guaranteed still mid-flight
kill -TERM "$SVC_PID"
wait "$SVC_PID"  # SIGTERM must be a clean exit (checkpoint written, rc 0)
grep -q "stop requested" "$SVC_DIR/run1.log"
# NOTE: log to a file and grep afterwards — piping the live process into
# `grep -q` would close its stdout on first match and cut the campaign short.
python -m repro.launch.fl_service --spec "$SVC_SPEC" \
  --checkpoint "$SVC_DIR/svc.npz" --history "$SVC_DIR/history.json" --resume \
  > "$SVC_DIR/run2.log" 2>&1
grep -q "resuming at round" "$SVC_DIR/run2.log"
# the resumed history must extend the checkpointed cursor to all 30 rounds,
# contiguously from 0 — no gap and no replay at the kill point
python - "$SVC_DIR/history.json" <<'EOF'
import json, sys
recs = json.load(open(sys.argv[1]))
rounds = [r["round"] for r in recs]
assert rounds == list(range(30)), f"history not contiguous 0..29: {rounds}"
assert any(r["round_status"] == "degraded" for r in recs), "dropout never degraded a round"
EOF

echo "tier-1 OK"
